"""Calibration stage 2: heterogeneous precision-allocation under a
global bytes budget.

The paper's kurtosis heuristic ranks experts by a weight-shape proxy and
spends a fixed *rank* budget; here the measured calibration statistics
drive a water-filling/knapsack allocation of BOTH per-expert bit-widths
and per-(projection, expert) compensator ranks under a single wire-byte
budget:

    minimize   sum_l sum_p sum_e  imp_e * err(e, p, bits_e, rank_ep)
    subject to sum of wire bytes <= budget

``err`` is the whitened-residual tail norm — for each candidate bit
width the expert is actually quantized (HQQ) and the singular spectrum
of its (activation-whitened) residual precomputed, so the objective is
the exact quantity the final compression realizes, not a proxy.  The
allocator is lazy-greedy: every knob (one expert's bits ladder, one
(projection, expert) rank ladder) exposes its next upgrade; the heap
pops the best benefit/byte, re-evaluating stale gains (a bits upgrade
changes every rank gain of that expert and vice versa).

The kurtosis heuristic survives as one pluggable *scorer* among several
(``SCORERS``): scorers only set the per-expert importance weights, the
budgeted knapsack machinery is shared.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..config import QuantConfig
from ..core.hqq import hqq_params
from ..core.kurtosis import kurtosis
from ..core.pipeline import whiten_vector
from ..core.quantize import (PLANES, dequantize, factor_wire_bytes,
                             quant_wire_bytes, quantize_with_params)
from .stats import LayerCalibStats

PROJS = ("w1", "w2", "w3")
DEFAULT_BITS_CANDIDATES = (2, 3, 4, 8)


# ---------------------------------------------------------------------------
# plan containers (JSON round-trippable for the artifact manifest)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LayerAllocation:
    """One MoE layer's allocation: per-expert bits (shared by the three
    projections of an expert — one precision per expert on the wire) and
    per-(projection, expert) compensator ranks."""
    bits: np.ndarray                  # (E,) int
    ranks: Dict[str, np.ndarray]      # proj -> (E,) int

    def to_json(self) -> Dict:
        return {"bits": np.asarray(self.bits, np.int64).tolist(),
                "ranks": {p: np.asarray(r, np.int64).tolist()
                          for p, r in self.ranks.items()}}

    @classmethod
    def from_json(cls, d: Dict) -> "LayerAllocation":
        return cls(np.asarray(d["bits"], np.int64),
                   {p: np.asarray(r, np.int64)
                    for p, r in d["ranks"].items()})


@dataclasses.dataclass
class CompressionPlan:
    """Output of the budget allocator; input to ``compress_moe_params``."""
    layers: List[LayerAllocation]
    budget_bytes: float
    spent_bytes: int
    scorer: str
    predicted_err: float = 0.0        # objective value at the allocation

    def to_json(self) -> Dict:
        return {"layers": [l.to_json() for l in self.layers],
                "budget_bytes": float(self.budget_bytes),
                "spent_bytes": int(self.spent_bytes),
                "scorer": self.scorer,
                "predicted_err": float(self.predicted_err)}

    @classmethod
    def from_json(cls, d: Dict) -> "CompressionPlan":
        return cls([LayerAllocation.from_json(l) for l in d["layers"]],
                   d["budget_bytes"], d["spent_bytes"], d["scorer"],
                   d.get("predicted_err", 0.0))

    def summary(self) -> Dict:
        bits = np.concatenate([l.bits for l in self.layers])
        ranks = np.concatenate([r for l in self.layers
                                for r in l.ranks.values()])
        return {"mean_bits": float(bits.mean()),
                "bits_hist": {int(b): int((bits == b).sum())
                              for b in np.unique(bits)},
                "mean_rank": float(ranks.mean()),
                "spent_bytes": int(self.spent_bytes),
                "budget_bytes": float(self.budget_bytes)}


# ---------------------------------------------------------------------------
# importance scorers (the kurtosis heuristic becomes one of several)
# ---------------------------------------------------------------------------

def _score_calibrated(weights: Dict[str, np.ndarray],
                      stats: Optional[LayerCalibStats]) -> np.ndarray:
    if stats is None:
        raise ValueError("scorer 'calibrated' needs collected LayerCalibStats")
    return stats.importance()


def _score_kurtosis(weights: Dict[str, np.ndarray],
                    stats: Optional[LayerCalibStats]) -> np.ndarray:
    """The paper's proxy: heavier-tailed experts matter more (no corpus)."""
    e = weights["w1"].shape[0]
    k = np.zeros(e)
    for w in weights.values():
        k += np.asarray([float(kurtosis(jnp.asarray(w[i])))
                         for i in range(e)])
    k = np.maximum(k - k.min(), 1e-6)
    return k / k.sum()


def _score_uniform(weights: Dict[str, np.ndarray],
                   stats: Optional[LayerCalibStats]) -> np.ndarray:
    e = weights["w1"].shape[0]
    return np.full(e, 1.0 / e)


SCORERS = {
    "calibrated": _score_calibrated,
    "kurtosis": _score_kurtosis,
    "uniform": _score_uniform,
}


# ---------------------------------------------------------------------------
# per-candidate error model (actual quantization, whitened spectra)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _ExpertTable:
    """Error/cost lookup for one (layer, projection, expert):
    ``tails[b_idx]`` is the whitened-residual singular spectrum's tail
    norms, so err(bits b_idx, rank r) = tails[b_idx][r]; normalized by
    the whitened weight norm (relative error)."""
    tails: List[np.ndarray]           # per bits candidate: (max_rank + 1,)
    k: int
    n: int


def _whitened_tails(resid: np.ndarray, white: Optional[np.ndarray],
                    wnorm: float) -> np.ndarray:
    r = resid if white is None else resid * white[:, None]
    s = np.linalg.svd(r, compute_uv=False)
    tail2 = np.concatenate([np.cumsum((s ** 2)[::-1])[::-1], [0.0]])
    return np.sqrt(np.maximum(tail2, 0.0)) / max(wnorm, 1e-12)


def _expert_table(w: np.ndarray, qcfg: QuantConfig,
                  bits_candidates: Sequence[int],
                  moment: Optional[np.ndarray]) -> _ExpertTable:
    """Quantize one expert's (K, N) matrix at every candidate width and
    record the whitened residual spectra.  The allocator's error model
    IS the pipeline's compression, not an analytic proxy: the residual
    comes from the same ``quantize_with_params``/``dequantize`` pair the
    stacks use, and the whitening from the same ``whiten_vector``."""
    k, n = w.shape
    g = min(qcfg.group_size, k) if qcfg.group_size > 0 else k
    w32 = jnp.asarray(w, jnp.float32)
    white = None if moment is None else whiten_vector(moment)
    wn = np.asarray(w, np.float64)
    wnorm = float(np.linalg.norm(wn if white is None
                                 else wn * white[:, None]))
    tails = []
    for b in bits_candidates:
        s, z = hqq_params(w32, b, g, qcfg.hqq_iters, qcfg.hqq_p,
                          qcfg.hqq_beta, qcfg.hqq_beta_scale)
        qt = quantize_with_params(w32, s, z, b, g)
        resid = np.asarray(w32 - dequantize(qt), np.float64)
        tails.append(_whitened_tails(resid, white, wnorm))
    return _ExpertTable(tails, k, n)


# ---------------------------------------------------------------------------
# the budgeted lazy-greedy knapsack
# ---------------------------------------------------------------------------

def _rank_candidates(buckets: Sequence[int], max_rank: int) -> List[int]:
    rc = sorted({0} | {int(b) for b in buckets if 0 < b <= max_rank})
    return rc


def allocate_budget(weights_by_layer: List[Dict[str, np.ndarray]],
                    qcfg: QuantConfig, budget_bytes: float, *,
                    stats: Optional[List[LayerCalibStats]] = None,
                    scorer: str = "calibrated",
                    bits_candidates: Sequence[int] = DEFAULT_BITS_CANDIDATES,
                    freq_weighted_cost: bool = False
                    ) -> CompressionPlan:
    """Allocate per-expert bits + per-(projection, expert) ranks under a
    global wire-byte budget (water-filling by marginal benefit/byte).

    ``budget_bytes`` constrains the summed wire bytes of every expert's
    quantized weights + allocated compensator (the artifact / model-size
    budget).  With ``freq_weighted_cost`` each expert's bytes are
    weighted by its measured routing frequency instead — a cache-less
    expected *bytes/token* budget (stats required).

    Every expert starts at the smallest candidate width and rank 0;
    upgrades are applied best-benefit-per-byte first until the budget is
    exhausted.  Infeasible budgets (below the floor) return the floor
    allocation with ``spent_bytes`` > ``budget_bytes`` — callers decide.
    """
    bits_candidates = sorted(set(int(b) for b in bits_candidates))
    for b in bits_candidates:
        if b not in PLANES:
            raise ValueError(f"bits candidate {b} unsupported "
                             f"(PLANES: {sorted(PLANES)})")
    if scorer not in SCORERS:
        raise ValueError(f"unknown scorer {scorer!r}; one of "
                         f"{sorted(SCORERS)}")
    if stats is not None and len(stats) != len(weights_by_layer):
        raise ValueError(f"{len(stats)} stats layers for "
                         f"{len(weights_by_layer)} weight layers")

    layers = []
    tables: Dict[Tuple[int, str, int], _ExpertTable] = {}
    imps: List[np.ndarray] = []
    for li, weights in enumerate(weights_by_layer):
        lstats = stats[li] if stats is not None else None
        imp = SCORERS[scorer](weights, lstats)
        imps.append(imp)
        e = weights["w1"].shape[0]
        for proj in PROJS:
            if proj not in weights:
                continue
            mom = lstats.moment_for(proj) if lstats is not None else None
            for ei in range(e):
                tables[(li, proj, ei)] = _expert_table(
                    weights[proj][ei], qcfg, bits_candidates,
                    None if mom is None else mom[ei])
        layers.append(LayerAllocation(
            np.full((e,), bits_candidates[0], np.int64),
            {p: np.zeros((e,), np.int64) for p in PROJS if p in weights}))

    def cost_scale(li: int, ei: int) -> float:
        if not freq_weighted_cost:
            return 1.0
        if stats is None:
            raise ValueError("freq_weighted_cost needs calibration stats")
        return float(max(stats[li].freq[ei], 1e-4))

    rank_cands = {key: _rank_candidates(qcfg.rank_buckets, min(t.k, t.n))
                  for key, t in tables.items()}
    bidx = {(li, ei): 0 for li, l in enumerate(layers)
            for ei in range(len(l.bits))}
    ridx = {key: 0 for key in tables}

    def expert_err(li, proj, ei) -> float:
        t = tables[(li, proj, ei)]
        r = rank_cands[(li, proj, ei)][ridx[(li, proj, ei)]]
        return float(imps[li][ei] * t.tails[bidx[(li, ei)]][r])

    def total_err() -> float:
        """Objective: importance-weighted relative error, mean over the
        (layer, projection) pools — same normalization as
        :func:`weighted_restoration_error` so predicted and achieved
        values are directly comparable."""
        pools = len({(li, p) for (li, p, _) in tables})
        return sum(expert_err(li, p, ei)
                   for (li, p, ei) in tables) / max(pools, 1)

    def quant_bytes(li, ei, b) -> float:
        g = qcfg.group_size
        tot = 0
        for proj in layers[li].ranks:
            t = tables[(li, proj, ei)]
            gg = min(g, t.k) if g > 0 else t.k
            tot += quant_wire_bytes(b, t.k, t.n, gg)
        return tot * cost_scale(li, ei)

    def rank_bytes(li, proj, ei, r) -> float:
        t = tables[(li, proj, ei)]
        return factor_wire_bytes(r, t.k, t.n, qcfg.factor_bits) \
            * cost_scale(li, ei)

    spent = 0.0
    for li, l in enumerate(layers):
        for ei in range(len(l.bits)):
            spent += quant_bytes(li, ei, bits_candidates[0])

    # -- candidate upgrades -------------------------------------------------
    def bits_upgrade(li, ei):
        """(gain, cost) of stepping expert (li, ei) one width up."""
        bi = bidx[(li, ei)]
        if bi + 1 >= len(bits_candidates):
            return None
        gain = 0.0
        for proj in layers[li].ranks:
            t = tables[(li, proj, ei)]
            r = rank_cands[(li, proj, ei)][ridx[(li, proj, ei)]]
            gain += imps[li][ei] * (t.tails[bi][r] - t.tails[bi + 1][r])
        cost = (quant_bytes(li, ei, bits_candidates[bi + 1])
                - quant_bytes(li, ei, bits_candidates[bi]))
        return gain, cost

    def rank_upgrade(li, proj, ei):
        key = (li, proj, ei)
        ri = ridx[key]
        cands = rank_cands[key]
        if ri + 1 >= len(cands):
            return None
        t = tables[key]
        bi = bidx[(li, ei)]
        gain = imps[li][ei] * (t.tails[bi][cands[ri]]
                               - t.tails[bi][cands[ri + 1]])
        cost = (rank_bytes(li, proj, ei, cands[ri + 1])
                - rank_bytes(li, proj, ei, cands[ri]))
        return gain, cost

    def push(heap, knob):
        up = (bits_upgrade(*knob[1:]) if knob[0] == "bits"
              else rank_upgrade(*knob[1:]))
        if up is None:
            return
        gain, cost = up
        if cost <= 0:
            return
        heapq.heappush(heap, (-gain / cost, gain, cost, knob))

    heap: list = []
    for (li, ei) in bidx:
        push(heap, ("bits", li, ei))
    for (li, proj, ei) in tables:
        push(heap, ("rank", li, proj, ei))

    # lazy-greedy: a popped entry's gain may be stale (its expert's other
    # knob moved since the push); recompute and re-push unless it is
    # still the best on offer
    while heap:
        prio, gain, cost, knob = heapq.heappop(heap)
        cur = (bits_upgrade(*knob[1:]) if knob[0] == "bits"
               else rank_upgrade(*knob[1:]))
        if cur is None:
            continue
        cgain, ccost = cur
        if ccost <= 0:
            continue
        cprio = -cgain / ccost
        if heap and cprio > heap[0][0] + 1e-15:
            heapq.heappush(heap, (cprio, cgain, ccost, knob))
            continue
        if spent + ccost > budget_bytes:
            continue                      # too big; cheaper knobs may fit
        spent += ccost
        if knob[0] == "bits":
            _, li, ei = knob
            bidx[(li, ei)] += 1
            layers[li].bits[ei] = bits_candidates[bidx[(li, ei)]]
        else:
            _, li, proj, ei = knob
            ridx[(li, proj, ei)] += 1
            layers[li].ranks[proj][ei] = \
                rank_cands[(li, proj, ei)][ridx[(li, proj, ei)]]
        push(heap, knob)

    return CompressionPlan(layers, float(budget_bytes), int(round(spent)),
                           scorer, predicted_err=total_err())


# ---------------------------------------------------------------------------
# uniform baseline + evaluation helpers (shared by benches and tests)
# ---------------------------------------------------------------------------

def uniform_plan(weights_by_layer: List[Dict[str, np.ndarray]],
                 qcfg: QuantConfig, bits: int, rank: int) -> CompressionPlan:
    """The ablation baseline: every expert at ``bits`` with rank
    ``rank`` compensators — the equal-bytes comparison point for the
    calibrated allocation."""
    layers = []
    for weights in weights_by_layer:
        e = weights["w1"].shape[0]
        layers.append(LayerAllocation(
            np.full((e,), bits, np.int64),
            {p: np.full((e,), min(rank, min(weights[p].shape[1:])),
                        np.int64)
             for p in PROJS if p in weights}))
    return CompressionPlan(layers, 0.0, plan_wire_bytes(layers, qcfg,
                                                        weights_by_layer),
                           "uniform-fixed")


def plan_wire_bytes(layers: List[LayerAllocation], qcfg: QuantConfig,
                    weights_by_layer: List[Dict[str, np.ndarray]]) -> int:
    """Total wire bytes a plan occupies (weights + compensators), by the
    same shared formulas the stacks and the offload meter use."""
    total = 0
    for l, weights in zip(layers, weights_by_layer):
        for proj, ranks in l.ranks.items():
            _, k, n = weights[proj].shape
            g = min(qcfg.group_size, k) if qcfg.group_size > 0 else k
            for ei, r in enumerate(ranks):
                total += quant_wire_bytes(int(l.bits[ei]), k, n, g)
                total += factor_wire_bytes(int(r), k, n, qcfg.factor_bits)
    return total


def stacks_wire_bytes(stacks_by_layer: List[Dict]) -> int:
    """Total artifact wire bytes of compressed stacks (all experts,
    compensated at their true ranks)."""
    return sum(s.expert_wire_bytes(e, compensated=True)
               for stacks in stacks_by_layer for s in stacks.values()
               for e in range(s.scale.shape[0]))


def weighted_restoration_error(stacks_by_layer: List[Dict],
                               weights_by_layer: List[Dict[str, np.ndarray]],
                               importance: List[np.ndarray]) -> float:
    """Importance-weighted relative restoration error of compressed
    stacks against the original weights: sum_e imp_e * ||W_e - W_hat_e||
    / ||W_e||, mean over projections and layers — the serving-quality
    proxy the allocation frontier reports."""
    errs = []
    for stacks, weights, imp in zip(stacks_by_layer, weights_by_layer,
                                    importance):
        for proj, stack in stacks.items():
            w = np.asarray(weights[proj], np.float64)
            e = w.shape[0]
            what = (np.asarray(stack.dequantize_all(), np.float64)
                    + np.asarray(stack.compensation_all(), np.float64))
            nw = np.maximum(np.linalg.norm(w.reshape(e, -1), axis=1), 1e-12)
            rel = np.linalg.norm((w - what).reshape(e, -1), axis=1) / nw
            errs.append(float((imp * rel).sum()))
    return float(np.mean(errs))


def moe_weights_by_layer(params, cfg) -> List[Dict[str, np.ndarray]]:
    """Extract each MoE layer's dense (E, K, N) projection stacks from a
    param tree (global layer order — matches ``compress_moe_params``)."""
    from ..models.transformer import layer_specs, unstack_params
    up = unstack_params(params, cfg)
    out = []
    for (lp,), spec in zip(up["segments"], layer_specs(cfg)):
        if spec.ffn == "moe":
            out.append({k: np.asarray(lp["moe"][k])
                        for k in PROJS if k in lp["moe"]})
    return out
