"""KV / recurrent-state caches.

Local (sliding-window) layers get *ring buffers* of window length instead of
full-sequence caches — at decode_32k this shrinks gemma3's cache HBM by the
5:1 local:global ratio; recurrent layers carry O(1) state, which is what
makes long_500k feasible for the ssm/hybrid archs.

Caches are plain dicts (pytree-friendly); every entry carries a ``pos``
plane (absolute position per slot, -1 = empty) so ring wraparound needs no
sorting — masking is purely position-arithmetic.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


def init_attn_cache(batch: int, length: int, kv_heads: int, head_dim: int,
                    dtype=jnp.bfloat16, kv_bits: int = 16
                    ) -> Dict[str, jax.Array]:
    if kv_bits == 8:
        # int8 codes + per (token, head) absmax scale: ~1.06 B/elem vs 2
        return {
            "k": jnp.zeros((batch, length, kv_heads, head_dim), jnp.int8),
            "v": jnp.zeros((batch, length, kv_heads, head_dim), jnp.int8),
            "k_scale": jnp.zeros((batch, length, kv_heads), jnp.bfloat16),
            "v_scale": jnp.zeros((batch, length, kv_heads), jnp.bfloat16),
            "pos": jnp.full((batch, length), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def _kv_quant(x: jax.Array):
    """(B, S, KV, hd) -> int8 codes + (B, S, KV) bf16 scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def update_attn_cache(cache: Dict[str, jax.Array], k_new: jax.Array,
                      v_new: jax.Array, pos: jax.Array
                      ) -> Dict[str, jax.Array]:
    """Write S_new tokens at absolute positions ``pos`` (B, S_new).

    Ring semantics: slot = pos % cache_len.  Works for both full caches
    (cache_len >= max position) and window rings.
    """
    length = cache["k"].shape[1]
    slot = pos % length
    b_idx = jnp.arange(k_new.shape[0])[:, None]
    out = {"pos": cache["pos"].at[b_idx, slot].set(pos)}
    if "k_scale" in cache:
        kq, ks = _kv_quant(k_new)
        vq, vs = _kv_quant(v_new)
        out["k"] = cache["k"].at[b_idx, slot].set(kq)
        out["v"] = cache["v"].at[b_idx, slot].set(vq)
        out["k_scale"] = cache["k_scale"].at[b_idx, slot].set(ks)
        out["v_scale"] = cache["v_scale"].at[b_idx, slot].set(vs)
        return out
    out["k"] = cache["k"].at[b_idx, slot].set(k_new.astype(cache["k"].dtype))
    out["v"] = cache["v"].at[b_idx, slot].set(v_new.astype(cache["v"].dtype))
    return out


def prefill_attn_cache(cache: Dict[str, jax.Array], k_all: jax.Array,
                       v_all: jax.Array, positions: jax.Array
                       ) -> Dict[str, jax.Array]:
    """Bulk cache write after prefill.  For ring caches only the last
    ``window`` tokens land (earlier writes are overwritten by later ones in
    ring order, matching sequential semantics)."""
    length = cache["k"].shape[1]
    s = k_all.shape[1]
    if s <= length:
        return update_attn_cache(cache, k_all, v_all, positions)
    # keep the trailing `length` tokens
    k_t = k_all[:, s - length:]
    v_t = v_all[:, s - length:]
    p_t = positions[:, s - length:]
    return update_attn_cache(cache, k_t, v_t, p_t)


def dequant_scales(cache: Dict[str, jax.Array]):
    """(k_scale, v_scale) if the cache is int8-quantized, else (None, None)."""
    return cache.get("k_scale"), cache.get("v_scale")


# ---------------------------------------------------------------------------
# paged KV cache (block-table paging, vLLM-style)
# ---------------------------------------------------------------------------
#
# Instead of one contiguous (slots, max_len, ...) buffer per plane, the
# serve engine can keep a physical *page pool* (num_pages, page_size, ...)
# shared by every slot, plus a per-slot block table (slots, max_blocks) of
# int32 page ids (-1 = unmapped).  Token capacity is then allocated in
# page_size quanta per request instead of a power-of-two bucket per slot,
# and two slots may map the same physical page (refcounted shared prefix).
#
# Physical page 0 is reserved as a WRITE SINK: decode steps on retired /
# empty slots (block entry -1, or a position past the slot's mapped range)
# scatter into it instead of corrupting live pages, and it is never
# gathered (gathers mask positions where the block entry is negative).

TRASH_PAGE = 0


def is_paged(cache) -> bool:
    return isinstance(cache, dict) and "block" in cache


def init_paged_attn_cache(num_slots: int, num_pages: int, page_size: int,
                          max_blocks: int, kv_heads: int, head_dim: int,
                          dtype=jnp.bfloat16, kv_bits: int = 16
                          ) -> Dict[str, jax.Array]:
    """Page pool + block table.  ``num_pages`` INCLUDES the trash page."""
    out = {
        "block": jnp.full((num_slots, max_blocks), -1, jnp.int32),
        "pos": jnp.full((num_pages, page_size), -1, jnp.int32),
    }
    kv_shape = (num_pages, page_size, kv_heads, head_dim)
    if kv_bits == 8:
        out["k"] = jnp.zeros(kv_shape, jnp.int8)
        out["v"] = jnp.zeros(kv_shape, jnp.int8)
        out["k_scale"] = jnp.zeros(kv_shape[:3], jnp.bfloat16)
        out["v_scale"] = jnp.zeros(kv_shape[:3], jnp.bfloat16)
    else:
        out["k"] = jnp.zeros(kv_shape, dtype)
        out["v"] = jnp.zeros(kv_shape, dtype)
    return out


def _page_targets(cache: Dict[str, jax.Array], pos: jax.Array):
    """(page, offset) scatter targets for absolute positions ``pos``
    (B, S): look the page id up through the block table, routing unmapped
    or out-of-range positions to the trash page."""
    ps = cache["k"].shape[1]
    nb = cache["block"].shape[1]
    blk = pos // ps
    b_idx = jnp.arange(pos.shape[0])[:, None]
    page = cache["block"][b_idx, jnp.clip(blk, 0, nb - 1)]
    page = jnp.where((blk >= 0) & (blk < nb), page, -1)
    return jnp.maximum(page, TRASH_PAGE), pos % ps


def paged_update_attn_cache(cache: Dict[str, jax.Array], k_new: jax.Array,
                            v_new: jax.Array, pos: jax.Array
                            ) -> Dict[str, jax.Array]:
    """Write S_new tokens at absolute positions ``pos`` (B, S_new) through
    the block table into the page pool (the paged twin of
    ``update_attn_cache``; no ring wraparound — global layers only)."""
    page, off = _page_targets(cache, pos)
    out = {"block": cache["block"],
           "pos": cache["pos"].at[page, off].set(pos)}
    if "k_scale" in cache:
        kq, ks = _kv_quant(k_new)
        vq, vs = _kv_quant(v_new)
        out["k"] = cache["k"].at[page, off].set(kq)
        out["v"] = cache["v"].at[page, off].set(vq)
        out["k_scale"] = cache["k_scale"].at[page, off].set(ks)
        out["v_scale"] = cache["v_scale"].at[page, off].set(vs)
        return out
    out["k"] = cache["k"].at[page, off].set(k_new.astype(cache["k"].dtype))
    out["v"] = cache["v"].at[page, off].set(v_new.astype(cache["v"].dtype))
    return out


def paged_gather(cache: Dict[str, jax.Array]):
    """Materialize each slot's logical KV view from the page pool.

    Returns ``(k, v, kv_pos, k_scale, v_scale)`` with k/v shaped
    (slots, max_blocks * page_size, KV, hd) — the exact shapes
    ``decode_attention`` consumes, so the gather is the ONLY paged-
    specific op in the decode scan.  Positions under unmapped block
    entries come back -1 (masked like any empty cache slot), which is
    what keeps one compiled decode signature valid for every length mix.
    """
    bt = cache["block"]
    s, nb = bt.shape
    ps = cache["k"].shape[1]
    page = jnp.maximum(bt, 0)

    def flat(plane):
        return plane[page].reshape((s, nb * ps) + plane.shape[2:])

    kv_pos = jnp.where(jnp.repeat(bt < 0, ps, axis=1), -1, flat(cache["pos"]))
    ks = flat(cache["k_scale"]) if "k_scale" in cache else None
    vs = flat(cache["v_scale"]) if "v_scale" in cache else None
    return flat(cache["k"]), flat(cache["v"]), kv_pos, ks, vs


def paged_claim(cache: Dict[str, jax.Array], req_cache: Dict[str, jax.Array],
                slot: int, pages: jax.Array, write_mask: jax.Array
                ) -> Dict[str, jax.Array]:
    """Map ``pages`` into row ``slot`` of the block table and scatter the
    request's contiguous prefilled planes into its freshly-allocated pages.

    ``req_cache`` planes are batch-1 contiguous of page-aligned length L;
    ``pages``: (max_blocks,) physical page ids (-1 pad past the request's
    allocation); ``write_mask``: (max_blocks,) — True for pages whose
    content this claim owns (fresh prompt pages get the matching req-cache
    chunk, fresh decode pages get the empty fill), False for
    prefix-SHARED pages (their content predates this request and must not
    be touched) and for -1 pads.  Masked-out writes land on the trash
    page.  ``slot`` / ``pages`` / ``write_mask`` are traced, so one
    compile serves every admission of a given prompt-length bucket."""
    ps = cache["k"].shape[1]
    nb = pages.shape[0]
    n_src = req_cache["k"].shape[1] // ps
    tgt = jnp.where(write_mask, jnp.maximum(pages, 0), TRASH_PAGE)

    def chunks(plane, fill):
        src = plane[0].reshape((n_src, ps) + plane.shape[2:])
        if nb > n_src:
            pad = jnp.full((nb - n_src, ps) + plane.shape[2:], fill,
                           src.dtype)
            src = jnp.concatenate([src, pad], axis=0)
        return src[:nb]

    out = {"block": jax.lax.dynamic_update_slice_in_dim(
        cache["block"], pages[None].astype(jnp.int32), slot, 0)}
    out["pos"] = cache["pos"].at[tgt].set(
        chunks(req_cache["pos"].astype(jnp.int32), -1))
    for name in (n for n in ("k", "v", "k_scale", "v_scale") if n in cache):
        out[name] = cache[name].at[tgt].set(
            chunks(req_cache[name].astype(cache[name].dtype), 0))
    return out


def paged_reset(cache: Dict[str, jax.Array], slot: int
                ) -> Dict[str, jax.Array]:
    """Unmap row ``slot`` of the block table (pages are freed host-side by
    the allocator; pool contents are rewritten on the next claim)."""
    row = jnp.full((1, cache["block"].shape[1]), -1, jnp.int32)
    out = dict(cache)
    out["block"] = jax.lax.dynamic_update_slice_in_dim(
        cache["block"], row, slot, 0)
    return out


def paged_seed_prefix(req_cache: Dict[str, jax.Array],
                      cache: Dict[str, jax.Array], pages: jax.Array
                      ) -> Dict[str, jax.Array]:
    """Gather the shared-prefix pages of ``pages`` (-1 past the prefix)
    into the leading span of a batch-1 contiguous request cache, so a
    suffix-only prefill can attend over the reused prefix KV without
    recomputing it."""
    ps = cache["k"].shape[1]
    m = req_cache["k"].shape[1] // ps          # static: req pages
    pg = pages[:m]
    page = jnp.maximum(pg, 0)

    def pull(plane):
        return plane[page].reshape((1, m * ps) + plane.shape[2:])

    out = dict(req_cache)
    out["pos"] = jnp.where(jnp.repeat(pg < 0, ps)[None, :], -1,
                           pull(cache["pos"]))
    for name in (n for n in ("k", "v", "k_scale", "v_scale")
                 if n in req_cache):
        out[name] = pull(cache[name]).astype(req_cache[name].dtype)
    return out


# ---------------------------------------------------------------------------
# slot claim / reset (continuous-batching scheduler)
# ---------------------------------------------------------------------------
#
# The serve scheduler keeps one fixed-shape cache whose batch rows are
# *slots*; requests come and go by writing a freshly-prefilled batch-1
# cache into a slot (claim) or clearing it (reset).  Shapes never change,
# so the jitted decode loop stays resident across the whole workload.

def _slot_fill(name: str, dtype) -> jax.Array:
    """Empty-slot fill value per cache plane: position planes use -1
    (= unwritten, masked by decode attention), xLSTM max-state planes use
    -inf (softmax-stabilizer identity), everything else zero."""
    if name in ("pos", "block"):
        return jnp.asarray(-1, dtype)
    if name == "m":
        return jnp.asarray(-jnp.inf, dtype)
    return jnp.asarray(0, dtype)


def claim_slot(cache: Dict[str, jax.Array], req_cache: Dict[str, jax.Array],
               slot: int, batch_axis: int = 0) -> Dict[str, jax.Array]:
    """Write a batch-1 per-request cache into row ``slot`` of a slotted
    cache.  ``batch_axis`` is 0 for plain layer caches and 1 for scanned
    (repeat-stacked) segment caches."""
    out = {}
    for k, v in cache.items():
        r = req_cache[k].astype(v.dtype)
        out[k] = jax.lax.dynamic_update_slice_in_dim(v, r, slot, batch_axis)
    return out


def reset_slot(cache: Dict[str, jax.Array], slot: int,
               batch_axis: int = 0) -> Dict[str, jax.Array]:
    """Clear row ``slot`` back to the empty-slot state (pos = -1 etc.)."""
    out = {}
    for k, v in cache.items():
        row_shape = v.shape[:batch_axis] + (1,) + v.shape[batch_axis + 1:]
        row = jnp.full(row_shape, _slot_fill(k, v.dtype), v.dtype)
        out[k] = jax.lax.dynamic_update_slice_in_dim(v, row, slot, batch_axis)
    return out


def init_rglru_cache(batch: int, width: int, conv_width: int,
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {
        "h": jnp.zeros((batch, width), dtype),
        "conv": jnp.zeros((batch, conv_width - 1, width), dtype),
    }


def init_mlstm_cache(batch: int, heads: int, head_dim: int,
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {
        "c": jnp.zeros((batch, heads, head_dim, head_dim), dtype),
        "n": jnp.zeros((batch, heads, head_dim), dtype),
        "m": jnp.full((batch, heads), -jnp.inf, dtype),
    }


def init_slstm_cache(batch: int, heads: int, head_dim: int,
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {
        "c": jnp.zeros((batch, heads, head_dim), dtype),
        "n": jnp.zeros((batch, heads, head_dim), dtype),
        "h": jnp.zeros((batch, heads, head_dim), dtype),
        "m": jnp.full((batch, heads, head_dim), -jnp.inf, dtype),
    }
