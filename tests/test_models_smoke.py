"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SHAPES
from repro.models import (ExecContext, decode_step, forward, init_caches,
                          init_params, lm_loss)
from repro.registry import ASSIGNED, PAPER_MODELS, get_config

ALL_ARCHS = sorted(ASSIGNED) + sorted(PAPER_MODELS)
B, S = 2, 16


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.encoder is not None:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder.source_len, cfg.encoder.d_model),
            jnp.bfloat16)
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))
        batch["mrope_pos"] = pos
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_finite(arch, rng):
    cfg = get_config(arch, reduced=True)
    params = init_params(rng, cfg, jnp.float32)
    batch = _batch(cfg, rng)
    ctx = ExecContext(mode="train")
    out = forward(params, batch["tokens"], cfg, ctx,
                  enc_embeds=batch.get("enc_embeds"),
                  mrope_pos=batch.get("mrope_pos"))
    assert out.logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(out.logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_finite(arch, rng):
    cfg = get_config(arch, reduced=True)
    params = init_params(rng, cfg, jnp.float32)
    batch = _batch(cfg, rng)
    ctx = ExecContext(mode="train")

    def loss_fn(p):
        return lm_loss(p, batch, cfg, ctx)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert np.isfinite(float(loss))
    gn = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g.astype(jnp.float32)))),
        grads, 0.0)
    assert np.isfinite(gn) and gn > 0.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_then_decode_matches_forward(arch, rng):
    """Decode-with-cache must agree with the full forward pass."""
    cfg = get_config(arch, reduced=True)
    params = init_params(rng, cfg, jnp.float32)
    batch = _batch(cfg, rng)
    tokens = batch["tokens"]
    full = forward(params, tokens, cfg, ExecContext(mode="train",
                                                    exact_capacity=True),
                   enc_embeds=batch.get("enc_embeds"),
                   mrope_pos=batch.get("mrope_pos"))

    # prefill on the first S-1 tokens, then decode token S-1
    caches = init_caches(cfg, B, max_len=S + 8, dtype=jnp.float32)
    pre = forward(params, tokens[:, :-1], cfg,
                  ExecContext(mode="prefill", exact_capacity=True),
                  caches=caches, enc_embeds=batch.get("enc_embeds"),
                  mrope_pos=(batch["mrope_pos"][:, :, :-1]
                             if "mrope_pos" in batch else None))
    step = decode_step(params, tokens[:, -1:], pre.caches, cfg,
                       ExecContext(mode="step", exact_capacity=True),
                       mrope_pos=(batch["mrope_pos"][:, :, -1:]
                                  if "mrope_pos" in batch else None))
    ref = full.logits[:, -1].astype(np.float32)
    got = step.logits[:, 0].astype(np.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)
