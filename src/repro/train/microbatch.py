"""Gradient accumulation: split the global batch into microbatches and
accumulate grads in f32 via lax.scan — peak activation memory scales with
the microbatch, not the global batch (the standard large-model trick; the
dry-run's train cells can trade memory term for step latency with it).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def microbatched_value_and_grad(loss_fn: Callable, num_micro: int):
    """loss_fn(params, batch) -> (loss, metrics).  Returns a function with
    the same signature as jax.value_and_grad(loss_fn, has_aux=True) that
    scans over ``num_micro`` slices of the batch's leading dim."""
    if num_micro <= 1:
        return jax.value_and_grad(loss_fn, has_aux=True)

    def split(batch):
        def one(x):
            b = x.shape[0]
            assert b % num_micro == 0, (b, num_micro)
            return x.reshape(num_micro, b // num_micro, *x.shape[1:])
        return jax.tree.map(one, batch)

    def vg(params, batch):
        micro = split(batch)
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def step(carry, mb):
            acc, loss_acc, metrics_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / num_micro,
                acc, grads)
            metrics_acc = jax.tree.map(
                lambda a, m: a + m / num_micro, metrics_acc, metrics)
            return (acc, loss_acc + loss / num_micro, metrics_acc), 0

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        first = jax.tree.map(lambda x: x[0], micro)
        (_, m0), _ = jax.eval_shape(grad_fn, params, first), None
        metrics0 = jax.tree.map(lambda s: jnp.zeros((), jnp.float32),
                                jax.eval_shape(grad_fn, params,
                                               first)[0][1])
        (grads, loss, metrics), _ = jax.lax.scan(
            step, (zeros, jnp.zeros((), jnp.float32), metrics0), micro)
        return (loss, metrics), grads

    return vg
