"""qwen3-moe-30b-a3b [moe]: 48L d=2048 32H (GQA kv=4) vocab=151936,
128 experts top-8, d_expert=768.  [hf:Qwen/Qwen3-30B-A3B]

Paper technique: full router-guided restoration.  Many-small-experts
regime = the paper's DeepSeek case -> R_avg=64, top-n=3 (paper §4.2)."""
from ..config import ModelConfig, MoEConfig, QuantConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
        head_dim=128, d_ff=0, vocab_size=151_936,
        block_pattern=("global",),
        rope_theta=1_000_000.0, act="silu", tie_embeddings=False,
        moe=MoEConfig(num_experts=128, top_k=8, d_expert=768,
                      router_norm_topk=True,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=64,
                                        top_n_restore=3)),
        max_position=131_072,
    )
