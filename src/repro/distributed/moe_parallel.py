"""Expert-parallel MoE execution under shard_map.

Wraps ``models.moe.moe_apply_ep_a2a`` (train/prefill: dispatch all_to_all)
and ``moe_apply_ep_replicated`` (decode: resident-expert partials + psum)
with the mesh specs derived from the run's ParallelConfig.  Falls back to
the plain GSPMD path when the expert count does not divide the EP axis.
"""
from __future__ import annotations

import re
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..config import ModelConfig, ParallelConfig
from ..models.moe import moe_apply, moe_apply_ep_a2a, moe_apply_ep_replicated
from .sharding import mesh_spec, shard_map

EP_AXIS = "model"


def _param_spec(path_leaf: str) -> P:
    """Specs for MoE-layer params entering shard_map (expert dim on EP)."""
    if re.search(r"(^|/)router$", path_leaf):
        return P(None, None)
    return None  # filled by ndim below


def _moe_param_specs(mp) -> Any:
    def one(path, leaf):
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        if p.endswith("router"):
            return P(None, None)
        if p.startswith("shared"):
            return P(*([None] * leaf.ndim))
        return P(*([EP_AXIS] + [None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(one, mp)


def ep_size(mesh: Optional[Mesh]) -> int:
    """Expert-parallel degree of a mesh (size of the EP axis, 1 if none)."""
    if mesh is None:
        return 1
    return int(mesh.shape.get(EP_AXIS, 1))


def make_moe_ep_fn(mesh: Mesh, pcfg: ParallelConfig) -> Callable:
    """Returns ctx.moe_ep_fn(h, mp, cfg, ctx, plan=None) -> (y, aux, topk).

    ``topk`` is the (b, s, k) router decision — first-class trace output
    matching the single-shard path, so the serve engine and offload
    metering see identical routing regardless of the execution path.

    ``plan`` is this layer's (2,) int32 [top_n, rank_cap] row of the
    bandwidth controller's restoration plan (None = static QuantConfig).
    It enters the shard_map region replicated — every shard applies the
    same restoration intensity — and stays *data*, so runtime plan
    changes never recompile the sharded decode loop either.
    """
    all_axes = tuple(mesh.axis_names)

    def moe_ep_fn(h, mp, cfg: ModelConfig, ctx, plan=None):
        mcfg = cfg.moe
        ep = ep_size(mesh)
        quantized = ctx.quantized and "stacks" in mp
        impl = getattr(ctx, "kernel_impl", None)
        mp_local = {k: v for k, v in mp.items() if k != "shared"}
        if mcfg.num_experts % ep or ep == 1:
            b, s, d = h.shape
            y2, aux, info = moe_apply(h.reshape(-1, d), mp_local, mcfg,
                                      act=cfg.act, quantized=quantized,
                                      exact_capacity=ctx.exact_capacity,
                                      impl=impl, plan=plan)
            return y2.reshape(b, s, d), aux, info.topk_idx.reshape(b, s, -1)

        replicated = ctx.ep_mode == "replicated"
        # a2a path: shard the seq dim over the EP axis inside the region
        # (sequence-parallel dispatch) — otherwise every EP rank routes the
        # same tokens and expert compute duplicates EP-fold.
        seq_logical = "moe_seq" if (not replicated
                                    and h.shape[1] % ep == 0) else "seq"
        hspec = mesh_spec(mesh, ("batch", seq_logical, None), h.shape, pcfg)
        tspec = mesh_spec(mesh, ("batch", seq_logical, None),
                          (h.shape[0], h.shape[1], mcfg.top_k), pcfg)
        pspecs = _moe_param_specs(mp_local)
        inner = (moe_apply_ep_replicated if replicated else moe_apply_ep_a2a)
        kw = {} if replicated else {"exact_capacity": ctx.exact_capacity}

        def body(h_l, mp_l, *plan_l):
            b_l, s_l, d = h_l.shape
            y2, aux, info = inner(h_l.reshape(-1, d), mp_l, mcfg, act=cfg.act,
                                  quantized=quantized, axis=EP_AXIS,
                                  impl=impl,
                                  plan=plan_l[0] if plan_l else None, **kw)
            # replicate aux scalars across the whole mesh (pmean of values
            # already equal along an axis is a no-op)
            aux = jax.tree.map(lambda v: jax.lax.pmean(v, all_axes), aux)
            topk = info.topk_idx.reshape(b_l, s_l, -1)
            return y2.reshape(b_l, s_l, d), aux, topk

        args = (h, mp_local)
        in_specs = (hspec, pspecs)
        if plan is not None:
            args = args + (plan,)
            in_specs = in_specs + (P(None),)
        y, aux, topk = shard_map(
            body, mesh=mesh,
            in_specs=in_specs,
            out_specs=(hspec, jax.tree.map(lambda _: P(), {"load_balance": 0,
                                                           "router_z": 0}),
                       tspec),
            check_vma=False,
        )(*args)
        return y, aux, topk

    return moe_ep_fn
