"""Serving: batched engine (prefill + decode), continuous-batching request
scheduler, sampling, router-trace export."""
from .engine import (GenerationResult, ServeEngine, ServeStats, bucket_len,
                     router_trace, sample)
from .scheduler import Request, RequestResult, Scheduler, synthetic_workload
