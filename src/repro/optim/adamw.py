"""Sharded AdamW with f32 master weights over bf16 compute params.

Optimizer state is a pytree mirroring the parameter tree (same logical
sharding), so pjit shards it with the same rules — no separate bookkeeping.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..config import TrainConfig


class OptState(NamedTuple):
    step: jax.Array
    master: Any      # f32 master copy of params
    m: Any
    v: Any


def adamw_init(params) -> OptState:
    # copy=True: master must never alias the compute params (donation)
    f32 = lambda p: jnp.array(p, jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(jnp.zeros((), jnp.int32),
                    jax.tree.map(f32, params),
                    jax.tree.map(zeros, params),
                    jax.tree.map(zeros, params))


def warmup_cosine(tcfg: TrainConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(tcfg.warmup_steps, 1)
    total = jnp.maximum(tcfg.total_steps - tcfg.warmup_steps, 1)
    prog = jnp.clip((s - tcfg.warmup_steps) / total, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tcfg.lr * jnp.where(s < tcfg.warmup_steps, warm,
                               jnp.maximum(cos, 0.02))


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jax.Array]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(grads, state: OptState, tcfg: TrainConfig,
                 param_dtype=jnp.bfloat16) -> Tuple[Any, OptState, Dict]:
    """Returns (new compute params, new state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if tcfg.clip_norm:
        grads, gn = clip_by_global_norm(grads, tcfg.clip_norm)
    else:
        gn = global_norm(grads)
    step = state.step + 1
    lr = warmup_cosine(tcfg, step)
    b1, b2, eps, wd = tcfg.b1, tcfg.b2, tcfg.eps, tcfg.weight_decay
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, p, m, v):
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        p = p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p)
        return p, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = treedef.flatten_up_to(state.master)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    new = [upd(g, p, m, v) for g, p, m, v in
           zip(flat_g, flat_p, flat_m, flat_v)]
    master = treedef.unflatten([t[0] for t in new])
    m = treedef.unflatten([t[1] for t in new])
    v = treedef.unflatten([t[2] for t in new])
    params = jax.tree.map(lambda p: p.astype(param_dtype), master)
    return params, OptState(step, master, m, v), {"grad_norm": gn, "lr": lr}
