"""Unified expert-execution backend: one place that owns the expert FFN.

All three MoE paths (``moe_apply``, ``moe_apply_ep_a2a``,
``moe_apply_ep_replicated``) dispatch the (E, C, d) expert-stacked buffers
through a single :func:`select_backend` decision instead of inlining the
dense/quantized branch.  Backends:

  ``dense``   reference einsum over full-precision (E, d, f) stacks
  ``ref``     quantized + router-guided compensation via the batched einsum
              oracle (``core.restoration.compensated_expert_ffn``)
  ``pallas``  fused dequant+low-rank Pallas kernel per projection
              (``kernels.ops.compensated_matmul_stack``); also runs under
              the Pallas interpreter on CPU (``pallas_interpret``)

Selection follows the kernel dispatch policy in ``kernels.ops``
(``REPRO_KERNEL_IMPL`` env / ``impl`` argument: auto | pallas |
pallas_interpret | ref), so the Pallas kernels are reachable from the
model rather than dead code behind the benchmarks.

Expert-parallel serving runs these same backends INSIDE the shard_map
regions of ``distributed/moe_parallel.py``: the ``params`` dict then
carries each shard's LOCAL expert slice — ``(E/ep, ...)`` weight /
stack leaves (with ``CompressedExpertStack.shape`` still naming the
global E, which is static metadata; kernels index only runtime leaves)
— and ``xe`` the shard's dispatched ``(E_local, C, d)`` buffers.  The
engine's ``kernel_impl`` threads through ``ExecContext`` into the
region, so one dispatch policy selects the execution path on every
shard, sharded or not.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..core.pipeline import CompressedExpertStack
from ..core.restoration import compensated_expert_ffn
from ..kernels import ops
from .layers import activation


def expert_ffn_dense(xe: jax.Array, w1, w3, w2, act: str) -> jax.Array:
    """xe: (E, C, d); w1/w3: (E, d, f); w2: (E, f, d)."""
    f = activation(act)
    h = jnp.einsum("ecd,edf->ecf", xe, w1)
    h = f(h) * jnp.einsum("ecd,edf->ecf", xe, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


class ExpertBackend:
    """Executes the expert FFN over dispatched (E, C, d) buffers.

    ``me`` is the (E, C) 0/1 router-guided compensation mask and
    ``rank_cap`` the traced per-layer compensator rank ceiling from the
    bandwidth controller's plan (None = full padded rank); both are
    ignored by the dense backend.
    """

    name = "base"

    def __call__(self, xe: jax.Array, params: Dict, me: jax.Array,
                 act: str, rank_cap: Optional[jax.Array] = None
                 ) -> jax.Array:
        raise NotImplementedError


class DenseBackend(ExpertBackend):
    """Full-precision einsum experts (training / uncompressed serving)."""

    name = "dense"

    def __call__(self, xe, params, me, act, rank_cap=None):
        return expert_ffn_dense(xe, params["w1"], params["w3"], params["w2"],
                                act)


class RefQuantBackend(ExpertBackend):
    """Quantized experts with masked compensation — batched einsum oracle."""

    name = "ref"

    def __call__(self, xe, params, me, act, rank_cap=None):
        stacks = params["stacks"]
        return compensated_expert_ffn(
            xe, stacks["w1"], stacks.get("w3"), stacks["w2"], me,
            act=activation(act), dtype=xe.dtype, rank_cap=rank_cap)


class PallasQuantBackend(ExpertBackend):
    """Fused dequant + router-guided low-rank epilogue per projection.

    ``impl`` is the *resolved* kernel implementation ('pallas' or
    'pallas_interpret'); each projection runs
    ``kernels.ops.compensated_matmul_stack`` so no dequantized weight is
    ever materialized.
    """

    name = "pallas"

    def __init__(self, impl: str = "pallas"):
        self.impl = impl

    def __call__(self, xe, params, me, act, rank_cap=None):
        stacks: Dict[str, CompressedExpertStack] = params["stacks"]
        f = activation(act)
        h1 = ops.compensated_matmul_stack(xe, stacks["w1"], me,
                                          impl=self.impl,
                                          out_dtype=jnp.float32,
                                          rank_cap=rank_cap)
        if "w3" in stacks:
            h3 = ops.compensated_matmul_stack(xe, stacks["w3"], me,
                                              impl=self.impl,
                                              out_dtype=jnp.float32,
                                              rank_cap=rank_cap)
            h = f(h1) * h3
        else:
            h = f(h1)
        ye = ops.compensated_matmul_stack(h.astype(xe.dtype), stacks["w2"],
                                          me, impl=self.impl,
                                          out_dtype=jnp.float32,
                                          rank_cap=rank_cap)
        return ye.astype(xe.dtype)


def select_backend(params: Dict, quantized: bool,
                   impl: Optional[str] = None) -> ExpertBackend:
    """Pick the expert backend for one MoE layer invocation.

    Dense weights (or ``quantized=False``) always run the einsum path;
    compressed stacks dispatch on the resolved kernel impl policy
    (``REPRO_KERNEL_IMPL`` / ``impl``): 'ref' uses the batched einsum
    oracle, 'pallas'/'pallas_interpret' the fused kernel.  Called per
    shard inside the expert-parallel shard_map paths with the local
    param slice — the decision depends only on tree structure and the
    impl policy, so every shard selects the same backend.
    """
    if not quantized or "stacks" not in params:
        return DenseBackend()
    resolved = ops.resolve_impl(impl)
    if resolved == "ref":
        return RefQuantBackend()
    return PallasQuantBackend(resolved)
