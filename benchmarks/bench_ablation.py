"""Fig 8 ablations on the trained bench MoE (2-bit, as in the paper):

(a) restored-expert count n sweep — gains saturate at the router knee;
(b) rank budget sweep — quality/overhead trade-off (MB per expert);
(c) kurtosis-guided vs uniform rank at equal budget.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.config import QuantConfig

from .common import compress_model, eval_nll, trained_moe


def run(quick: bool = True):
    cfg, params = trained_moe(steps=60 if quick else 200)
    rows = []

    # (a) number of restored experts
    for n in (0, 1, 2):
        qcfg = QuantConfig(enabled=True, bits=2, rank_budget=32,
                           top_n_restore=n, hqq_iters=20)
        cfg2, qp, _ = compress_model(cfg, params, qcfg)
        nll = eval_nll(cfg2, qp, quantized=True)
        rows.append({"name": f"fig8a/top{n}", "nll": nll})

    # (b) rank budget sweep + wire overhead
    for budget in (16, 32, 128):
        qcfg = QuantConfig(enabled=True, bits=2, rank_budget=budget,
                           top_n_restore=1, hqq_iters=20)
        cfg2, qp, reps = compress_model(cfg, params, qcfg)
        nll = eval_nll(cfg2, qp, quantized=True)
        # overhead: mean compensator bytes / quantized expert bytes
        any_layer = next(iter(reps.values()))
        ranks = np.concatenate([r["ranks"] for r in any_layer.values()])
        d, fe = cfg.d_model, cfg.moe.d_expert
        comp_mb = float(np.mean(ranks) * (d + fe) * 3 / 2 ** 20)
        qexp_mb = 3 * d * fe * 0.25 / 2 ** 20
        rows.append({"name": f"fig8b/rank{budget}", "nll": nll,
                     "comp_mb": round(comp_mb, 4),
                     "pct_of_expert": round(100 * comp_mb / qexp_mb, 2)})

    # (c) allocation strategy at equal budget: uniform (ablation) vs
    # kurtosis-guided (paper) vs error-guided (beyond-paper)
    for budget in (16, 32):
        for alloc in ("uniform", "kurtosis", "error"):
            qcfg = QuantConfig(enabled=True, bits=2, rank_budget=budget,
                               top_n_restore=1, hqq_iters=20,
                               kurtosis_guided=(alloc != "uniform"),
                               rank_alloc=alloc)
            cfg2, qp, _ = compress_model(cfg, params, qcfg)
            nll = eval_nll(cfg2, qp, quantized=True)
            rows.append({"name": f"fig8c/r{budget}-{alloc}", "nll": nll})

    # (c-mech) same comparison at the level the allocation optimizes:
    # total residual energy after compensation, on heavy-tailed init
    # weights where the kurtosis<->error correlation holds (fig4b_init)
    rows += _mechanism_rows()
    return rows


def _mechanism_rows():
    import jax
    import jax.numpy as jnp
    from repro.core import compress_expert_stack

    from .common import bench_moe_cfg, heavy_tail_expert_init
    cfg = bench_moe_cfg()
    params = heavy_tail_expert_init(cfg, 0)(jax.random.key(0))
    w = params["segments"][0][0]["moe"]["w1"]
    if w.ndim == 4:
        w = w[0]
    rows = []
    for alloc in ("uniform", "kurtosis", "error"):
        qcfg = QuantConfig(enabled=True, bits=2, rank_budget=32,
                           hqq_iters=10, kurtosis_guided=(alloc != "uniform"),
                           rank_alloc=alloc)
        _, rep = compress_expert_stack(jnp.asarray(w), qcfg)
        resid = float(np.sqrt(np.mean(rep["rel_err_comp"] ** 2)))
        rows.append({"name": f"fig8c-mech/{alloc}",
                     "rms_rel_residual": resid})
    return rows


if __name__ == "__main__":
    for r in run():
        extra = ",".join(f"{k}={v}" for k, v in r.items() if k != "name")
        print(f"{r['name']},{extra}")
