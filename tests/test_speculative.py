"""Speculative-decoding tier (serve/speculative.py + the engine's round).

The subsystem's correctness oracle is the autoregressive engine itself:
at temperature 0, draft/verify rounds must produce EXACTLY the tokens
the plain decode loop produces, for any drafter, because a rejected
draft is by definition not the argmax — so banning it from the next
round's first sample (the point-mass rejection residual) never changes
the greedy choice.  Checked here across kernel impls, expert-parallel
sharding (ep=2 under the dist tier), the paged KV cache, and the async
streaming engine (where the metered-bytes oracle must stay exact with
speculation on).

The other invariant is KV hygiene: the verify pass appends cache
entries for every drafted position, and ``cache_rollback`` must leave
the cache bit-identical to one that never saw the rejected suffix —
checked directly on the contiguous (fp + int8-scale) and paged layouts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

from repro.config import ModelConfig, MoEConfig, QuantConfig, ServeConfig, \
    StreamConfig
from repro.models import init_params
from repro.models.kvcache import (init_attn_cache, init_paged_attn_cache,
                                  paged_update_attn_cache,
                                  update_attn_cache)
from repro.models.transformer import cache_rollback, compress_moe_params
from repro.offload.prefetch import LayerAheadPrefetcher, LookaheadPrefetcher
from repro.serve import (DraftModelDrafter, NGramDrafter, Request,
                         ServeEngine, accept_drafts, mask_banned)
from repro.serve.scheduler import Scheduler

E = 8
MAX_NEW = 8


def moe_cfg():
    return ModelConfig(
        name="spec-tier", family="moe", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0, vocab_size=128,
        block_pattern=("global",), max_position=512,
        moe=MoEConfig(num_experts=E, top_k=2, d_expert=64,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=16,
                                        top_n_restore=1, hqq_iters=2)))


@pytest.fixture(scope="module")
def base():
    cfg = moe_cfg()
    return cfg, init_params(jax.random.key(0), cfg, jnp.float32)


def requests():
    rng = np.random.default_rng(3)
    return [Request(uid=i, tokens=rng.integers(1, 128, (int(n),))
                    .astype(np.int32), max_new=MAX_NEW)
            for i, n in enumerate((4, 6, 5))]


def build(cfg, params, impl="ref", ep=1, stream=False, cache_capacity=E):
    qp, cq, stacks = compress_moe_params(params, cfg)
    eng = ServeEngine(cq, qp, ServeConfig(temperature=0.0), quantized=True,
                      kernel_impl=impl)
    eng.attach_offload(stacks, policy="ours", cache_capacity=cache_capacity,
                       ep=ep)
    if stream:
        eng.attach_streaming(StreamConfig(enabled=True))
    return eng


def serve(eng, **kw):
    return eng.serve(requests(), num_slots=2, chunk=4, **kw)


_plain = {}


def plain_tokens(cfg, params, impl, **build_kw):
    key = (impl,) + tuple(sorted(build_kw.items()))
    if key not in _plain:
        stats = serve(build(cfg, params, impl, **build_kw))
        _plain[key] = [r.tokens.tolist() for r in stats.results]
    return _plain[key]


# ---------------------------------------------------------------------------
# acceptance math (device-side): deterministic edges + hypothesis
# ---------------------------------------------------------------------------

def test_accept_drafts_greedy_edges():
    """Greedy acceptance is prefix-of-argmax-matches: full-accept and
    full-reject are the {k, 0} accepted-length edges."""
    v, k = 16, 3
    logits = jnp.zeros((2, k, v)).at[:, :, 5].set(9.0)
    agree = jnp.full((2, k), 5, jnp.int32)
    differ = jnp.full((2, k), 6, jnp.int32)
    key = jax.random.key(0)
    assert accept_drafts(logits, agree, key, 0.0).all()
    assert not accept_drafts(logits, differ, key, 0.0).any()
    # first-position rejection truncates the whole round (prefix rule)
    mixed = jnp.asarray([[6, 5, 5], [5, 6, 5]], jnp.int32)
    acc = np.asarray(accept_drafts(logits, mixed, key, 0.0))
    assert acc.tolist() == [[False, False, False], [True, False, False]]


def test_accept_drafts_sampling_edges():
    """temperature > 0: p_target(draft)=1 accepts surely, p=0 rejects
    surely — the same {k, 0} edges under the stochastic rule."""
    v, k = 16, 3
    logits = jnp.full((2, k, v), -1e9).at[:, :, 5].set(0.0)
    agree = jnp.full((2, k), 5, jnp.int32)
    differ = jnp.full((2, k), 6, jnp.int32)
    key = jax.random.key(1)
    assert accept_drafts(logits, agree, key, 0.7).all()
    assert not accept_drafts(logits, differ, key, 0.7).any()


def _check_greedy_is_argmax_prefix(seed: int, rows: int, k: int):
    """The greedy acceptance mask equals the cumulative prefix of
    per-position argmax agreement — accepted length is exactly the
    draft's prefix-match length, anywhere in [0, k]."""
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    logits = jax.random.normal(k1, (rows, k, 8))
    draft = jax.random.randint(k2, (rows, k), 0, 8)
    acc = np.asarray(accept_drafts(logits, draft, k3, 0.0))
    match = np.asarray(draft) == np.asarray(jnp.argmax(logits, axis=-1))
    assert np.array_equal(acc, np.cumprod(match, axis=1).astype(bool))


def _check_sampling_is_prefix(seed: int, rows: int, k: int):
    """Under the stochastic rule the mask is still a prefix
    (cumulative), never a gap."""
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    logits = jax.random.normal(k1, (rows, k, 8)) * 3.0
    draft = jax.random.randint(k2, (rows, k), 0, 8)
    acc = np.asarray(accept_drafts(logits, draft, k3, 0.9))
    assert np.array_equal(acc, np.cumprod(acc, axis=1).astype(bool))


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 5),
           st.integers(1, 4))
    def test_accept_drafts_is_argmax_prefix(seed, rows, k):
        _check_greedy_is_argmax_prefix(seed, rows, k)

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 5),
           st.integers(1, 4))
    def test_accept_drafts_sampling_is_prefix(seed, rows, k):
        _check_sampling_is_prefix(seed, rows, k)


@pytest.mark.parametrize("seed", range(10))
def test_accept_drafts_prefix_seeded(seed):
    """Seeded fallback for the hypothesis properties (CI installs
    hypothesis; this keeps the tier meaningful without it)."""
    _check_greedy_is_argmax_prefix(seed, 1 + seed % 5, 1 + seed % 4)
    _check_sampling_is_prefix(seed, 1 + seed % 5, 1 + seed % 4)


def test_mask_banned():
    logits = jnp.zeros((3, 8))
    banned = jnp.asarray([2, -1, 7], jnp.int32)
    out = np.asarray(mask_banned(logits, banned))
    assert np.isneginf(out[0, 2]) and np.isneginf(out[2, 7])
    assert np.isfinite(out[0, [i for i in range(8) if i != 2]]).all()
    assert np.isfinite(out[1]).all()     # -1 = nothing banned


# ---------------------------------------------------------------------------
# drafters (host-side)
# ---------------------------------------------------------------------------

def test_ngram_backoff_disambiguates():
    """The stream 1,2,1,3,1,2,1,3,... is ambiguous at order 2 (context
    (1,) maps to both 2 and 3) but exact at order 3: backoff must
    continue the cycle perfectly."""
    d = NGramDrafter(order=3)
    d.reset_slot(0, np.asarray([1, 2, 1, 3, 1, 2, 1, 3], np.int32))
    assert d.propose(0, 4).tolist() == [1, 2, 1, 3]
    # unseen context falls back through shorter orders to repeat-last
    d2 = NGramDrafter(order=3)
    d2.reset_slot(0, np.asarray([7], np.int32))
    assert d2.propose(0, 3).tolist() == [7, 7, 7]


def test_ngram_reset_clears_slot_state():
    d = NGramDrafter(order=2)
    d.reset_slot(0, np.asarray([5, 6, 5, 6], np.int32))
    assert d.propose(0, 2).tolist() == [5, 6]
    d.reset_slot(0, np.asarray([9], np.int32))
    assert d.propose(0, 2).tolist() == [9, 9]


def test_draft_model_drafter_shapes(base):
    cfg, _ = base
    d = DraftModelDrafter.from_target(cfg, window=8, kernel_impl="ref")
    d.reset_slot(0, np.asarray([3, 4, 5], np.int32))
    d.reset_slot(1, np.asarray([6], np.int32))
    out = d.propose_all(2, 3)
    assert out.shape == (2, 3) and out.dtype == np.int32
    assert (0 <= out).all() and (out < cfg.vocab_size).all()


# ---------------------------------------------------------------------------
# scheduler: valid_len (rejected speculative suffixes never reach results)
# ---------------------------------------------------------------------------

def test_record_chunk_valid_len_truncates():
    sched = Scheduler(num_slots=2)
    for r in [Request(uid=0, tokens=np.asarray([1]), max_new=8),
              Request(uid=1, tokens=np.asarray([1]), max_new=8)]:
        sched.submit(r)
    sched.admit()
    toks = np.arange(8, dtype=np.int32).reshape(2, 4)
    lps = np.zeros((2, 4), np.float32)
    accepted = sched.record_chunk(toks, lps, None, now=1.0,
                                  valid_len=np.asarray([2, 4]))
    assert accepted.T.tolist() == [[True, True, False, False],
                                   [True, True, True, True]]
    assert sched.slots[0].tokens == [0, 1]
    assert sched.slots[1].tokens == [4, 5, 6, 7]


def test_record_chunk_valid_len_respects_retirement():
    """A slot that hits max_new inside its accepted prefix retires there;
    the rest of the accepted prefix is dropped like any post-retirement
    step."""
    sched = Scheduler(num_slots=1)
    sched.submit(Request(uid=0, tokens=np.asarray([1]), max_new=2))
    sched.admit()
    toks = np.asarray([[3, 4, 5, 6]], np.int32)
    accepted = sched.record_chunk(toks, np.zeros((1, 4), np.float32), None,
                                  now=1.0, valid_len=np.asarray([3]))
    assert accepted[:, 0].tolist() == [True, True, False, False]
    assert sched.slots[0] is None
    assert sched.finished[0].tokens.tolist() == [3, 4]


# ---------------------------------------------------------------------------
# prefetchers
# ---------------------------------------------------------------------------

def test_layer_ahead_prediction_expires_when_unconsumed():
    """A fully-masked step must EXPIRE the pending prediction: a later
    step would otherwise meter the stale warm as a fresh prefetch for
    routing that is a full step old."""
    pf = LayerAheadPrefetcher(num_layers=1, top_k=2)
    pf.observe(0, np.asarray([[1, 2]]))
    assert pf.predict(0) is not None
    pf.observe(0, np.asarray([[-1, -1]]))     # dead chunk: nothing routed
    assert pf.predict(0) is None
    # and the expired prediction was never scored as issued
    assert pf.stats.issued == 0


def test_lookahead_scores_rejected_positions_as_waste():
    pf = LookaheadPrefetcher(num_layers=1, top_k=2)
    trace = np.full((2, 1, 1, 2), -1, np.int64)
    trace[0, 0, 0] = [3, 5]
    trace[1, 0, 0] = [5, 6]
    pf.begin_round(trace)
    p0 = pf.predict(0, 0)
    assert sorted(p0.tolist()) == [3, 5]
    w = pf.score(p0, np.asarray([[3, 5]]), {3: 100, 5: 100})
    assert w == 0 and pf.stats.useful == 2
    p1 = pf.predict(1, 0)
    w = pf.score(p1, np.empty((0,), np.int64), {5: 100, 6: 100})
    assert w == 200                      # position rejected wholesale
    assert pf.bytes_wasted == 200 and pf.stats.wasted == 2


# ---------------------------------------------------------------------------
# KV rollback: bit-identical to never having drafted
# ---------------------------------------------------------------------------

def _rollback_cfg():
    return ModelConfig(
        name="rollback", family="dense", num_layers=1, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=8, d_ff=64, vocab_size=32,
        block_pattern=("global",), max_position=64)


@pytest.mark.parametrize("kv_bits", (16, 8))
def test_cache_rollback_contiguous_bit_identical(kv_bits):
    """Write a prefix, append a draft suffix, roll back: every plane
    (pos, k, v, int8 scales) must equal a cache that never saw the
    suffix."""
    cfg = _rollback_cfg()
    rng = np.random.default_rng(0)

    def kv(n):
        return (jnp.asarray(rng.standard_normal((1, n, 1, 8)), jnp.float32),
                jnp.asarray(rng.standard_normal((1, n, 1, 8)), jnp.float32))

    pk, pv = kv(5)
    dk, dv = kv(3)
    for row_new_len in (5, 6, 8):
        ref = init_attn_cache(1, 16, 1, 8, kv_bits=kv_bits)
        ref = update_attn_cache(ref, pk, pv, jnp.arange(5)[None])
        keep = row_new_len - 5
        if keep:
            ref = update_attn_cache(ref, dk[:, :keep], dv[:, :keep],
                                    jnp.arange(5, row_new_len)[None])
        tst = init_attn_cache(1, 16, 1, 8, kv_bits=kv_bits)
        tst = update_attn_cache(tst, pk, pv, jnp.arange(5)[None])
        tst = update_attn_cache(tst, dk, dv, jnp.arange(5, 8)[None])
        rolled = cache_rollback(
            cfg, {"segments": ((tst,),), "pos": jnp.asarray([8])},
            jnp.asarray([row_new_len]))
        out = rolled["segments"][0][0]
        assert int(rolled["pos"][0]) == row_new_len
        for plane in ref:
            assert np.array_equal(np.asarray(out[plane]),
                                  np.asarray(ref[plane])), (plane,
                                                            row_new_len)


def test_cache_rollback_paged_bit_identical():
    """Paged rollback masks the pool through the block table with
    per-page limits; rows roll back to different lengths, and the
    non-trash pages must match a pool that never saw the rejected
    positions (the trash page is scratch by contract)."""
    cfg = _rollback_cfg()
    rng = np.random.default_rng(1)
    ps = 4

    def kv(n):
        return (jnp.asarray(rng.standard_normal((2, n, 1, 8)), jnp.float32),
                jnp.asarray(rng.standard_normal((2, n, 1, 8)), jnp.float32))

    def fresh():
        c = init_paged_attn_cache(2, 5, ps, max_blocks=2, kv_heads=1,
                                  head_dim=8)
        return dict(c, block=jnp.asarray([[1, 2], [3, 4]], jnp.int32))

    pk, pv = kv(5)
    dk, dv = kv(3)
    pref_pos = jnp.broadcast_to(jnp.arange(5)[None], (2, 5))
    draft_pos = jnp.broadcast_to(jnp.arange(5, 8)[None], (2, 3))
    new_len = jnp.asarray([5, 7])

    tst = fresh()
    tst = dict(tst, **paged_update_attn_cache(tst, pk, pv, pref_pos))
    tst = dict(tst, **paged_update_attn_cache(tst, dk, dv, draft_pos))
    rolled = cache_rollback(
        cfg, {"segments": ((tst,),), "pos": jnp.asarray([8, 8])}, new_len)
    out = rolled["segments"][0][0]

    # reference: rejected draft positions parked on the trash page (an
    # out-of-range position routes there), i.e. never written to a page
    ref = fresh()
    ref = dict(ref, **paged_update_attn_cache(ref, pk, pv, pref_pos))
    keep_pos = jnp.where(draft_pos < new_len[:, None], draft_pos, -1)
    ref = dict(ref, **paged_update_attn_cache(ref, dk, dv, keep_pos))
    assert np.array_equal(np.asarray(out["block"]), np.asarray(ref["block"]))
    for plane in ("pos", "k", "v"):
        assert np.array_equal(np.asarray(out[plane])[1:],
                              np.asarray(ref[plane])[1:]), plane


# ---------------------------------------------------------------------------
# engine: greedy token identity + accepted-length edges + oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ("ref", "pallas_interpret"))
@pytest.mark.parametrize("spec_k", (1, 3))
def test_greedy_spec_is_token_identical(base, impl, spec_k):
    cfg, params = base
    stats = serve(build(cfg, params, impl), spec_k=spec_k)
    toks = [r.tokens.tolist() for r in stats.results]
    assert toks == plain_tokens(cfg, params, impl), (impl, spec_k)
    sp = stats.spec_report
    assert sp["spec_k"] == spec_k
    assert 0.0 <= sp["acceptance_rate"] <= 1.0
    assert 0.0 <= sp["lookahead_accuracy"] <= 1.0
    assert sp["draft_overhead_bytes"] >= 0
    # logprob convention matches the plain loop (raw log_softmax)
    for r in stats.results:
        assert np.isfinite(r.logprobs).all()


@pytest.mark.dist
@pytest.mark.parametrize("ep", (2,))
def test_greedy_spec_token_identity_expert_parallel(base, ep):
    cfg, params = base
    stats = serve(build(cfg, params, "ref", ep=ep), spec_k=2)
    toks = [r.tokens.tolist() for r in stats.results]
    assert toks == plain_tokens(cfg, params, "ref", ep=ep)


def test_greedy_spec_token_identity_paged(base):
    """Spec + paged cache: rejected-suffix writes overshoot through the
    block table onto the trash page and roll back; tokens must match the
    non-speculative paged serve exactly."""
    cfg, params = base
    stats = serve(build(cfg, params, "ref"), spec_k=3, page_size=4)
    toks = [r.tokens.tolist() for r in stats.results]
    ref = serve(build(cfg, params, "ref"), page_size=4)
    assert toks == [r.tokens.tolist() for r in ref.results]


def test_accepted_length_edge_all_rejected(base):
    """A drafter that always proposes a token the greedy stream never
    emits pins acceptance at exactly 0 (accepted length 1 per round —
    the bonus token only)."""
    cfg, params = base

    class NeverRight:
        def reset_slot(self, slot, toks):
            pass

        def observe(self, slot, toks):
            pass

        def propose_all(self, num_slots, k):
            return np.full((num_slots, k), self.token, np.int32)

    d = NeverRight()
    d.token = next(t for t in range(cfg.vocab_size)
                   if all(t not in row
                          for row in plain_tokens(cfg, params, "ref")))
    stats = serve(build(cfg, params, "ref"), spec_k=2, drafter=d)
    assert [r.tokens.tolist() for r in stats.results] == \
        plain_tokens(cfg, params, "ref")
    assert stats.spec_report["acceptance_rate"] == 0.0


def test_accepted_length_edge_all_accepted(base):
    """The windowed self-draft (window covering the whole stream) agrees
    with the target everywhere: acceptance is exactly 1 (accepted length
    k+1 per live round)."""
    cfg, params = base
    eng = build(cfg, params, "ref")
    d = DraftModelDrafter.self_draft(eng.cfg, eng.params, window=32,
                                     quantized=True, kernel_impl="ref")
    stats = serve(eng, spec_k=2, drafter=d)
    assert [r.tokens.tolist() for r in stats.results] == \
        plain_tokens(cfg, params, "ref")
    assert stats.spec_report["acceptance_rate"] == 1.0


def test_metered_bytes_oracle_with_spec(base):
    """PR 8's exactness invariant survives speculation: every metered
    wire byte (demand + lookahead warms, wasted ones included) is a real
    observed ring copy."""
    cfg, params = base
    eng = build(cfg, params, "ref", stream=True, cache_capacity=3)
    stats = serve(eng, spec_k=3)
    assert [r.tokens.tolist() for r in stats.results] == \
        plain_tokens(cfg, params, "ref")
    for li, s in enumerate(eng._stores):
        assert s.total_bytes == s.observed_copy_bytes, (
            li, s.total_bytes, s.observed_copy_bytes)
    rep = stats.offload_report
    assert rep["observed_copy_bytes"] == rep["total_bytes"] > 0
    sp = stats.spec_report
    assert sp["lookahead_prefetch_bytes"] >= sp["draft_overhead_bytes"] >= 0


def test_sampling_spec_serves_and_reports(base):
    """temperature > 0: rounds are distribution-preserving rather than
    token-identical — the run must complete with full-length results and
    finite logprobs, and the residual banning path must engage (the
    report sees rejections)."""
    cfg, params = base
    qp, cq, stacks = compress_moe_params(params, cfg)
    eng = ServeEngine(cq, qp, ServeConfig(temperature=0.8), quantized=True,
                      kernel_impl="ref")
    eng.attach_offload(stacks, policy="ours", cache_capacity=E)
    stats = serve(eng, spec_k=2, seed=7)
    assert sorted(r.uid for r in stats.results) == [0, 1, 2]
    for r in stats.results:
        assert r.tokens.shape[0] == MAX_NEW
        assert np.isfinite(r.logprobs).all()
    assert 0.0 <= stats.spec_report["acceptance_rate"] <= 1.0
