"""Training CLI: ``python -m repro.launch.train --arch <id> [...]``.

On real hardware this launches the pjit-sharded loop on the production
mesh; on CPU it runs the reduced config end-to-end (smoke-scale) with the
same code path — checkpointing, straggler monitor, resumption.
"""
import argparse

import jax

from ..config import TrainConfig
from ..registry import get_config
from ..train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (pod-scale) config instead of the "
                         "reduced CPU config")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full_config)
    print(f"training {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"on {jax.device_count()} device(s)")
    tcfg = TrainConfig(total_steps=args.steps, lr=args.lr,
                       warmup_steps=max(args.steps // 10, 1),
                       checkpoint_every=max(args.steps // 4, 1),
                       loss_chunk=0)
    res = train(cfg, tcfg, checkpoint_dir=args.ckpt, log_every=10,
                batch_shape=(args.batch, args.seq))
    print(f"done; final loss {res.history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
