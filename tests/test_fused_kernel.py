"""Fused decode kernel validation (the tentpole kernel).

Parity matrix: the single fused ``pallas_call`` (bitplane unpack + HQQ
dequant at true per-expert width + rank-capped compensator GEMM +
gate-weighted combine), executed by the Pallas interpreter on CPU, must
bit-match the pure-jnp oracle across

    bits x rank_cap {0, half, full} x comp-mask {none, partial, all}
    x gates {absent, present} x heterogeneous expert_bits,

and the traced (top_n, rank_cap) plan row must never trigger a
recompile (the compile-count pin).  A compiled-Mosaic parity cell runs
when a TPU is attached; CI covers the interpreter path.
"""
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoEConfig, QuantConfig
from repro.core import compress_ffn_weights
from repro.core.pipeline import compress_expert_stack
from repro.kernels import ops
from repro.models.moe import moe_apply

TOL = dict(rtol=1e-4, atol=1e-3)


def _stack(bits=2, e=4, k=128, n=128, rank_budget=8, seed=0,
           expert_bits=None):
    rng = np.random.default_rng(seed)
    qcfg = QuantConfig(enabled=True, bits=bits, group_size=64,
                       rank_budget=rank_budget, top_n_restore=1,
                       hqq_iters=2)
    w = jnp.asarray(rng.standard_normal((e, k, n)), jnp.float32) * 0.05
    stack, _ = compress_expert_stack(
        w, qcfg, bits=None if expert_bits is None
        else np.asarray(expert_bits))
    return stack, w


def _inputs(e, c, k, mask_mode, gated, seed=1):
    rng = np.random.default_rng(seed)
    xe = jnp.asarray(rng.standard_normal((e, c, k)), jnp.float32)
    me = {"none": jnp.zeros((e, c), jnp.float32),
          "partial": jnp.asarray((rng.random((e, c)) < 0.5), jnp.float32),
          "all": jnp.ones((e, c), jnp.float32)}[mask_mode]
    ge = (jnp.asarray(rng.random((e, c)), jnp.float32) if gated else None)
    return xe, me, ge


def _parity(stack, xe, me, ge, rank_cap):
    y_ref = ops.fused_expert_matmul(xe, stack, me, gates=ge,
                                    rank_cap=rank_cap, impl="ref",
                                    out_dtype=jnp.float32)
    y_pl = ops.fused_expert_matmul(xe, stack, me, gates=ge,
                                   rank_cap=rank_cap,
                                   impl="pallas_interpret",
                                   out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref), **TOL)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("rank_mode", ["zero", "half", "full"])
def test_fused_parity_bits_x_rank(bits, rank_mode):
    stack, _ = _stack(bits=bits)
    xe, me, ge = _inputs(4, 8, 128, "partial", gated=True, seed=bits)
    cap = {"zero": jnp.int32(0),
           "half": jnp.int32(stack.pad_rank // 2),
           "full": None}[rank_mode]
    _parity(stack, xe, me, ge, cap)


@pytest.mark.parametrize("mask_mode", ["none", "partial", "all"])
@pytest.mark.parametrize("gated", [False, True])
def test_fused_parity_topn_x_gates(mask_mode, gated):
    """mask 'none'/'partial'/'all' are the (E, C) images of plan
    top_n = 0 / 0<n<k / k; gates off covers backends that leave the
    combine to the caller."""
    stack, _ = _stack(bits=2)
    xe, me, ge = _inputs(4, 8, 128, mask_mode, gated, seed=7)
    _parity(stack, xe, me, ge, jnp.int32(stack.pad_rank // 2))


def test_fused_parity_heterogeneous_expert_bits():
    """Sub-width experts in a shared max-width container must dequantize
    at their TRUE width inside the kernel (expert_bits input)."""
    stack, _ = _stack(bits=3, expert_bits=[2, 3, 2, 3])
    assert stack.expert_bits == (2, 3, 2, 3) and stack.bits == 3
    xe, me, ge = _inputs(4, 8, 128, "partial", gated=True, seed=11)
    _parity(stack, xe, me, ge, None)


def test_fused_parity_ragged_capacity():
    """C not divisible by bm exercises the pad/slice wrapper."""
    stack, _ = _stack(bits=4)
    xe, me, ge = _inputs(4, 5, 128, "partial", gated=True, seed=13)
    _parity(stack, xe, me, ge, jnp.int32(3))


def test_fused_matches_unfused_sequence():
    """The fused kernel computes exactly what the unfused op-sequence
    (compensated matmul stack, then gate multiply) computes."""
    stack, _ = _stack(bits=2)
    xe, me, ge = _inputs(4, 8, 128, "partial", gated=True, seed=17)
    cap = jnp.int32(stack.pad_rank // 2)
    y_seq = ops.compensated_matmul_stack(xe, stack, me, impl="ref",
                                         out_dtype=jnp.float32,
                                         rank_cap=cap) * ge[..., None]
    y_fused = ops.fused_expert_matmul(xe, stack, me, gates=ge,
                                      rank_cap=cap,
                                      impl="pallas_interpret",
                                      out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_seq),
                               **TOL)


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled Mosaic parity needs a TPU")
def test_fused_parity_compiled_mosaic():
    stack, _ = _stack(bits=2)
    xe, me, ge = _inputs(4, 8, 128, "partial", gated=True, seed=23)
    y_ref = ops.fused_expert_matmul(xe, stack, me, gates=ge,
                                    rank_cap=jnp.int32(4), impl="ref",
                                    out_dtype=jnp.float32)
    y_tpu = ops.fused_expert_matmul(xe, stack, me, gates=ge,
                                    rank_cap=jnp.int32(4), impl="pallas",
                                    out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_tpu), np.asarray(y_ref), **TOL)


def test_fused_fuzz_hypothesis():
    """Randomized parity cells (shapes, seeds, caps) when hypothesis is
    installed; the parametrized matrix above is the CI floor."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(bits=st.sampled_from([2, 3, 4, 8]),
           c=st.integers(min_value=1, max_value=9),
           cap=st.integers(min_value=0, max_value=8),
           gated=st.booleans(), seed=st.integers(0, 2 ** 16))
    def prop(bits, c, cap, gated, seed):
        stack, _ = _stack(bits=bits, seed=seed % 7)
        xe, me, ge = _inputs(4, c, 128, "partial", gated, seed=seed)
        _parity(stack, xe, me, ge, jnp.int32(cap))

    prop()


# ---------------------------------------------------------------------------
# compile-count pin: the controller plan is data, never a shape
# ---------------------------------------------------------------------------

def test_rank_cap_change_does_not_recompile():
    stack, _ = _stack(bits=2)
    xe, me, ge = _inputs(4, 8, 128, "partial", gated=True, seed=29)

    f = jax.jit(lambda cap: ops.fused_expert_matmul(
        xe, stack, me, gates=ge, rank_cap=cap, impl="pallas_interpret",
        out_dtype=jnp.float32))
    f(jnp.int32(0)).block_until_ready()
    logger = logging.getLogger("jax._src.dispatch")
    seen = []
    handler = logging.Handler()
    handler.emit = lambda record: seen.append(record.getMessage())
    logger.addHandler(handler)
    try:
        with jax.log_compiles():
            for cap in (1, 3, stack.pad_rank):
                f(jnp.int32(cap)).block_until_ready()
    finally:
        logger.removeHandler(handler)
    compiles = [m for m in seen if "Compiling" in m or "compil" in m]
    assert not compiles, f"plan change recompiled: {compiles}"
    assert f._cache_size() == 1


def test_plan_row_change_does_not_recompile_moe_apply():
    """End to end through the MoE layer: differing (top_n, rank_cap)
    plan rows reuse one compiled executable of the fused serving path."""
    rng = np.random.default_rng(0)
    e, d, fe = 4, 64, 128
    qcfg = QuantConfig(enabled=True, bits=2, rank_budget=8,
                       top_n_restore=1, hqq_iters=2)
    mcfg = MoEConfig(num_experts=e, top_k=2, d_expert=fe, quant=qcfg)
    w1 = jnp.asarray(rng.standard_normal((e, d, fe)), jnp.float32) * 0.05
    w3 = jnp.asarray(rng.standard_normal((e, d, fe)), jnp.float32) * 0.05
    w2 = jnp.asarray(rng.standard_normal((e, fe, d)), jnp.float32) * 0.05
    stacks, _ = compress_ffn_weights(w1, w2, w3, qcfg)
    params = {"router": jnp.asarray(rng.standard_normal((d, e)),
                                    jnp.float32), "stacks": stacks}
    x2 = jnp.asarray(rng.standard_normal((16, d)), jnp.float32)

    f = jax.jit(lambda x2, plan: moe_apply(
        x2, params, mcfg, quantized=True, impl="pallas_interpret",
        plan=plan)[0])
    outs = [f(x2, jnp.asarray(row, jnp.int32)).block_until_ready()
            for row in ((0, 0), (1, 4), (2, 8))]
    assert f._cache_size() == 1
    # and the plan genuinely changes the computation (not a dead input)
    assert not np.allclose(np.asarray(outs[0]), np.asarray(outs[2]),
                           atol=1e-6)
