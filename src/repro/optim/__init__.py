"""Sharded optimization: AdamW + schedules + clipping + grad compression."""
from .adamw import (OptState, adamw_init, adamw_update, clip_by_global_norm,
                    global_norm, warmup_cosine)
