"""Atomic, keep-k, mesh-agnostic checkpointing."""
from .manager import CheckpointManager
