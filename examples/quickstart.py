"""Quickstart: the paper's pipeline end-to-end on a miniature MoE.

1. build a Mixtral-shaped tiny MoE and fake-pretrain it a few steps;
2. offline-compress its experts (HQQ int2 + kurtosis-ranked SVD
   compensators — paper §3.1);
3. serve with router-guided top-n restoration (paper §3.2);
4. compare held-out NLL: fp32 vs uniform-int2 vs BEAM-LRC, and report the
   per-token wire bytes each policy would move under offloading.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, MoEConfig, QuantConfig, TrainConfig
from repro.core import compress_ffn_weights, restoration_wire_bytes
from repro.models import ExecContext, forward, init_params
from repro.models.transformer import unstack_params
from repro.serve import router_trace
from repro.train import train


def main():
    cfg = ModelConfig(
        name="quickstart-moe", family="moe", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=0, vocab_size=512,
        block_pattern=("global",), max_position=2048,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=256,
                      quant=QuantConfig(enabled=True, bits=2,
                                        rank_budget=32, top_n_restore=1)))

    print("== 1. pretrain a tiny MoE on the synthetic Zipf-Markov LM ==")
    tcfg = TrainConfig(total_steps=60, lr=2e-3, warmup_steps=10,
                       checkpoint_every=10 ** 9, loss_chunk=0)
    res = train(cfg, tcfg, log_every=20, batch_shape=(8, 128))
    params = res.state.params
    print(f"   final loss: {res.history[-1]['loss']:.3f}")

    print("== 2. offline compression (HQQ int2 + kurtosis-guided SVD) ==")
    qcfg = cfg.moe.quant
    up = unstack_params(params, cfg)
    cfg_q = dataclasses.replace(cfg, force_unroll_plan=True)
    segs = []
    for seg in up["segments"]:
        p = dict(seg[0])
        mp = dict(p["moe"])
        stacks, rep = compress_ffn_weights(mp["w1"], mp["w2"], mp["w3"], qcfg)
        print(f"   layer: kurtosis={np.round(rep['w1']['kurtosis'], 1)}")
        print(f"          ranks   ={rep['w1']['ranks']}")
        print(f"          rel_err quant->comp: "
              f"{rep['w1']['rel_err_quant'].mean():.3f} -> "
              f"{rep['w1']['rel_err_comp'].mean():.3f}")
        mp["stacks"] = stacks
        for k in ("w1", "w2", "w3"):
            mp.pop(k)
        p["moe"] = mp
        segs.append((p,))
    qparams = dict(up)
    qparams["segments"] = tuple(segs)

    print("== 3. serve: fp32 vs uniform-int2 vs router-guided restoration ==")
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, 512, (4, 64)), jnp.int32)

    def nll(p, c, quantized):
        ctx = ExecContext(mode="train", quantized=quantized,
                          exact_capacity=True)
        out = forward(p, tokens, c, ctx)
        lg = out.logits[:, :-1].astype(jnp.float32)
        t = tokens[:, 1:]
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        sel = jnp.take_along_axis(lg, t[..., None], axis=-1)[..., 0]
        return float(jnp.mean(lse - sel))

    print(f"   fp32 NLL:               {nll(params, cfg, False):.4f}")
    print(f"   BEAM-LRC int2 (top-1):  {nll(qparams, cfg_q, True):.4f}")
    qcfg0 = dataclasses.replace(qcfg, top_n_restore=0)
    cfg_q0 = dataclasses.replace(
        cfg_q, moe=dataclasses.replace(cfg_q.moe, quant=qcfg0))
    print(f"   uniform int2 (no comp): {nll(qparams, cfg_q0, True):.4f}")

    print("== 4. offload wire-bytes per MoE invocation ==")
    trace = router_trace(cfg, params, np.asarray(tokens[:1, :16]))
    stacks0 = segs[0][0]["moe"]["stacks"]
    acct = restoration_wire_bytes(stacks0, trace[:, 0, :], n=1,
                                  top_k=cfg.moe.top_k)
    print(f"   fp16 policy:  {acct['fp16'] / 2**20:.2f} MiB")
    print(f"   uniform int2: {acct['quant'] / 2**20:.2f} MiB")
    print(f"   BEAM-LRC:     {acct['ours'] / 2**20:.2f} MiB "
          f"({acct['restored']} of {acct['activated']} experts restored)")


if __name__ == "__main__":
    main()
