"""llama4-scout-17b-a16e [moe]: 48L d=5120 40H (GQA kv=8) vocab=202048,
16 experts top-1 + 1 shared expert (d=8192), early fusion (frontend stub).
[hf:meta-llama/Llama-4-Scout-17B-16E]

Paper technique: top-k=1 -> n=1 (restore the routed expert); the shared
expert is statically compensated.  Skewed-router regime = Mixtral case."""
from ..config import ModelConfig, MoEConfig, QuantConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=8192, vocab_size=202_048,
        block_pattern=("global",),
        rope_theta=500_000.0, act="silu", tie_embeddings=False,
        moe=MoEConfig(num_experts=16, top_k=1, d_expert=8192,
                      num_shared_experts=1, d_shared=8192,
                      router_norm_topk=False,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=32,
                                        top_n_restore=1)),
        max_position=131_072,
    )
