"""Logical-axis sharding: path rules -> PartitionSpecs with divisibility
fallback.

Parameters, optimizer state, caches and activations are annotated with
*logical* axes via path-suffix regex rules; a per-run ``ParallelConfig``
maps logical names to mesh axes.  A mapping that does not divide the
dimension falls back to successively shorter mesh-axis prefixes and
finally to replication — e.g. gemma3-1b's 4 query heads on a 16-way
``model`` axis end up replicated while its d_ff=6912 shards 16-way.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map  # noqa: F401  (re-export for EP/collectives)
from ..config import ParallelConfig

# (path-suffix regex, logical axes aligned to the TRAILING dims)
PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embed/tok$",                ("vocab", "embed")),
    (r"head/w$",                   ("embed", "vocab")),
    (r"attn/wq$",                  ("embed", "heads", None)),
    (r"attn/(wk|wv)$",             ("embed", "kv_heads", None)),
    (r"attn/wo$",                  ("heads", None, "embed")),
    (r"attn/(bq|bk|bv)$",          (None, None)),
    (r"cross_wq$",                 ("embed", "heads", None)),
    (r"cross_(wk|wv)$",            (None, "heads", None)),
    (r"cross_wo$",                 ("heads", None, "embed")),
    (r"ffn/(w1|w3)$",              ("embed", "mlp")),
    (r"ffn/w2$",                   ("mlp", "embed")),
    (r"shared/(w1|w3)$",           ("embed", "mlp")),
    (r"shared/w2$",                ("mlp", "embed")),
    (r"moe/router$",               ("embed", None)),
    (r"moe/(w1|w3)$",              ("expert", "embed", "expert_mlp")),
    (r"moe/w2$",                   ("expert", "expert_mlp", "embed")),
    # compressed expert stacks (serving): shard by expert, keep factors local
    (r"moe/stacks/\w+/(planes/\d+|scale|zero|u|v|u_scale|v_scale)$",
     ("expert", None, None)),
    (r"ffn/stacks/(w1|w3)/(planes/\d+|scale|zero)$", (None, None, "mlp")),
    (r"ffn/stacks/w2/(planes/\d+|scale|zero)$",      (None, "mlp_in", "embed")),
    (r"ffn/stacks/\w+/(u|v|u_scale|v_scale)$",       (None, None, None)),
    (r"rglru/(wx|wgate)$",         ("embed", "lru")),
    (r"rglru/wo$",                 ("lru", "embed")),
    (r"rglru/(rg_wa|rg_wx)$",      (None, "lru")),
    (r"rglru/(conv_w)$",           (None, "lru")),
    (r"rglru/(conv_b|rg_ba|rg_bx|lam)$", ("lru",)),
    (r"mlstm/w_up$",               ("embed", "mlp")),
    (r"mlstm/(wq|wk|wv)$",         ("mlp", None, None)),
    (r"mlstm/w_if$",               ("mlp", None)),
    (r"mlstm/w_down$",             ("mlp", "embed")),
    (r"slstm/w_zifo$",             ("embed", None, None, None)),
    (r"(norm|scale|bias|b_if|b_zifo|lam)\w*$", None),  # replicate small
)

CACHE_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"/(k|v)$",        ("batch", "kv_seq", "kv_heads", None)),
    (r"/(k_scale|v_scale)$", ("batch", "kv_seq", "kv_heads")),
    (r"/pos$",          ("batch", "kv_seq")),
    (r"/(cross_k|cross_v)$", ("batch", None, "heads", None)),
    (r"/h$",            ("batch", "lru")),
    (r"/conv$",         ("batch", None, "lru")),
    (r"/c$",            ("batch", None, None, None)),
    (r"/(n|m)$",        ("batch", None, None)),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def logical_axes_for(path: str, ndim: int, rules) -> Tuple[Optional[str], ...]:
    """Match path suffix against rules; align to trailing dims."""
    for pat, axes in rules:
        if re.search(pat, path):
            if axes is None:
                return (None,) * ndim
            axes = tuple(axes)
            if len(axes) > ndim:  # unstacked (repeat-1) leaf
                axes = axes[len(axes) - ndim:]
            return (None,) * (ndim - len(axes)) + axes
    return (None,) * ndim


def mesh_spec(mesh: Mesh, logical: Sequence[Optional[str]],
              shape: Sequence[int], pcfg: ParallelConfig) -> P:
    """Logical axes -> PartitionSpec with divisibility fallback."""
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        entry: Any = None
        if name is not None:
            axes = tuple(a for a in pcfg.rule_for(name)
                         if a in mesh.shape and a not in used)
            # longest divisible prefix
            while axes:
                size = int(np.prod([mesh.shape[a] for a in axes]))
                if dim % size == 0:
                    break
                axes = axes[:-1]
            if axes:
                entry = axes if len(axes) > 1 else axes[0]
                used.update(axes)
        out.append(entry)
    # normalize: trailing Nones are semantically replicated but make
    # PartitionSpec(None, ...) != PartitionSpec() — distinct jit cache
    # keys, which would force a spurious first-chunk recompile when a
    # placed input meets a constraint-normalized output sharding
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(mesh: Mesh, tree, pcfg: ParallelConfig, rules=PARAM_RULES):
    """NamedSharding tree for an (abstract) pytree by path rules."""
    def one(path, leaf):
        p = _path_str(path)
        logical = logical_axes_for(p, len(leaf.shape), rules)
        return NamedSharding(mesh, mesh_spec(mesh, logical, leaf.shape, pcfg))

    return jax.tree_util.tree_map_with_path(one, tree)


def tree_constraint(mesh: Optional[Mesh], tree, pcfg: ParallelConfig,
                    rules=PARAM_RULES):
    """``with_sharding_constraint`` every leaf of a (traced) pytree by the
    same path rules ``tree_shardings`` uses for placement.

    Applied by the serve engine to the cache/logits *outputs* of its
    jitted entry points: pinning outputs to the same rule-derived
    shardings the inputs were placed with keeps the chunked decode loop's
    call signature at a fixpoint — one compile per shape bucket instead
    of a sharding-propagation churn across the first chunks."""
    if mesh is None:
        return tree

    def one(path, leaf):
        logical = logical_axes_for(_path_str(path), leaf.ndim, rules)
        spec = mesh_spec(mesh, logical, leaf.shape, pcfg)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(one, tree)


def constraint_fn(mesh: Optional[Mesh], pcfg: ParallelConfig):
    """ExecContext.constrain: logical activation axes -> constraint."""
    if mesh is None:
        return lambda x, axes: x

    def constrain(x, axes):
        spec = mesh_spec(mesh, axes, x.shape, pcfg)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain
