"""Roofline-term derivation from the compiled dry-run artifact.

Per (arch, shape, mesh) cell:

    compute    = FLOPs_dev / peak_FLOPs_chip
    memory     = bytes_dev / HBM_bw_chip
    collective = wire_bytes_dev / ICI_bw_chip

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device, post-SPMD).
Collective wire bytes are NOT in cost_analysis: we parse the optimized HLO
(``compiled.as_text()``) and sum shape bytes over every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, applying the
standard ring-transfer factors (all-reduce 2(n-1)/n, gather/scatter
(n-1)/n, permute 1).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict, Optional

# TPU v5e-class hardware constants (per chip), per the assignment
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (aggregate assumption documented)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_COLL_RE = re.compile(
    r"=\s+\((.+?)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_wire_bytes(hlo_text: str, default_group: int = 16,
                          top: Optional[list] = None) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (ring-transfer factors).

    ``top`` (optional list) collects (wire_bytes, kind, shape) per op for
    bottleneck diagnosis."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        if "-start(" not in line and not re.search(
                r"\s(all-reduce|all-gather|reduce-scatter|all-to-all|"
                r"collective-permute)\(", line):
            if not any(k in line for k in
                       ("all-reduce(", "all-gather(", "reduce-scatter(",
                        "all-to-all(", "collective-permute(")):
                continue
        m = _COLL_RE.search(line)
        shapes = []
        kind = None
        if m:
            kind = m.group(3)
            shapes = [(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if not mt:
                continue
            kind = mt.group(2)
            shapes = _SHAPE_RE.findall(mt.group(1))
        n = _group_size(line, default_group)
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / max(n, 1) * nbytes
        elif kind in ("all-gather", "all-to-all"):
            wire = (n - 1) / max(n, 1) * nbytes
        elif kind == "reduce-scatter":
            wire = (n - 1) / max(n, 1) * nbytes * n  # operand = result * n
        else:  # collective-permute
            wire = float(nbytes)
        out[kind] = out.get(kind, 0.0) + wire
        out["total"] = out.get("total", 0.0) + wire
        if top is not None:
            top.append((wire, kind,
                        ";".join(f"{d}[{s}]" for d, s in shapes)))
    return out


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops_dev: float
    bytes_dev: float
    wire_bytes_dev: float
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops_global: float
    useful_ratio: float          # MODEL_FLOPS / global HLO FLOPs
    mem_per_device: Optional[float] = None
    note: str = ""

    def to_dict(self):
        return asdict(self)


def derive_terms(arch: str, shape_name: str, mesh_name: str, *,
                 cost: Dict, hlo_text: str, n_devices: int,
                 model_flops_global: float,
                 mem_per_device: Optional[float] = None,
                 default_group: int = 16,
                 wire_override: Optional[float] = None) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    wire = (wire_override if wire_override is not None else
            collective_wire_bytes(hlo_text, default_group).get("total", 0.0))
    t_c = flops / PEAK_FLOPS
    t_m = nbytes / HBM_BW
    t_x = wire / ICI_BW
    dominant = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                   key=lambda kv: kv[1])[0]
    hlo_global = flops * n_devices
    ratio = model_flops_global / hlo_global if hlo_global else 0.0
    return RooflineTerms(arch, shape_name, mesh_name, flops, nbytes, wire,
                         t_c, t_m, t_x, dominant, model_flops_global, ratio,
                         mem_per_device)


# ---------------------------------------------------------------------------
# kernel tile tables (seed candidates for kernels/autotune.py)
# ---------------------------------------------------------------------------

VMEM_BYTES = 16 * 2 ** 20    # per-core VMEM (v5e-class); tiles must fit
VMEM_BUDGET = 0.7            # leave headroom for double buffering

# MXU/VPU-aligned tile menus: sublane multiples for the token dim (decode
# blocks are tiny), lane multiples for N, PACK_BLOCK multiples for K
BM_CANDIDATES = (8, 16, 32, 64, 128)
BN_CANDIDATES = (128, 256, 512)
BK_CANDIDATES = (128, 256, 512, 1024)


def fused_tile_vmem_bytes(bm: int, bn: int, bk: int, bits: int,
                          group_size: int, rank: int) -> int:
    """Resident VMEM footprint of one fused-kernel grid step: x tile,
    packed planes, scale/zero, compensator factors, f32 accumulator and
    rank-space scratch (see ``kernels/quant_matmul.py::_fused_kernel``)."""
    plane_b = _packed_nbytes(bits, bk, bn)
    scales_b = 2 * (bk // group_size) * bn * 4
    factors_b = bk * rank + rank * bn + rank * 4 + rank * 4
    return (bm * bk * 4 + plane_b + scales_b + factors_b
            + bm * bn * 4 + bm * rank * 4 + bm * bn * 4)


def _plane_widths(bits: int):
    from ..core.quantize import PLANES
    return tuple(p for p, _ in PLANES[bits])


def _packed_nbytes(bits: int, k: int, n: int) -> int:
    from ..core.quantize import packed_nbytes
    return packed_nbytes(bits, k, n)


def fused_tile_candidates(m: int, k: int, n: int, bits: int,
                          group_size: int, rank: int):
    """Roofline-derived (bm, bn, bk) candidates for the fused decode
    kernel, best-first.

    Ranking: prefer the largest K tile (amortizes the sequential-grid
    revisits of x), then the largest N tile that keeps the step under
    the VMEM budget; bm clamps to the token block (decode C is tiny, so
    the small-m preset bm=8 dominates serving shapes).  This static
    table seeds the autotuner; on-device timing can reorder it."""
    out = []
    for bm in BM_CANDIDATES:
        if bm > max(8, m):
            continue
        for bn in BN_CANDIDATES:
            if bn > n:
                continue
            for bk in BK_CANDIDATES:
                if bk > k or bk % group_size or bk % 64:
                    continue
                if (fused_tile_vmem_bytes(bm, bn, bk, bits, group_size, rank)
                        > VMEM_BYTES * VMEM_BUDGET):
                    continue
                out.append((bm, bn, bk))
    # best-first: big K, then big N, then the smallest viable bm
    out.sort(key=lambda t: (-t[2], -t[1], t[0]))
    return out


def fused_hbm_bytes(e: int, m: int, k: int, n: int, bits: int,
                    group_size: int, rank: int, bm: int, bn: int,
                    bk: int) -> int:
    """Analytic HBM traffic of one fused-kernel invocation (per expert
    stack), tile-multiplicity aware.

    The grid is (E, m/bm, n/bn, k/bk) with K innermost-sequential;
    every operand block is fetched once per grid step that maps to it
    (conservative: Mosaic elides refetches of blocks whose index map is
    constant across consecutive steps, so this is an upper bound):

    - x:        (bm, bk) per (i, j, kk)   -> m*k*4      x  n/bn
    - planes:   packed bits per (j, kk)   -> packed(k,n) x  m/bm
    - scale/zero: f32 per (j, kk)         -> 2*(k/g)*n*4 x m/bm
    - U (int8): (bk, r) per (i, j, kk)    -> k*r        x (m/bm)(n/bn)
    - V (int8): (r, bn) per (i, j)        -> r*n        x  m/bm
    - me/gates: (bm,) per (i, j)          -> m*4        x  n/bn (x2)
    - out:      written once              -> m*n*4

    The unfused op-sequence additionally round-trips the dequantized
    weights (k*n*4), the rank-space activation, and the ungated output
    through HBM — ``benchmarks/bench_kernels.py`` measures that side
    from ``cost_analysis`` of the compiled XLA sequence and reports the
    reduction against this bound.
    """
    from ..core.quantize import packed_nbytes
    mi, nj = -(-m // bm), -(-n // bn)
    planes_b = packed_nbytes(bits, k, n)
    scales_b = 2 * (k // group_size) * n * 4
    x_b = m * k * 4 * nj
    u_b = k * rank * mi * nj
    v_b = rank * n * mi
    f_scales_b = rank * 4 * 2 * mi * nj
    masks_b = 2 * m * 4 * nj
    out_b = m * n * 4
    per_expert = (x_b + planes_b * mi + scales_b * mi + u_b + v_b
                  + f_scales_b + masks_b + out_b)
    return e * per_expert


def model_flops(cfg, shape, active_params: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (inference)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active_params * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active_params * tokens
    tokens = shape.global_batch  # one decode step
    return 2.0 * active_params * tokens
