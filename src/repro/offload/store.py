"""Expert store + device cache for offloaded serving (paper §2.1, §4.3).

``ExpertStore`` keeps compressed experts in *host* memory (numpy) and
fetches them on demand; ``ExpertCache`` is the device-resident LRU that
Mixtral-Offloading/HOBBIT-style systems maintain.  Every fetch is metered
in bytes so benchmarks can report exact PCIe/host-link traffic for
fp16 / uniform-quant / BEAM-LRC policies.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.pipeline import CompressedExpertStack


@dataclasses.dataclass
class FetchStats:
    bytes_moved: int = 0
    fetches: int = 0
    hits: int = 0
    misses: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class ExpertCache:
    """Per-layer LRU over expert ids with byte-metered misses."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._lru: "collections.OrderedDict[int, int]" = collections.OrderedDict()
        self.stats = FetchStats()

    def access(self, expert: int, nbytes: int) -> bool:
        """True on hit; on miss, meters ``nbytes`` and inserts."""
        if expert in self._lru:
            self._lru.move_to_end(expert)
            self.stats.hits += 1
            return True
        self.stats.misses += 1
        self.stats.fetches += 1
        self.stats.bytes_moved += nbytes
        self._lru[expert] = nbytes
        if len(self._lru) > self.capacity:
            self._lru.popitem(last=False)
        return False


class ExpertStore:
    """Host-side store of one MoE layer's compressed projections.

    ``fetch_policy``:
      'fp16'   — move full-precision experts (Mixtral-Offloading baseline)
      'quant'  — uniform low-bit, no compensators (HQQ/GPTQ baseline)
      'ours'   — low-bit + compensators for the top-n experts (BEAM-LRC)
    """

    def __init__(self, stacks: Dict[str, CompressedExpertStack],
                 cache_capacity: int = 4):
        self.stacks = stacks
        self.num_experts = next(iter(stacks.values())).scale.shape[0]
        self.cache = ExpertCache(cache_capacity)
        self.comp_bytes_moved = 0

    def expert_bytes(self, e: int, policy: str) -> int:
        if policy == "fp16":
            return sum(s.fp16_wire_bytes for s in self.stacks.values())
        return sum(s.expert_wire_bytes(e, compensated=False)
                   for s in self.stacks.values())

    def compensator_bytes(self, e: int) -> int:
        return sum(int(s.ranks[e] * (s.shape[1] + s.shape[2])
                       * s.factor_bits / 8) + 4 * s.ranks[e]
                   for s in self.stacks.values())

    def access_token(self, topk: np.ndarray, top_n: int, policy: str
                     ) -> int:
        """Meter one token's expert fetches; returns bytes moved."""
        before = self.cache.stats.bytes_moved + self.comp_bytes_moved
        for rank, e in enumerate(topk):
            e = int(e)
            self.cache.access(e, self.expert_bytes(e, policy))
            if policy == "ours" and rank < top_n:
                # compensators ride along only for the top-n experts
                self.comp_bytes_moved += self.compensator_bytes(e)
        return (self.cache.stats.bytes_moved + self.comp_bytes_moved
                - before)

    @property
    def total_bytes(self) -> int:
        return self.cache.stats.bytes_moved + self.comp_bytes_moved


def meter_decode_trace(stores: List[ExpertStore], trace: np.ndarray, *,
                       policy: str = "ours", top_n: int = 1,
                       prefetcher=None) -> Dict:
    """Replay a live decode trace through per-layer stores.

    ``trace``: (steps, moe_layers, B, k) routed expert ids, exactly the
    ``GenerationResult.router_trace`` the serve engine's jitted decode
    loop emits — so the wire bytes / hit rates below are measured from
    real serving decisions, not the synthetic simulator.

    The stores keep their cumulative lifetime stats (and cache state warm
    across calls); the returned report covers THIS replay only, so
    repeated ``generate`` calls don't double-count earlier traffic.

    Returns a report dict: bytes/token, cache hit rate, prefetch accuracy.
    """
    trace = np.asarray(trace)
    steps, layers, b, _ = trace.shape
    if layers != len(stores):
        raise ValueError(f"trace has {layers} MoE layers but "
                         f"{len(stores)} stores attached")
    bytes0 = sum(s.total_bytes for s in stores)
    hits0 = sum(s.cache.stats.hits for s in stores)
    misses0 = sum(s.cache.stats.misses for s in stores)
    pf0 = (prefetcher.stats.issued, prefetcher.stats.useful) \
        if prefetcher is not None else (0, 0)
    for t in range(steps):
        for l in range(layers):
            experts = trace[t, l]                     # (B, k)
            if prefetcher is not None:
                prefetcher.observe(l, experts)  # observe flattens + uniques
            for row in experts:
                stores[l].access_token(row, top_n=top_n, policy=policy)
    total = sum(s.total_bytes for s in stores) - bytes0
    hits = sum(s.cache.stats.hits for s in stores) - hits0
    misses = sum(s.cache.stats.misses for s in stores) - misses0
    issued = (prefetcher.stats.issued - pf0[0]) if prefetcher else 0
    useful = (prefetcher.stats.useful - pf0[1]) if prefetcher else 0
    tokens = steps * b
    return {
        "policy": policy,
        "tokens": tokens,
        "total_bytes": int(total),
        "bytes_per_token": total / max(tokens, 1),
        "hit_rate": hits / max(hits + misses, 1),
        "prefetch_accuracy": (useful / max(issued, 1)
                              if prefetcher is not None else None),
    }
