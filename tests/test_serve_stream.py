"""Streaming decode runtime: jitted scan loop, first-class router trace,
live offload metering — plus the regression pinning the trace-returning
forward against the old eager ``moe.route`` hook."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoEConfig, QuantConfig, ServeConfig
from repro.core import compress_ffn_weights
from repro.launch.steps import make_context
from repro.models import forward, init_params
from repro.models.transformer import unstack_params
from repro.serve import ServeEngine, router_trace


def moe_cfg(layers=2):
    return ModelConfig(
        name="tiny-moe", family="moe", num_layers=layers, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0, vocab_size=128,
        block_pattern=("global",), max_position=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=16,
                                        top_n_restore=1, hqq_iters=3)))


def _hooked_trace(cfg, params, tokens):
    """The OLD router-trace implementation (monkey-patch ``moe.route``
    under ``disable_jit``), kept inline as the regression oracle for the
    first-class trace output that replaced it."""
    import repro.models.moe as moe_mod
    from repro.models import model as lm
    traces = []
    orig = moe_mod.route

    def hooked(x2, w, mcfg):
        info = orig(x2, w, mcfg)
        traces.append(np.asarray(info.topk_idx))
        return info

    moe_mod.route = hooked
    try:
        with jax.disable_jit():
            ctx = make_context(cfg, "train", exact_capacity=True)
            lm.forward(params, jnp.asarray(tokens), cfg, ctx)
    finally:
        moe_mod.route = orig
    return np.stack(traces, axis=1)          # (T, layers, k)


def test_trace_matches_old_hook():
    """First-class (jitted) trace must be identical to the old hook."""
    cfg = moe_cfg()
    params = init_params(jax.random.key(2), cfg, jnp.float32)
    tokens = np.random.default_rng(0).integers(0, 128, (2, 8),
                                               dtype=np.int32)
    new = router_trace(cfg, params, tokens)
    old = _hooked_trace(cfg, params, tokens)
    assert new.shape == old.shape == (16, 2, 2)
    np.testing.assert_array_equal(new, old)


def test_trace_scanned_segments_layer_order():
    """Scanned (repeat > 1) segments must unstack into global layer order:
    per-layer traces differ, and each must match its unrolled twin."""
    cfg = moe_cfg(layers=4)
    params = init_params(jax.random.key(3), cfg, jnp.float32)
    tokens = np.random.default_rng(1).integers(0, 128, (1, 12),
                                               dtype=np.int32)
    tr_scanned = router_trace(cfg, params, tokens)
    # unrolled plan = ground-truth ordering (one segment per layer)
    cfg_u = dataclasses.replace(cfg, force_unroll_plan=True)
    params_u = unstack_params(params, cfg)
    tr_unrolled = router_trace(cfg_u, params_u, tokens)
    assert tr_scanned.shape == (12, 4, 2)
    np.testing.assert_array_equal(tr_scanned, tr_unrolled)


def test_engine_decode_loop_streams_trace():
    cfg = moe_cfg()
    params = init_params(jax.random.key(1), cfg, jnp.float32)
    eng = ServeEngine(cfg, params)
    res = eng.generate(np.zeros((2, 4), np.int32), max_new=6)
    assert res.tokens.shape == (2, 6)
    assert res.logprobs.shape == (2, 6)
    assert res.router_trace.shape == (6, 2, 2, 2)  # (steps, L, B, k)
    assert res.router_trace.min() >= 0
    assert res.router_trace.max() < cfg.moe.num_experts
    assert res.request_trace(0).shape == (6, 2, 2)
    assert res.decode_tokens_per_s > 0


def test_engine_greedy_decode_deterministic():
    cfg = moe_cfg()
    params = init_params(jax.random.key(5), cfg, jnp.float32)
    eng = ServeEngine(cfg, params, ServeConfig(temperature=0.0))
    prompts = np.random.default_rng(2).integers(0, 128, (2, 4),
                                                dtype=np.int32)
    a = eng.generate(prompts, max_new=5, seed=0)
    b = eng.generate(prompts, max_new=5, seed=7)  # greedy: seed-independent
    np.testing.assert_array_equal(a.tokens, b.tokens)
    np.testing.assert_array_equal(a.router_trace, b.router_trace)


def test_engine_moe_config_without_moe_layers():
    """cfg.moe set but the plan has no MoE FFN layers (first_layer_dense
    on a 1-layer model): trace must be None, not a garbage object array."""
    cfg = dataclasses.replace(moe_cfg(), num_layers=1,
                              first_layer_dense=True)
    params = init_params(jax.random.key(6), cfg, jnp.float32)
    res = ServeEngine(cfg, params).generate(np.zeros((1, 4), np.int32),
                                            max_new=3)
    assert res.tokens.shape == (1, 3)
    assert res.router_trace is None
    assert res.request_trace(0) is None


def test_engine_temperature_change_takes_effect():
    """scfg.temperature is read per generate call (static jit arg), not
    baked into the first compile."""
    cfg = moe_cfg()
    params = init_params(jax.random.key(7), cfg, jnp.float32)
    eng = ServeEngine(cfg, params, ServeConfig(temperature=0.0))
    prompts = np.random.default_rng(3).integers(0, 128, (2, 4),
                                                dtype=np.int32)
    greedy = eng.generate(prompts, max_new=8, seed=0)
    eng.scfg = dataclasses.replace(eng.scfg, temperature=1.5)
    s0 = eng.generate(prompts, max_new=8, seed=0)
    s1 = eng.generate(prompts, max_new=8, seed=1)
    # sampled decodes vary with seed; greedy did not (same engine instance)
    assert not np.array_equal(s0.tokens, s1.tokens)
    assert not np.array_equal(greedy.tokens, s0.tokens)


@pytest.mark.slow
def test_engine_live_offload_report():
    """Quantized serving with attached stores: the engine's own decode
    routing produces the wire-bytes / hit-rate / prefetch report."""
    cfg = moe_cfg()
    params = init_params(jax.random.key(4), cfg, jnp.float32)
    up = unstack_params(params, cfg)
    cfg_q = dataclasses.replace(cfg, force_unroll_plan=True)
    segs, stacks_by_layer = [], []
    for seg in up["segments"]:
        p = dict(seg[0])
        mp = dict(p["moe"])
        stacks, _ = compress_ffn_weights(mp["w1"], mp["w2"], mp["w3"],
                                         cfg.moe.quant)
        stacks_by_layer.append(stacks)
        mp["stacks"] = stacks
        for k in ("w1", "w2", "w3"):
            mp.pop(k)
        p["moe"] = mp
        segs.append((p,))
    qparams = dict(up)
    qparams["segments"] = tuple(segs)

    eng = ServeEngine(cfg_q, qparams, quantized=True)
    eng.attach_offload(stacks_by_layer, policy="ours", cache_capacity=2)
    res = eng.generate(np.zeros((2, 4), np.int32), max_new=8)
    rep = res.offload_report
    assert rep is not None
    assert rep["tokens"] == 16                   # steps * batch
    assert rep["total_bytes"] > 0
    assert rep["bytes_per_token"] > 0
    assert 0.0 <= rep["hit_rate"] <= 1.0
    assert 0.0 <= rep["prefetch_accuracy"] <= 1.0
    # a second generate on the SAME engine must report only its own
    # traffic (stores stay warm, but no double-counting of call 1)
    rep_again = eng.generate(np.zeros((2, 4), np.int32),
                             max_new=8).offload_report
    assert rep_again["tokens"] == 16
    # warm cache: second call moves at most the first call's bytes
    assert rep_again["bytes_per_token"] <= rep["bytes_per_token"]
    # same decode, fp16 policy: every miss moves the full-precision expert,
    # so it must beat uniform low-bit ('quant') on bytes — same access
    # pattern, strictly larger per-miss payload
    def rerun(policy):
        e = ServeEngine(cfg_q, qparams, quantized=True)
        e.attach_offload(list(stacks_by_layer), policy=policy,
                         cache_capacity=2)
        return e.generate(np.zeros((2, 4), np.int32), max_new=8) \
                .offload_report
    rep_q, rep_fp16 = rerun("quant"), rerun("fp16")
    assert rep_fp16["bytes_per_token"] > rep_q["bytes_per_token"]
