"""Fault tolerance: checkpoint/restart continuity, torn-write recovery,
straggler monitoring, failure injection — and transfer-fault injection
for the async expert-streaming path (delay/stall backends against the
``offload/staging.py`` engine: slow copies may only block on a true
miss, a stalled copy degrades to the resident low-bit fallback instead
of wedging decode, and the stall/degraded-token counts surface in
``ServeStats.stream_report``)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.config import (ModelConfig, MoEConfig, QuantConfig, ServeConfig,
                          StreamConfig, TrainConfig)
from repro.models import init_params
from repro.train import FailureInjector, StragglerMonitor, train


def tiny_cfg():
    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128, vocab_size=128,
        block_pattern=("global",), max_position=512)


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    mgr.save(10, tree)
    restored, man = mgr.restore(jax.tree.map(np.zeros_like, tree))
    assert man["step"] == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_keep_k_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_torn_write_falls_back(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    tree = {"x": jnp.arange(4.0)}
    mgr.save(1, tree)
    mgr.save(2, jax.tree.map(lambda a: a + 1, tree))
    # corrupt the newest checkpoint data (manifest committed, data torn)
    (mgr.dir / "step_00000002.npz").write_bytes(b"garbage")
    restored, man = mgr.restore(tree)
    assert man["step"] == 1
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.asarray(tree["x"]))


def test_failure_injection_and_restart_continuity(tmp_path):
    """Kill training mid-run, restart, assert the loss curve continues
    from the checkpoint (deterministic data => comparable history)."""
    cfg = tiny_cfg()
    tcfg = TrainConfig(total_steps=9, checkpoint_every=3, lr=1e-3,
                       warmup_steps=2, loss_chunk=0)
    # uninterrupted reference run
    ref = train(cfg, tcfg, checkpoint_dir=None, log_every=0,
                batch_shape=(2, 32))
    # crashed run
    with pytest.raises(RuntimeError, match="injected failure"):
        train(cfg, tcfg, checkpoint_dir=str(tmp_path), log_every=0,
              failure=FailureInjector(fail_at_step=7), batch_shape=(2, 32))
    # restart resumes from step 6 checkpoint
    res = train(cfg, tcfg, checkpoint_dir=str(tmp_path), log_every=0,
                batch_shape=(2, 32))
    assert res.resumed_from == 6
    steps = [h["step"] for h in res.history]
    assert steps == [6, 7, 8]
    # loss continuity: restarted losses match the uninterrupted run
    ref_by_step = {h["step"]: h["loss"] for h in ref.history}
    for h in res.history:
        assert abs(h["loss"] - ref_by_step[h["step"]]) < 2e-2, \
            (h["step"], h["loss"], ref_by_step[h["step"]])


def test_straggler_monitor_flags_and_aborts():
    mon = StragglerMonitor(threshold=2.0, warmup=2, policy="warn")
    for s in range(5):
        mon.observe(s, 0.10)
    assert mon.observe(5, 0.50)          # 5x the EWMA -> flagged
    assert mon.flagged == [5]
    mon2 = StragglerMonitor(threshold=2.0, warmup=1, policy="abort")
    mon2.observe(0, 0.1)
    mon2.observe(1, 0.1)
    with pytest.raises(TimeoutError):
        mon2.observe(2, 10.0)


# ---------------------------------------------------------------------------
# transfer-fault injection: async expert streaming under slow/wedged DMA
# ---------------------------------------------------------------------------

def _stream_setup():
    cfg = ModelConfig(
        name="stream-fault", family="moe", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0, vocab_size=128,
        block_pattern=("global",), max_position=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=16,
                                        top_n_restore=1, hqq_iters=2)))
    params = init_params(jax.random.key(1), cfg, jnp.float32)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, 128, (int(n),)).astype(np.int32)
               for n in (4, 6)]
    return cfg, params, prompts


def _stream_engine(cfg, params, stream_cfg=None, backend=None):
    from repro.models.transformer import compress_moe_params
    from repro.serve import ServeEngine
    qp, cq, stacks = compress_moe_params(params, cfg)
    eng = ServeEngine(cq, qp, ServeConfig(temperature=0.0), quantized=True)
    eng.attach_offload(stacks, policy="ours", cache_capacity=8)
    eng.attach_streaming(stream_cfg or StreamConfig(enabled=True),
                         backend=backend)
    return eng


def _serve(eng, prompts):
    return eng.generate_many(prompts, max_new=6, num_slots=2, chunk=4)


def test_slow_copies_block_only_on_true_miss():
    """A uniformly slow link (every copy delayed) stalls the cold first
    pass — and may not add a single stall or copy once every routed
    expert is staged (the warm pass has no true miss to block on)."""
    from repro.offload.staging import FakeTransferBackend
    cfg, params, prompts = _stream_setup()
    backend = FakeTransferBackend(delay_s=0.01)
    eng = _stream_engine(cfg, params, backend=backend)
    stats = _serve(eng, prompts)
    sr = stats.stream_report
    assert sr["stalls"] > 0 and sr["stall_s"] > 0      # cold misses blocked
    assert sr["degraded_tokens"] == 0                  # ...but were served
    copies0, stalls0 = backend.copies, eng.stream.stalls
    stats2 = _serve(eng, prompts)
    assert backend.copies == copies0, "warm pass issued copies"
    assert eng.stream.stalls == stalls0, "warm pass blocked without a miss"
    # delayed copies change timing only, never tokens
    ref = _serve(_stream_engine(cfg, params), prompts)
    assert [r.tokens.tolist() for r in stats2.results] == \
        [r.tokens.tolist() for r in ref.results]


def test_stalled_copy_degrades_to_fallback():
    """A wedged DMA channel (copies for one expert never complete) must
    not wedge decode: after ``stall_timeout_s`` the affected tokens are
    served by the device-resident low-bit fallback, the stalled slot is
    abandoned, and the counts surface in ``ServeStats.stream_report``."""
    from repro.offload.staging import FakeTransferBackend
    cfg, params, prompts = _stream_setup()
    backend = FakeTransferBackend(stall=(1,))        # expert 1 never lands
    eng = _stream_engine(
        cfg, params,
        StreamConfig(enabled=True, miss_policy="degrade",
                     stall_timeout_s=0.05),
        backend=backend)
    stats = _serve(eng, prompts)                     # must terminate
    sr = stats.stream_report
    assert sr["degraded_tokens"] > 0
    assert sr["abandoned_copies"] > 0 or sr["in_flight"] > 0
    # the meter never counts the wedged expert as served at full fidelity:
    # metered bytes still reconcile with observed copies exactly
    for s in eng._stores:
        assert s.total_bytes == s.observed_copy_bytes


def test_stall_under_block_policy_degrades_after_timeout():
    """miss_policy='block' waits for a stalled copy up to the timeout,
    then degrades the chunk rather than hanging the scan."""
    from repro.offload.staging import FakeTransferBackend
    cfg, params, prompts = _stream_setup()
    backend = FakeTransferBackend(stall=(2,))
    eng = _stream_engine(
        cfg, params,
        StreamConfig(enabled=True, miss_policy="block",
                     stall_timeout_s=0.05, max_reruns=2),
        backend=backend)
    stats = _serve(eng, prompts)
    sr = stats.stream_report
    assert sr["stalls"] > 0
    assert sr["degraded_tokens"] > 0
    for s in eng._stores:
        assert s.total_bytes == s.observed_copy_bytes


def test_elastic_restore_onto_new_sharding(tmp_path):
    """Checkpoints are mesh-agnostic: restore re-shards transparently."""
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    mgr.save(1, tree)
    # single-device "new topology": just a different device_put layout
    restored, _ = mgr.restore(tree, shardings=jax.tree.map(
        lambda _: jax.devices()[0], tree))
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
