"""Mixtral-8x22B (paper reference model, Table 1): 56L hidden (6144,16384),
8 experts top-2.  Paper setting: R_avg=32, top-n=1."""
from ..config import ModelConfig, MoEConfig, QuantConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=0, vocab_size=32_768,
        block_pattern=("global",),
        rope_theta=1_000_000.0, act="silu", tie_embeddings=False,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=16384,
                      router_norm_topk=True,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=32,
                                        top_n_restore=1)),
        max_position=65_536,
    )
