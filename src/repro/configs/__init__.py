"""One module per architecture (exact dims from the assignment)."""
