"""Distributed-optimization collectives.

``compressed_psum_grads``: int8-quantized gradient all-reduce — quantize
per-tensor to int8 with a per-shard f32 scale, psum the int8 payload (as
int32 accumulators to avoid overflow across ranks) and the scales, then
dequantize.  Cuts gradient all-reduce wire bytes ~4x vs f32 at the cost of
stochastic-rounding noise; exposed via ``ParallelConfig.grad_compress_bits``
and validated in tests against exact psum (bounded relative error).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import axis_size, shard_map


def _quantize_grad(g: jax.Array, key) -> tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    x = g / scale
    # stochastic rounding keeps the compressed psum unbiased
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(x + noise), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(g: jax.Array, axes: Sequence[str], key) -> jax.Array:
    """int8-compressed mean-psum of one gradient tensor over ``axes``.

    Ranks agree on a common scale via pmax (one tiny f32 all-reduce), then
    psum the int8 payload as int32 — ~4x fewer wire bytes than f32.
    """
    g32 = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))
    s = jnp.maximum(jax.lax.pmax(amax, axes) / 127.0, 1e-12)
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(g32 / s + noise), -127, 127).astype(jnp.int8)
    acc = jax.lax.psum(q.astype(jnp.int32), axes)
    n = 1
    for a in axes:
        n *= axis_size(a)
    return acc.astype(jnp.float32) * s / n


def compressed_psum_grads(grads: Any, mesh: Mesh, axes: Sequence[str],
                          seed: jax.Array) -> Any:
    """Tree-wide int8 all-reduce under shard_map (replicated-grad layout).

    Used by the data-parallel trainer when grad_compress_bits == 8; the
    exact-psum path stays the default.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)

    specs = tuple(P() for _ in leaves)

    def body(seed_, *ls):
        out = []
        for i, g in enumerate(ls):
            key = jax.random.fold_in(jax.random.key(seed_[0]), i)
            out.append(compressed_psum(g, axes, key).astype(g.dtype))
        return tuple(out)

    out = shard_map(body, mesh=mesh,
                    in_specs=(P(),) + specs, out_specs=specs,
                    check_vma=False)(jnp.asarray([seed]), *leaves)
    return jax.tree_util.tree_unflatten(treedef, list(out))
