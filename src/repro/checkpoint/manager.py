"""Fault-tolerant checkpointing: atomic, keep-k, mesh-agnostic resume.

Design for 1000+ nodes (emulated here on one host):
- tensors are saved *unsharded* (gathered per leaf) in an .npz plus a JSON
  manifest, so a restore onto a DIFFERENT mesh/topology re-shards
  transparently (elastic scaling);
- writes go to ``step_XXXX.tmp`` then ``os.replace`` (atomic on POSIX), so
  a crash mid-write can never corrupt the latest checkpoint;
- the manifest carries a content checksum; restore validates it and falls
  back to the previous checkpoint on mismatch (torn-write recovery);
- ``keep`` retention bounds disk; ``latest_step`` scans only committed
  manifests.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _checksum(flat: Dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(flat):
        h.update(k.encode())
        h.update(np.ascontiguousarray(flat[k]).tobytes()[:4096])
        h.update(str(flat[k].shape).encode())
    return h.hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- write --------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> Path:
        flat = _flatten(tree)
        tmp_npz = self.dir / f"step_{step:08d}.npz.tmp"
        final_npz = self.dir / f"step_{step:08d}.npz"
        with open(tmp_npz, "wb") as f:
            np.savez(f, **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "checksum": _checksum(flat),
            "n_tensors": len(flat),
            "bytes": int(sum(v.nbytes for v in flat.values())),
            "extra": extra or {},
        }
        tmp_man = self.dir / f"step_{step:08d}.json.tmp"
        final_man = self.dir / f"step_{step:08d}.json"
        tmp_man.write_text(json.dumps(manifest))
        os.replace(tmp_npz, final_npz)      # atomic commits: data first,
        os.replace(tmp_man, final_man)      # manifest last = commit point
        self._retain()
        return final_npz

    def _retain(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            for suffix in (".npz", ".json"):
                p = self.dir / f"step_{s:08d}{suffix}"
                if p.exists():
                    p.unlink()

    # -- read ---------------------------------------------------------------
    def all_steps(self):
        return sorted(int(p.stem.split("_")[1])
                      for p in self.dir.glob("step_*.json"))

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``template`` (shapes validated).
        ``shardings`` (optional pytree) re-shards onto the current mesh —
        this is what makes restarts elastic across topology changes."""
        steps = self.all_steps()
        if step is None:
            if not steps:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
            candidates = steps[::-1]
        else:
            candidates = [step]
        last_err: Optional[Exception] = None
        for s in candidates:
            try:
                return self._restore_one(template, s, shardings)
            except Exception as e:  # torn write -> try previous
                last_err = e
        raise last_err

    def _restore_one(self, template, step: int, shardings):
        man = json.loads((self.dir / f"step_{step:08d}.json").read_text())
        with np.load(self.dir / f"step_{step:08d}.npz") as z:
            flat = {k: z[k] for k in z.files}
        if _checksum(flat) != man["checksum"]:
            raise IOError(f"checksum mismatch at step {step}")
        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for path, leaf in leaves:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx",
                                                         getattr(p, "name", p))))
                           for p in path)
            arr = flat[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), out)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, man
