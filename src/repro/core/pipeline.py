"""Offline compression pipeline (paper §3.1): HQQ quantize -> kurtosis ->
rank allocation -> one-time SVD -> packed artifact.

Operates on *expert stacks*: a (E, K, N) weight tensor holding one
projection (w1/w2/w3) for all E experts of a layer.  Dense models use E=1
stacks (the degenerate static quantize-then-compensate form — see
DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..config import QuantConfig
from .compensator import _sym_quant_cols
from .hqq import hqq_params
from .kurtosis import allocate_ranks, kurtosis, uniform_ranks
from .quantize import (QuantizedTensor, dequantize, factor_wire_bytes,
                       pack_bits, packed_nbytes, quant_wire_bytes,
                       quantize_with_params, unpack_bits)


@partial(jax.tree_util.register_dataclass,
         data_fields=("planes", "scale", "zero", "u", "v", "u_scale", "v_scale"),
         meta_fields=("bits", "group_size", "shape", "ranks", "pad_rank",
                      "factor_bits", "expert_bits"))
@dataclass
class CompressedExpertStack:
    """Quantized weights + padded low-rank compensators for E experts.

    planes[i]: (E, K//c_i, N) uint8;  scale/zero: (E, K//G, N) f32
    u: (E, K, R) int8/bf16;  v: (E, R, N);  R = pad_rank
    ranks: per-expert TRUE ranks (tuple, static) for bandwidth accounting.

    Heterogeneous precision (calibrated allocation): ``bits`` is the
    bit-plane CONTAINER width shared by the stacked layout, while
    ``expert_bits[e]`` is expert e's true quantization width (codes fit
    in the container; scale/zero were fit at the true width, so the
    dequant math is bit-exact) — the same container-vs-wire idiom as the
    sub-byte compensator factors in an int8 container.  ``expert_bits``
    is None for uniform stacks (every expert at ``bits``).
    """
    planes: Tuple[jax.Array, ...]
    scale: jax.Array
    zero: jax.Array
    u: jax.Array
    v: jax.Array
    u_scale: jax.Array
    v_scale: jax.Array
    bits: int
    group_size: int
    shape: Tuple[int, int, int]        # (E, K, N)
    ranks: Tuple[int, ...]
    pad_rank: int
    factor_bits: int
    expert_bits: Optional[Tuple[int, ...]] = None

    # -- helpers ----------------------------------------------------------
    def expert_qt(self, e: int) -> QuantizedTensor:
        """Expert e's packed tensor at the CONTAINER width (unpacking
        semantics); wire accounting must use :meth:`bits_of` /
        :meth:`expert_wire_bytes`, not this view's ``nbytes_packed``."""
        return QuantizedTensor(tuple(p[e] for p in self.planes),
                               self.scale[e], self.zero[e],
                               self.bits, self.group_size, self.shape[1:])

    def dequantize_all(self, dtype=jnp.float32) -> jax.Array:
        """(E, K, N) dequantized (no compensation).

        E is taken from the runtime leaves (inside shard_map the stack
        carries the LOCAL expert slice, not the global count in ``shape``).
        """
        _, K, N = self.shape
        E = self.scale.shape[0]
        q = jax.vmap(lambda *pl: unpack_bits(tuple(pl), self.bits))(*self.planes)
        g = q.astype(jnp.float32).reshape(E, K // self.group_size,
                                          self.group_size, N)
        w = (g - self.zero[:, :, None, :]) * self.scale[:, :, None, :]
        return w.reshape(E, K, N).astype(dtype)

    def compensation_all(self, dtype=jnp.float32) -> jax.Array:
        """(E, K, N) dense U V term for every expert."""
        u = self.u.astype(jnp.float32) * self.u_scale
        v = self.v.astype(jnp.float32) * self.v_scale
        return jnp.einsum("ekr,ern->ekn", u, v).astype(dtype)

    # -- bandwidth accounting (bytes on the wire) --------------------------
    def bits_of(self, e: int) -> int:
        """Expert e's TRUE quantization width (wire accounting)."""
        return self.bits if self.expert_bits is None else self.expert_bits[e]

    def expert_wire_bytes(self, e: int, compensated: bool) -> int:
        _, K, N = self.shape
        b = quant_wire_bytes(self.bits_of(e), K, N, self.group_size)
        if compensated:
            b += factor_wire_bytes(self.ranks[e], K, N, self.factor_bits)
        return b

    @property
    def fp16_wire_bytes(self) -> int:
        _, K, N = self.shape
        return K * N * 2


def whiten_vector(moment: np.ndarray, eps: float = 1e-6) -> np.ndarray:
    """(K,) scale-free whitening weights sqrt(m / mean(m) + eps) from a
    calibrated input second-moment diagonal.  THE single definition of
    the whitening recipe — shared by the compensator SVD below and the
    budget allocator's error model (``calib/allocate.py``), so the
    allocator optimizes exactly what compression realizes."""
    m = np.asarray(moment, np.float64).reshape(-1)
    m = m / max(float(m.mean()), 1e-30)
    return np.sqrt(m + eps)


def whitened_residual_factors(resid: jax.Array, rank: int, pad_rank: int,
                              moment: Optional[np.ndarray] = None,
                              eps: float = 1e-6
                              ) -> Tuple[jax.Array, jax.Array]:
    """Rank-``rank`` factors (u (K, R), v (R, N)) of one expert's quant
    residual, optionally whitened by the calibrated input second moment.

    ``moment`` is the (K,) diagonal of E[x x^T] over the calibration
    tokens routed to this expert.  The SVD then truncates in the
    activation-weighted norm ||diag(sqrt(m)) (R - UV)||_F — rank goes to
    the input directions the router actually exercises — while the
    STORED factors still approximate R itself (U is un-whitened), so the
    runtime restoration math is unchanged.  ``moment=None`` is the
    paper's plain weight-space SVD, bit-identical to the previous
    behaviour.
    """
    if moment is None:
        white = None
        r_in = resid
    else:
        white = jnp.asarray(whiten_vector(moment, eps), jnp.float32)
        r_in = resid * white[:, None]
    uu, ss, vt = jnp.linalg.svd(r_in, full_matrices=False)
    sq = jnp.sqrt(ss[:pad_rank])
    uu = uu[:, :pad_rank] * sq[None, :]
    vv = vt[:pad_rank, :] * sq[:, None]
    if white is not None:
        uu = uu / white[:, None]
    mask = (jnp.arange(pad_rank) < rank)
    return uu * mask[None, :], vv * mask[:, None]


def compress_expert_stack(w: jax.Array, qcfg: QuantConfig,
                          ranks: Optional[np.ndarray] = None,
                          bits: Optional[np.ndarray] = None,
                          moments: Optional[np.ndarray] = None
                          ) -> Tuple[CompressedExpertStack, Dict]:
    """Full offline pipeline for one (E, K, N) projection stack.

    ``ranks``/``bits``: optional per-expert allocations from a
    ``CompressionPlan`` (calibrated heterogeneous precision); ``bits``
    None means uniform ``qcfg.bits``.  ``moments``: optional (E, K)
    calibrated input second-moment diagonals — compensator SVDs are then
    computed in the activation-weighted norm (see
    :func:`whitened_residual_factors`).

    Returns the packed artifact plus a report dict (kurtosis, ranks,
    bits, residual norms before/after compensation) used by benchmarks.
    """
    E, K, N = w.shape
    w32 = jnp.asarray(w, jnp.float32)
    # group_size <= 0 means per-channel (one group spanning all of K) —
    # the coarse granularity at which RTN/GPTQ-class int2 collapses
    if qcfg.group_size <= 0 or qcfg.group_size > K:
        qcfg = dataclasses.replace(qcfg, group_size=K)

    # 1. per-expert kurtosis (paper §3.1 step 1)
    kurt = np.array([float(kurtosis(w32[e])) for e in range(E)])

    # 2. HQQ quantization (paper §3.1 step 2; done before allocation so the
    # 'error' strategy can rank by measured residuals).  Heterogeneous
    # per-expert bits share one bit-plane container at the layer max
    # width; each expert's scale/zero are fit at its TRUE width, which
    # stays the wire-accounting width.
    if bits is None:
        expert_bits = np.full((E,), qcfg.bits, np.int64)
    else:
        expert_bits = np.asarray(bits, np.int64).reshape(E)
    store_bits = int(expert_bits.max())

    def _q(we, b):
        s, z = hqq_params(we, b, qcfg.group_size, qcfg.hqq_iters,
                          qcfg.hqq_p, qcfg.hqq_beta, qcfg.hqq_beta_scale)
        return quantize_with_params(we, s, z, b, qcfg.group_size,
                                    store_bits=store_bits)

    qts = [_q(w32[e], int(expert_bits[e])) for e in range(E)]

    # 3. rank allocation: kurtosis proxy (paper) | measured residual
    # (beyond-paper) | uniform (ablation)
    max_rank = min(K, N)
    strategy = qcfg.rank_alloc if qcfg.kurtosis_guided else "uniform"
    if ranks is None:
        if strategy == "error":
            from .quantize import quant_error
            errs = np.array([float(quant_error(w32[e], qts[e]))
                             for e in range(E)])
            ranks = allocate_ranks(errs, qcfg.rank_budget, qcfg.rank_buckets,
                                   max_rank=max_rank)
        elif strategy == "kurtosis":
            ranks = allocate_ranks(kurt, qcfg.rank_budget, qcfg.rank_buckets,
                                   max_rank=max_rank)
        else:
            r = (qcfg.uniform_rank if qcfg.uniform_rank is not None
                 else qcfg.rank_budget)
            ranks = uniform_ranks(E, r, qcfg.rank_buckets)
    ranks = np.minimum(np.asarray(ranks, np.int64), max_rank)
    pad_rank = int(max(int(ranks.max()), 1))
    planes = tuple(jnp.stack([qt.planes[i] for qt in qts])
                   for i in range(len(qts[0].planes)))
    scale = jnp.stack([qt.scale for qt in qts])
    zero = jnp.stack([qt.zero for qt in qts])

    # 4. residual SVD at the allocated rank (activation-whitened when
    # calibrated moments are given), zero-padded to pad_rank
    deq = jnp.stack([dequantize(qt) for qt in qts])
    resid = w32 - deq
    us, vs, uss, vss = [], [], [], []
    for e in range(E):
        r = int(ranks[e])
        uu, vv = whitened_residual_factors(
            resid[e], r, pad_rank,
            moment=None if moments is None else moments[e])
        if qcfg.factor_bits >= 16:
            us.append(uu.astype(jnp.bfloat16)); vs.append(vv.astype(jnp.bfloat16))
            uss.append(jnp.ones((1, pad_rank), jnp.float32))
            vss.append(jnp.ones((pad_rank, 1), jnp.float32))
        else:
            qu, su = _sym_quant_cols(uu, qcfg.factor_bits, axis=0)
            qv, sv = _sym_quant_cols(vv, qcfg.factor_bits, axis=1)
            us.append(qu); vs.append(qv); uss.append(su); vss.append(sv)

    hetero = bool((expert_bits != expert_bits[0]).any()) \
        or int(expert_bits[0]) != store_bits
    stack = CompressedExpertStack(
        planes=planes, scale=scale, zero=zero,
        u=jnp.stack(us), v=jnp.stack(vs),
        u_scale=jnp.stack(uss), v_scale=jnp.stack(vss),
        bits=store_bits, group_size=qcfg.group_size, shape=(E, K, N),
        ranks=tuple(int(r) for r in ranks), pad_rank=pad_rank,
        factor_bits=qcfg.factor_bits,
        expert_bits=tuple(int(b) for b in expert_bits) if hetero else None)

    # 5. report
    comp = stack.compensation_all()
    nw = jnp.maximum(jnp.linalg.norm(w32.reshape(E, -1), axis=1), 1e-12)
    report = {
        "kurtosis": kurt,
        "ranks": np.asarray(ranks),
        "bits": np.asarray(expert_bits),
        "rel_err_quant": np.asarray(
            jnp.linalg.norm(resid.reshape(E, -1), axis=1) / nw),
        "rel_err_comp": np.asarray(
            jnp.linalg.norm((resid - comp).reshape(E, -1), axis=1) / nw),
    }
    return stack, report


def compress_ffn_weights(w1: jax.Array, w2: jax.Array, w3: jax.Array,
                         qcfg: QuantConfig, allocation=None, stats=None):
    """Compress the three projections of a (shared or routed) FFN stack.

    Rank allocation runs per projection pool (paper computes kurtosis per
    projection matrix w1/w2/w3 and budgets over the N experts of a pool)
    unless ``allocation`` (one layer of a ``calib.CompressionPlan``)
    pins per-expert bits and per-(projection, expert) ranks from the
    offline budget allocator.  ``stats`` (a ``calib.LayerCalibStats``)
    supplies the calibrated input second moments that make the
    compensator SVDs activation-weighted: w1/w3 whiten by the MoE-layer
    input moment, w2 by the expert-hidden moment.
    """
    out, reports = {}, {}
    for name, w in (("w1", w1), ("w2", w2), ("w3", w3)):
        if w is None:
            continue
        kw = {}
        if allocation is not None:
            kw["bits"] = allocation.bits
            kw["ranks"] = allocation.ranks[name]
        if stats is not None:
            kw["moments"] = stats.moment_for(name)
        stack, rep = compress_expert_stack(w, qcfg, **kw)
        out[name] = stack
        reports[name] = rep
    return out, reports
