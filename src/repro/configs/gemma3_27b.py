"""gemma3-27b [dense]: 62L d=5376 32H (GQA kv=16) ff=21504 vocab=262144.
5:1 local:global interleave, 128k context. [hf:google/gemma-3-27b-pt]"""
from ..config import ModelConfig, QuantConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        num_layers=62, d_model=5376, num_heads=32, num_kv_heads=16,
        head_dim=128, d_ff=21504, vocab_size=262_144,
        block_pattern=("local",) * 5 + ("global",),
        window_size=1024,
        rope_theta=1_000_000.0, rope_local_theta=10_000.0,
        act="gelu_tanh", tie_embeddings=True, scale_embed=True,
        post_attn_norm=True,
        quant=QuantConfig(enabled=True, bits=2, rank_budget=32,
                          top_n_restore=1),
        max_position=131_072,
    )
