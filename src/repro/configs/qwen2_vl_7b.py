"""qwen2-vl-7b [vlm]: qwen2-7b backbone + M-RoPE; dynamic-resolution
vision frontend is a STUB (precomputed patch embeddings merged into the
token stream; input_specs provides 3xBxS multimodal positions).
[arXiv:2409.12191]"""
from ..config import ModelConfig, QuantConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        head_dim=128, d_ff=18944, vocab_size=152_064,
        block_pattern=("global",), qkv_bias=True,
        rope_theta=1_000_000.0, rope_kind="mrope",
        act="silu", tie_embeddings=False, frontend="vision_stub",
        quant=QuantConfig(enabled=True, bits=2, rank_budget=32,
                          top_n_restore=1),
        max_position=131_072,
    )
