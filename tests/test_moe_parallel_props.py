"""Property tests for the MoE all-to-all dispatch/combine core.

``moe_apply_ep_a2a`` / ``moe_apply_ep_replicated`` are both built from
``make_dispatch`` / ``dispatch_tokens`` / ``combine_tokens`` plus a
collective; these properties pin the host-side invariants the
collectives rely on:

- dispatch/combine is a permutation inverse at exact capacity: every
  (token, expert) assignment lands in exactly one (expert, slot) cell,
  no token is lost or duplicated, and combining the identity expert
  reproduces the input exactly (normalized gates sum to 1);
- the expert-parallel shard decomposition is exact: mapping global
  expert ids into per-shard local slices (the OOB-sentinel arithmetic
  of ``moe_apply_ep_replicated``) partitions the assignments, and the
  shard-wise combines SUM to the global combine — the algebraic fact
  the decode path's psum implements;
- ``top_n`` edges: n >= k compensates every assignment, n = 0 none.

Each property runs under hypothesis (random T, E, k, top_n, shard
counts, including empty-expert and n >= k edges) when available, and on
a deterministic case matrix regardless — the checks themselves are
shared, so the tier executes even without the hypothesis dependency.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MoEConfig
from repro.models.moe import (combine_tokens, dispatch_tokens, make_dispatch,
                              route)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:     # container without hypothesis: deterministic matrix
    HAVE_HYPOTHESIS = False

SETTINGS = dict(max_examples=25, deadline=None)


def _routing(t, e, k, seed):
    """Realistic routing: softmax-then-topk over random logits (distinct
    experts per token, normalized gates)."""
    rng = np.random.default_rng(seed)
    mcfg = MoEConfig(num_experts=e, top_k=k, d_expert=8)
    x2 = jnp.asarray(rng.standard_normal((t, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, e)), jnp.float32)
    return x2, route(x2, w, mcfg)


# ---------------------------------------------------------------------------
# shared property checks
# ---------------------------------------------------------------------------

def check_roundtrip_permutation_inverse(t, e, k, top_n, seed):
    """Exact capacity: dispatch scatters injectively, combine inverts."""
    x2, info = _routing(t, e, k, seed)
    disp = make_dispatch(info, e, t, top_n)
    e_idx = np.asarray(disp.e_idx)
    slot = np.asarray(disp.slot)
    t_idx = np.asarray(disp.t_idx)

    # no assignment dropped at exact capacity, and (expert, slot) cells
    # are unique: nothing overwrites, nothing is lost
    assert (slot < t).all()
    cells = set(zip(e_idx.tolist(), slot.tolist()))
    assert len(cells) == t * k

    xe, me = dispatch_tokens(x2, disp, e)
    xe_np = np.asarray(xe)
    # every assignment's token is present where dispatch says it is
    x_np = np.asarray(x2)
    for a in range(t * k):
        np.testing.assert_array_equal(xe_np[e_idx[a], slot[a]],
                                      x_np[t_idx[a]])
    # experts beyond any token's top-k stay empty (empty-expert edge)
    routed = set(e_idx.tolist())
    for expert in range(e):
        if expert not in routed:
            assert not xe_np[expert].any()

    # identity expert + normalized gates => combine returns the input
    y = np.asarray(combine_tokens(xe, disp, t))
    np.testing.assert_allclose(y, x_np, rtol=1e-5, atol=1e-5)

    # top_n edges ride the same dispatch: the comp mask covers exactly
    # the rank < top_n assignments (all at n >= k, none at n = 0)
    me_np = np.asarray(me)
    comp_cells = int((me_np > 0).sum())
    assert comp_cells == t * min(top_n, k)


def check_shard_decomposition(t, e, k, ep, seed):
    """Per-shard local dispatch partitions the global assignments and the
    shard combines sum to the global combine (what psum computes)."""
    assert e % ep == 0
    x2, info = _routing(t, e, k, seed)
    e_local = e // ep

    g_disp = make_dispatch(info, e, t, 1)
    xe_g, _ = dispatch_tokens(x2, g_disp, e)
    y_global = np.asarray(combine_tokens(xe_g, g_disp, t))

    y_sum = np.zeros_like(y_global)
    occupied = 0
    for m in range(ep):
        # the moe_apply_ep_replicated id mapping: foreign ids -> OOB
        # sentinel row e_local with gate 0
        topi_local = np.asarray(info.topk_idx) - m * e_local
        oob = (topi_local < 0) | (topi_local >= e_local)
        topi_local = np.where(oob, e_local, topi_local)
        gates = np.where(oob, 0.0, np.asarray(info.gates))
        local = info._replace(topk_idx=jnp.asarray(topi_local),
                              gates=jnp.asarray(gates.astype(np.float32)))
        disp = make_dispatch(local, e_local + 1, t, 1)
        xe, _ = dispatch_tokens(x2, disp, e_local + 1)
        xe_np = np.asarray(xe)
        occupied += int((np.abs(xe_np[:e_local]).sum(-1) > 0).sum())
        ye = np.concatenate([xe_np[:e_local], np.zeros_like(xe_np[:1])])
        y_sum += np.asarray(combine_tokens(jnp.asarray(ye), disp, t))

    # every real (expert, slot) cell shows up on exactly one shard
    cells_global = int((np.abs(np.asarray(xe_g)).sum(-1) > 0).sum())
    assert occupied == cells_global
    np.testing.assert_allclose(y_sum, y_global, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# deterministic matrix (always runs, hypothesis or not)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,e,k,top_n,seed", [
    (16, 8, 2, 1, 0),
    (12, 4, 3, 0, 1),      # n = 0: no compensation
    (9, 6, 2, 5, 2),       # n >= k: everything compensated
    (1, 8, 1, 1, 3),       # single token
    (5, 16, 2, 2, 4),      # more experts than assignments: empty experts
])
def test_roundtrip_cases(t, e, k, top_n, seed):
    check_roundtrip_permutation_inverse(t, e, k, top_n, seed)


@pytest.mark.parametrize("t,e,k,ep,seed", [
    (16, 8, 2, 2, 0),
    (16, 8, 2, 8, 1),
    (7, 4, 2, 4, 2),
    (10, 6, 3, 3, 3),
])
def test_shard_decomposition_cases(t, e, k, ep, seed):
    check_shard_decomposition(t, e, k, ep, seed)


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @given(st.data())
    @settings(**SETTINGS)
    def test_roundtrip_permutation_inverse_property(data):
        t = data.draw(st.integers(1, 24), label="tokens")
        e = data.draw(st.integers(1, 16), label="experts")
        k = data.draw(st.integers(1, min(e, 4)), label="top_k")
        top_n = data.draw(st.integers(0, k + 2), label="top_n")
        seed = data.draw(st.integers(0, 2 ** 16), label="seed")
        check_roundtrip_permutation_inverse(t, e, k, top_n, seed)

    @given(st.data())
    @settings(**SETTINGS)
    def test_shard_decomposition_property(data):
        ep = data.draw(st.sampled_from([2, 3, 4, 8]), label="ep")
        e = ep * data.draw(st.integers(1, 3), label="experts_per_shard")
        t = data.draw(st.integers(1, 16), label="tokens")
        k = data.draw(st.integers(1, min(e, 3)), label="top_k")
        seed = data.draw(st.integers(0, 2 ** 16), label="seed")
        check_shard_decomposition(t, e, k, ep, seed)
