"""KV / recurrent-state caches.

Local (sliding-window) layers get *ring buffers* of window length instead of
full-sequence caches — at decode_32k this shrinks gemma3's cache HBM by the
5:1 local:global ratio; recurrent layers carry O(1) state, which is what
makes long_500k feasible for the ssm/hybrid archs.

Caches are plain dicts (pytree-friendly); every entry carries a ``pos``
plane (absolute position per slot, -1 = empty) so ring wraparound needs no
sorting — masking is purely position-arithmetic.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


def init_attn_cache(batch: int, length: int, kv_heads: int, head_dim: int,
                    dtype=jnp.bfloat16, kv_bits: int = 16
                    ) -> Dict[str, jax.Array]:
    if kv_bits == 8:
        # int8 codes + per (token, head) absmax scale: ~1.06 B/elem vs 2
        return {
            "k": jnp.zeros((batch, length, kv_heads, head_dim), jnp.int8),
            "v": jnp.zeros((batch, length, kv_heads, head_dim), jnp.int8),
            "k_scale": jnp.zeros((batch, length, kv_heads), jnp.bfloat16),
            "v_scale": jnp.zeros((batch, length, kv_heads), jnp.bfloat16),
            "pos": jnp.full((batch, length), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def _kv_quant(x: jax.Array):
    """(B, S, KV, hd) -> int8 codes + (B, S, KV) bf16 scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def update_attn_cache(cache: Dict[str, jax.Array], k_new: jax.Array,
                      v_new: jax.Array, pos: jax.Array
                      ) -> Dict[str, jax.Array]:
    """Write S_new tokens at absolute positions ``pos`` (B, S_new).

    Ring semantics: slot = pos % cache_len.  Works for both full caches
    (cache_len >= max position) and window rings.
    """
    length = cache["k"].shape[1]
    slot = pos % length
    b_idx = jnp.arange(k_new.shape[0])[:, None]
    out = {"pos": cache["pos"].at[b_idx, slot].set(pos)}
    if "k_scale" in cache:
        kq, ks = _kv_quant(k_new)
        vq, vs = _kv_quant(v_new)
        out["k"] = cache["k"].at[b_idx, slot].set(kq)
        out["v"] = cache["v"].at[b_idx, slot].set(vq)
        out["k_scale"] = cache["k_scale"].at[b_idx, slot].set(ks)
        out["v_scale"] = cache["v_scale"].at[b_idx, slot].set(vs)
        return out
    out["k"] = cache["k"].at[b_idx, slot].set(k_new.astype(cache["k"].dtype))
    out["v"] = cache["v"].at[b_idx, slot].set(v_new.astype(cache["v"].dtype))
    return out


def prefill_attn_cache(cache: Dict[str, jax.Array], k_all: jax.Array,
                       v_all: jax.Array, positions: jax.Array
                       ) -> Dict[str, jax.Array]:
    """Bulk cache write after prefill.  For ring caches only the last
    ``window`` tokens land (earlier writes are overwritten by later ones in
    ring order, matching sequential semantics)."""
    length = cache["k"].shape[1]
    s = k_all.shape[1]
    if s <= length:
        return update_attn_cache(cache, k_all, v_all, positions)
    # keep the trailing `length` tokens
    k_t = k_all[:, s - length:]
    v_t = v_all[:, s - length:]
    p_t = positions[:, s - length:]
    return update_attn_cache(cache, k_t, v_t, p_t)


def dequant_scales(cache: Dict[str, jax.Array]):
    """(k_scale, v_scale) if the cache is int8-quantized, else (None, None)."""
    return cache.get("k_scale"), cache.get("v_scale")


# ---------------------------------------------------------------------------
# slot claim / reset (continuous-batching scheduler)
# ---------------------------------------------------------------------------
#
# The serve scheduler keeps one fixed-shape cache whose batch rows are
# *slots*; requests come and go by writing a freshly-prefilled batch-1
# cache into a slot (claim) or clearing it (reset).  Shapes never change,
# so the jitted decode loop stays resident across the whole workload.

def _slot_fill(name: str, dtype) -> jax.Array:
    """Empty-slot fill value per cache plane: position planes use -1
    (= unwritten, masked by decode attention), xLSTM max-state planes use
    -inf (softmax-stabilizer identity), everything else zero."""
    if name == "pos":
        return jnp.asarray(-1, dtype)
    if name == "m":
        return jnp.asarray(-jnp.inf, dtype)
    return jnp.asarray(0, dtype)


def claim_slot(cache: Dict[str, jax.Array], req_cache: Dict[str, jax.Array],
               slot: int, batch_axis: int = 0) -> Dict[str, jax.Array]:
    """Write a batch-1 per-request cache into row ``slot`` of a slotted
    cache.  ``batch_axis`` is 0 for plain layer caches and 1 for scanned
    (repeat-stacked) segment caches."""
    out = {}
    for k, v in cache.items():
        r = req_cache[k].astype(v.dtype)
        out[k] = jax.lax.dynamic_update_slice_in_dim(v, r, slot, batch_axis)
    return out


def reset_slot(cache: Dict[str, jax.Array], slot: int,
               batch_axis: int = 0) -> Dict[str, jax.Array]:
    """Clear row ``slot`` back to the empty-slot state (pos = -1 etc.)."""
    out = {}
    for k, v in cache.items():
        row_shape = v.shape[:batch_axis] + (1,) + v.shape[batch_axis + 1:]
        row = jnp.full(row_shape, _slot_fill(k, v.dtype), v.dtype)
        out[k] = jax.lax.dynamic_update_slice_in_dim(v, row, slot, batch_axis)
    return out


def init_rglru_cache(batch: int, width: int, conv_width: int,
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {
        "h": jnp.zeros((batch, width), dtype),
        "conv": jnp.zeros((batch, conv_width - 1, width), dtype),
    }


def init_mlstm_cache(batch: int, heads: int, head_dim: int,
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {
        "c": jnp.zeros((batch, heads, head_dim, head_dim), dtype),
        "n": jnp.zeros((batch, heads, head_dim), dtype),
        "m": jnp.full((batch, heads), -jnp.inf, dtype),
    }


def init_slstm_cache(batch: int, heads: int, head_dim: int,
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {
        "c": jnp.zeros((batch, heads, head_dim), dtype),
        "n": jnp.zeros((batch, heads, head_dim), dtype),
        "h": jnp.zeros((batch, heads, head_dim), dtype),
        "m": jnp.full((batch, heads, head_dim), -jnp.inf, dtype),
    }
