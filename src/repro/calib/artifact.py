"""Calibration stage 3: serialized compression artifacts.

One directory per artifact (``artifact.npz`` + ``artifact.json`` via the
checkpoint manager's structure-carrying codec) holding:

- the per-MoE-layer ``CompressedExpertStack`` dicts — bit-plane packed
  weights, scales/zeros, padded-rank factors, per-expert true ranks and
  bits — exactly the trees ``compress_moe_params`` produces, restored
  bit-identically;
- the ``CompressionPlan`` (JSON, in the manifest) that produced them;
- a config fingerprint + params seed for the boot-time compatibility
  check, plus the codec's content checksum.

``launch/serve.py --artifact`` then boots a quantized engine straight
off disk: no HQQ iterations, no SVDs — serve startup becomes
load-an-artifact instead of recompress-every-time.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Optional, Tuple

from ..checkpoint.manager import (load_artifact, register_artifact_dataclass,
                                  save_artifact)
from ..config import ModelConfig
from ..core.compensator import Compensator
from ..core.pipeline import CompressedExpertStack
from ..core.quantize import QuantizedTensor
from .allocate import CompressionPlan

ARTIFACT_VERSION = 1

# the compression dataclasses the codec round-trips (meta fields = the
# jax.tree_util registration's static fields)
register_artifact_dataclass(QuantizedTensor,
                            ("bits", "group_size", "shape"))
register_artifact_dataclass(Compensator,
                            ("rank", "pad_rank", "factor_bits"))
register_artifact_dataclass(CompressedExpertStack,
                            ("bits", "group_size", "shape", "ranks",
                             "pad_rank", "factor_bits", "expert_bits"))


def config_fingerprint(cfg: ModelConfig) -> str:
    """Stable hash of everything the artifact layout depends on —
    restoring onto a config with a different expert geometry or quant
    recipe must fail the compatibility check, not segfault in a kernel."""
    d = dataclasses.asdict(cfg)
    blob = json.dumps(d, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def save_compression_artifact(path, cfg: ModelConfig,
                              stacks_by_layer: List[Dict],
                              plan: Optional[CompressionPlan] = None,
                              seed: int = 0,
                              extra: Optional[Dict] = None) -> Dict:
    """Serialize compressed stacks (+ the plan that produced them)."""
    meta = {
        "version": ARTIFACT_VERSION,
        "arch": cfg.name,
        "fingerprint": config_fingerprint(cfg),
        "seed": int(seed),
        "moe_layers": len(stacks_by_layer),
        "plan": None if plan is None else plan.to_json(),
        "extra": extra or {},
    }
    return save_artifact(path, stacks_by_layer, meta=meta)


def load_compression_artifact(path, cfg: Optional[ModelConfig] = None,
                              strict: bool = True
                              ) -> Tuple[List[Dict], Optional[CompressionPlan],
                                         Dict]:
    """Load ``(stacks_by_layer, plan, manifest-meta)``; when ``cfg`` is
    given the stored fingerprint must match (``strict=False`` downgrades
    a mismatch to a manifest flag for inspection tools)."""
    tree, manifest = load_artifact(path)
    meta = manifest["meta"]
    if meta.get("version") != ARTIFACT_VERSION:
        raise ValueError(f"artifact version {meta.get('version')} != "
                         f"{ARTIFACT_VERSION}")
    if cfg is not None:
        want = config_fingerprint(cfg)
        if meta["fingerprint"] != want:
            msg = (f"artifact was compressed for {meta['arch']} "
                   f"(fingerprint {meta['fingerprint']}), not "
                   f"{cfg.name} ({want})")
            if strict:
                raise ValueError(msg)
            meta = {**meta, "fingerprint_mismatch": msg}
    stacks_by_layer = list(tree)
    plan = (CompressionPlan.from_json(meta["plan"])
            if meta.get("plan") else None)
    return stacks_by_layer, plan, meta
