"""Perf-regression gate behavior: detection, tolerance, baseline update,
and malformed-input exit codes (tools/bench_check.py)."""
import importlib.util
import json
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "bench_check", REPO / "tools" / "bench_check.py")
bench_check = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_check)


def _snapshot(base_tok_s=100.0, new_tok_s=100.0,
              base_mb=2.0, new_mb=2.0):
    row = lambda tok, mb: [{"name": "b4/g64/r16", "tok_s": tok,
                            "mb_per_tok": mb}]
    return {"serve": {
        "baseline": {"time": "t0", "rows": row(base_tok_s, base_mb)},
        "runs": [{"time": "t1", "rows": row(new_tok_s, new_mb)}],
    }}


def _write(tmp_path, snap):
    p = tmp_path / "BENCH_serving.json"
    p.write_text(json.dumps(snap))
    return p


def test_within_tolerance_passes(tmp_path, capsys):
    p = _write(tmp_path, _snapshot(new_tok_s=95.0))   # -5% under 10% tol
    assert bench_check.main(["--snapshot", str(p)]) == 0
    assert "bench-check ok" in capsys.readouterr().out


def test_throughput_regression_detected(tmp_path, capsys):
    p = _write(tmp_path, _snapshot(new_tok_s=80.0))   # -20% over 10% tol
    assert bench_check.main(["--snapshot", str(p)]) == 1
    assert "regression budget" in capsys.readouterr().out


def test_bytes_regression_detected(tmp_path):
    # deterministic byte metric growing 50%: fails even at a loose
    # wall-clock tolerance (tok/s noise must not loosen the byte gate)
    p = _write(tmp_path, _snapshot(new_mb=3.0))
    assert bench_check.main(["--snapshot", str(p),
                             "--tol-tok-s", "0.40"]) == 1


def test_loose_tok_s_tolerance_is_respected(tmp_path):
    p = _write(tmp_path, _snapshot(new_tok_s=70.0))   # -30%
    assert bench_check.main(["--snapshot", str(p)]) == 1
    assert bench_check.main(["--snapshot", str(p),
                             "--tol-tok-s", "0.40"]) == 0


def test_update_baseline_roundtrip(tmp_path):
    p = _write(tmp_path, _snapshot(new_tok_s=80.0))
    assert bench_check.main(["--snapshot", str(p)]) == 1
    assert bench_check.main(["--snapshot", str(p),
                             "--update-baseline"]) == 0
    # baseline moved to the newest run -> the same run now gates clean
    assert bench_check.main(["--snapshot", str(p)]) == 0
    snap = json.loads(p.read_text())
    assert snap["serve"]["baseline"] == snap["serve"]["runs"][-1]


@pytest.mark.parametrize("payload", ["{truncated", "[1, 2]", '"nope"'])
def test_malformed_snapshot_exits_2(tmp_path, payload):
    p = tmp_path / "BENCH_serving.json"
    p.write_text(payload)
    assert bench_check.main(["--snapshot", str(p)]) == 2


def test_missing_snapshot_is_not_an_error(tmp_path):
    assert bench_check.main(
        ["--snapshot", str(tmp_path / "nope.json")]) == 0


def test_vanished_row_reported_not_gated(tmp_path, capsys):
    snap = _snapshot()
    snap["serve"]["runs"][-1]["rows"] = []            # row gone entirely
    p = _write(tmp_path, snap)
    assert bench_check.main(["--snapshot", str(p)]) == 0
    assert "row gone" in capsys.readouterr().out
