# Repo verification targets.
#
#   make tier1   fast correctness gate (excludes @pytest.mark.slow)
#   make tier1-dist      multi-device tier: the @pytest.mark.dist tests
#                        run IN-PROCESS on 8 forced host devices
#   make test    full suite, including slow/benchmarks-adjacent tests
#   make bench-smoke     quick continuous-batching serving sweep
#                        (writes the BENCH_serving.json snapshot)
#   make bench-ep        expert-parallel shard-count sweep (8 host devices)
#   make bench-frontier  bandwidth-budget frontier sweep (controller)
#   make compress-smoke  calibrate -> allocate -> artifact -> serve 8
#                        tokens from it (the offline-pipeline CI gate)
#   make bench-kernels   kernel microbench + fused-vs-unfused HBM bytes
#                        (appends to the BENCH_serving.json trajectory)
#   make bench-check     perf-regression gate: newest BENCH_serving.json
#                        run vs its committed baseline (>10% fails;
#                        accept intended changes with
#                        `python tools/bench_check.py --update-baseline`)
#   make tier1-kernels   fused-kernel parity tier under the Pallas
#                        interpreter (REPRO_KERNEL_IMPL=pallas_interpret
#                        forces the serving path through the kernel)
#   make tier1-stream    async expert-streaming tier: the metered-bytes
#                        oracle, staging-ring state machine (hypothesis),
#                        and transfer fault-injection tests
#   make tier1-paged     paged-KV-cache tier: paged-vs-contiguous token
#                        identity across ragged mixes, page-pool
#                        refcount/aliasing properties, prefix reuse,
#                        scheduler timing fixes
#   make bench-stream    compute/transfer overlap sweep (streamed vs
#                        resident decode; appends to BENCH_serving.json)
#   make bench-paged     paged-cache HBM bytes/token + prefix-reuse sweep
#                        vs the bucketed baseline (appends to
#                        BENCH_serving.json; cache_mb_per_tok gated down)
#   make tier1-spec      speculative-decoding tier: rejection-sampling
#                        acceptance properties, temp-0 token identity vs
#                        the non-speculative engine, KV rollback
#                        bit-identity, lookahead prefetch metering
#   make bench-spec      draft/verify serving sweep: lookahead prefetch
#                        accuracy vs the layer-ahead heuristic on the
#                        same workload (appends to BENCH_serving.json;
#                        prefetch_acc + accept_rate gated up)
#   make lint    repro-lint static analysis over src/ tools/ benchmarks/
#                (jit purity, canonical byte accounting, tile legality;
#                see tools/repro_lint.py --list-rules)
#   make docs-check      every doc cross-reference resolves
#   make check   the gate bundle CI runs: lint + docs-check +
#                bench-check + tier1-stream + tier1-paged + tier1-spec
#                (add gates HERE so CI cannot drift)
#   make serve-example   live-decode offload + controller report

PY = PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: tier1 tier1-dist tier1-kernels tier1-stream tier1-paged \
	tier1-spec test bench-smoke bench-ep bench-frontier bench-kernels \
	bench-stream bench-paged bench-spec bench-check compress-smoke \
	lint docs-check check serve-example

# dist-marked tests are excluded here only to avoid running them twice
# in CI — tier1-dist runs exactly those, in-process on 8 host devices;
# the full `make test` / `pytest -x -q` gate still covers both.
tier1:
	$(PY) -m pytest -x -q -m "not slow and not dist"

tier1-dist:
	REPRO_HOST_DEVICES=8 $(PY) -m pytest -x -q -m "dist and not slow"

# fused-kernel parity + backend dispatch with the env policy pinned to the
# interpreter: the same tests tier1 runs, but the engine/serving paths are
# forced through the Pallas kernel body rather than the ref oracle
tier1-kernels:
	REPRO_KERNEL_IMPL=pallas_interpret $(PY) -m pytest -x -q \
		tests/test_fused_kernel.py tests/test_expert_backend.py \
		tests/test_autotune.py tests/test_kernels_quant_matmul.py

# the async-streaming correctness tier: metered bytes == observed
# transfer-engine copies (the oracle), ring state-machine properties,
# and the delay/stall fault-injection suite
tier1-stream:
	$(PY) -m pytest -x -q tests/test_streaming_oracle.py \
		tests/test_staging_ring.py tests/test_fault_tolerance.py

# the paged-KV-cache correctness tier: paged decode token-identical to
# the contiguous path across ragged/int8/local-window mixes, page-pool
# refcount + no-aliasing properties, shared-prefix reuse, and the
# scheduler timing/termination regressions
# dist-marked rows (ep=2 parity) run under tier1-dist like every other
# dist test; this tier is the single-device matrix
tier1-paged:
	$(PY) -m pytest -x -q -m "not dist" tests/test_paged_cache.py

# the speculative-decoding correctness tier: acceptance-mask properties
# (hypothesis + deterministic edges), greedy spec decode token-identical
# to the autoregressive engine, rejected-suffix KV rollback leaving the
# cache bit-identical to never having drafted, and the metered-bytes
# oracle with speculation on
# dist-marked rows (ep=2 identity) run under tier1-dist like every other
# dist test; this tier is the single-device matrix
tier1-spec:
	$(PY) -m pytest -x -q -m "not dist" tests/test_speculative.py

test:
	$(PY) -m pytest -q

bench-smoke:
	$(PY) benchmarks/bench_serving.py --quick

bench-ep:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) benchmarks/bench_serving.py --quick --mesh ep=8

bench-frontier:
	$(PY) benchmarks/bench_serving.py --quick --frontier

bench-kernels:
	$(PY) -m benchmarks.bench_kernels --quick

bench-stream:
	$(PY) benchmarks/bench_serving.py --quick --stream

bench-paged:
	$(PY) benchmarks/bench_serving.py --quick --paged

bench-spec:
	$(PY) benchmarks/bench_serving.py --quick --spec

# wall-clock tok/s is noisy on shared CI hosts: gate it loosely there via
# TOL_TOK_S; the deterministic bytes/token metrics keep the tight 10%
TOL_TOK_S ?= 0.10
bench-check:
	python tools/bench_check.py --tol-tok-s $(TOL_TOK_S)

compress-smoke:
	$(PY) -m repro.launch.compress --arch mixtral-8x7b \
		--out experiments/compress_smoke --calib-batches 2 \
		--calib-batch-size 4 --calib-seq-len 64 --budget-frac 0.9
	$(PY) -m repro.launch.serve --arch mixtral-8x7b --offload \
		--artifact experiments/compress_smoke \
		--batch 1 --prompt-len 8 --max-new 8

lint:
	python tools/repro_lint.py

docs-check:
	python tools/docs_check.py

# single meta-target for the gate bundle CI runs (not the individual
# targets), so adding a gate here adds it to CI automatically; the
# streaming tier rides along because its oracle is the cheap end-to-end
# proof that the offload byte meter still matches real data movement,
# the paged tier because token identity vs the contiguous cache is the
# paged path's correctness oracle, and the speculative tier because
# token identity vs the autoregressive engine is the draft/verify
# path's correctness oracle
check: lint docs-check bench-check tier1-stream tier1-paged tier1-spec

serve-example:
	$(PY) examples/serve_offload.py
