"""Serving CLI: ``python -m repro.launch.serve --arch <id> [...]``.

Boots the reduced config on CPU (or full config on a real pod), randomly
initializes or restores weights, and serves synthetic traffic through
the continuous-batching engine — one slot-indexed KV cache and one
compiled ``lax.scan`` decode chunk stay resident while the scheduler
admits, retires, and refills requests between chunks:

- default: one fixed batch (``--batch`` x ``--prompt-len``), reporting
  prefill latency and decode tokens/s;
- ``--requests N``: a scheduled workload of N ragged-length requests
  (optionally arriving at ``--rate`` req/s) onto ``--slots`` decode
  slots in ``--chunk``-step scan chunks, reporting throughput and
  p50/p95 request latency;
- ``--offload``: compress the MoE experts offline (BEAM-LRC: low-bit +
  rank-padded compensators) and serve from byte-metered host-side
  expert stores, reporting live wire bytes/token and cache hit rate;
- ``--artifact DIR`` (with ``--offload``): boot from a serialized
  compression artifact (``launch/compress.py``) instead of
  recompressing at startup — the stacks (possibly heterogeneous
  per-expert bits/ranks from the calibrated allocator) load off disk
  after a config-fingerprint + checksum check, and serving is
  bit-identical to in-memory compression of the same plan;
- ``--bytes-per-token B`` / ``--target-tokens-per-s T`` (with
  ``--offload``): close the loop with the runtime bandwidth-budget
  controller — between scan chunks it retunes the per-layer
  (top_n, rank_cap) restoration plan to meet the budget (B directly, or
  the bytes/token a ``--link-bw`` link affords at T tokens/s), budgeting
  either the aggregate link or (``--budget-scope per_shard``) the
  hottest shard's link;
- ``--stream`` (with ``--offload``): serve through the REAL async
  expert-streaming engine — compressed experts live in host memory and
  stream into device containers through per-layer staging rings
  overlapped with decode; ``--stream-miss block`` keeps decode
  token-identical to all-resident (stage + re-run on a true miss),
  ``--stream-miss degrade`` serves misses from the device-resident
  ``--stream-fallback-bits`` fallback instead of stalling; the report
  adds overlap efficiency, stalls, and the metered==observed byte check;
- ``--spec-k K`` (with ``--requests``): speculative decoding — a
  ``--drafter`` (backoff n-gram / small draft model / windowed
  self-draft) proposes K tokens per slot per round, one batched target
  pass verifies them by rejection sampling (token-identical to plain
  decode at temperature 0), accepted prefixes commit their KV entries
  and rejected suffixes roll back; with ``--offload`` the verify pass's
  router trace drives the lookahead prefetcher, and the report adds
  acceptance rate, lookahead prefetch accuracy, and the wasted-
  speculation draft overhead bytes;
- ``--mesh ep=N``: expert-parallel sharded serving — experts (and their
  quantized planes + compensator factors) partition over an N-way
  ``('model',)`` mesh, decode runs resident-expert partials + psum under
  shard_map, and the offload meter splits into per-shard stores whose
  link bytes reduce into the report.  On CPU this needs
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ControlConfig
from ..registry import get_config
from ..models import init_params
from ..models.transformer import compress_moe_params
from ..serve import ServeEngine, synthetic_workload
from .mesh import make_serve_mesh, parse_mesh_spec


def _maybe_autotune(stacks_by_layer):
    """``REPRO_AUTOTUNE=1`` boot-time tile sweep for the fused decode
    kernel: time the roofline candidates once per unique (bits,
    group_size, rank, K, N) decode shape on the local device and persist
    the winners (``kernels/autotune.py``).  Only runs where the compiled
    Mosaic kernel is the serving path — on CPU the lookup table already
    decides, and interpreter timings would be meaningless."""
    from ..kernels.autotune import autotune_enabled, tune_fused
    from ..kernels.ops import resolve_impl
    if not autotune_enabled() or resolve_impl(None) != "pallas":
        return
    seen = set()
    for stacks in stacks_by_layer:
        for name, stack in stacks.items():
            e, k, n = stack.shape
            key = (stack.bits, stack.group_size, stack.pad_rank, k, n)
            if key in seen:
                continue
            seen.add(key)
            xe = jnp.zeros((len(stack.ranks), 8, k), jnp.float32)
            me = jnp.ones((len(stack.ranks), 8), jnp.float32)
            tiles = tune_fused(xe, stack, me, None, None,
                               out_dtype=jnp.float32, interpret=False)
            print(f"autotune: fused b{stack.bits} k{k} n{n} -> "
                  f"bm,bn,bk={tiles}")


def main():
    ap = argparse.ArgumentParser(
        description="serve synthetic traffic through the continuous-"
                    "batching engine (scheduler + fixed-shape scan chunks)")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=2,
                    help="fixed-batch mode: rows decoded side by side")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--requests", type=int, default=0,
                    help="schedule N ragged requests through the slot pool "
                         "instead of one fixed batch")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="offered load in requests/s (0 = all at t=0)")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode-slot pool size (compiled batch rows)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps per scan chunk; the scheduler "
                         "refills finished slots between chunks")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV cache: allocate attention cache in "
                         "pages of this many tokens (power of two; 0 = "
                         "contiguous bucketed cache). Capacity is "
                         "per-request instead of worst-case-bucketed, "
                         "decode still compiles once")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="refcount-share physical pages across requests "
                         "with a common prompt prefix so the shared "
                         "span's prefill runs once (needs --page-size)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft this many tokens "
                         "per slot per round and verify them in one "
                         "batched target pass (0 = off; needs "
                         "--requests; token-identical at temperature 0)")
    ap.add_argument("--drafter", default="ngram",
                    choices=("ngram", "model", "self"),
                    help="speculative drafter: backoff n-gram over each "
                         "slot's committed stream, a small random-init "
                         "dense draft model, or the serving model itself "
                         "re-read over a token window (the idealized "
                         "high-acceptance drafter)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="",
                    help="expert-parallel serving mesh, e.g. 'ep=4': "
                         "partition experts over N devices (CPU needs "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N)")
    # -- offload + bandwidth-budget controller ---------------------------
    ap.add_argument("--offload", action="store_true",
                    help="compress MoE experts and meter offloaded serving "
                         "(wire bytes, cache hits) from live decode routing")
    ap.add_argument("--artifact", default="",
                    help="boot the compressed stacks from a "
                         "launch/compress.py artifact directory instead "
                         "of recompressing at startup (needs --offload)")
    ap.add_argument("--cache-experts", type=int, default=4,
                    help="device-resident expert LRU capacity per layer")
    ap.add_argument("--bytes-per-token", type=float, default=0.0,
                    help="bandwidth budget: adapt per-layer (top_n, "
                         "rank_cap) to this many wire bytes per token")
    ap.add_argument("--target-tokens-per-s", type=float, default=0.0,
                    help="bandwidth SLO: budget = link-bw / target tok/s")
    ap.add_argument("--link-bw", type=float, default=25e9,
                    help="link bandwidth (bytes/s) for --target-tokens-per-s")
    ap.add_argument("--budget-scope", default="aggregate",
                    choices=("aggregate", "per_shard"),
                    help="what the byte budget constrains under --mesh: "
                         "the summed links or the hottest shard's link")
    # -- async expert streaming -------------------------------------------
    ap.add_argument("--stream", action="store_true",
                    help="serve through the async expert-streaming engine "
                         "(needs --offload): experts live in host memory "
                         "and stream into device containers via per-layer "
                         "staging rings; decode blocks only on a true miss")
    ap.add_argument("--stream-ring", type=int, default=2,
                    help="staging-ring slots per layer (in-flight H2D "
                         "copies; 2 = double buffer)")
    ap.add_argument("--stream-miss", default="block",
                    choices=("block", "degrade"),
                    help="on a routed expert whose copy has not landed: "
                         "'block' stages + re-runs the chunk (token-"
                         "identical to all-resident), 'degrade' serves it "
                         "from the resident low-bit fallback")
    ap.add_argument("--stream-fallback-bits", type=int, default=2,
                    help="bit width of the device-resident fallback copy "
                         "that serves missed experts under 'degrade'")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full_config)
    if cfg.encoder is not None or cfg.rope_kind == "mrope":
        print(f"note: {cfg.name} needs frontend inputs; serving the "
              f"text-only path")
    # params follow --seed on BOTH paths, so `--offload` (in-memory
    # compression) and `--offload --artifact` compare bit-identically at
    # any seed, not just the default 0
    params = init_params(jax.random.key(args.seed), cfg, jnp.float32)
    mesh = make_serve_mesh(parse_mesh_spec(args.mesh).get("ep", 1)
                           if args.mesh else 1)

    want_budget = args.bytes_per_token > 0 or args.target_tokens_per_s > 0
    if want_budget and not args.offload:
        ap.error("--bytes-per-token/--target-tokens-per-s need --offload "
                 "(the controller feeds on the offload byte meters)")
    if args.artifact and not args.offload:
        ap.error("--artifact needs --offload (it replaces the startup "
                 "compression of the offload path)")
    if args.stream and not args.offload:
        ap.error("--stream needs --offload (the stream engine is driven "
                 "by the offload stores' metering events)")
    if args.stream and args.mesh:
        ap.error("--stream requires the single-device serving path "
                 "(mesh-sharded streaming is not supported)")
    if args.spec_k > 0 and args.requests <= 0:
        ap.error("--spec-k needs --requests (speculative rounds run "
                 "through the continuous-batching scheduler)")
    if args.offload:
        if cfg.moe is None:
            ap.error(f"--offload needs an MoE arch; {cfg.name} has none")
        if args.artifact:
            from ..calib import load_compression_artifact
            from ..models.transformer import apply_compressed_stacks
            stacks_by_layer, plan, meta = load_compression_artifact(
                args.artifact, cfg)
            if meta.get("seed", 0) != args.seed:
                ap.error(f"artifact was compressed against params seed "
                         f"{meta.get('seed')}, serving seed {args.seed}")
            qparams, cfg_q = apply_compressed_stacks(params, cfg,
                                                     stacks_by_layer)
            print(f"booted artifact {args.artifact}: "
                  f"{meta['moe_layers']} MoE layers, "
                  f"plan={'none (uniform)' if plan is None else plan.scorer},"
                  f" checksum ok — no startup recompression")
        else:
            qparams, cfg_q, stacks_by_layer = compress_moe_params(params,
                                                                  cfg)
        _maybe_autotune(stacks_by_layer)
        eng = ServeEngine(cfg_q, qparams, quantized=True, mesh=mesh)
        eng.attach_offload(stacks_by_layer, policy="ours",
                           cache_capacity=args.cache_experts)
        if want_budget:
            eng.attach_controller(ControlConfig(
                enabled=True, bytes_per_token=args.bytes_per_token,
                tokens_per_s=args.target_tokens_per_s,
                link_bw=args.link_bw, budget_scope=args.budget_scope))
        if args.stream:
            from ..config import StreamConfig
            eng.attach_streaming(StreamConfig(
                enabled=True, ring_slots=args.stream_ring,
                miss_policy=args.stream_miss,
                fallback_bits=args.stream_fallback_bits))
    else:
        eng = ServeEngine(cfg, params, mesh=mesh)

    if args.requests > 0:
        reqs = synthetic_workload(
            args.requests, cfg.vocab_size, rate=args.rate,
            max_new=args.max_new, min_len=max(args.prompt_len // 2, 1),
            max_len=args.prompt_len, seed=args.seed)
        stats = eng.serve(reqs, num_slots=args.slots, chunk=args.chunk,
                          seed=args.seed, page_size=args.page_size,
                          prefix_cache=args.prefix_cache,
                          spec_k=args.spec_k,
                          drafter=args.drafter if args.spec_k > 0 else None)
        lat = stats.latency_percentiles((50.0, 95.0))
        print(f"{cfg.name}: {args.requests} requests on {args.slots} slots "
              f"(chunk {args.chunk}, rate "
              f"{args.rate if args.rate > 0 else 'closed-loop'}): "
              f"{stats.tokens_per_s:.1f} tok/s, "
              f"latency p50 {lat[50.0] * 1e3:.0f}ms "
              f"p95 {lat[95.0] * 1e3:.0f}ms, "
              f"{stats.chunks} chunks, compiles {eng.num_compiles}")
        print(f"cache: {stats.cache_hbm_bytes / 2**20:.2f} MiB HBM "
              f"({stats.cache_hbm_bytes_per_token / 2**10:.1f} KiB/token), "
              f"{stats.prefill_tokens} prefill tokens")
        pr = stats.page_report
        if pr is not None:
            print(f"pages ({pr['num_pages']}x{pr['page_size']}): "
                  f"{pr['allocs']} allocs, prefix hit "
                  f"{pr['prefix_hit_rate']:.0%} "
                  f"({pr['prefix_hits']}/{pr['prefix_queries']}), "
                  f"peak shared ref {pr['peak_shared_ref']}, "
                  f"{pr['evictions']} evictions")
        rep = stats.offload_report
        if rep is not None:
            print(f"offload ({rep['policy']}): "
                  f"{rep['bytes_per_token'] / 2**10:.1f} KiB/token, "
                  f"cache hit {rep['hit_rate']:.0%}, prefetch accuracy "
                  f"{rep['prefetch_accuracy']:.0%}")
            if rep["ep"] > 1:
                shares = ", ".join(f"{b / 2**10:.0f}"
                                   for b in rep["per_shard_bytes"])
                print(f"  per-shard links (ep={rep['ep']}): [{shares}] KiB, "
                      f"hottest {rep['max_shard_bytes_per_token'] / 2**10:.1f}"
                      f" KiB/token")
        sp = stats.spec_report
        if sp is not None:
            print(f"speculative (k={sp['spec_k']}, {sp['drafter']}): "
                  f"acceptance {sp['acceptance_rate']:.0%} "
                  f"({sp['accepted_draft_tokens']}/{sp['drafted_tokens']} "
                  f"drafts over {sp['rounds']} rounds), lookahead "
                  f"prefetch accuracy {sp['lookahead_accuracy']:.0%}, "
                  f"draft overhead "
                  f"{sp['draft_overhead_bytes'] / 2**10:.1f} KiB")
        sr = stats.stream_report
        if sr is not None:
            print(f"stream ({sr['miss_policy']}, ring {sr['ring_slots']}): "
                  f"overlap {sr['overlap_efficiency']:.0%}, "
                  f"{sr['observed_copies']} copies "
                  f"({sr['observed_copy_bytes'] / 2**20:.1f} MiB observed "
                  f"== {sr['metered_bytes'] / 2**20:.1f} MiB metered), "
                  f"{sr['stalls']} stalls ({sr['stall_s'] * 1e3:.0f}ms), "
                  f"{sr['reruns']} re-runs, "
                  f"{sr['degraded_tokens']} degraded tokens")
        if eng.controller is not None and eng.controller.history:
            c = eng.controller
            tail = c.history[len(c.history) // 2:]
            meas = float(np.mean([h.bytes_per_token for h in tail]))
            plan = c.plan().summary()
            print(f"controller: budget "
                  f"{c.ccfg.target_bytes_per_token / 2**10:.1f} KiB/token, "
                  f"converged tail {meas / 2**10:.1f} KiB/token "
                  f"({len(c.history)} updates), plan mean top_n "
                  f"{plan['mean_top_n']:.2f} rank_cap "
                  f"{plan['mean_rank_cap']:.1f}")
        return

    prompts = np.random.default_rng(args.seed).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    res = eng.generate(prompts, max_new=args.max_new)
    print(f"{cfg.name}: prefill {res.prefill_s * 1e3:.0f}ms, "
          f"decode {res.decode_tokens_per_s:.1f} tok/s "
          f"({args.batch}x{args.max_new} tokens)")
    if res.offload_report is not None:
        rep = res.offload_report
        print(f"offload ({rep['policy']}): "
              f"{rep['bytes_per_token'] / 2**10:.1f} KiB/token, "
              f"cache hit {rep['hit_rate']:.0%}")
    if res.stream_report is not None:
        sr = res.stream_report
        print(f"stream ({sr['miss_policy']}, ring {sr['ring_slots']}): "
              f"overlap {sr['overlap_efficiency']:.0%}, "
              f"{sr['observed_copies']} copies "
              f"({sr['observed_copy_bytes'] / 2**20:.1f} MiB observed == "
              f"{sr['metered_bytes'] / 2**20:.1f} MiB metered), "
              f"{sr['stalls']} stalls, {sr['degraded_tokens']} degraded "
              f"tokens")


if __name__ == "__main__":
    main()
