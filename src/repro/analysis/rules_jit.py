"""RL1xx jit-scope purity rules and RL4xx repo-idiom rules.

RL101 host-sync-in-jit        .item()/.tolist()/.block_until_ready(),
                              float()/int()/bool(), np.asarray/np.array on
                              a traced value inside jit scope — a forced
                              device sync (or a trace-time concretization
                              error waiting for the first real input).
RL102 traced-control-flow     Python ``if``/``while`` testing a traced
                              value, or ``for``/``while`` over
                              ``range(traced)`` — concretizes the tracer;
                              when it survives (cond on a leading-axis
                              bool) it recompiles per value.  The traced
                              plan row must stay data (``jnp.where`` /
                              ``lax.cond``), never Python control flow.
RL103 traced-static-arg       traced value flowing into a shape/static
                              argument (``jnp.zeros(shape=...)``,
                              ``.reshape``, ``ShapeDtypeStruct``, a
                              callee's ``static_argnames``) — every new
                              value is a fresh compile of the decode scan.
RL104 device-get-in-jit       ``jax.device_get`` anywhere in jit scope
                              (scan bodies included) — the repo idiom is
                              to return values and fetch on the host.
RL401 unpinned-mesh-output    a jitted entry point in a mesh-path module
                              (one importing ``tree_constraint``) returns
                              a bare ``caches``/``logits`` value without
                              routing it through a pinning helper —
                              sharding-propagation churn shows up as a
                              spurious recompile per chunk.
"""
from __future__ import annotations

import ast
import re
from typing import List

from .core import Finding, rule
from .jitscope import JitScope, _dotted
from .taint import TaintAnalysis, _is_none_check

SYNC_METHODS = {"item", "tolist", "block_until_ready", "copy_to_host_async"}
SYNC_CASTS = {"float", "int", "bool"}
NP_SYNC = {"np.asarray", "np.array", "np.copy", "numpy.asarray",
           "numpy.array", "numpy.copy", "onp.asarray", "onp.array"}

SHAPE_KWARGS = {"shape", "new_sizes", "length", "num", "total_repeat_length"}
SHAPE_FUNCS = {  # positional index of the shape/static-size argument
    "jnp.zeros": 0, "jnp.ones": 0, "jnp.full": 0, "jnp.empty": 0,
    "jnp.arange": 0, "jax.ShapeDtypeStruct": 0,
    "jnp.broadcast_to": 1,                      # (array, shape)
    "lax.broadcasted_iota": 1,                  # (dtype, shape, dim)
    "jax.lax.broadcasted_iota": 1,
}

PIN_HELPERS = {"tree_constraint", "with_sharding_constraint", "_pin_caches",
               "_pin_logits", "_pin_outputs"}
_MESH_OUT_RE = re.compile(r"(^|_)(caches|logits)$")


def _iter_scope(scope: JitScope):
    for q in sorted(scope.members):
        info = scope.index.functions.get(q)
        if info is None:
            continue
        yield q, info, TaintAnalysis(info)


@rule("RL101", "host sync on a traced value inside jit scope")
def rl101(scope: JitScope, ctx) -> List[Finding]:
    out = []
    for q, info, ta in ctx.scope_taints(scope):
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Call):
                continue
            head = _dotted(node.func)
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr in SYNC_METHODS and \
                    ta.expr_tainted(node.func.value):
                out.append(ctx.finding(
                    "RL101", info,  node,
                    f".{node.func.attr}() on a traced value in jit scope "
                    f"({q.split('.')[-1]}) forces a host sync"))
            elif head in SYNC_CASTS and node.args and \
                    ta.expr_tainted(node.args[0]):
                out.append(ctx.finding(
                    "RL101", info, node,
                    f"{head}() on a traced value in jit scope concretizes "
                    f"the tracer"))
            elif head in NP_SYNC and node.args and \
                    ta.expr_tainted(node.args[0]):
                out.append(ctx.finding(
                    "RL101", info, node,
                    f"{head}() on a traced value in jit scope pulls the "
                    f"array to host"))
    return out


@rule("RL102", "Python control flow on a traced value inside jit scope")
def rl102(scope: JitScope, ctx) -> List[Finding]:
    out = []
    for q, info, ta in ctx.scope_taints(scope):
        for node in ast.walk(info.node):
            if isinstance(node, (ast.If, ast.While)):
                if _is_none_check(node.test):
                    continue
                if ta.expr_tainted(node.test):
                    kw = "if" if isinstance(node, ast.If) else "while"
                    out.append(ctx.finding(
                        "RL102", info, node,
                        f"Python `{kw}` on a traced value in jit scope "
                        f"({q.split('.')[-1]}); keep plan/gate values as "
                        f"data (jnp.where / lax.cond)"))
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                it = node.iter
                if isinstance(it, ast.Call) and \
                        _dotted(it.func) == "range" and \
                        any(ta.expr_tainted(a) for a in it.args):
                    out.append(ctx.finding(
                        "RL102", info, node,
                        "`for ... in range(<traced>)` in jit scope "
                        "concretizes the tracer; use lax.fori_loop/scan"))
    return out


@rule("RL103", "traced value flowing into a shape/static argument")
def rl103(scope: JitScope, ctx) -> List[Finding]:
    out = []
    for q, info, ta in ctx.scope_taints(scope):
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Call):
                continue
            head = _dotted(node.func) or ""
            # shape-taking constructors: the shape positional
            if head in SHAPE_FUNCS and \
                    len(node.args) > SHAPE_FUNCS[head] and \
                    ta.expr_tainted(node.args[SHAPE_FUNCS[head]]):
                out.append(ctx.finding(
                    "RL103", info, node,
                    f"traced value as the shape argument of {head}() — "
                    f"recompiles per value"))
                continue
            # .reshape(...) with traced dims
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "reshape" and \
                    any(ta.expr_tainted(a) for a in node.args):
                out.append(ctx.finding(
                    "RL103", info, node,
                    "traced value in .reshape() dims — recompiles per "
                    "value (derive dims from .shape instead)"))
                continue
            # shape-named keywords anywhere
            for kwarg in node.keywords:
                if kwarg.arg in SHAPE_KWARGS and ta.expr_tainted(kwarg.value):
                    out.append(ctx.finding(
                        "RL103", info, node,
                        f"traced value into static `{kwarg.arg}=` of "
                        f"{head or 'call'}() — recompiles per value"))
            # calls into a known jitted callee's static_argnames
            target = scope.index.resolve_call(node.func, info)
            if target and target in scope.members:
                tinfo = scope.index.functions[target]
                if "jit" in tinfo.root_kinds:
                    for kwarg in node.keywords:
                        if kwarg.arg in tinfo.static_params and \
                                kwarg.arg not in ("self", "cls") and \
                                ta.expr_tainted(kwarg.value):
                            out.append(ctx.finding(
                                "RL103", info, node,
                                f"traced value bound to static arg "
                                f"`{kwarg.arg}` of jitted "
                                f"{target.split('.')[-1]}() — every new "
                                f"value is a fresh compile"))
    return out


@rule("RL104", "jax.device_get inside jit scope")
def rl104(scope: JitScope, ctx) -> List[Finding]:
    out = []
    for q, info, _ta in ctx.scope_taints(scope):
        for node in ast.walk(info.node):
            if isinstance(node, ast.Call) and \
                    _dotted(node.func) in ("jax.device_get", "device_get"):
                out.append(ctx.finding(
                    "RL104", info, node,
                    f"jax.device_get in jit scope ({q.split('.')[-1]}); "
                    f"return the value and fetch on the host"))
    return out


@rule("RL401", "unpinned cache/logits output on a mesh-path jit entry")
def rl401(scope: JitScope, ctx) -> List[Finding]:
    out = []
    for q in sorted(scope.roots):
        info = scope.index.functions.get(q)
        if info is None or "jit" not in scope.roots[q]:
            continue
        # mesh-path modules self-identify by importing tree_constraint
        imports = scope.index.imports.get(info.module, {})
        if not any(k in PIN_HELPERS or v.split(".")[-1] in PIN_HELPERS
                   for k, v in imports.items()):
            continue
        pinned: set = set()
        for node in ast.walk(info.node):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call):
                head = _dotted(node.value.func) or ""
                if head.split(".")[-1] in PIN_HELPERS:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            pinned.add(t.id)
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Return) or node.value is None:
                continue
            elts = node.value.elts if isinstance(node.value, ast.Tuple) \
                else [node.value]
            for el in elts:
                if isinstance(el, ast.Call):
                    head = _dotted(el.func) or ""
                    if head.split(".")[-1] in PIN_HELPERS:
                        continue
                if isinstance(el, ast.Name) and \
                        _MESH_OUT_RE.search(el.id) and el.id not in pinned:
                    out.append(ctx.finding(
                        "RL401", info, node,
                        f"jitted mesh-path entry {q.split('.')[-1]}() "
                        f"returns `{el.id}` without a sharding pin "
                        f"(tree_constraint/with_sharding_constraint) — "
                        f"propagation churn recompiles per chunk"))
    return out
