"""Pinned host-memory images of compressed expert stacks (streaming).

Real offloaded serving keeps the compressed experts in page-locked
("pinned") host memory so the DMA engine can source async H2D copies
from them.  :class:`HostExpertImage` is that staging area for one MoE
layer: per-projection numpy snapshots of every
``CompressedExpertStack`` leaf, taken once at attach time, from which
the transfer engine (``offload/staging.py``) slices per-expert copy
payloads — bit-plane codes + scale/zero for a weight fetch, factor rank
rows for a compensator fetch.  On this CPU-hosted reproduction "pinned"
is emulated by ordinary host numpy buffers; the contract that matters
(payloads are sliced host-side and cross to the device via
``jax.device_put``, never read in place by compute) is the real one.

The companion :func:`build_fallback_stack` produces the device-resident
low-bit fallback copy — MoBiLE's "little expert": a plain RTN
requantization of the dequantized layer at ``fallback_bits``, packed
into the SAME container layout (bit width, group size, padded rank, all
meta identical), with zeroed compensator factors.  The streaming engine
boots every device container from it, so a routed expert whose copy has
not landed is served degraded instead of stalling the scan, and
streamed payloads can be scattered into the container without any
shape/meta (and therefore any jit-signature) change.

No wire-byte arithmetic lives here: byte accounting stays with the
canonical formulas in ``core/quantize.py`` via the store's metering
(``offload/store.py``); this module only assembles payload pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.pipeline import CompressedExpertStack
from ..core.quantize import PLANES, _group_minmax, pack_bits

# leaves that move with a weight fetch vs a factor fetch
WEIGHT_LEAVES = ("planes", "scale", "zero")
FACTOR_LEAVES = ("u", "v", "u_scale", "v_scale")


class HostExpertImage:
    """Host-side per-expert image of one MoE layer's compressed stacks.

    ``stacks``: {proj: CompressedExpertStack} with the TRUE (offline
    compressed) contents.  Leaves are snapshotted to numpy immediately,
    so later in-place container swaps in the serving param tree cannot
    corrupt the copy source.
    """

    def __init__(self, stacks: Dict[str, CompressedExpertStack]):
        self.meta = {name: s for name, s in stacks.items()}
        self.num_experts = next(iter(stacks.values())).scale.shape[0]
        self._host: Dict[str, Dict] = {}
        for name, s in stacks.items():
            self._host[name] = {
                "planes": tuple(np.asarray(p) for p in s.planes),
                "scale": np.asarray(s.scale),
                "zero": np.asarray(s.zero),
                "u": np.asarray(s.u),
                "v": np.asarray(s.v),
                "u_scale": np.asarray(s.u_scale),
                "v_scale": np.asarray(s.v_scale),
            }

    @property
    def host_nbytes(self) -> int:
        """Actual host staging-buffer footprint (container form)."""
        total = 0
        for leaves in self._host.values():
            total += sum(p.nbytes for p in leaves["planes"])
            total += sum(leaves[k].nbytes for k in
                         ("scale", "zero", "u", "v", "u_scale", "v_scale"))
        return total

    def weight_payload(self, e: int) -> Dict[str, Dict]:
        """Copy payload for expert ``e``'s quantized weights: one
        container-form slice per projection (codes + scale/zero)."""
        out = {}
        for name, leaves in self._host.items():
            out[name] = {
                "planes": tuple(p[e] for p in leaves["planes"]),
                "scale": leaves["scale"][e],
                "zero": leaves["zero"][e],
            }
        return out

    def factor_payload(self, e: int, ranks: Dict[str, Tuple[int, int]]
                       ) -> Dict[str, Dict]:
        """Copy payload for expert ``e``'s compensator factor rows.

        ``ranks``: {proj: (lo, hi)} row window per projection (a raised
        rank cap fetches only the missing delta rows).  Projections with
        an empty window are omitted."""
        out = {}
        for name, leaves in self._host.items():
            lo, hi = ranks.get(name, (0, 0))
            if hi <= lo:
                continue
            out[name] = {
                "u": leaves["u"][e][:, lo:hi],
                "v": leaves["v"][e][lo:hi, :],
                "u_scale": leaves["u_scale"][e][:, lo:hi],
                "v_scale": leaves["v_scale"][e][lo:hi, :],
            }
        return out


def _clamp_fallback_bits(bits: int, container_bits: int) -> int:
    """Largest supported plane width <= min(bits, container width)."""
    cap = min(int(bits), int(container_bits))
    ok = [b for b in PLANES if b <= cap]
    if not ok:
        raise ValueError(f"no supported fallback width <= {cap}")
    return max(ok)


def build_fallback_stack(stack: CompressedExpertStack,
                         fallback_bits: int = 2) -> CompressedExpertStack:
    """Device-resident low-bit fallback ("little expert") for one stack.

    RTN-requantizes the dequantized stack at ``fallback_bits`` (clamped
    to the container width), packs the codes back into the ORIGINAL
    container layout, and zeroes the compensator factors.  Every meta
    field — container bits, group size, ranks, pad_rank, expert_bits —
    is preserved, so the fallback is pytree/shape/dtype-identical to the
    true stack: the streaming engine can boot the serving containers
    from it and later scatter true expert payloads in without touching
    the jitted decode loop's signature.
    """
    fb = _clamp_fallback_bits(fallback_bits, stack.bits)
    w = stack.dequantize_all()                    # (E, K, N) f32
    G = stack.group_size
    qmax = (1 << fb) - 1

    def _rtn_one(we):
        g, lo, hi = _group_minmax(we, G)
        scale = jnp.maximum((hi - lo) / qmax, 1e-8)
        zero = -lo / scale
        q = jnp.clip(jnp.round(g / scale + zero), 0, qmax)
        q = q.reshape(we.shape).astype(jnp.uint8)
        n = we.shape[1]
        return (pack_bits(q, stack.bits), scale.reshape(-1, n),
                zero.reshape(-1, n))

    planes, scale, zero = jax.vmap(_rtn_one)(w)
    return dataclasses.replace(
        stack,
        planes=tuple(jnp.asarray(p) for p in planes),
        scale=scale.astype(stack.scale.dtype),
        zero=zero.astype(stack.zero.dtype),
        u=jnp.zeros_like(stack.u), v=jnp.zeros_like(stack.v),
        u_scale=jnp.zeros_like(stack.u_scale),
        v_scale=jnp.zeros_like(stack.v_scale))


def build_fallback_stacks(stacks: Dict[str, CompressedExpertStack],
                          fallback_bits: int = 2
                          ) -> Dict[str, CompressedExpertStack]:
    """Fallback copies for every projection of one MoE layer."""
    return {name: build_fallback_stack(s, fallback_bits)
            for name, s in stacks.items()}
