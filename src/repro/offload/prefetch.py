"""Layer-ahead expert prefetcher (related-work systems [5,19,33,42]).

While layer l computes, predict layer l+1's experts and issue their
fetches.  Prediction uses the previous token's routing at l+1 (decode-time
temporal locality) — the cheap predictor HOBBIT-class systems use; accuracy
and the wasted-fetch ratio are metered so benchmarks can quantify the
prediction-miss penalty the paper's related-work section describes.

The prediction set is capped at ``top_k`` experts per active request
stream (ranked by how many streams routed to them last step): ``top_k``
is the router's per-token fetch width, so the prefetcher never issues
more speculative traffic per stream than the demand path would.
``ExpertStore.prefetch`` inserts the predictions into the device LRU and
meters their bytes — correct predictions become cache *hits* on the
demand access, mispredictions are metered as wasted prefetch bytes
(``offload/store.py::replay_decode_trace``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class PrefetchStats:
    issued: int = 0
    useful: int = 0
    wasted: int = 0

    @property
    def accuracy(self) -> float:
        return self.useful / self.issued if self.issued else 0.0


class LayerAheadPrefetcher:
    """Predicts layer l+1 experts = previous token's experts at l+1."""

    def __init__(self, num_layers: int, top_k: int):
        self.top_k = int(top_k)
        self.prev_token: List[Optional[np.ndarray]] = [None] * num_layers
        self.stats = PrefetchStats()

    def predict(self, layer: int) -> Optional[np.ndarray]:
        return self.prev_token[layer]

    def observe(self, layer: int, experts: np.ndarray):
        """Score the pending prediction against this step's experts and
        remember them for the next step.  ``experts`` may be any shape
        (batched decode passes the whole step's (rows, k) ids); entries
        < 0 (masked scheduler slots) are ignored; the stored prediction
        keeps at most ``top_k`` experts per observed row, most-frequent
        first."""
        a = np.asarray(experts)
        rows = a.reshape(-1, a.shape[-1]) if a.ndim > 1 else a.reshape(1, -1)
        rows = rows[(rows >= 0).any(axis=1)]
        flat = rows.reshape(-1)
        flat = flat[flat >= 0]
        if flat.size == 0:
            return                     # fully-masked step: keep prediction
        uniq, counts = np.unique(flat, return_counts=True)
        order = np.argsort(-counts, kind="stable")
        cap = self.top_k * max(len(rows), 1)
        experts = np.sort(uniq[order[:cap]])
        pred = self.prev_token[layer]
        if pred is not None:
            hit = len(np.intersect1d(pred, experts))
            self.stats.issued += len(pred)
            self.stats.useful += hit
            self.stats.wasted += len(pred) - hit
        self.prev_token[layer] = experts.copy()
