# Repo verification targets.
#
#   make tier1   fast correctness gate (excludes @pytest.mark.slow)
#   make test    full suite, including slow/benchmarks-adjacent tests
#   make serve-example   live-decode offload report from the serve engine

PY = PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: tier1 test serve-example

tier1:
	$(PY) -m pytest -x -q -m "not slow"

test:
	$(PY) -m pytest -q

serve-example:
	$(PY) examples/serve_offload.py
