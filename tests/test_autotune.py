"""Autotuner policy: deterministic lookup, clamping contracts, and
cache persistence (kernels/autotune.py)."""
import json

import jax.numpy as jnp
import numpy as np

from repro.core.quantize import PACK_BLOCK
from repro.kernels import autotune
from repro.kernels.autotune import Autotuner, clamp_tiles, choose_tiles
from repro.launch.roofline import (fused_tile_candidates,
                                   fused_tile_vmem_bytes)


def test_default_table_lookup_is_deterministic():
    a = choose_tiles("fused", bits=2, group_size=64, rank=16,
                     m=8, k=1024, n=1024)
    b = choose_tiles("fused", bits=2, group_size=64, rank=16,
                     m=8, k=1024, n=1024)
    assert a == b == (8, 256, 512)      # the decode preset, clamp-stable


def test_decode_preset_small_m():
    """Single-token decode blocks must get bm=8 (the `_pad_m` waste fix),
    never a 128-row tile."""
    for m in (1, 2, 8):
        bm, _, _ = choose_tiles("fused", bits=2, group_size=64, rank=16,
                                m=m, k=512, n=512)
        assert bm == 8


def test_clamp_preserves_divisibility():
    for m, k, n in ((1, 192, 384), (8, 512, 128), (33, 1024, 1024)):
        bm, bn, bk = clamp_tiles(m, k, n, 128, 512, 1024, group_size=64)
        assert k % bk == 0 and n % bn == 0
        assert bk % PACK_BLOCK == 0 and bk % 64 == 0
        assert bm % 8 == 0 and bm <= max(8, -(-m // 8) * 8)


def test_roofline_candidates_fit_vmem_and_problem():
    from repro.launch.roofline import VMEM_BUDGET, VMEM_BYTES
    cands = fused_tile_candidates(8, 1024, 1024, 2, 64, 16)
    assert cands, "decode shape must have at least one candidate"
    for bm, bn, bk in cands:
        assert bm <= 8 and bn <= 1024 and bk <= 1024
        assert bk % 64 == 0
        assert (fused_tile_vmem_bytes(bm, bn, bk, 2, 64, 16)
                <= VMEM_BYTES * VMEM_BUDGET)
    # best-first: the first candidate has the largest K tile
    assert cands[0][2] == max(c[2] for c in cands)


def test_record_and_disk_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    t = Autotuner()
    t.record("fused", (8, 128, 256), 42.0, bits=2, group_size=64,
             rank=16, m=8, k=512, n=512)
    # a fresh tuner (fresh memory) must see the persisted winner
    t2 = Autotuner()
    assert t2.choose("fused", bits=2, group_size=64, rank=16,
                     m=8, k=512, n=512) == (8, 128, 256)
    data = json.loads((tmp_path / "autotune.json").read_text())
    dev = next(iter(data.values()))
    assert dev["fused/b2/g64/r16/m8/k512/n512"]["tiles"] == [8, 128, 256]


def test_tune_fused_interpret_smoke(tmp_path, monkeypatch):
    """tune_fused times the candidates under the interpreter and records
    an in-memory winner without touching the disk cache."""
    from repro.config import QuantConfig
    from repro.core.pipeline import compress_expert_stack

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    rng = np.random.default_rng(0)
    qcfg = QuantConfig(enabled=True, bits=2, group_size=64, rank_budget=8,
                       top_n_restore=1, hqq_iters=1)
    w = jnp.asarray(rng.standard_normal((2, 128, 128)), jnp.float32) * 0.05
    stack, _ = compress_expert_stack(w, qcfg)
    xe = jnp.asarray(rng.standard_normal((2, 8, 128)), jnp.float32)
    me = jnp.ones((2, 8), jnp.float32)
    best = autotune.tune_fused(xe, stack, me, None, None,
                               out_dtype=jnp.float32, interpret=True,
                               repeats=1)
    assert 128 % best[2] == 0 and 128 % best[1] == 0
    assert not (tmp_path / "autotune.json").exists()   # interpret: no persist


def test_store_disk_is_atomic(tmp_path, monkeypatch):
    """The cache write must go through a same-directory temp file and
    os.replace, leaving no partial file behind."""
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache))
    t = Autotuner()
    t.record("fused", (8, 128, 256), 1.0, bits=2, group_size=64,
             rank=16, m=8, k=512, n=512)
    t.record("fused", (8, 256, 512), 2.0, bits=4, group_size=64,
             rank=16, m=8, k=1024, n=1024)
    leftovers = [p for p in tmp_path.iterdir() if p.name != cache.name]
    assert leftovers == [], leftovers
    data = json.loads(cache.read_text())       # complete, parseable JSON
    dev = next(iter(data.values()))
    assert len(dev) == 2


def test_corrupt_disk_cache_falls_back_to_defaults(tmp_path, monkeypatch):
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache))
    expected = Autotuner().choose("fused", bits=2, group_size=64, rank=16,
                                  m=8, k=1024, n=1024)
    for payload in ('{"truncated', '[1, 2, 3]', '"just a string"', ""):
        cache.write_text(payload)
        t = Autotuner()
        assert t.choose("fused", bits=2, group_size=64, rank=16,
                        m=8, k=1024, n=1024) == expected
        # and a later record must recover the file to valid JSON
        t.record("fused", (8, 128, 256), 1.0, bits=2, group_size=64,
                 rank=16, m=8, k=512, n=512)
        assert isinstance(json.loads(cache.read_text()), dict)


def test_structurally_corrupt_entry_is_ignored(tmp_path, monkeypatch):
    """Valid JSON whose entries have the wrong shape must not raise."""
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache))
    expected = Autotuner().choose("fused", bits=2, group_size=64, rank=16,
                                  m=8, k=1024, n=1024)
    key = "fused/b2/g64/r16/m8/k1024/n1024"
    from repro.kernels.autotune import device_kind
    for bad in (None, 7, {"us": 1.0}, {"tiles": "wat"},
                {"tiles": [8, 128]}, {"tiles": [8, "x", 512]}):
        cache.write_text(json.dumps({device_kind(): {key: bad}}))
        assert Autotuner().choose("fused", bits=2, group_size=64, rank=16,
                                  m=8, k=1024, n=1024) == expected
