"""Fig 6 analogue: quality under compression on a *trained* MoE.

The paper reports zero-shot accuracy (MMLU etc.); offline we measure
held-out NLL on the synthetic LM.  Because NLL sits just above the data's
irreducible entropy, the headline metric is the paper's actual claim
shape: quantization DEGRADATION (ΔNLL vs fp32) and the fraction of it the
router-guided compensation RECOVERS.

Ladder (mirrors Fig 6's method axis):
  rtn-pc-int2    per-channel round-to-nearest — the GPTQ-int2 collapse
                 regime (paper: 70.03% -> 34.41% on Mixtral-8x7B)
  hqq-int2       group-64 HQQ — survives degraded (paper's base quant)
  ours-int2      HQQ + kurtosis-ranked compensators, router top-1
  ours-pc-int2   compensators on TOP of the per-channel collapse — shows
                 restoration works even at the collapse point
  (ladder repeated at int3)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.config import QuantConfig

from .common import compress_model, eval_nll, trained_moe

EVAL_BATCHES = 8


def run(quick: bool = True):
    cfg, params = trained_moe(steps=60 if quick else 300)
    rows = []
    ref = eval_nll(cfg, params, quantized=False, batches=EVAL_BATCHES)
    rows.append({"name": "fig6/fp32", "nll": ref, "delta": 0.0})

    def q(name, qcfg, baseline_delta=None):
        cfg2, qp, _ = compress_model(cfg, params, qcfg)
        nll = eval_nll(cfg2, qp, quantized=True, batches=EVAL_BATCHES)
        row = {"name": f"fig6/{name}", "nll": nll, "delta": nll - ref}
        if baseline_delta is not None and baseline_delta > 0:
            row["recovered_pct"] = 100 * (1 - (nll - ref) / baseline_delta)
        rows.append(row)
        return nll - ref

    for bits in (2, 3):
        d_pc = q(f"rtn-pc-int{bits}",
                 QuantConfig(enabled=True, bits=bits, group_size=0,
                             rank_budget=0, top_n_restore=0, hqq_iters=0,
                             kurtosis_guided=False, uniform_rank=0))
        d_hqq = q(f"hqq-int{bits}",
                  QuantConfig(enabled=True, bits=bits, group_size=64,
                              rank_budget=0, top_n_restore=0, hqq_iters=20,
                              kurtosis_guided=False, uniform_rank=0))
        q(f"ours-int{bits}",
          QuantConfig(enabled=True, bits=bits, group_size=64,
                      rank_budget=32, top_n_restore=1, hqq_iters=20),
          baseline_delta=d_hqq)
        q(f"ours-pc-int{bits}",
          QuantConfig(enabled=True, bits=bits, group_size=0,
                      rank_budget=32, top_n_restore=1, hqq_iters=20),
          baseline_delta=d_pc)
    return rows


if __name__ == "__main__":
    for r in run():
        extra = ",".join(f"{k}={v:+.4f}" if isinstance(v, float) else str(v)
                         for k, v in r.items() if k != "name")
        print(f"{r['name']},{extra}")
