"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state; the 512-device host-platform override happens only in dryrun.py.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires XLA host device override)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
