"""Block-size autotuner for the fused Pallas decode kernel.

Three-level lookup, cheapest first:

1. in-memory cache (one entry per problem key per process);
2. the persisted per-device cache ``~/.cache/repro/autotune.json``
   (override with ``REPRO_AUTOTUNE_CACHE``), written only by an actual
   on-device timing sweep;
3. the deterministic in-repo ``DEFAULT_TABLE`` seeded from the roofline
   tile menus (``launch/roofline.py::fused_tile_candidates``) — CI and
   fresh checkouts never tune, they look up.

Problem key: ``(kind, bits, group_size, rank, m, k, n)`` per device
kind.  ``m`` buckets to the next power of two (ragged decode blocks
share an entry); the traced plan values (top_n, rank_cap) are DATA and
deliberately not part of the key, so a controller plan change can never
force a retune or a recompile.

Tuning itself (``tune_fused``) times every roofline candidate with the
compiled kernel on the local device and persists the winner.  It only
runs when explicitly asked (``REPRO_AUTOTUNE=1`` or a direct call) —
never implicitly on the serving path.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Dict, Optional, Tuple

_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_TUNE_ENV = "REPRO_AUTOTUNE"

# Deterministic defaults: (kind, bits, group_size, rank, m_bucket) ->
# (bm, bn, bk).  Derived offline from the roofline tile menu (largest
# K tile, then largest N tile under the VMEM budget; bm = the decode
# small-m preset for m <= 8).  ``None`` entries in a key match any
# value, checked most-specific-first.
DEFAULT_TABLE: Dict[Tuple, Tuple[int, int, int]] = {
    # decode presets: single-token / few-slot blocks never pad past the
    # f32 sublane minimum (the `_pad_m` decode-waste fix)
    ("fused", None, None, None, 8): (8, 256, 512),
    ("fused", None, None, None, 16): (16, 256, 512),
    ("fused", None, None, None, 32): (32, 256, 512),
    # prefill / calibration blocks: larger token tiles
    ("fused", None, None, None, None): (64, 256, 512),
    ("qmm", None, None, None, None): (128, 256, 512),
}


def _m_bucket(m: int) -> int:
    b = 8
    while b < m:
        b *= 2
    return b


def device_kind() -> str:
    import jax
    try:
        return jax.devices()[0].device_kind.replace(" ", "_")
    except Exception:
        return "unknown"


def cache_path() -> Path:
    env = os.environ.get(_CACHE_ENV)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "autotune.json"


def _key_str(kind: str, bits: int, group_size: int, rank: int,
             m: int, k: int, n: int) -> str:
    return f"{kind}/b{bits}/g{group_size}/r{rank}/m{_m_bucket(m)}/k{k}/n{n}"


class Autotuner:
    """Process-wide tile chooser (see module docstring for the policy)."""

    def __init__(self):
        self._mem: Dict[str, Tuple[int, int, int]] = {}
        self._disk: Optional[Dict] = None

    # -- persisted cache ---------------------------------------------------
    def _load_disk(self) -> Dict:
        if self._disk is None:
            self._disk = {}
            p = cache_path()
            try:
                loaded = json.loads(p.read_text())
                if isinstance(loaded, dict):
                    self._disk = loaded
            except (OSError, ValueError):
                pass        # missing/corrupt/truncated cache -> defaults
        return self._disk

    def _store_disk(self, key: str, tiles: Tuple[int, int, int],
                    us: float) -> None:
        disk = self._load_disk()
        dev = disk.setdefault(device_kind(), {})
        dev[key] = {"tiles": list(tiles), "us": round(us, 2),
                    "time": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                          time.gmtime())}
        p = cache_path()
        try:
            p.parent.mkdir(parents=True, exist_ok=True)
            # write-then-rename: a reader (or a crash) mid-write must see
            # either the old complete file or the new one, never a torn mix
            tmp = p.with_name(f"{p.name}.tmp.{os.getpid()}")
            tmp.write_text(json.dumps(disk, indent=1, sort_keys=True) + "\n")
            os.replace(tmp, p)
        except OSError:
            pass            # cache persistence is best-effort

    # -- lookup ------------------------------------------------------------
    def _default(self, kind: str, bits: int, group_size: int, rank: int,
                 m: int) -> Optional[Tuple[int, int, int]]:
        mb = _m_bucket(m)
        for key in ((kind, bits, group_size, rank, mb),
                    (kind, bits, None, None, mb),
                    (kind, None, None, None, mb),
                    (kind, None, None, None, None)):
            if key in DEFAULT_TABLE:
                return DEFAULT_TABLE[key]
        return None

    def choose(self, kind: str, *, bits: int, group_size: int, rank: int,
               m: int, k: int, n: int) -> Tuple[int, int, int]:
        """(bm, bn, bk) for a problem, clamped to its actual dims."""
        key = _key_str(kind, bits, group_size, rank, m, k, n)
        if key in self._mem:
            return self._mem[key]
        disk = self._load_disk().get(device_kind(), {})
        hit = disk.get(key) if isinstance(disk, dict) else None
        try:
            tiles = tuple(hit["tiles"]) if hit else None
            if tiles is not None and (len(tiles) != 3 or not all(
                    isinstance(t, int) and t > 0 for t in tiles)):
                tiles = None
        except (KeyError, TypeError):
            tiles = None    # structurally corrupt entry -> defaults
        if tiles is None:
            tiles = self._default(kind, bits, group_size, rank, m)
        if tiles is None:
            tiles = (8 if m <= 8 else 128, 256, 512)
        tiles = clamp_tiles(m, k, n, *tiles, group_size=group_size)
        self._mem[key] = tiles
        return tiles

    def record(self, kind: str, tiles: Tuple[int, int, int], us: float, *,
               bits: int, group_size: int, rank: int,
               m: int, k: int, n: int, persist: bool = True) -> None:
        key = _key_str(kind, bits, group_size, rank, m, k, n)
        self._mem[key] = tuple(tiles)
        if persist:
            self._store_disk(key, tuple(tiles), us)


def clamp_tiles(m: int, k: int, n: int, bm: int, bn: int, bk: int, *,
                group_size: int) -> Tuple[int, int, int]:
    """Fit a tile request to the problem, preserving the divisibility
    contracts (bk multiple of PACK_BLOCK and group_size; bm a sublane
    multiple so single-token decode pads to 8 rows, not a full tile)."""
    from ..core.quantize import PACK_BLOCK
    bm = min(bm, -(-max(m, 1) // 8) * 8)      # round m up to sublane, clamp
    bm = max(8, bm)
    bn = min(bn, n)
    while n % bn:
        bn //= 2
    bk = min(bk, k)
    while k % bk:
        bk //= 2
    step = max(PACK_BLOCK, group_size)
    if bk % step:
        bk = step if k % step == 0 else k
    return bm, bn, bk


_TUNER = Autotuner()


def choose_tiles(kind: str, *, bits: int, group_size: int, rank: int,
                 m: int, k: int, n: int) -> Tuple[int, int, int]:
    """Module-level convenience over the process-wide :class:`Autotuner`."""
    return _TUNER.choose(kind, bits=bits, group_size=group_size, rank=rank,
                         m=m, k=k, n=n)


def autotune_enabled() -> bool:
    return os.environ.get(_TUNE_ENV, "") not in ("", "0")


def tune_fused(xe, stack, me, ge, rank_cap, *, out_dtype, interpret: bool,
               repeats: int = 3) -> Tuple[int, int, int]:
    """Time every roofline candidate of the fused kernel on this device
    and persist the winner.  Called explicitly (bench / REPRO_AUTOTUNE=1
    serving boot) — never implicitly from the hot path."""
    from ..launch.roofline import fused_tile_candidates
    from . import ops

    e, m, k = xe.shape
    n = stack.scale.shape[-1]
    rank = stack.pad_rank
    cands = fused_tile_candidates(m, k, n, stack.bits, stack.group_size,
                                  rank)
    if not cands:
        cands = [clamp_tiles(m, k, n, 8, 256, 512,
                             group_size=stack.group_size)]
    best, best_us = None, float("inf")
    for bm, bn, bk in cands:
        bm, bn, bk = clamp_tiles(m, k, n, bm, bn, bk,
                                 group_size=stack.group_size)
        try:
            def run():
                y = ops.fused_expert_matmul(
                    xe, stack, me, gates=ge, rank_cap=rank_cap,
                    impl="pallas_interpret" if interpret else "pallas",
                    out_dtype=out_dtype, bm=bm, bn=bn, bk=bk)
                y.block_until_ready()
            run()                                    # compile + warm
            t0 = time.perf_counter()
            for _ in range(repeats):
                run()
            us = (time.perf_counter() - t0) / repeats * 1e6
        except Exception:
            continue
        if us < best_us:
            best, best_us = (bm, bn, bk), us
    if best is None:
        best = clamp_tiles(m, k, n, 8, 256, 512,
                           group_size=stack.group_size)
        best_us = 0.0
    _TUNER.record("fused", best, best_us, bits=stack.bits,
                  group_size=stack.group_size, rank=rank, m=m, k=k, n=n,
                  persist=not interpret)
    return best
