"""Paged KV cache + shared-prefix reuse + scheduler timing regressions.

The correctness oracle for the paged cache is the slot-contiguous path:
the same ragged workload served through block-table paging must be
token-identical (and logprob-close) to the bucketed contiguous cache,
because paging only changes WHERE kv rows live, never what attention
computes.  The matrix covers ragged prompt mixes, local ring-window
layers, int8 KV, live offload metering (byte-identical), and — via the
dist tier — expert-parallel serving.

Also here: the PagePool refcount/aliasing/LRU property tests, the
shared-prefix reuse guarantees (refcount >= 2, shared-span prefill paid
once), and the scheduler timing bugfixes (per-step TTFT interpolation,
the zero-token NaN sentinel, the exact idle-gap sleep).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoEConfig, QuantConfig
from repro.models import init_params
from repro.models.transformer import compress_moe_params
from repro.serve import (PagePool, Request, Scheduler, ServeEngine,
                         ServeStats, prefix_page_hashes)
from repro.serve.scheduler import RequestResult


def _moe_cfg():
    return ModelConfig(
        name="paged-moe", family="moe", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0, vocab_size=128,
        block_pattern=("global",), max_position=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=16,
                                        top_n_restore=1, hqq_iters=2)))


def _dense_cfg(pattern=("global",), kv_bits=16, window=16):
    return ModelConfig(
        name="paged-dense", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
        block_pattern=pattern, window_size=window, max_position=512,
        kv_bits=kv_bits)


RAGGED = ((5, 7), (19, 4), (33, 9), (9, 3), (12, 6), (24, 5))


def _reqs(mix=RAGGED, prefix=0, seed=0, vocab=128):
    rng = np.random.default_rng(seed)
    sysp = (np.arange(1, prefix + 1, dtype=np.int32) % vocab)
    out = []
    for i, (plen, max_new) in enumerate(mix):
        toks = rng.integers(1, vocab, (plen,), dtype=np.int32)
        if prefix:
            toks = np.concatenate([sysp, toks])
        out.append(Request(uid=i, tokens=toks, max_new=max_new))
    return out


def _toks(stats):
    return [r.tokens.tolist() for r in stats.results]


def _assert_parity(a, b, tol=2e-2):
    assert _toks(a) == _toks(b)
    for x, y in zip(a.results, b.results):
        np.testing.assert_allclose(x.logprobs, y.logprobs,
                                   rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# paged vs contiguous parity matrix
# ---------------------------------------------------------------------------

def test_paged_matches_contiguous_ragged_one_compile():
    cfg = _moe_cfg()
    eng = ServeEngine(cfg, init_params(jax.random.key(0), cfg, jnp.float32))
    base = eng.serve(_reqs(), num_slots=3, chunk=4)
    d0 = eng.num_compiles["decode"]
    paged = eng.serve(_reqs(), num_slots=3, chunk=4, page_size=8)
    _assert_parity(base, paged)
    # the paged pool sizes to the actual request mix, not the global
    # worst-case power-of-two bucket
    assert paged.cache_hbm_bytes < base.cache_hbm_bytes
    # exactly ONE decode compile for the whole 6-way ragged mix (block
    # tables are traced data), and a different ragged workload in the
    # same worst-case envelope (same max_blocks / pool size) reuses it
    assert eng.num_compiles["decode"] == d0 + 1
    mix2 = ((33, 9), (19, 4), (24, 5), (7, 5))
    eng.serve(_reqs(mix2, seed=3), num_slots=3, chunk=4, page_size=8)
    assert eng.num_compiles["decode"] == d0 + 1
    # every page released once the workload drained
    eng._page_pool.check_leaks()
    assert all(r == 0 for r in eng._page_pool.refcount)


def test_paged_matches_contiguous_local_window():
    cfg = _dense_cfg(pattern=("global", "local"), window=16)
    eng = ServeEngine(cfg, init_params(jax.random.key(1), cfg, jnp.float32))
    mix = ((6, 6), (25, 7), (14, 4), (34, 5))
    base = eng.serve(_reqs(mix, seed=2), num_slots=2, chunk=4)
    paged = eng.serve(_reqs(mix, seed=2), num_slots=2, chunk=4, page_size=8)
    _assert_parity(base, paged)


def test_paged_matches_contiguous_int8_kv():
    cfg = _dense_cfg(kv_bits=8)
    eng = ServeEngine(cfg, init_params(jax.random.key(2), cfg, jnp.float32))
    mix = ((6, 6), (25, 7), (14, 4))
    base = eng.serve(_reqs(mix, seed=4), num_slots=2, chunk=4)
    paged = eng.serve(_reqs(mix, seed=4), num_slots=2, chunk=4, page_size=8)
    # int8 codes + scales relocate exactly with their pages
    _assert_parity(base, paged)


def test_paged_offload_bytes_identical():
    """The offload meter replays the masked router trace — identical
    tokens must meter identical wire bytes on both cache layouts."""
    cfg = _moe_cfg()
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    qparams, cfg_q, stacks = compress_moe_params(params, cfg)
    eng = ServeEngine(cfg_q, qparams, quantized=True)

    def run(**kw):
        eng.attach_offload(stacks, policy="ours", cache_capacity=3)
        return eng.serve(_reqs(), num_slots=3, chunk=4, **kw)

    base, paged = run(), run(page_size=8)
    _assert_parity(base, paged)
    assert (base.offload_report["total_bytes"]
            == paged.offload_report["total_bytes"])
    assert ([r.offload_bytes for r in base.results]
            == [r.offload_bytes for r in paged.results])


@pytest.mark.dist
def test_paged_matches_contiguous_ep2(dist_run):
    script = """
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, MoEConfig, QuantConfig
from repro.launch.mesh import make_serve_mesh
from repro.models import init_params
from repro.models.transformer import compress_moe_params
from repro.serve import Request, ServeEngine

cfg = ModelConfig(
    name="paged-ep", family="moe", num_layers=2, d_model=64,
    num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0, vocab_size=64,
    block_pattern=("global",), max_position=512,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                  quant=QuantConfig(enabled=True, bits=2, rank_budget=8,
                                    top_n_restore=1, hqq_iters=2)))
params = init_params(jax.random.key(0), cfg, jnp.float32)
qparams, cfg_q, stacks = compress_moe_params(params, cfg)

def reqs():
    rng = np.random.default_rng(0)
    return [Request(uid=i, tokens=rng.integers(1, 64, (p,), dtype=np.int32),
                    max_new=m)
            for i, (p, m) in enumerate(((5, 6), (19, 4), (12, 7)))]

results = {}
for ep in (1, 2):
    eng = ServeEngine(cfg_q, qparams, quantized=True,
                      mesh=make_serve_mesh(ep))
    base = eng.serve(reqs(), num_slots=2, chunk=4)
    paged = eng.serve(reqs(), num_slots=2, chunk=4, page_size=8)
    results[f"ep{ep}"] = {
        "match": [r.tokens.tolist() for r in base.results]
                 == [r.tokens.tolist() for r in paged.results],
        "hbm_shrunk": paged.cache_hbm_bytes < base.cache_hbm_bytes,
    }
print("RESULTS:" + json.dumps(results))
"""
    results = dist_run(script)
    for ep, r in results.items():
        assert r["match"], f"{ep}: paged decode diverged"
        assert r["hbm_shrunk"], f"{ep}: paged cache not smaller"


# ---------------------------------------------------------------------------
# shared-prefix reuse
# ---------------------------------------------------------------------------

def test_prefix_sharing_refcounts_and_prefill_reuse():
    cfg = _moe_cfg()
    eng = ServeEngine(cfg, init_params(jax.random.key(0), cfg, jnp.float32))
    mk = lambda: _reqs(prefix=24, seed=1)
    base = eng.serve(mk(), num_slots=3, chunk=4, page_size=8)
    pre = eng.serve(mk(), num_slots=3, chunk=4, page_size=8,
                    prefix_cache=True)
    _assert_parity(base, pre)
    rep = pre.page_report
    # concurrent residents mapped the same physical prefix pages ...
    assert rep["peak_shared_ref"] >= 2
    assert rep["prefix_hits"] > 0
    # ... so the shared span's prefill ran once, not once per request
    assert pre.prefill_tokens < base.prefill_tokens
    eng._page_pool.check_leaks()


def test_prefix_pages_park_and_revive_across_waves():
    """A fully-retired prefix parks (refcount 0) and a later wave with
    the same prompt prefix revives it instead of re-prefilling."""
    cfg = _moe_cfg()
    eng = ServeEngine(cfg, init_params(jax.random.key(0), cfg, jnp.float32))
    # one slot: requests run strictly one after another, so every wave
    # boundary fully releases the prefix pages before the next lookup
    reqs = _reqs(mix=((9, 3), (11, 3), (7, 3)), prefix=16, seed=5)
    stats = eng.serve(reqs, num_slots=1, chunk=4, page_size=8,
                      prefix_cache=True, pool_pages=12)
    rep = stats.page_report
    assert rep["prefix_hits"] > 0          # later waves revived the pages
    assert rep["evictions"] == 0           # pool_pages headroom: no LRU
    eng._page_pool.check_leaks()


# ---------------------------------------------------------------------------
# PagePool properties (host allocator, no jax)
# ---------------------------------------------------------------------------

def test_pool_alloc_never_aliases_and_never_trash():
    pool = PagePool(num_pages=9, page_size=8)
    a, b = pool.alloc(3), pool.alloc(4)
    assert 0 not in a + b                  # page 0 is the write sink
    assert len(set(a + b)) == 7            # disjoint unless prefix-shared
    with pytest.raises(RuntimeError):
        pool.alloc(2)                      # 1 left
    pool.release(a)
    c = pool.alloc(3)
    assert set(c) & set(b) == set()        # recycled, still no aliasing


def test_pool_refcounts_and_leak_check():
    pool = PagePool(num_pages=6, page_size=8)
    pages = pool.alloc(2)
    pool.retain(pages)                     # second tenant
    with pytest.raises(RuntimeError):
        pool.check_leaks()
    pool.release(pages)
    with pytest.raises(RuntimeError):
        pool.check_leaks()                 # first release: still live
    pool.release(pages)
    pool.check_leaks()                     # refcounts all zero at retire
    assert all(r == 0 for r in pool.refcount)
    with pytest.raises(RuntimeError):
        pool.release(pages)                # over-release is a bug


def test_pool_park_revive_and_lru_eviction():
    pool = PagePool(num_pages=5, page_size=2)   # 4 allocatable
    h = prefix_page_hashes(list(range(8)), 2)   # 4 chained hashes
    pages = pool.alloc(2)
    for p, hh in zip(pages, h[:2]):
        pool.register(p, hh)
    pool.release(pages)                    # both park, oldest first
    assert pool.lookup(h) == pages         # parked pages still match
    pool.retain(pages)                     # revive: leaves LRU, keeps hash
    pool.release(pages)
    # pressure: 4-page alloc must evict BOTH parked pages (oldest first)
    got = pool.alloc(4)
    assert pool.stats.evictions == 2
    assert pool.lookup(h) == []            # registrations dropped
    pool.release(got)                      # no hash left: all go free
    with pytest.raises(RuntimeError):
        pool.retain(pages)                 # retain of a free page is a bug


def test_pool_register_first_writer_wins():
    pool = PagePool(num_pages=5, page_size=2)
    h = prefix_page_hashes([1, 2], 2)[0]
    a, b = pool.alloc(2)
    pool.register(a, h)
    pool.register(b, h)                    # duplicate content: kept on a
    assert pool.lookup([h]) == [a]
    with pytest.raises(RuntimeError):
        pool.register(99 % pool.num_pages, h)   # free page: not allowed


def test_prefix_page_hashes_chained():
    ps = 4
    base = list(range(10))                 # 2 full pages + partial
    h = prefix_page_hashes(base, ps)
    assert len(h) == 2                     # partial page never hashed
    assert prefix_page_hashes(base[:8] + [99, 98], ps) == h  # same prefix
    div = prefix_page_hashes([7] + base[1:], ps)
    assert div[0] != h[0] and div[1] != h[1]   # divergence poisons chain
    assert prefix_page_hashes(base, 8)[0] != h[0]  # page size seeds hash


# ---------------------------------------------------------------------------
# scheduler timing / termination bugfixes
# ---------------------------------------------------------------------------

def _req(uid, plen=4, max_new=3, eos=None, arrival=0.0):
    return Request(uid=uid, tokens=np.zeros(plen, np.int32),
                   max_new=max_new, eos_id=eos, arrival_s=arrival)


def test_ttft_interpolates_within_chunk():
    s = Scheduler(2)
    s.submit(_req(0, max_new=4))
    s.submit(_req(1, max_new=2, eos=7))
    s.admit(0.0)
    toks = np.array([[1, 2, 3, 4], [7, 0, 0, 0]])
    lps = np.zeros((2, 4), np.float32)
    s.record_chunk(toks, lps, None, now=9.0, t_start=1.0)
    r0 = next(r for r in s.finished if r.uid == 0)
    r1 = next(r for r in s.finished if r.uid == 1)
    # chunk spans [1.0, 9.0] over 4 steps: step c completes at 1 + 2(c+1),
    # not at the chunk-end wall time the old code stamped on every step
    assert r0.first_token_s == pytest.approx(3.0)
    assert r0.finished_s == pytest.approx(9.0)     # retired at step 3
    assert r1.first_token_s == pytest.approx(3.0)  # EOS at step 0
    assert r1.finished_s == pytest.approx(3.0)
    assert r1.first_token_s < 9.0                  # the regression


def test_record_chunk_without_t_start_keeps_chunk_end_stamps():
    s = Scheduler(1)
    s.submit(_req(0, max_new=2))
    s.admit(0.0)
    s.record_chunk(np.array([[1, 2]]), np.zeros((1, 2), np.float32),
                   None, now=5.0)
    assert s.finished[0].first_token_s == 5.0      # legacy behavior


def test_zero_token_budget_emits_nan_sentinel():
    s = Scheduler(1)
    s.submit(_req(0, max_new=0, arrival=1.0))
    s.admit(2.0)
    s.record_chunk(np.array([[9, 9]]), np.zeros((1, 2), np.float32),
                   None, now=6.0, t_start=3.0)
    r = s.finished[0]
    assert r.gen_tokens == 0 and r.finish_reason == "length"
    # the old -1.0 placeholder leaked into aggregates as a NEGATIVE ttft
    assert math.isnan(r.first_token_s) and math.isnan(r.ttft_s)
    assert r.finished_s == 3.0             # done on entry: decode start
    stats = ServeStats([r], 1, 2, 6.0, 0.1, 0.2, 1, 0)
    assert stats.ttft_percentiles() == {}  # NaN excluded, not averaged


def test_servestats_rejects_negative_latencies():
    def res(first, finished):
        return RequestResult(
            uid=0, prompt_len=4, tokens=np.zeros(1, np.int32),
            logprobs=np.zeros(1, np.float32), trace=None,
            finish_reason="length", arrival_s=2.0, admitted_s=2.0,
            first_token_s=first, finished_s=finished)
    with pytest.raises(AssertionError):
        ServeStats([res(2.5, 1.0)], 1, 2, 1.0, 0.1, 0.2, 1, 1)
    with pytest.raises(AssertionError):
        ServeStats([res(0.5, 3.0)], 1, 2, 1.0, 0.1, 0.2, 1, 1)
    ServeStats([res(2.5, 3.0)], 1, 2, 1.0, 0.1, 0.2, 1, 1)  # sane: ok


def test_idle_gap_sleeps_exactly_once_to_next_arrival(monkeypatch):
    """The old idle path slept in capped 0.25 s slices, spinning the
    loop awake ~4x/s under sparse offered load; it must sleep the exact
    gap once and wake at the arrival."""
    import repro.serve.engine as engine_mod

    class _Clock:
        def __init__(self):
            self.t, self.sleeps = 0.0, []

        def perf_counter(self):
            return self.t

        def sleep(self, s):
            self.sleeps.append(s)
            self.t += s

    clock = _Clock()
    monkeypatch.setattr(engine_mod, "time", clock)
    cfg = _moe_cfg()
    eng = ServeEngine(cfg, init_params(jax.random.key(0), cfg, jnp.float32))
    reqs = [_req(0, plen=6, max_new=2, arrival=0.0),
            _req(1, plen=6, max_new=2, arrival=5.0)]
    stats = eng.serve(reqs, num_slots=1, chunk=2)
    # the fake clock only advances inside sleep, so the one idle gap is
    # exactly (arrival - now) + the epsilon — in a single sleep
    assert clock.sleeps == [pytest.approx(5.0 + 1e-4)]
    assert all(np.isfinite(r.ttft_s) and r.ttft_s >= 0
               for r in stats.results)
