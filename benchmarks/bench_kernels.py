"""Kernel microbenchmarks (CPU timing is indicative only; the TPU story is
the packed-byte traffic, reported as `derived`).

For each bit width: quant_matmul wire bytes vs fp16, and the fused
low-rank epilogue's marginal cost at the paper's rank budgets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize
from repro.core.quantize import packed_nbytes
from repro.kernels import ops

from .common import timed


def run(quick: bool = True):
    rows = []
    rng = np.random.default_rng(0)
    m, k, n = (64, 1024, 1024) if quick else (256, 4096, 4096)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    fp16_bytes = k * n * 2
    for bits in (2, 3, 4, 8):
        qt = quantize(w, bits, 64)
        us = timed(lambda: ops.quant_matmul(x, qt, impl="ref"))
        wire = packed_nbytes(bits, k, n) + (k // 64) * n * 4
        rows.append({"name": f"kernel/quant_matmul_int{bits}",
                     "us_per_call": us,
                     "derived": f"wire_reduction={fp16_bytes / wire:.2f}x"})
    qt = quantize(w, 2, 64)
    for rank in (16, 32, 128):
        u = jnp.asarray(rng.integers(-127, 127, (k, rank)).astype(np.int8))
        v = jnp.asarray(rng.integers(-127, 127, (rank, n)).astype(np.int8))
        us_ = jnp.ones((1, rank), jnp.float32) * 0.01
        vs_ = jnp.ones((rank, 1), jnp.float32) * 0.01
        mask = jnp.ones((m,), jnp.float32)
        us = timed(lambda: ops.lowrank_comp_matmul(
            x, qt, u, v, us_, vs_, mask, impl="ref"))
        extra = rank * (k + n)
        rows.append({"name": f"kernel/lowrank_fused_r{rank}",
                     "us_per_call": us,
                     "derived": f"comp_bytes_pct="
                                f"{100 * extra / (packed_nbytes(2, k, n)):.1f}%"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
