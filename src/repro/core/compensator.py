"""Low-rank residual compensators (paper §3.1, step 2).

One truncated SVD of the quantization residual E = W - Q^-1(Q(W)) at the
allocated rank r, reparameterized U <- U sqrt(S), V <- sqrt(S) V^T, with the
factors themselves stored quantized (paper: INT3; default here int8).

Ranks differ per expert (kurtosis-guided), but jit needs static shapes, so a
layer's compensators are zero-padded to the layer-max rank; the *true* rank
is kept for bandwidth accounting (padding columns are exact zeros and do not
change the math).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .quantize import QuantizedTensor, dequantize, factor_wire_bytes, quantize


@partial(jax.tree_util.register_dataclass,
         data_fields=("u", "v", "u_scale", "v_scale"),
         meta_fields=("rank", "pad_rank", "factor_bits"))
@dataclass
class Compensator:
    """Rank-r factors for one weight matrix; zero-padded to ``pad_rank``.

    Factors are stored symmetric-quantized per column (u) / row (v) at
    ``factor_bits`` (int8 codes in an int8 array; sub-byte widths reuse the
    int8 container but clamp the code range, and bandwidth accounting uses
    the true bit width).  ``u``: (m, R), ``v``: (R, n).
    """
    u: jax.Array
    v: jax.Array
    u_scale: jax.Array      # (1, R)
    v_scale: jax.Array      # (R, 1)
    rank: int               # true allocated rank (bandwidth accounting)
    pad_rank: int           # static padded rank (jit shapes)
    factor_bits: int

    @property
    def nbytes_wire(self) -> int:
        """Bytes moved per transfer of this compensator (true rank only);
        one shared formula with the stack/store accounting."""
        return factor_wire_bytes(self.rank, self.u.shape[0], self.v.shape[1],
                                 self.factor_bits)

    def materialize(self, dtype=jnp.float32) -> jax.Array:
        """Dense E_hat = U V (including dequantized factors)."""
        u = self.u.astype(jnp.float32) * self.u_scale
        v = self.v.astype(jnp.float32) * self.v_scale
        return (u @ v).astype(dtype)


def _sym_quant_cols(x: jax.Array, bits: int, axis: int):
    """Symmetric per-column (axis kept) quantization into int8 codes."""
    qmax = (1 << (bits - 1)) - 1
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q, scale


def build_compensator(residual: jax.Array, rank: int, pad_rank: int,
                      factor_bits: int = 8) -> Compensator:
    """Truncated SVD of ``residual`` at ``rank``, padded to ``pad_rank``."""
    m, n = residual.shape
    rank = int(min(rank, m, n))
    pad_rank = int(max(pad_rank, rank))
    if rank > 0:
        # full_matrices=False keeps this O(mn*min(m,n)); offline-only cost.
        u, s, vt = jnp.linalg.svd(residual.astype(jnp.float32),
                                  full_matrices=False)
        sq = jnp.sqrt(s[:rank])
        u = u[:, :rank] * sq[None, :]
        v = vt[:rank, :] * sq[:, None]
    else:
        u = jnp.zeros((m, 0), jnp.float32)
        v = jnp.zeros((0, n), jnp.float32)
    if pad_rank > rank:
        u = jnp.pad(u, ((0, 0), (0, pad_rank - rank)))
        v = jnp.pad(v, ((0, pad_rank - rank), (0, 0)))
    if factor_bits >= 16:
        return Compensator(u.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
                           jnp.ones((1, pad_rank), jnp.float32),
                           jnp.ones((pad_rank, 1), jnp.float32),
                           rank, pad_rank, factor_bits)
    qu, su = _sym_quant_cols(u, factor_bits, axis=0)   # per rank-column
    qv, sv = _sym_quant_cols(v, factor_bits, axis=1)   # per rank-row
    return Compensator(qu, qv, su, sv, rank, pad_rank, factor_bits)


def compensated_weight(qt: QuantizedTensor, comp: Optional[Compensator],
                       dtype=jnp.float32) -> jax.Array:
    """W_hat = Q^-1(Q(W)) + U V (paper §3.2 reconstruction)."""
    w = dequantize(qt, jnp.float32)
    if comp is not None:
        w = w + comp.materialize(jnp.float32)
    return w.astype(dtype)


def compensation_quality(w: jax.Array, qt: QuantizedTensor,
                         comp: Optional[Compensator]) -> dict:
    """Diagnostics: residual norms before/after compensation."""
    w32 = w.astype(jnp.float32)
    e0 = w32 - dequantize(qt)
    e1 = w32 - compensated_weight(qt, comp)
    nw = jnp.maximum(jnp.linalg.norm(w32), 1e-12)
    return {
        "rel_err_quant": float(jnp.linalg.norm(e0) / nw),
        "rel_err_comp": float(jnp.linalg.norm(e1) / nw),
    }
