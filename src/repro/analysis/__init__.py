"""repro-lint: static invariant checking for the repro codebase.

The runtime guarantees this repo leans on — plan changes never recompile
the decode scan, metered wire bytes match compressed-artifact bytes,
fused-kernel tiles fit the VMEM budget — are enforced at diff time by an
AST-based lint pass (``tools/repro_lint.py`` / ``make lint``):

- ``jitscope``    builds the jit-scope call graph (jit/scan/shard_map/
                  pallas_call roots and everything reachable from them);
- ``taint``       intra-procedural traced-value inference inside that scope;
- ``rules_jit``   RL1xx purity rules (host sync, Python control flow on
                  traced values, traced values into static/shape args) and
                  RL4xx repo idioms (device_get, mesh output pinning);
- ``rules_bytes`` RL2xx canonical wire-byte accounting (all bits/rank ->
                  bytes arithmetic lives in ``core/quantize.py``);
- ``rules_pallas``RL3xx Pallas tile legality (PACK_BLOCK divisibility and
                  the roofline VMEM budget, including autotune defaults).

Rules carry stable IDs; suppress a finding inline with
``# repro-lint: disable=RL101`` or via the committed baseline file
(see ``core.Baseline``).  README.md §Lint documents the workflow and
ARCHITECTURE.md §Enforced invariants maps each rule to the runtime test
that backs it.
"""
from .core import (Baseline, Finding, LintConfig, all_rules, lint_paths,
                   run_lint)

__all__ = ["Baseline", "Finding", "LintConfig", "all_rules", "lint_paths",
           "run_lint"]
