# Repo verification targets.
#
#   make tier1   fast correctness gate (excludes @pytest.mark.slow)
#   make tier1-dist      multi-device tier: the @pytest.mark.dist tests
#                        run IN-PROCESS on 8 forced host devices
#   make test    full suite, including slow/benchmarks-adjacent tests
#   make bench-smoke     quick continuous-batching serving sweep
#                        (writes the BENCH_serving.json snapshot)
#   make bench-ep        expert-parallel shard-count sweep (8 host devices)
#   make bench-frontier  bandwidth-budget frontier sweep (controller)
#   make compress-smoke  calibrate -> allocate -> artifact -> serve 8
#                        tokens from it (the offline-pipeline CI gate)
#   make docs-check      every doc cross-reference resolves
#   make serve-example   live-decode offload + controller report

PY = PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: tier1 tier1-dist test bench-smoke bench-ep bench-frontier \
	compress-smoke docs-check serve-example

# dist-marked tests are excluded here only to avoid running them twice
# in CI — tier1-dist runs exactly those, in-process on 8 host devices;
# the full `make test` / `pytest -x -q` gate still covers both.
tier1:
	$(PY) -m pytest -x -q -m "not slow and not dist"

tier1-dist:
	REPRO_HOST_DEVICES=8 $(PY) -m pytest -x -q -m "dist and not slow"

test:
	$(PY) -m pytest -q

bench-smoke:
	$(PY) benchmarks/bench_serving.py --quick

bench-ep:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) benchmarks/bench_serving.py --quick --mesh ep=8

bench-frontier:
	$(PY) benchmarks/bench_serving.py --quick --frontier

compress-smoke:
	$(PY) -m repro.launch.compress --arch mixtral-8x7b \
		--out experiments/compress_smoke --calib-batches 2 \
		--calib-batch-size 4 --calib-seq-len 64 --budget-frac 0.9
	$(PY) -m repro.launch.serve --arch mixtral-8x7b --offload \
		--artifact experiments/compress_smoke \
		--batch 1 --prompt-len 8 --max-new 8

docs-check:
	python tools/docs_check.py

serve-example:
	$(PY) examples/serve_offload.py
