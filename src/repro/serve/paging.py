"""Host-side page allocator for the paged KV cache.

The device side (``models/kvcache.py``) is dumb on purpose: page pools are
plain buffers and block tables are plain int32 arrays.  All policy lives
here, in ordinary Python on the serve thread:

- a free list over physical page ids 1..P-1 (page 0 is the device-side
  trash/write-sink and is never handed out),
- per-page refcounts so prefix-shared pages stay alive until the last
  slot mapping them retires,
- a content-hash registry (chained blake2b over full token pages) that
  turns "two prompts share a leading prefix" into "their block tables
  point at the same physical pages", and
- LRU retention of *freed* hashed pages: a page whose refcount hits zero
  but whose content is registered parks in an LRU instead of returning
  to the free list, so a later request with the same prefix can revive
  it without recomputing prefill.  Allocation pressure evicts parked
  pages oldest-first.

The allocator never touches device memory; correctness is enforced by
the invariant that a physical page is in exactly one of {free, parked,
live (refcount > 0)} and only live pages appear in live block tables.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


def prefix_page_hashes(tokens: Sequence[int], page_size: int) -> List[bytes]:
    """Chained content hash per *full* leading page of ``tokens``.

    Hash j covers tokens[0 : (j+1)*page_size], so equal hash j implies the
    entire prefix up to and including page j is identical — matching a run
    of leading hashes is exactly matching a shared prompt prefix.  The
    final partial page (if any) is never hashed: its page will also hold
    this request's first generated tokens, so it is never shareable.
    """
    out: List[bytes] = []
    h = hashlib.blake2b(str(page_size).encode(), digest_size=16)
    for j in range(len(tokens) // page_size):
        chunk = tokens[j * page_size:(j + 1) * page_size]
        h.update(b"|".join(str(int(t)).encode() for t in chunk))
        out.append(h.digest())
        h = hashlib.blake2b(out[-1], digest_size=16)
    return out


@dataclass
class PoolStats:
    allocs: int = 0
    frees: int = 0
    prefix_hits: int = 0        # pages reused from a live or parked match
    prefix_queries: int = 0     # full prompt pages that could have matched
    evictions: int = 0
    peak_live: int = 0
    peak_shared_ref: int = 0    # highest refcount any page reached


@dataclass
class PagePool:
    """Refcounted allocator over physical pages 1..num_pages-1."""
    num_pages: int              # INCLUDING the reserved trash page 0
    page_size: int
    refcount: List[int] = field(init=False)
    _free: List[int] = field(init=False)
    # parked: freed-but-hash-registered pages, oldest first (LRU eviction)
    _parked: "OrderedDict[int, bytes]" = field(init=False)
    _page_of_hash: Dict[bytes, int] = field(init=False)
    _hash_of_page: Dict[int, bytes] = field(init=False)
    stats: PoolStats = field(init=False)

    def __post_init__(self):
        if self.num_pages < 2:
            raise ValueError("need at least one allocatable page "
                             "(page 0 is the trash page)")
        self.refcount = [0] * self.num_pages
        self._free = list(range(self.num_pages - 1, 0, -1))
        self._parked = OrderedDict()
        self._page_of_hash = {}
        self._hash_of_page = {}
        self.stats = PoolStats()

    # -- capacity ----------------------------------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free) + len(self._parked)

    @property
    def num_live(self) -> int:
        return sum(1 for r in self.refcount if r > 0)

    def _take_one(self) -> int:
        if self._free:
            return self._free.pop()
        # evict the oldest parked page: drop its hash registration
        page, h = self._parked.popitem(last=False)
        del self._page_of_hash[h]
        del self._hash_of_page[page]
        self.stats.evictions += 1
        return page

    def alloc(self, n: int) -> List[int]:
        """Allocate ``n`` fresh pages (refcount 1 each), evicting parked
        prefix pages LRU-first under pressure.  Raises when the pool is
        truly out of capacity — the scheduler sizes the pool so a full
        slot complement always fits, so this is a programming error."""
        if n > self.num_free:
            raise RuntimeError(
                f"page pool exhausted: want {n}, free {self.num_free} "
                f"(live {self.num_live}/{self.num_pages - 1})")
        pages = [self._take_one() for _ in range(n)]
        for p in pages:
            self.refcount[p] = 1
        self.stats.allocs += n
        self.stats.peak_live = max(self.stats.peak_live, self.num_live)
        return pages

    # -- refcounting -------------------------------------------------------

    def retain(self, pages: Sequence[int]) -> None:
        """Add a reference to live or parked pages (prefix reuse).  A
        parked page revives: it leaves the LRU but keeps its hash."""
        for p in pages:
            if self.refcount[p] == 0:
                if p not in self._parked:
                    raise RuntimeError(f"retain of free page {p}")
                del self._parked[p]
            self.refcount[p] += 1
            self.stats.peak_shared_ref = max(self.stats.peak_shared_ref,
                                             self.refcount[p])
        self.stats.peak_live = max(self.stats.peak_live, self.num_live)

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference per page; zero-ref pages return to the free
        list, or park in the LRU if their content is hash-registered."""
        for p in pages:
            if self.refcount[p] <= 0:
                raise RuntimeError(f"release of non-live page {p}")
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                h = self._hash_of_page.get(p)
                if h is None:
                    self._free.append(p)
                else:
                    self._parked[p] = h
                self.stats.frees += 1

    # -- prefix registry ---------------------------------------------------

    def lookup(self, hashes: Sequence[bytes]) -> List[int]:
        """Longest-run match: page ids for the leading run of ``hashes``
        that are registered (live or parked).  Stops at the first miss —
        chained hashes make any later match meaningless."""
        self.stats.prefix_queries += len(hashes)
        out: List[int] = []
        for h in hashes:
            p = self._page_of_hash.get(h)
            if p is None:
                break
            out.append(p)
        self.stats.prefix_hits += len(out)
        return out

    def register(self, page: int, h: bytes) -> None:
        """Publish a live page's content hash so later requests can map
        it.  First writer wins; an existing registration for the same
        hash is kept (both pages hold identical content — re-pointing
        live block tables is not worth it)."""
        if self.refcount[page] <= 0:
            raise RuntimeError(f"register of non-live page {page}")
        if h in self._page_of_hash or page in self._hash_of_page:
            return
        self._page_of_hash[h] = page
        self._hash_of_page[page] = h

    # -- accounting --------------------------------------------------------

    def check_leaks(self) -> None:
        """After every request retired, all pages must be free or parked."""
        live = [p for p in range(1, self.num_pages) if self.refcount[p] > 0]
        if live:
            raise RuntimeError(f"page leak: live refcounts at {live}")

    def report(self) -> Dict[str, float]:
        s = self.stats
        return {
            "num_pages": self.num_pages - 1,
            "page_size": self.page_size,
            "allocs": s.allocs,
            "frees": s.frees,
            "prefix_hits": s.prefix_hits,
            "prefix_queries": s.prefix_queries,
            "prefix_hit_rate": (s.prefix_hits / s.prefix_queries
                                if s.prefix_queries else 0.0),
            "evictions": s.evictions,
            "peak_live": s.peak_live,
            "peak_shared_ref": s.peak_shared_ref,
        }
