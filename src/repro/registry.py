"""Architecture registry: ``--arch <id>`` -> ModelConfig.

The 10 assigned archs (each with its 4-shape cell set) plus the paper's
three reference MoE models used by the accuracy/throughput benchmarks.
"""
from __future__ import annotations

from typing import Callable, Dict, List

from .config import ModelConfig, SHAPES, ShapeConfig
from .configs import (deepseek_moe_16b, gemma3_1b, gemma3_27b,
                      llama3_2_3b, llama4_scout_17b_a16e, mixtral_8x22b,
                      mixtral_8x7b, qwen2_7b, qwen2_vl_7b,
                      qwen3_moe_30b_a3b, recurrentgemma_9b, whisper_base,
                      xlstm_125m)
from .configs.base import reduce_config, supports_shape

ASSIGNED: Dict[str, Callable[[], ModelConfig]] = {
    "gemma3-1b": gemma3_1b.config,
    "gemma3-27b": gemma3_27b.config,
    "llama3.2-3b": llama3_2_3b.config,
    "qwen2-7b": qwen2_7b.config,
    "recurrentgemma-9b": recurrentgemma_9b.config,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b.config,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e.config,
    "xlstm-125m": xlstm_125m.config,
    "whisper-base": whisper_base.config,
    "qwen2-vl-7b": qwen2_vl_7b.config,
}

PAPER_MODELS: Dict[str, Callable[[], ModelConfig]] = {
    "mixtral-8x7b": mixtral_8x7b.config,
    "mixtral-8x22b": mixtral_8x22b.config,
    "deepseek-moe-16b": deepseek_moe_16b.config,
}

REGISTRY = {**ASSIGNED, **PAPER_MODELS}


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    cfg = REGISTRY[name]()
    return reduce_config(cfg) if reduced else cfg


def list_cells(archs=None) -> List[tuple]:
    """All (arch, shape) dry-run cells, with skip reasons where assigned."""
    cells = []
    for a in (archs or ASSIGNED):
        cfg = get_config(a)
        for s in SHAPES.values():
            cells.append((a, s.name, supports_shape(cfg, s)))
    return cells
