"""Layer-ahead expert prefetcher (related-work systems [5,19,33,42]).

While layer l computes, predict layer l+1's experts and issue their
fetches.  Prediction uses the previous token's routing at l+1 (decode-time
temporal locality) — the cheap predictor HOBBIT-class systems use; accuracy
and the wasted-fetch ratio are metered so benchmarks can quantify the
prediction-miss penalty the paper's related-work section describes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class PrefetchStats:
    issued: int = 0
    useful: int = 0
    wasted: int = 0

    @property
    def accuracy(self) -> float:
        return self.useful / self.issued if self.issued else 0.0


class LayerAheadPrefetcher:
    """Predicts layer l+1 experts = previous token's experts at l+1."""

    def __init__(self, num_layers: int, top_k: int):
        self.prev_token: List[Optional[np.ndarray]] = [None] * num_layers
        self.stats = PrefetchStats()

    def predict(self, layer: int) -> Optional[np.ndarray]:
        return self.prev_token[layer]

    def observe(self, layer: int, experts: np.ndarray):
        """Score the pending prediction against this step's experts and
        remember them for the next step.  ``experts`` may be any shape
        (batched decode passes the whole step's ids); it is flattened."""
        experts = np.unique(np.asarray(experts).reshape(-1))
        pred = self.prev_token[layer]
        if pred is not None:
            hit = len(np.intersect1d(pred, experts))
            self.stats.issued += len(pred)
            self.stats.useful += hit
            self.stats.wasted += len(pred) - hit
        self.prev_token[layer] = experts.copy()
