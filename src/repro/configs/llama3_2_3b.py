"""llama3.2-3b [dense]: 28L d=3072 24H (GQA kv=8) ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2-3B]"""
from ..config import ModelConfig, QuantConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
        head_dim=128, d_ff=8192, vocab_size=128_256,
        block_pattern=("global",),
        rope_theta=500_000.0, act="silu", tie_embeddings=True,
        quant=QuantConfig(enabled=True, bits=2, rank_budget=32,
                          top_n_restore=1),
        max_position=131_072,
    )
