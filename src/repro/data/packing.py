"""Sequence packing: concatenate variable-length documents into fixed
(seq_len) rows with segment ids, so no FLOPs are spent on padding.

``pack_documents`` is greedy first-fit over a document stream; the
returned ``segment_ids`` feed the attention mask (tokens never attend
across document boundaries) and the loss mask (no loss across joints).
"""
from __future__ import annotations

from typing import Dict, Iterable, List

import numpy as np


def pack_documents(docs: Iterable[np.ndarray], seq_len: int,
                   pad_id: int = 0) -> Dict[str, np.ndarray]:
    """Greedy first-fit packing.  Returns tokens/segment_ids/mask, each
    (rows, seq_len); segment_ids are 1-based per document, 0 = padding."""
    rows: List[np.ndarray] = []
    segs: List[np.ndarray] = []
    cur = np.full(seq_len, pad_id, np.int32)
    cur_seg = np.zeros(seq_len, np.int32)
    fill = 0
    seg_id = 0
    for doc in docs:
        doc = np.asarray(doc, np.int32)
        while doc.size:
            if fill == seq_len:
                rows.append(cur); segs.append(cur_seg)
                cur = np.full(seq_len, pad_id, np.int32)
                cur_seg = np.zeros(seq_len, np.int32)
                fill = 0
            take = min(doc.size, seq_len - fill)
            seg_id += 1
            cur[fill:fill + take] = doc[:take]
            cur_seg[fill:fill + take] = seg_id
            fill += take
            doc = doc[take:]
    if fill:
        rows.append(cur); segs.append(cur_seg)
    tokens = np.stack(rows) if rows else np.zeros((0, seq_len), np.int32)
    seg = np.stack(segs) if segs else np.zeros((0, seq_len), np.int32)
    # loss mask: positions whose NEXT token is in the same segment
    mask = np.zeros_like(seg, np.float32)
    mask[:, :-1] = (seg[:, :-1] == seg[:, 1:]) & (seg[:, :-1] > 0)
    return {"tokens": tokens, "segment_ids": seg, "mask": mask}


def packing_efficiency(packed: Dict[str, np.ndarray]) -> float:
    seg = packed["segment_ids"]
    return float((seg > 0).mean()) if seg.size else 0.0


def segment_attention_bias(segment_ids: np.ndarray) -> np.ndarray:
    """(B, S) segment ids -> (B, S, S) additive bias blocking cross-doc
    attention (combined with the causal mask downstream)."""
    same = segment_ids[:, :, None] == segment_ids[:, None, :]
    live = (segment_ids > 0)[:, :, None] & (segment_ids > 0)[:, None, :]
    return np.where(same & live, 0.0, -1e30).astype(np.float32)
