"""Serving example: batched generation with live offloading metering.

Loads the quickstart-style compressed MoE, serves batched requests with the
router-guided restoration path through the jitted streaming decode loop,
and meters the engine's OWN routing decisions through the per-layer
``ExpertStore`` (LRU cache + layer-ahead prefetcher) — bytes/token, cache
hit rate, and prefetch accuracy come from live decode, not a replayed
simulator trace.  The fig-7 event-driven simulator then projects that live
trace onto the paper's GPU-only and GPU-NDP hardware profiles.

Run:  PYTHONPATH=src python examples/serve_offload.py
"""
import dataclasses

import jax
import numpy as np

from repro.config import ModelConfig, MoEConfig, QuantConfig, TrainConfig
from repro.core import compress_ffn_weights
from repro.core.quantize import packed_nbytes
from repro.models import init_params
from repro.models.transformer import unstack_params
from repro.offload import (GPU_NDP, GPU_ONLY, LayerSpecSim, simulate_decode)
from repro.serve import ServeEngine
from repro.train import train


def main():
    cfg = ModelConfig(
        name="serve-moe", family="moe", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=0, vocab_size=512,
        block_pattern=("global",), max_position=2048,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=256,
                      quant=QuantConfig(enabled=True, bits=2,
                                        rank_budget=32, top_n_restore=1)))
    res = train(cfg, TrainConfig(total_steps=40, lr=2e-3, warmup_steps=10,
                                 checkpoint_every=10 ** 9, loss_chunk=0),
                log_every=0, batch_shape=(8, 128))
    params = res.state.params

    # --- compress for serving -------------------------------------------
    up = unstack_params(params, cfg)
    cfg_q = dataclasses.replace(cfg, force_unroll_plan=True)
    segs = []
    stacks_by_layer = []
    for seg in up["segments"]:
        p = dict(seg[0])
        mp = dict(p["moe"])
        stacks, _ = compress_ffn_weights(mp["w1"], mp["w2"], mp["w3"],
                                         cfg.moe.quant)
        stacks_by_layer.append(stacks)
        mp["stacks"] = stacks
        [mp.pop(k) for k in ("w1", "w2", "w3")]
        p["moe"] = mp
        segs.append((p,))
    qparams = dict(up)
    qparams["segments"] = tuple(segs)

    # --- batched generation + live offload metering ----------------------
    # the engine's jitted decode loop returns the per-step router trace;
    # attach_offload feeds it straight into the metered per-layer stores
    eng = ServeEngine(cfg_q, qparams, quantized=True)
    eng.attach_offload(stacks_by_layer, policy="ours", cache_capacity=2)
    prompts = np.random.default_rng(0).integers(0, 512, (4, 16),
                                                dtype=np.int32)
    out = eng.generate(prompts, max_new=16)
    print(f"generated {out.tokens.shape} tokens  "
          f"prefill {out.prefill_s * 1e3:.0f}ms  "
          f"decode {out.decode_tokens_per_s:.1f} tok/s (CPU emulation)")

    rep = out.offload_report
    print(f"live offload ({rep['policy']}): "
          f"{rep['bytes_per_token'] / 2**20:.2f} MiB/token, "
          f"cache hit {rep['hit_rate']:.0%}, "
          f"prefetch accuracy {rep['prefetch_accuracy']:.0%}")

    # --- projected device throughput (paper fig-7 hardware profiles) -----
    # feed the simulator the LIVE decode trace of one request stream
    trace = out.request_trace(0)                      # (steps, layers, k)
    d, fe, e = 4096, 14336, 8   # Mixtral-8x7B expert dims
    spec = LayerSpecSim(
        d, fe, e, 2,
        bytes_fp16=3 * d * fe * 2,
        bytes_quant=3 * (packed_nbytes(2, d, fe) + (d // 64) * fe * 4),
        comp_bytes=[32 * (d + fe)] * e)
    big_trace = np.tile(trace % e, (8, 16, 1))[:64, :32, :]
    for prof, policy in ((GPU_ONLY, "fp16"), (GPU_ONLY, "ours"),
                         (GPU_NDP, "ours_ndp")):
        r = simulate_decode(big_trace, spec, prof, policy, top_n=1,
                            num_layers=32)
        print(f"  {prof.name:16s} {policy:9s} {r.tokens_per_s:8.2f} tok/s  "
              f"{r.transfer_bytes_per_token / 2**20:7.1f} MiB/tok")


if __name__ == "__main__":
    main()
