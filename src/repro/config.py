"""Frozen-dataclass configuration system for the BEAM-LRC framework.

Every tunable in the framework flows through these dataclasses so that a
single ``--arch`` + ``--shape`` + ``--mesh`` selection fully determines a
run.  Configs are hashable/frozen; derived quantities are properties.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple


def replace(cfg, **kw):
    """dataclasses.replace that tolerates nested dotted keys ('moe.top_k')."""
    nested: dict[str, dict] = {}
    flat: dict[str, Any] = {}
    for k, v in kw.items():
        if "." in k:
            head, rest = k.split(".", 1)
            nested.setdefault(head, {})[rest] = v
        else:
            flat[k] = v
    for head, sub in nested.items():
        flat[head] = replace(getattr(cfg, head), **sub)
    return dataclasses.replace(cfg, **flat)


# ---------------------------------------------------------------------------
# Quantization / compensation (the paper's technique)
# ---------------------------------------------------------------------------

RANK_BUCKETS: Tuple[int, ...] = (0, 16, 32, 128, 256, 512, 1024)


@dataclass(frozen=True)
class QuantConfig:
    """Configuration of BEAM-LRC quantize-then-compensate.

    ``bits`` is the expert-weight precision; ``rank_budget`` is R_avg from
    paper §3.1; ``top_n_restore`` is the number of router-ranked experts
    whose compensators are applied per token (n < k).
    """
    enabled: bool = False
    bits: int = 2                      # expert weight bits: 2 | 3 | 4 | 8
    group_size: int = 64               # quantization group along K
    rank_budget: int = 32              # R_avg (paper: 32 Mixtral, 64 DeepSeek)
    rank_buckets: Tuple[int, ...] = RANK_BUCKETS
    top_n_restore: int = 1             # n (paper: 1 Mixtral, 3 DeepSeek)
    factor_bits: int = 8               # compensator factor storage precision
    factor_group_size: int = 64
    hqq_iters: int = 20                # half-quadratic optimization steps
    hqq_p: float = 0.7                 # l_p norm of HQQ shrinkage
    hqq_beta: float = 10.0             # initial HQQ penalty
    hqq_beta_scale: float = 1.01
    scale_dtype: str = "f32"           # f32 | bf16 storage for scale/zero
    kurtosis_guided: bool = True       # False -> uniform rank (ablation)
    compensate_shared: bool = True     # statically compensate shared experts
    uniform_rank: Optional[int] = None # override when kurtosis_guided=False
    # beyond-paper: allocate by the MEASURED per-expert residual instead of
    # its kurtosis proxy (residuals are computed offline anyway; the paper's
    # §6 names "model-aware rank allocation" as future work)
    rank_alloc: str = "kurtosis"       # kurtosis | error | uniform

    def __post_init__(self):
        assert self.bits in (1, 2, 3, 4, 8), f"unsupported bits={self.bits}"
        assert self.factor_bits in (3, 4, 8, 16)
        # group_size <= 0 -> per-channel quantization (resolved to K at
        # compression time); used by the GPTQ-collapse baseline in fig6


# ---------------------------------------------------------------------------
# Model family configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                      # per-expert FFN hidden dim
    num_shared_experts: int = 0
    d_shared: int = 0                  # shared-expert hidden (0 -> d_expert)
    capacity_factor: float = 1.25
    router_norm_topk: bool = True      # renormalize selected probs
    router_aux_weight: float = 0.01    # load-balancing loss weight
    router_z_weight: float = 1e-3      # router z-loss weight
    router_jitter: float = 0.0
    quant: QuantConfig = field(default_factory=QuantConfig)


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for enc-dec models (whisper)."""
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    source_len: int = 1500             # whisper: 30s audio -> 1500 frames


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // num_heads
    # --- layer pattern: names cycled over layers -------------------------
    # entries: 'global' | 'local' | 'recurrent' | 'mlstm' | 'slstm'
    block_pattern: Tuple[str, ...] = ("global",)
    window_size: int = 4096            # for 'local' sliding-window layers
    # --- positional ------------------------------------------------------
    rope_theta: float = 10_000.0
    rope_kind: str = "default"         # default | mrope | none
    rope_local_theta: float = 0.0      # gemma3 uses a different local theta
    abs_pos_embed: bool = False        # whisper-style additive sinusoidal
    # --- misc ------------------------------------------------------------
    act: str = "silu"                  # silu | gelu
    norm_eps: float = 1e-6
    qkv_bias: bool = False
    tie_embeddings: bool = True
    logit_softcap: float = 0.0
    post_attn_norm: bool = False       # gemma3-style extra norms
    scale_embed: bool = False          # gemma-style sqrt(d) embedding scale
    # --- MoE ---------------------------------------------------------------
    moe: Optional[MoEConfig] = None
    moe_layer_period: int = 1          # MoE every k-th layer (1 = all)
    first_layer_dense: bool = False    # deepseek-style dense layer 0
    gated_ffn: bool = True             # False -> plain 2-matrix MLP (whisper)
    # --- dense quantize-then-compensate (degenerate static form) ----------
    quant: QuantConfig = field(default_factory=QuantConfig)
    # --- enc-dec -----------------------------------------------------------
    encoder: Optional[EncoderConfig] = None
    # --- recurrent (RG-LRU / xLSTM) ----------------------------------------
    lru_width: int = 0                 # RG-LRU recurrence width (0 -> d_model)
    conv1d_width: int = 4              # temporal conv width in recurrent block
    # --- modality frontend stub -------------------------------------------
    frontend: str = "none"             # none | audio_stub | vision_stub
    max_position: int = 524_288
    kv_bits: int = 16                  # 8 = int8 KV cache (beyond-paper)
    # unrolled per-layer plan (needed when per-layer compensator ranks
    # differ, e.g. after offline compression of a real model)
    force_unroll_plan: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % self.num_kv_heads == 0 or self.num_kv_heads == 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def is_moe_layer(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe_layer_period == 0)

    @property
    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, h, kv, hd, ff, v = (self.d_model, self.num_heads, self.num_kv_heads,
                               self.head_dim, self.d_ff, self.vocab_size)
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind in ("global", "local"):
                total += d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
                if self.qkv_bias:
                    total += (h + 2 * kv) * hd
            elif kind == "recurrent":
                w = self.lru_width or d
                total += 2 * d * w + w * d + self.conv1d_width * w + 3 * w
            elif kind == "mlstm":
                total += 2 * d * 2 * d + 2 * d * d // 4 + 2 * d * d
            elif kind == "slstm":
                total += 4 * d * d + 4 * d * d // 4
            if self.is_moe_layer(i):
                m = self.moe
                total += d * m.num_experts  # router
                total += m.num_experts * 3 * d * m.d_expert
                total += m.num_shared_experts * 3 * d * (m.d_shared or m.d_expert)
            elif kind in ("global", "local", "recurrent"):
                if ff > 0:
                    total += 3 * d * ff
            total += 2 * d  # norms
        if self.encoder is not None:
            e = self.encoder
            total += e.num_layers * (4 * e.d_model * e.d_model + 2 * e.d_model * e.d_ff)
            # cross-attention in every decoder layer
            total += self.num_layers * (4 * d * d)
        return total

    @property
    def num_active_params(self) -> int:
        """Active params per token (MoE counts only routed top-k + shared)."""
        if self.moe is None:
            return self.num_params
        m = self.moe
        full_experts = m.num_experts * 3 * self.d_model * m.d_expert
        active_experts = (m.top_k + m.num_shared_experts) * 3 * self.d_model * (m.d_expert)
        n_moe_layers = sum(1 for i in range(self.num_layers) if self.is_moe_layer(i))
        return self.num_params - n_moe_layers * (full_experts - active_experts
                                                 + m.num_shared_experts * 3 * self.d_model * m.d_expert
                                                 - m.num_shared_experts * 3 * self.d_model * (m.d_shared or m.d_expert))


# ---------------------------------------------------------------------------
# Input shapes (assigned per arch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":   ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k":  ShapeConfig("long_500k", "decode", 524_288, 1),
}


# ---------------------------------------------------------------------------
# Parallelism / runtime
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelConfig:
    multi_pod: bool = False
    # logical -> mesh axis rules; tried in order, first divisible rule wins.
    rules: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
        ("batch",      ("pod", "data")),
        ("seq",        ()),               # activation seq (opt-in seq-parallel)
        ("moe_seq",    ("model",)),       # seq sharding inside MoE dispatch
        ("kv_seq",     ("data",)),        # long-context KV sharding
        ("vocab",      ("model",)),
        ("embed",      ()),
        ("heads",      ("model",)),
        ("kv_heads",   ("model",)),
        ("mlp",        ("model",)),
        ("expert",     ("model",)),
        ("expert_mlp", ()),
        ("lowrank",    ()),
        ("conv",       ()),
        ("lru",        ("model",)),
    )
    remat_policy: str = "minimal"      # none | minimal | full
    scan_layers: bool = True
    grad_compress_bits: int = 0        # 0 = off, 8 = int8 compressed psum
    use_shard_map_moe: bool = False    # explicit all_to_all EP path
    donate_state: bool = True

    def rule_for(self, logical: str) -> Tuple[str, ...]:
        for name, axes in self.rules:
            if name == logical:
                return axes
        return ()


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    clip_norm: float = 1.0
    microbatch: int = 0                # 0 = no accumulation
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    seed: int = 0
    z_loss: float = 1e-4
    loss_chunk: int = 512   # sequence-chunked xent: peak logits = B*chunk*V


@dataclass(frozen=True)
class ControlConfig:
    """Runtime bandwidth-budget controller (serve/controller.py).

    Between scheduler scan chunks the controller compares the metered
    offload wire bytes/token against a budget and adjusts a per-layer
    ``(top_n, rank_cap)`` restoration plan.  The budget is either
    ``bytes_per_token`` directly, or derived from a ``tokens_per_s``
    SLO over ``link_bw`` (bytes/token the link can afford at that rate).
    Both zero -> no budget: the plan stays pinned at the static
    ``QuantConfig.top_n_restore`` / full-rank point.
    """
    enabled: bool = False
    bytes_per_token: float = 0.0       # wire-byte budget per decoded token
    tokens_per_s: float = 0.0          # alternative SLO: link_bw / tok_s
    link_bw: float = 25e9              # link bandwidth for the SLO form
    gain: float = 0.5                  # integral step: fraction of the
                                       # ladder crossed at 100% budget error
    deadband: float = 0.05             # |relative error| tolerated w/o moves
    ema: float = 0.5                   # weight of the newest bytes/token
                                       # sample (per-chunk LRU noise filter)
    max_step_frac: float = 0.125       # per-update ladder step ceiling —
                                       # large jumps limit-cycle on noisy
                                       # cache dynamics instead of settling
    min_top_n: int = 0                 # plan floor (0 = pure low-bit)
    max_top_n: int = -1                # plan ceiling (-1 = router top_k)
    rank_fracs: Tuple[float, ...] = (0.25, 0.5, 0.75, 1.0)
    # expert-parallel serving: what the bytes/token budget constrains —
    # 'aggregate' sums every shard's link traffic (one shared host link),
    # 'per_shard' budgets the HOTTEST shard's link (per-device links: the
    # slowest link gates decode, so the max is the latency-relevant signal)
    budget_scope: str = "aggregate"    # aggregate | per_shard

    def __post_init__(self):
        assert self.budget_scope in ("aggregate", "per_shard"), \
            self.budget_scope

    @property
    def target_bytes_per_token(self) -> float:
        """Resolved budget in bytes/token (0.0 = unconstrained)."""
        if self.bytes_per_token > 0:
            return self.bytes_per_token
        if self.tokens_per_s > 0:
            return self.link_bw / self.tokens_per_s
        return 0.0


@dataclass(frozen=True)
class StreamConfig:
    """True asynchronous expert streaming (offload/staging.py).

    When enabled, offloaded serving actually *moves* expert bytes: the
    compressed stacks live in a host-memory wire image, a per-layer
    staging ring issues async H2D copies for every byte the offload
    meter charges, and the decode scan reads mutable device stack
    containers assembled from the streamed payloads (initialized to a
    device-resident ``fallback_bits`` "little expert" copy).

    ``miss_policy``:
      'block'    a chunk that routed to a not-yet-streamed expert stalls,
                 stages it, and re-runs from a cache snapshot — streamed
                 decode is token-identical to the all-resident path;
      'degrade'  never stall: the missed expert is served from the
                 resident low-bit fallback (MoBiLE little-expert
                 semantics) and the affected tokens count as degraded.
    A copy stalled longer than ``stall_timeout_s`` degrades even under
    'block' (a wedged link must not hang decode forever).
    """
    enabled: bool = False
    ring_slots: int = 2                # per-layer staging depth (double buffer)
    miss_policy: str = "block"         # block | degrade
    fallback_bits: int = 2             # resident low-bit fallback width
    stall_timeout_s: float = 5.0       # stalled-copy degrade threshold
    max_reruns: int = 8                # fixpoint re-run bound per chunk

    def __post_init__(self):
        assert self.miss_policy in ("block", "degrade"), self.miss_policy
        assert self.ring_slots >= 1, self.ring_slots


@dataclass(frozen=True)
class SpecConfig:
    """Speculative decoding (serve/speculative.py).

    ``k`` > 0 turns each serve-loop iteration into a draft/verify round:
    a drafter proposes ``k`` continuations per slot, one batched target
    pass scores all ``k+1`` positions, and rejection sampling keeps a
    per-slot prefix — token-identical to autoregressive decode at
    temperature 0, distribution-preserving otherwise.  The verify pass's
    router trace doubles as the lookahead routing oracle that warms the
    expert stores for not-yet-verified tokens.
    """
    k: int = 0                         # drafted tokens per round (0 = off)
    drafter: str = "ngram"             # ngram | model | self
    ngram_order: int = 3               # longest backoff context is order-1 tokens
    draft_window: int = 32             # model drafter: tail tokens re-read per step

    def __post_init__(self):
        assert self.k >= 0, self.k
        assert self.drafter in ("ngram", "model", "self"), self.drafter
        assert self.ngram_order >= 2, self.ngram_order
        assert self.draft_window >= 1, self.draft_window


@dataclass(frozen=True)
class ServeConfig:
    max_seq_len: int = 4096
    prefill_chunk: int = 512
    temperature: float = 0.0
    eos_id: int = 1
    offload: bool = False              # expert offloading emulation on/off
    prefetch_layers: int = 1
    cache_experts: int = 4             # device-resident expert cache per layer
    # continuous batching: decode-slot pool size and scan chunk length
    # (the scheduler refills completed slots between fixed-shape chunks)
    num_slots: int = 4
    chunk_steps: int = 8
    # paged KV cache: page_size > 0 (power of two) switches the serve
    # cache's global-attention layers to block-table paging; prefix_cache
    # additionally refcount-shares physical pages across requests whose
    # prompts share full leading pages (prefill for the shared span runs
    # once)
    page_size: int = 0
    prefix_cache: bool = False
    # adaptive top-n restoration under a bandwidth budget; when enabled,
    # ServeEngine.attach_offload auto-attaches the controller (the
    # controller feeds on the offload byte meters)
    control: ControlConfig = field(default_factory=ControlConfig)
    # true async expert streaming; when enabled, attach_offload
    # auto-attaches the transfer engine (it feeds the same byte meters)
    stream: StreamConfig = field(default_factory=StreamConfig)
    # speculative decoding defaults (ServeEngine.serve(spec_k=) overrides)
    spec: SpecConfig = field(default_factory=SpecConfig)


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)
    dtype: str = "bfloat16"
