"""Hypothesis property tests for the staging-ring state machine.

The ``StagingRing`` (offload/staging.py) is the fixed-capacity slot pool
each layer's async H2D copies move through: FREE --issue--> IN_FLIGHT
--poll--> READY --release--> FREE, with ``abandon`` the stalled-copy
escape hatch.  The serve engine carries ring bookkeeping across scan
chunks via ``snapshot``/``restore``.  Invariants pinned here:

- a slot is never handed out again while its copy is in flight (or
  staged-but-unconsumed): issue only ever claims FREE slots, and a held
  slot's ``generation`` stays fixed until release/abandon,
- capacity is respected under arbitrary issue/complete/release/abandon
  interleavings — ``try_issue`` returns None at occupancy == capacity,
  it never queues past the ring,
- bookkeeping state round-trips exactly through ``snapshot``/``restore``
  at any point in the interleaving (the chunk-boundary contract).

The stateful hypothesis machine needs the ``hypothesis`` package (CI
installs it); the deterministic edge tests and the seeded-interleaving
fallback below run everywhere, so the ring tier is never a no-op.
"""
import numpy as np
import pytest

try:
    from hypothesis import settings, strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, precondition, rule)
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

from repro.offload.staging import (FREE, IN_FLIGHT, READY,
                                   FakeTransferBackend, StagingRing)


def _payload():
    return np.zeros((2,), np.float32)


def make_ring(capacity, delay_s=0.0, stall=None, clock=None):
    clock = clock or (lambda: 0.0)
    backend = FakeTransferBackend(delay_s=delay_s, stall=stall, clock=clock)
    return StagingRing(capacity, backend, clock=clock, tag=0), backend


# ---------------------------------------------------------------------------
# stateful interleaving machine
# ---------------------------------------------------------------------------

class _RingDriver:
    """Shared op interpreter: one step of the ring interleaving, with
    every state-machine invariant asserted.  The hypothesis machine and
    the seeded fallback below both drive this, so the checked properties
    are identical with and without hypothesis installed."""

    def __init__(self, cap: int):
        self.blocked = set()
        self.ring, self.backend = make_ring(
            cap, stall=lambda tag: tag in self.blocked)
        self.next_expert = 0
        # slot index -> (expert, generation at issue, kind); present
        # while we hold the slot (IN_FLIGHT or READY)
        self.held = {}

    def issue(self, kind):
        e = self.next_expert
        self.next_expert += 1
        self.blocked.add((0, e, kind))
        before = self.ring.occupancy
        slot = self.ring.try_issue(e, _payload(), 16, kind=kind)
        if before == self.ring.capacity:
            assert slot is None, "issued past ring capacity"
            self.blocked.discard((0, e, kind))
            return
        assert slot is not None
        assert slot.index not in self.held, \
            "issue handed out a slot still held by an earlier copy"
        assert slot.state == IN_FLIGHT and slot.expert == e
        self.held[slot.index] = (e, slot.generation, kind)

    def complete_and_release(self, idx):
        slot = self.ring.slots[idx]
        e, gen, kind = self.held[idx]
        self.blocked.discard((0, e, kind))
        self.ring.poll()
        assert slot.state == READY, "unstalled copy did not become READY"
        assert slot.generation == gen, "slot reused while held"
        self.ring.release(slot)
        assert slot.state == FREE and slot.expert == -1
        del self.held[idx]

    def abandon(self, idx):
        slot = self.ring.slots[idx]
        e, gen, kind = self.held[idx]
        assert slot.state == IN_FLIGHT and slot.generation == gen
        self.ring.abandon(slot)          # stalled-copy escape hatch
        assert slot.state == FREE
        del self.held[idx]

    def poll_is_stable(self):
        snap = [(s.state, s.expert, s.generation) for s in self.ring.slots]
        self.ring.poll()                  # every copy still blocked or READY
        self.ring.poll()
        after = [(s.state, s.expert, s.generation) for s in self.ring.slots]
        # a stalled IN_FLIGHT copy must stay IN_FLIGHT; READY stays READY
        for (st0, e0, g0), (st1, e1, g1) in zip(snap, after):
            if st0 in (FREE, READY):
                assert st1 == st0
            assert (e1, g1) == (e0, g0)

    def snapshot_roundtrip(self):
        snap = self.ring.snapshot()
        self.ring.restore(snap)
        assert self.ring.snapshot() == snap

    def in_flight_indices(self):
        return [s.index for s in self.ring.slots if s.state == IN_FLIGHT]

    def check_invariants(self):
        assert self.ring.occupancy <= self.ring.capacity
        # every slot we hold is still ours: same expert, same generation
        for idx, (e, gen, _kind) in self.held.items():
            slot = self.ring.slots[idx]
            assert slot.state in (IN_FLIGHT, READY)
            assert slot.expert == e and slot.generation == gen
        # and every non-FREE slot is accounted for
        busy = {s.index for s in self.ring.slots if s.state != FREE}
        assert busy == set(self.held)


if HAVE_HYPOTHESIS:
    class RingMachine(RuleBasedStateMachine):
        """Arbitrary op interleavings; every copy starts stalled (its
        tag sits in ``driver.blocked``), so the machine — not wall
        time — decides when each copy completes, making in-flight
        windows arbitrarily long relative to the other operations."""

        @initialize(cap=st.integers(1, 4))
        def setup(self, cap):
            self.driver = _RingDriver(cap)

        @rule(kind=st.sampled_from(["w", "f"]))
        def issue(self, kind):
            self.driver.issue(kind)

        @precondition(lambda self: self.driver.in_flight_indices())
        @rule(pick=st.randoms(use_true_random=False))
        def complete_and_release(self, pick):
            self.driver.complete_and_release(
                pick.choice(self.driver.in_flight_indices()))

        @precondition(lambda self: self.driver.in_flight_indices())
        @rule(pick=st.randoms(use_true_random=False))
        def abandon(self, pick):
            self.driver.abandon(
                pick.choice(self.driver.in_flight_indices()))

        @rule()
        def poll_is_stable(self):
            self.driver.poll_is_stable()

        @rule()
        def snapshot_roundtrip(self):
            self.driver.snapshot_roundtrip()

        @invariant()
        def ring_invariants(self):
            # setup() is itself a rule: hypothesis checks invariants
            # once before @initialize has run
            if hasattr(self, "driver"):
                self.driver.check_invariants()

    TestRingMachine = RingMachine.TestCase
    TestRingMachine.settings = settings(max_examples=40, deadline=None,
                                        stateful_step_count=30)


@pytest.mark.parametrize("seed", range(8))
def test_seeded_interleavings(seed):
    """Hypothesis-free fallback over the same driver + invariants."""
    rng = np.random.default_rng(seed)
    drv = _RingDriver(int(rng.integers(1, 5)))
    for _ in range(120):
        op = rng.integers(0, 5)
        inflight = drv.in_flight_indices()
        if op == 0 or not inflight:
            drv.issue("w" if rng.integers(2) else "f")
        elif op == 1:
            drv.complete_and_release(int(rng.choice(inflight)))
        elif op == 2:
            drv.abandon(int(rng.choice(inflight)))
        elif op == 3:
            drv.poll_is_stable()
        else:
            drv.snapshot_roundtrip()
        drv.check_invariants()


# ---------------------------------------------------------------------------
# deterministic edges
# ---------------------------------------------------------------------------

def test_capacity_one_ring_blocks_second_issue():
    ring, _ = make_ring(1)
    s0 = ring.try_issue(0, _payload(), 8)
    assert s0 is not None and ring.occupancy == 1
    assert ring.try_issue(1, _payload(), 8) is None
    ring.poll()
    assert s0.state == READY             # delay 0, no stall
    ring.release(s0)
    assert ring.try_issue(1, _payload(), 8) is not None


def test_delay_gates_readiness_on_injected_clock():
    t = [0.0]
    ring, _ = make_ring(2, delay_s=1.0, clock=lambda: t[0])
    slot = ring.try_issue(3, _payload(), 8)
    ring.poll()
    assert slot.state == IN_FLIGHT       # 0s elapsed < 1s delay
    t[0] = 0.999
    ring.poll()
    assert slot.state == IN_FLIGHT
    t[0] = 1.0
    ring.poll()
    assert slot.state == READY


def test_stalled_copy_never_ready_and_wait_times_out():
    ring, _ = make_ring(2, stall=lambda tag: True,
                        clock=__import__("time").monotonic)
    slot = ring.try_issue(5, _payload(), 8)
    assert not ring.wait(slot, timeout_s=0.05)
    assert slot.state == IN_FLIGHT
    ring.abandon(slot)                   # the degrade path frees the slot
    assert slot.state == FREE and ring.occupancy == 0


def test_wait_returns_true_for_ready_copy():
    ring, _ = make_ring(2, clock=__import__("time").monotonic)
    slot = ring.try_issue(7, _payload(), 8)
    assert ring.wait(slot, timeout_s=1.0)
    assert slot.state == READY


def test_release_requires_ready_and_abandon_requires_in_flight():
    ring, _ = make_ring(2, stall=lambda tag: True)
    slot = ring.try_issue(0, _payload(), 8)
    with pytest.raises(AssertionError):
        ring.release(slot)               # still IN_FLIGHT
    ring.abandon(slot)
    with pytest.raises(AssertionError):
        ring.abandon(slot)               # already FREE


def test_snapshot_restore_capacity_mismatch_rejected():
    ring, _ = make_ring(2)
    other, _ = make_ring(3)
    with pytest.raises(ValueError):
        other.restore(ring.snapshot())


def test_find_locates_staged_expert_by_kind():
    ring, _ = make_ring(2)
    ring.try_issue(4, _payload(), 8, kind="w")
    ring.try_issue(4, _payload(), 8, kind="f")
    assert ring.find(4, "w").kind == "w"
    assert ring.find(4, "f").kind == "f"
    assert ring.find(9, "w") is None
