"""DeepSeek-MoE-16B (paper reference model, Table 1): 28L hidden
(2048, 11008 dense layer-0), 64 routed experts top-6 + 2 shared.
Paper setting: uniform router -> R_avg=64, top-n=3."""
from ..config import ModelConfig, MoEConfig, QuantConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=10944, vocab_size=102_400,
        block_pattern=("global",), first_layer_dense=True,
        rope_theta=10_000.0, act="silu", tie_embeddings=False,
        moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408,
                      num_shared_experts=2, d_shared=1408,
                      router_norm_topk=False,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=64,
                                        top_n_restore=3)),
        max_position=16_384,
    )
