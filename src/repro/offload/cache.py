"""Re-export of the device-resident expert LRU (lives with the store)."""
from .store import ExpertCache, FetchStats

__all__ = ["ExpertCache", "FetchStats"]
