"""RL3xx Pallas tile-legality rules.

The fused decode kernel's contracts are numeric, so these rules evaluate
real values instead of pattern-matching: the block-size sources checked
are the literal ``DEFAULT_TABLE`` in ``kernels/autotune.py``, the
candidate menus in ``launch/roofline.py``, and the default tile keyword
values of kernel wrappers that invoke ``pl.pallas_call`` on packed
operands.

RL301 tile-pack-divisibility  a bk (K-tile) entry not divisible by
                              ``PACK_BLOCK`` — a K tile that splits a
                              packing block reads bytes it cannot fully
                              consume and breaks the block-local unpack.
RL302 tile-vmem-budget        a (bm, bn, bk) entry whose resident
                              footprint per grid step —
                              ``launch/roofline.py::fused_tile_vmem_bytes``
                              at the documented worst case (8-bit
                              container, group 64, rank 256) — exceeds
                              ``VMEM_BYTES * VMEM_BUDGET``.
RL303 pallas-missing-guard    a ``pl.pallas_call`` on packed planes whose
                              enclosing function neither asserts
                              ``bk % PACK_BLOCK`` nor routes tiles
                              through ``clamp_tiles``/``_tile_sizes``.

``PACK_BLOCK`` is read from the ``core/quantize.py`` AST (so the lint
needs no jax import to parse); the VMEM formula is imported from
``launch/roofline.py`` — the check uses the same equation the autotuner
candidates do.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from .core import Finding, rule
from .jitscope import _dotted

# worst-case problem parameters the static budget check evaluates at:
# widest supported container, default quant group, generous padded rank
WORST_CASE = {"bits": 8, "group_size": 64, "rank": 256}


def _literal_assign(tree: ast.Module, name: str):
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    try:
                        return ast.literal_eval(node.value), node
                    except ValueError:
                        return None, node
        if isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name) and \
                node.target.id == name and node.value is not None:
            try:
                return ast.literal_eval(node.value), node
            except ValueError:
                return None, node
    return None, None


def _pack_block(ctx) -> int:
    for module, tree in ctx.index.trees.items():
        if str(ctx.index.module_paths[module]).endswith("core/quantize.py"):
            val, _ = _literal_assign(tree, "PACK_BLOCK")
            if isinstance(val, int):
                return val
    return 64


def _vmem_formula():
    """(fused_tile_vmem_bytes, budget_bytes) from the live roofline module;
    None when the repro package is not importable (fixture-only runs)."""
    try:
        from ..launch.roofline import (VMEM_BUDGET, VMEM_BYTES,
                                       fused_tile_vmem_bytes)
        return fused_tile_vmem_bytes, VMEM_BYTES * VMEM_BUDGET
    except Exception:
        return None, None


def _table_entries(ctx):
    """Yield (path, node, key, (bm, bn, bk)) from autotune DEFAULT_TABLE
    and (path, node, ('BK_CANDIDATES', i), bk) style candidate menus."""
    for module, tree in ctx.index.trees.items():
        path = ctx.index.module_paths[module]
        sp = str(path)
        if sp.endswith("kernels/autotune.py"):
            table, node = _literal_assign(tree, "DEFAULT_TABLE")
            if isinstance(table, dict):
                for key, tiles in table.items():
                    yield "table", path, node, key, tiles
        if sp.endswith("launch/roofline.py"):
            for cname in ("BK_CANDIDATES",):
                vals, node = _literal_assign(tree, cname)
                if isinstance(vals, tuple):
                    for bk in vals:
                        yield "bk_menu", path, node, (cname, bk), bk


@rule("RL301", "kernel K-tile not divisible by PACK_BLOCK")
def rl301(scope, ctx) -> List[Finding]:
    out = []
    pack = _pack_block(ctx)
    for kind, path, node, key, val in _table_entries(ctx):
        if kind == "table":
            bm, bn, bk = val
        else:
            bk = val
        if bk % pack:
            out.append(ctx.finding_at(
                "RL301", path, node,
                f"tile entry {key}: bk={bk} is not a multiple of "
                f"PACK_BLOCK={pack}; a K tile that splits a packing "
                f"block breaks the block-local unpack"))
    # default tile kwargs of pallas wrappers over packed planes
    for path, fnode, defaults in _kernel_defaults(ctx):
        bk = defaults.get("bk")
        if isinstance(bk, int) and bk % pack:
            out.append(ctx.finding_at(
                "RL301", path, fnode,
                f"{fnode.name}() default bk={bk} is not a multiple of "
                f"PACK_BLOCK={pack}"))
    return out


@rule("RL302", "kernel tile exceeds the roofline VMEM budget")
def rl302(scope, ctx) -> List[Finding]:
    vmem, budget = _vmem_formula()
    if vmem is None:
        return []
    out = []
    for kind, path, node, key, val in _table_entries(ctx):
        if kind != "table":
            continue
        bm, bn, bk = val
        need = vmem(bm, bn, bk, **WORST_CASE)
        if need > budget:
            out.append(ctx.finding_at(
                "RL302", path, node,
                f"tile entry {key}: ({bm}, {bn}, {bk}) needs "
                f"{need / 2**20:.2f} MiB VMEM at the worst case "
                f"{WORST_CASE}, over the {budget / 2**20:.2f} MiB "
                f"budget (fused_tile_vmem_bytes)"))
    for path, fnode, defaults in _kernel_defaults(ctx):
        bm, bn, bk = (defaults.get("bm"), defaults.get("bn"),
                      defaults.get("bk"))
        if all(isinstance(v, int) for v in (bm, bn, bk)):
            need = vmem(bm, bn, bk, **WORST_CASE)
            if need > budget:
                out.append(ctx.finding_at(
                    "RL302", path, fnode,
                    f"{fnode.name}() default tiles ({bm}, {bn}, {bk}) "
                    f"need {need / 2**20:.2f} MiB VMEM at the worst "
                    f"case, over the {budget / 2**20:.2f} MiB budget"))
    return out


@rule("RL303", "pallas_call on packed planes without a PACK_BLOCK guard")
def rl303(scope, ctx) -> List[Finding]:
    out = []
    for module, tree in ctx.index.trees.items():
        path = ctx.index.module_paths[module]
        for fnode in ast.walk(tree):
            if not isinstance(fnode, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                continue
            calls = [n for n in ast.walk(fnode)
                     if isinstance(n, ast.Call)
                     and (_dotted(n.func) or "").endswith("pallas_call")]
            if not calls:
                continue
            if not _uses_planes(fnode):
                continue                      # unquantized kernel (attn...)
            if _has_pack_guard(fnode):
                continue
            for call in calls:
                out.append(ctx.finding_at(
                    "RL303", path, call,
                    f"{fnode.name}() launches a Pallas kernel over packed "
                    f"planes without asserting bk % PACK_BLOCK (or "
                    f"clamping via clamp_tiles/_tile_sizes)"))
    return out


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _kernel_defaults(ctx):
    """(path, FunctionDef, {kw: default int}) for functions that launch
    pallas_call on packed planes and take bm/bn/bk tile kwargs."""
    for module, tree in ctx.index.trees.items():
        path = ctx.index.module_paths[module]
        for fnode in ast.walk(tree):
            if not isinstance(fnode, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                continue
            if not any(isinstance(n, ast.Call)
                       and (_dotted(n.func) or "").endswith("pallas_call")
                       for n in ast.walk(fnode)):
                continue
            if not _uses_planes(fnode):
                continue
            a = fnode.args
            names = [p.arg for p in a.args] + [p.arg for p in a.kwonlyargs]
            defaults = ([None] * (len(a.args) - len(a.defaults))
                        + list(a.defaults) + list(a.kw_defaults))
            kv = {}
            for nm, d in zip(names, defaults):
                if nm in ("bm", "bn", "bk") and isinstance(d, ast.Constant) \
                        and isinstance(d.value, int):
                    kv[nm] = d.value
            if kv:
                yield path, fnode, kv


def _uses_planes(fnode: ast.AST) -> bool:
    for n in ast.walk(fnode):
        if isinstance(n, ast.Name) and n.id in ("planes", "PLANES"):
            return True
        if isinstance(n, ast.Attribute) and n.attr == "planes":
            return True
    return False


def _has_pack_guard(fnode: ast.AST) -> bool:
    for n in ast.walk(fnode):
        if isinstance(n, ast.Assert):
            for sub in ast.walk(n.test):
                if isinstance(sub, ast.Name) and sub.id == "PACK_BLOCK":
                    return True
        if isinstance(n, ast.Call):
            head = (_dotted(n.func) or "").split(".")[-1]
            if head in ("clamp_tiles", "_tile_sizes"):
                return True
    return False
