"""Runtime bandwidth-budget controller: ladder, determinism, the
disabled-path bit-identity guarantee, rank-capped metering, and
convergence of the adaptive simulator policy on both hardware profiles."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ControlConfig, ModelConfig, MoEConfig, QuantConfig
from repro.core import compress_ffn_weights
from repro.core.restoration import compensated_expert_ffn
from repro.models import init_params
from repro.models.transformer import compress_moe_params
from repro.offload import (GPU_NDP, GPU_ONLY, ExpertStore, LayerSpecSim,
                           ShardedExpertStore, make_expert_stores,
                           replay_decode_trace, simulate_decode)
from repro.offload.simulator import make_router_trace
from repro.serve import (BandwidthController, ServeEngine, static_plan,
                         synthetic_workload)


def make_controller(budget=1000.0, pads=(16, 32), top_k=2, **kw):
    cc = ControlConfig(enabled=True, bytes_per_token=budget, **kw)
    return BandwidthController(list(pads), top_k, cc, static_top_n=1)


# ---------------------------------------------------------------------------
# ladder / plan mapping
# ---------------------------------------------------------------------------

def test_ladder_endpoints_and_monotonic_top_n():
    c = make_controller()
    lo = c.plan_at(0)
    assert lo.top_n.tolist() == [0, 0] and lo.rank_cap.tolist() == [0, 0]
    hi = c.plan_at(c.max_level)
    assert hi.top_n.tolist() == [2, 2]
    assert hi.rank_cap.tolist() == [16, 32]     # per-layer padded ranks
    prev = c.plan_at(0)
    for lvl in range(1, c.max_level + 1):
        cur = c.plan_at(lvl)
        assert (cur.top_n >= prev.top_n).all()
        # one micro-step moves exactly one layer by one rung
        changed = int((cur.top_n != prev.top_n).sum()
                      + ((cur.top_n == prev.top_n)
                         & (cur.rank_cap != prev.rank_cap)).sum())
        assert changed == 1
        prev = cur


def test_static_level_matches_frozen_operating_point():
    c = make_controller()
    p = c.plan_at(c._static_level())
    assert p.top_n.tolist() == [1, 1]           # static_top_n
    assert p.rank_cap.tolist() == [16, 32]      # full rank


def test_inactive_controller_pins_static_plan():
    for cc in (ControlConfig(enabled=False, bytes_per_token=100.0),
               ControlConfig(enabled=True)):    # no budget
        c = BandwidthController([8, 8], 2, cc, static_top_n=1)
        assert not c.active
        want = static_plan([8, 8], 1)
        for nbytes in (10, 10_000, 0):
            p = c.update(nbytes, 4)
            np.testing.assert_array_equal(p.top_n, want.top_n)
            np.testing.assert_array_equal(p.rank_cap, want.rank_cap)
        assert len(c.history) == 3              # telemetry still recorded


def test_controller_deterministic():
    seq = [(5_000, 8), (2_000, 8), (900, 4), (12_000, 8), (1_000, 8)] * 4
    runs = []
    for _ in range(2):
        c = make_controller(budget=1200.0, gain=0.4)
        plans = [c.update(b, t).as_array().copy() for b, t in seq]
        runs.append((plans, [h.level for h in c.history]))
    for a, b in zip(*[r[0] for r in runs]):
        np.testing.assert_array_equal(a, b)
    assert runs[0][1] == runs[1][1]


def test_controller_moves_toward_budget():
    c = make_controller(budget=1000.0, gain=0.5)
    lvl = c.level
    c.update(4000, 1)                # way over budget -> throttle down
    assert c.level < lvl
    for _ in range(20):
        c.update(10, 1)              # way under -> restore more
    assert c.level == c.max_level


# ---------------------------------------------------------------------------
# rank-capped restoration numerics
# ---------------------------------------------------------------------------

def _ffn_stacks(seed=0, e=2, k=64, n=128):
    rng = np.random.default_rng(seed)
    w1 = jnp.asarray(rng.standard_normal((e, k, n)).astype(np.float32))
    w2 = jnp.asarray(rng.standard_normal((e, n, k)).astype(np.float32))
    w3 = jnp.asarray(rng.standard_normal((e, k, n)).astype(np.float32))
    qcfg = QuantConfig(enabled=True, bits=2, rank_budget=16, hqq_iters=2,
                       group_size=16, factor_group_size=16)
    stacks, _ = compress_ffn_weights(w1, w2, w3, qcfg)
    return stacks


def test_rank_cap_at_pad_rank_is_bit_identical():
    stacks = _ffn_stacks()
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (2, 4, 64)).astype(np.float32))
    mask = jnp.ones((2, 4), jnp.float32)
    pad = max(s.pad_rank for s in stacks.values())
    base = compensated_expert_ffn(x, stacks["w1"], stacks["w3"],
                                  stacks["w2"], mask)
    capped = compensated_expert_ffn(x, stacks["w1"], stacks["w3"],
                                    stacks["w2"], mask,
                                    rank_cap=jnp.int32(pad))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(capped))


def test_rank_cap_zero_equals_no_compensation():
    stacks = _ffn_stacks()
    x = jnp.asarray(np.random.default_rng(2).standard_normal(
        (2, 4, 64)).astype(np.float32))
    ones = jnp.ones((2, 4), jnp.float32)
    capped = compensated_expert_ffn(x, stacks["w1"], stacks["w3"],
                                    stacks["w2"], ones,
                                    rank_cap=jnp.int32(0))
    uncomp = compensated_expert_ffn(x, stacks["w1"], stacks["w3"],
                                    stacks["w2"], jnp.zeros((2, 4)))
    np.testing.assert_allclose(np.asarray(capped), np.asarray(uncomp),
                               rtol=1e-6, atol=1e-6)


def test_rank_cap_truncates_like_sliced_factors():
    stacks = _ffn_stacks()
    st = stacks["w1"]
    x = jnp.asarray(np.random.default_rng(3).standard_normal(
        (2, 4, 64)).astype(np.float32))
    ones = jnp.ones((2, 4), jnp.float32)
    cap = 4
    capped = compensated_expert_ffn(x, st, None, stacks["w2"], ones,
                                    rank_cap=jnp.int32(cap))
    # oracle: zero factor dims >= cap by hand (a slice of the padding)
    rmask = (jnp.arange(st.pad_rank) < cap)
    st_cut = dataclasses.replace(st, u=st.u * rmask[None, None, :],
                                 v=st.v * rmask[None, :, None])
    w2 = stacks["w2"]
    w2_cut = dataclasses.replace(w2, u=w2.u * rmask[None, None, :],
                                 v=w2.v * rmask[None, :, None])
    oracle = compensated_expert_ffn(x, st_cut, None, w2_cut, ones)
    np.testing.assert_allclose(np.asarray(capped), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# rank-capped metering
# ---------------------------------------------------------------------------

def test_store_rank_cap_fetches_delta_on_raise():
    stacks = _ffn_stacks()
    store = ExpertStore(stacks, cache_capacity=4)
    topk = np.array([0, 1])
    store.access_token(topk, top_n=1, policy="ours", rank_cap=4)
    c4 = store.comp_bytes_moved
    assert c4 == store.compensator_bytes(0, 4) > 0
    # same cap again: factors are resident, no re-charge
    store.access_token(topk, top_n=1, policy="ours", rank_cap=4)
    assert store.comp_bytes_moved == c4
    # raised cap: only the missing rank rows move
    store.access_token(topk, top_n=1, policy="ours", rank_cap=8)
    assert store.comp_bytes_moved == store.compensator_bytes(0, 8)
    # lowered cap: a superset is resident, nothing moves
    store.access_token(topk, top_n=1, policy="ours", rank_cap=2)
    assert store.comp_bytes_moved == store.compensator_bytes(0, 8)
    # uncapped tops up to the full true rank
    store.access_token(topk, top_n=1, policy="ours")
    assert store.comp_bytes_moved == store.compensator_bytes(0)


def test_replay_per_layer_plan_matches_scalar_when_uniform():
    stacks = _ffn_stacks()
    trace = np.asarray(
        make_router_trace(None, 12, 2, 2, seed=0, num_experts=2)
    ).transpose(0, 1, 2)[:, :, None, :]        # (steps, 2, B=1, k)
    pad = max(s.pad_rank for s in stacks.values())
    s_scalar = [ExpertStore(stacks, 2), ExpertStore(stacks, 2)]
    s_array = [ExpertStore(stacks, 2), ExpertStore(stacks, 2)]
    t1, _ = replay_decode_trace(s_scalar, trace, top_n=1)
    t2, _ = replay_decode_trace(s_array, trace, top_n=np.array([1, 1]),
                                rank_caps=np.array([pad, pad]))
    assert t1 == t2
    assert (sum(s.total_bytes for s in s_scalar)
            == sum(s.total_bytes for s in s_array))


# ---------------------------------------------------------------------------
# expert-parallel sharded metering + shard-aware control
# ---------------------------------------------------------------------------

def _moe_stacks(seed=0, e=8):
    """Multi-expert stacks (the sharded store needs E > 1 to partition)."""
    rng = np.random.default_rng(seed)
    w1 = jnp.asarray(rng.standard_normal((e, 64, 128)).astype(np.float32))
    w2 = jnp.asarray(rng.standard_normal((e, 128, 64)).astype(np.float32))
    w3 = jnp.asarray(rng.standard_normal((e, 64, 128)).astype(np.float32))
    qcfg = QuantConfig(enabled=True, bits=2, rank_budget=8, hqq_iters=2,
                       group_size=16, factor_group_size=16)
    stacks, _ = compress_ffn_weights(w1, w2, w3, qcfg)
    return stacks


def _trace(steps=40, layers=2, b=2, k=2, e=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, e, (steps, layers, b, k))


def test_sharded_store_conserves_bytes_across_shard_counts():
    """Eviction-free regime (per-shard capacity >= residents): the same
    routing trace meters IDENTICAL total bytes, hits, and misses at every
    shard count, and per-shard bytes sum to the total — residency and
    resident-rank-cap state are per-expert, so they decompose exactly
    over any expert partition."""
    stacks = _moe_stacks()
    trace = _trace()
    ref = [ExpertStore(stacks, cache_capacity=8),
           ExpertStore(stacks, cache_capacity=8)]
    t_ref, _ = replay_decode_trace(ref, trace, top_n=1, rank_caps=[4, 8])
    total_ref = sum(s.total_bytes for s in ref)
    hits_ref = sum(s.cache.stats.hits for s in ref)
    assert total_ref > 0
    for ep in (2, 4, 8):
        sh = [ShardedExpertStore(stacks, ep, cache_capacity=8)
              for _ in range(2)]
        t_sh, _ = replay_decode_trace(sh, trace, top_n=1, rank_caps=[4, 8])
        assert t_sh == t_ref
        assert sum(s.total_bytes for s in sh) == total_ref, ep
        assert sum(s.cache.stats.hits for s in sh) == hits_ref, ep
        for s in sh:
            assert int(s.shard_totals.sum()) == s.total_bytes
            assert s.shard_totals.shape == (ep,)


def test_sharded_store_rank_positions_preserved():
    """A token's foreign experts are masked in place, so the rank < top_n
    compensation decision matches the single-store path exactly."""
    stacks = _moe_stacks()
    single = ExpertStore(stacks, cache_capacity=8)
    sharded = ShardedExpertStore(stacks, 4, cache_capacity=8)
    topk = np.array([5, 1])      # rank 0 on shard 2, rank 1 on shard 0
    b1 = single.access_token(topk, top_n=1, policy="ours")
    b2 = sharded.access_token(topk, top_n=1, policy="ours")
    assert b1 == b2
    assert sharded.comp_bytes_moved == single.comp_bytes_moved > 0
    # only expert 5 (global rank 0) was compensated, on its owning shard
    assert sharded.shards[2].comp_bytes_moved == sharded.comp_bytes_moved
    assert sharded.shards[0].comp_bytes_moved == 0


def test_make_expert_stores_falls_back_when_not_partitionable():
    stacks = _moe_stacks(e=8)
    stores = make_expert_stores([stacks], ep=4, cache_capacity=2)
    assert isinstance(stores[0], ShardedExpertStore)
    stores = make_expert_stores([stacks], ep=3, cache_capacity=2)
    assert isinstance(stores[0], ExpertStore)     # 8 % 3: GSPMD fallback
    stores = make_expert_stores([stacks], ep=1, cache_capacity=2)
    assert isinstance(stores[0], ExpertStore)


def test_controller_plan_invariant_across_shard_counts():
    """Same trace + same budget => same plan sequence at every shard
    count (aggregate scope): per-shard bytes sum to the single-store
    bytes, so the controller's input signal — and therefore its
    deterministic level trajectory — cannot depend on ep."""
    stacks = _moe_stacks()
    trace = _trace(steps=48)
    plans_by_ep = {}
    for ep in (1, 2, 4):
        stores = make_expert_stores([stacks, stacks], ep=ep,
                                    cache_capacity=8)
        c = BandwidthController.from_stacks(
            [s.stacks for s in stores], 2,
            ControlConfig(enabled=True, bytes_per_token=20_000.0, gain=0.4),
            static_top_n=1)
        plans = []
        for chunk in np.split(trace, 8):        # 8 chunk-boundary updates
            plan = c.plan()
            before = sum(s.total_bytes for s in stores)
            shard_before = sum(np.asarray(s.shard_totals) for s in stores)
            ntok, _ = replay_decode_trace(stores, chunk, top_n=plan.top_n,
                                          rank_caps=plan.rank_cap)
            moved = sum(s.total_bytes for s in stores) - before
            shard_moved = (sum(np.asarray(s.shard_totals) for s in stores)
                           - shard_before)
            plans.append(c.update(moved, ntok,
                                  shard_bytes=shard_moved).as_array())
        plans_by_ep[ep] = np.stack(plans)
    np.testing.assert_array_equal(plans_by_ep[1], plans_by_ep[2])
    np.testing.assert_array_equal(plans_by_ep[1], plans_by_ep[4])


def test_per_shard_budget_scope_targets_hottest_link():
    """With budget_scope='per_shard' the controller reacts to the MAX
    shard's bytes/token; the aggregate scope to the sum.  A skewed load
    that is under budget in aggregate but over it on one link must
    throttle only the per-shard controller."""
    mk = lambda scope: BandwidthController(
        [16, 16], 2,
        ControlConfig(enabled=True, bytes_per_token=1000.0, gain=0.5,
                      ema=1.0, budget_scope=scope), static_top_n=1)
    agg, per = mk("aggregate"), mk("per_shard")
    skewed = np.array([1800, 100, 50, 50])     # sum 2000, max 1800
    # 1 token: aggregate 2000 B/tok and hottest link 1800 B/tok are both
    # over the 1000 budget => both scopes throttle
    lvl_a, lvl_p = agg.level, per.level
    agg.update(2000, 1, shard_bytes=skewed)
    per.update(2000, 1, shard_bytes=skewed)
    assert agg.level < lvl_a and per.level < lvl_p   # both over budget
    agg2, per2 = mk("aggregate"), mk("per_shard")
    balanced = np.array([600, 600, 600, 600])  # sum 2400 over, links under
    lvl_a, lvl_p = agg2.level, per2.level
    agg2.update(2400, 1, shard_bytes=balanced)
    per2.update(2400, 1, shard_bytes=balanced)
    assert agg2.level < lvl_a                  # aggregate throttles
    assert per2.level > lvl_p                  # links under budget: restore
                                               # MORE on every link
    # recorded telemetry reflects the controlled signal
    assert per2.history[-1].bytes_per_token == 600.0
    assert agg2.history[-1].bytes_per_token == 2400.0


# ---------------------------------------------------------------------------
# engine integration: disabled bit-identity + live control, one compile
# ---------------------------------------------------------------------------

def _quant_engine():
    cfg = ModelConfig(
        name="ctrl-moe", family="moe", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0, vocab_size=128,
        block_pattern=("global",), max_position=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=8,
                                        top_n_restore=1, hqq_iters=2)))
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    qparams, cfg_q, stacks_by_layer = compress_moe_params(params, cfg)
    eng = ServeEngine(cfg_q, qparams, quantized=True)
    eng.attach_offload(stacks_by_layer, policy="ours", cache_capacity=2)
    return eng, stacks_by_layer


def test_disabled_controller_bit_identical_and_budget_drives_plan():
    eng, stacks = _quant_engine()
    wl = lambda: synthetic_workload(5, 128, max_new=8, seed=3)

    base = eng.serve(wl(), num_slots=2, chunk=4)
    base_tokens = np.concatenate([r.tokens for r in base.results])
    base_bytes = base.offload_report["total_bytes"]
    assert base.plan_trace is None              # no controller attached

    # controller attached but with no budget: decode output AND metered
    # bytes must be bit-identical to the static top_n_restore path
    eng.attach_offload(stacks, policy="ours", cache_capacity=2)
    eng.attach_controller(ControlConfig(enabled=True))
    idle = eng.serve(wl(), num_slots=2, chunk=4)
    np.testing.assert_array_equal(
        np.concatenate([r.tokens for r in idle.results]), base_tokens)
    assert idle.offload_report["total_bytes"] == base_bytes
    assert idle.plan_trace is not None
    assert (idle.plan_trace == idle.plan_trace[0]).all()   # pinned static

    # an aggressive budget must move the plan off the static point and
    # reduce wire traffic, reusing the already-compiled decode loop
    compiles_before = eng.num_compiles["decode"]
    eng.attach_offload(stacks, policy="ours", cache_capacity=2)
    eng.attach_controller(ControlConfig(enabled=True, bytes_per_token=1.0,
                                        gain=0.5))
    tight = eng.serve(wl(), num_slots=2, chunk=4)
    assert not (tight.plan_trace == idle.plan_trace[0]).all()
    assert tight.offload_report["total_bytes"] < base_bytes
    assert eng.controller.history                  # fed at chunk boundaries
    # plan values changed every chunk, yet no new decode compile: the
    # plan is data, not shape
    assert eng.num_compiles["decode"] == compiles_before


def test_serve_config_control_auto_attaches():
    from repro.config import ServeConfig
    eng, stacks = _quant_engine()
    scfg = ServeConfig(control=ControlConfig(enabled=True,
                                             bytes_per_token=123.0))
    eng2 = ServeEngine(eng.cfg, eng.params, scfg, quantized=True)
    assert eng2.controller is None
    eng2.attach_offload(stacks, policy="ours", cache_capacity=2)
    assert eng2.controller is not None
    assert eng2.controller.ccfg.target_bytes_per_token == 123.0


def test_same_trace_same_budget_same_plan_sequence():
    eng, stacks = _quant_engine()
    plan_traces = []
    for _ in range(2):
        eng.attach_offload(stacks, policy="ours", cache_capacity=2)
        eng.attach_controller(ControlConfig(enabled=True,
                                            bytes_per_token=15_000.0,
                                            gain=0.4))
        stats = eng.serve(synthetic_workload(6, 128, max_new=8, seed=5),
                          num_slots=2, chunk=4)
        plan_traces.append(stats.plan_trace)
    np.testing.assert_array_equal(plan_traces[0], plan_traces[1])


# ---------------------------------------------------------------------------
# adaptive simulator policy: 10% convergence on both hardware profiles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("profile,policy,static", [
    (GPU_ONLY, "ours_adaptive", "ours"),
    (GPU_NDP, "ours_adaptive_ndp", "ours_ndp"),
])
def test_adaptive_sim_converges_within_10pct(profile, policy, static):
    d, fe, e = 4096, 14336, 8
    from repro.core.quantize import packed_nbytes
    spec = LayerSpecSim(
        d, fe, e, 2,
        bytes_fp16=3 * d * fe * 2,
        bytes_quant=3 * (packed_nbytes(2, d, fe) + (d // 64) * fe * 4),
        comp_bytes=[32 * (d + fe)] * e, ranks=[32] * e)
    trace = make_router_trace(None, 192, 8, 2, seed=0, num_experts=e)
    lo = simulate_decode(trace, spec, profile, static, top_n=0, num_layers=8)
    hi = simulate_decode(trace, spec, profile, static, top_n=2, num_layers=8)
    for frac in (0.4, 0.8):
        target = (lo.tail_bytes_per_token
                  + frac * (hi.tail_bytes_per_token
                            - lo.tail_bytes_per_token))
        r = simulate_decode(
            trace, spec, profile, policy, top_n=1, num_layers=8,
            control=ControlConfig(enabled=True, bytes_per_token=target,
                                  gain=0.3))
        err = abs(r.tail_bytes_per_token - target) / target
        assert err < 0.10, (profile.name, frac, err)


def test_adaptive_sim_requires_ranks_and_control():
    spec = LayerSpecSim(64, 128, 4, 2, bytes_fp16=100, bytes_quant=10,
                        comp_bytes=[4] * 4)
    trace = np.zeros((4, 2, 2), np.int64)
    with pytest.raises(ValueError):
        simulate_decode(trace, spec, GPU_ONLY, "ours_adaptive")
    with pytest.raises(ValueError):
        simulate_decode(trace, spec, GPU_ONLY, "ours_adaptive",
                        control=ControlConfig(enabled=True,
                                              bytes_per_token=5.0))
