"""Intra-procedural traced-value ("taint") inference for jit-scope rules.

Inside a jit-scope function, *traced* values are the ones whose concrete
value is unavailable at trace time — branching on them, host-converting
them, or passing them into a static/shape argument is a recompile or
concretization hazard.  The inference is deliberately shallow (one
function at a time, two forward passes to a fixpoint) and tuned for
precision over recall: a missed taint costs a missed finding, a false
taint costs developer trust.

Seeds: every parameter not classified static by ``jitscope`` (self/cls,
jit ``static_argnames``, partial-bound kernel leaders, int/bool/str
annotations, repo-conventional config names), plus the results of
``jnp.* / jax.* / lax.*`` calls.

Sanitizers (results are trace-time statics):
- ``.shape`` / ``.dtype`` / ``.ndim`` / ``.size`` and ``len(...)``;
- known static metadata attributes of registered dataclasses
  (``bits``, ``group_size``, ``pad_rank``, ...);
- ``x is None`` / ``x is not None`` comparisons (Python-level identity);
- plain attribute access on tainted objects, EXCEPT the well-known
  array-field names of the repo's containers (``planes``, ``scale``,
  ``caches``...) — dataclass meta fields vastly outnumber data fields
  at typical use sites.
"""
from __future__ import annotations

import ast
from typing import Optional, Set

from .jitscope import FunctionInfo, _dotted

# attribute reads that are static under jit no matter the base
STATIC_ATTRS = {
    "shape", "dtype", "ndim", "size", "bits", "group_size", "pad_rank",
    "factor_bits", "expert_bits", "top_k", "num_experts", "d_model",
    "n_layers", "ranks", "kind",
}

# attribute reads that carry array data through a (possibly tainted) object
TRACED_ATTRS = {
    "planes", "scale", "zero", "u", "v", "u_scale", "v_scale",
    "caches", "logits", "trace", "aux", "segments",
}

# calls whose result is always a trace-time static
UNTAINT_CALLS = {
    "len", "isinstance", "hasattr", "type", "str", "repr", "getattr",
    "min", "max",  # min/max of statics stay static; of tainted -> arg rule
}

_TRACING_HEADS = ("jnp.", "jax.", "lax.", "pl.", "pltpu.")


class TaintAnalysis:
    """Tainted-name set + expression classifier for one function."""

    def __init__(self, info: FunctionInfo):
        self.info = info
        self.tainted: Set[str] = {
            p for p in info.params if p not in info.static_params}
        self._run()

    # -- statement pass ----------------------------------------------------
    def _run(self):
        body = getattr(self.info.node, "body", None)
        if body is None:                        # Lambda
            return
        if not isinstance(body, list):
            body = [body]
        for _ in range(2):                      # tiny fixpoint
            before = set(self.tainted)
            for stmt in body:
                self._stmt(stmt)
            if self.tainted == before:
                break

    def _stmt(self, node: ast.AST):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = node.value
            if value is not None and self.expr_tainted(value):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    self._taint_target(t)
        # walk nested statements (if/for/while/with/try bodies)
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.stmt):
                self._stmt(child)
        if isinstance(node, (ast.For, ast.AsyncFor)):
            # iterating a traced container yields traced elements
            if self.expr_tainted(node.iter):
                self._taint_target(node.target)
        if isinstance(node, ast.With):
            for item in node.items:
                if item.optional_vars is not None and \
                        self.expr_tainted(item.context_expr):
                    self._taint_target(item.optional_vars)

    def _taint_target(self, t: ast.AST):
        if isinstance(t, ast.Name):
            self.tainted.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                self._taint_target(el)
        elif isinstance(t, ast.Starred):
            self._taint_target(t.value)

    # -- expression classifier ---------------------------------------------
    def expr_tainted(self, node: ast.AST) -> bool:
        if node is None:
            return False
        if isinstance(node, ast.Name):
            if node.id.isupper():               # module constants
                return False
            return node.id in self.tainted
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            if node.attr in TRACED_ATTRS and self.expr_tainted(node.value):
                return True
            return False                        # meta fields dominate
        if isinstance(node, ast.Subscript):
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Call):
            head = _dotted(node.func) or ""
            tail = head.split(".")[-1]
            if tail in UNTAINT_CALLS and tail not in ("min", "max"):
                return False
            if head.startswith(_TRACING_HEADS) or head in ("jnp", "jax"):
                return True
            if isinstance(node.func, ast.Attribute) and \
                    self.expr_tainted(node.func.value):
                return True                     # method on traced value
            return any(self.expr_tainted(a) for a in node.args) or \
                any(self.expr_tainted(k.value) for k in node.keywords)
        if isinstance(node, ast.BinOp):
            return self.expr_tainted(node.left) or \
                self.expr_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr_tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.expr_tainted(v) for v in node.values
                       if not _is_none_check(v))
        if isinstance(node, ast.Compare):
            if _is_none_check(node):
                return False
            if _is_key_membership(node):
                return False
            return self.expr_tainted(node.left) or \
                any(self.expr_tainted(c) for c in node.comparators)
        if isinstance(node, ast.IfExp):
            return self.expr_tainted(node.body) or \
                self.expr_tainted(node.orelse) or self.expr_tainted(node.test)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.expr_tainted(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Lambda):
            return False
        return False


def _is_key_membership(node: ast.AST) -> bool:
    """``"key" in tree`` / ``"key" not in tree`` — pytree *structure*
    tests (dict key membership), static under jit even on traced trees."""
    return (isinstance(node, ast.Compare)
            and len(node.ops) == 1
            and isinstance(node.ops[0], (ast.In, ast.NotIn))
            and isinstance(node.left, ast.Constant)
            and isinstance(node.left.value, str))


def _is_none_check(node: ast.AST) -> bool:
    """``x is None`` / ``x is not None`` — a Python-identity test, always
    legal on traced optionals (the value itself is never inspected)."""
    return (isinstance(node, ast.Compare)
            and len(node.ops) == 1
            and isinstance(node.ops[0], (ast.Is, ast.IsNot))
            and isinstance(node.comparators[0], ast.Constant)
            and node.comparators[0].value is None)


def analysis_for(info: FunctionInfo) -> TaintAnalysis:
    return TaintAnalysis(info)
