#!/usr/bin/env bash
# Production launcher for the serving CLI.
#
#   bash src/repro/launch/run.sh --arch mixtral-8x7b --offload \
#       --requests 64 --rate 4 [...]
#
# Everything after the script name is forwarded verbatim to
# `python -m repro.launch.serve`.  Override the module with
# REPRO_MODULE (e.g. REPRO_MODULE=repro.launch.compress for the
# offline pipeline, or REPRO_MODULE=benchmarks.bench_serving below a
# checkout root).
#
# Knobs (all optional, env-overridable):
#   REPRO_HOST_DEVICES=N   force N XLA host-platform devices (CPU
#                          expert-parallel runs, e.g. `--mesh ep=8`)
#   REPRO_KERNEL_IMPL      kernel dispatch policy: auto | pallas |
#                          pallas_interpret | ref (see kernels/ops.py)
#   REPRO_AUTOTUNE=1       time the fused-kernel tile candidates on
#                          this device at boot and persist the winners
#                          (kernels/autotune.py); default = table lookup
#   XLA_EXTRA_FLAGS        appended to the XLA_FLAGS this script sets
set -euo pipefail

repo_root="$(cd -- "$(dirname -- "${BASH_SOURCE[0]}")/../../.." && pwd)"

# -- allocator: tcmalloc if the host has it (large stack/plane allocs churn
# glibc malloc), and silence its large-alloc reports — expert stacks are
# routinely gigabytes
for so in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
          /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4; do
    if [[ -e "$so" ]]; then
        export LD_PRELOAD="$so${LD_PRELOAD:+:$LD_PRELOAD}"
        export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
        break
    fi
done

# -- logging: XLA/TSL banner noise off unless the caller asked for it
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# -- dtypes: f32 end to end (never silently promote to f64 on CPU)
export JAX_ENABLE_X64="${JAX_ENABLE_X64:-0}"
export JAX_DEFAULT_DTYPE_BITS="${JAX_DEFAULT_DTYPE_BITS:-32}"

# -- XLA flags: step markers at the outer while loop (the decode scan) so
# profiles bucket per decode chunk; TPU-only flags (latency-hiding
# scheduling for the offload/collective overlap) only where a TPU chip is
# attached — CPU/GPU jaxlib aborts on unregistered flags;
# REPRO_HOST_DEVICES forces a CPU device mesh
xla_flags="--xla_step_marker_location=STEP_MARK_AT_TOP_LEVEL_WHILE_LOOP"
if compgen -G "/dev/accel*" > /dev/null || [[ -c /dev/vfio/vfio ]]; then
    xla_flags="$xla_flags --xla_tpu_enable_latency_hiding_scheduler=true"
fi
if [[ -n "${REPRO_HOST_DEVICES:-}" ]]; then
    xla_flags="$xla_flags --xla_force_host_platform_device_count=${REPRO_HOST_DEVICES}"
fi
export XLA_FLAGS="$xla_flags${XLA_EXTRA_FLAGS:+ $XLA_EXTRA_FLAGS}${XLA_FLAGS:+ $XLA_FLAGS}"

export PYTHONPATH="$repo_root/src${PYTHONPATH:+:$PYTHONPATH}"

exec /usr/bin/env python3 -m "${REPRO_MODULE:-repro.launch.serve}" "$@"
