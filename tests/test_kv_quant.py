"""int8 KV cache (beyond-paper serving optimization): decode with a
quantized cache must match the bf16-cache decode closely."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import ExecContext, decode_step, forward, init_caches, \
    init_params


def _cfg(kv_bits=16):
    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
        block_pattern=("global",), max_position=256, kv_bits=kv_bits)


def test_kv8_cache_structure():
    cfg = _cfg(8)
    caches = init_caches(cfg, 2, max_len=32, dtype=jnp.float32)
    c0 = caches["segments"][0][0]   # stacked over the 2-layer scan segment
    assert c0["k"].dtype == jnp.int8
    assert "k_scale" in c0 and c0["k_scale"].shape == (2, 2, 32, 2)
    # int8 codes + bf16 scales ~ 1.06 B/elem vs 2 for bf16
    bytes_q = c0["k"].nbytes + c0["k_scale"].nbytes
    bytes_bf16 = c0["k"].size * 2
    assert bytes_q < 0.6 * bytes_bf16


def test_kv8_decode_matches_bf16_cache():
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, 128, (2, 12)), jnp.int32)
    outs = {}
    for bits in (16, 8):
        cfg = _cfg(bits)
        params = init_params(jax.random.key(0), cfg, jnp.float32)
        caches = init_caches(cfg, 2, max_len=20, dtype=jnp.float32)
        pre = forward(params, tokens[:, :-1], cfg,
                      ExecContext(mode="prefill"), caches=caches)
        step = decode_step(params, tokens[:, -1:], pre.caches, cfg,
                           ExecContext(mode="step"))
        outs[bits] = np.asarray(step.logits[:, 0], np.float32)
    # int8 KV with per-slot scales: small, bounded deviation
    err = np.abs(outs[8] - outs[16]).max() / (np.abs(outs[16]).max() + 1e-6)
    assert err < 0.05, err
