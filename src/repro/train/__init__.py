"""Training loop with checkpoint/restart, failure injection, stragglers."""
from .loop import (FailureInjector, StragglerMonitor, TrainResult, train)
