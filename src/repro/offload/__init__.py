"""Offloading: expert store, LRU cache, bandwidth cost models, layer-ahead
prefetch, the fig-7 event-driven throughput simulator, and the async
expert-streaming engine (pinned host images + staging rings) that turns
the byte meter into a verified data path."""
from .bandwidth import GPU_NDP, GPU_ONLY, TPU_V5E_OFFLOAD, HardwareProfile
from .cache import *  # noqa
from .hostmem import (HostExpertImage, build_fallback_stack,
                      build_fallback_stacks)
from .prefetch import (LayerAheadPrefetcher, LookaheadPrefetcher,
                       PrefetchStats)
from .simulator import LayerSpecSim, SimResult, make_router_trace, simulate_decode
from .staging import (DeviceTransferBackend, ExpertStreamEngine,
                      FakeTransferBackend, StagingRing, StagingSlot)
from .store import (ExpertCache, ExpertStore, FetchStats,
                    ShardedExpertStore, make_expert_stores,
                    meter_decode_trace, offload_report, replay_decode_trace,
                    replay_spec_round, snapshot_offload)
