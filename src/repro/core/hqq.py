"""Half-Quadratic Quantization (HQQ) — calibration-free zero-point search.

Faithful re-implementation of Badri & Shaji (2023): minimize
``||W - Q^-1(Q(W))||_p^p`` (p < 1) over the zero-point via half-quadratic
splitting.  Per iteration:

    W_q = clip(round(W/s + z))
    W_r = (W_q - z) * s                       # current dequant
    W_e = shrink_lp(W - W_r, beta, p)         # generalized soft-threshold
    z   = mean_g( W_q - (W - W_e)/s )         # closed-form zero update
    beta *= kappa

The shrinkage operator is the proximal map of the l_p norm,
``sign(x) * relu(|x| - |x|^(p-1)/beta)``.  Scale is held at its min/max
initialization (HQQ's default); only the zero-point moves.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .quantize import QuantizedTensor, quantize_with_params


def shrink_lp(x: jax.Array, beta: float, p: float) -> jax.Array:
    ax = jnp.abs(x)
    # |x|^(p-1) for p<1 explodes at 0; HQQ clamps via the relu outside.
    thresh = jnp.power(jnp.maximum(ax, 1e-8), p - 1.0) / beta
    return jnp.sign(x) * jnp.maximum(ax - thresh, 0.0)


@partial(jax.jit, static_argnames=("bits", "group_size", "iters"))
def hqq_params(w: jax.Array, bits: int, group_size: int = 64,
               iters: int = 20, p: float = 0.7, beta: float = 10.0,
               beta_scale: float = 1.01):
    """Return HQQ-optimized (scale, zero), each (K//G, N) f32.

    The l_p shrinkage threshold |x|^(p-1)/beta is not scale-invariant, so
    the optimization runs on std-normalized weights (scale folded back at
    the end) — otherwise small-magnitude layers see a relatively huge
    threshold and HQQ silently degrades to RTN-or-worse.
    """
    k, n = w.shape
    w32 = w.astype(jnp.float32)
    wstd = jnp.maximum(jnp.std(w32), 1e-12)
    w = w32 / wstd
    qmax = (1 << bits) - 1
    g = w.reshape(k // group_size, group_size, n)
    lo = g.min(axis=1, keepdims=True)
    hi = g.max(axis=1, keepdims=True)
    scale = jnp.maximum((hi - lo) / qmax, 1e-8)
    zero = -lo / scale

    def body(i, carry):
        zero, beta = carry
        wq = jnp.clip(jnp.round(g / scale + zero), 0, qmax)
        wr = (wq - zero) * scale
        we = shrink_lp(g - wr, beta, p)
        zero = jnp.mean(wq - (g - we) / scale, axis=1, keepdims=True)
        return zero, beta * beta_scale

    zero, _ = jax.lax.fori_loop(0, iters, body, (zero, jnp.float32(beta)))
    # fold the normalization back into the (scale, zero) pair
    return (scale * wstd).reshape(-1, n), \
        jnp.broadcast_to(zero, scale.shape).reshape(-1, n)


def hqq_quantize(w: jax.Array, bits: int, group_size: int = 64,
                 iters: int = 20, p: float = 0.7, beta: float = 10.0,
                 beta_scale: float = 1.01) -> QuantizedTensor:
    scale, zero = hqq_params(w, bits, group_size, iters, p, beta, beta_scale)
    return quantize_with_params(w, scale, zero, bits, group_size)
