#!/usr/bin/env python3
"""Perf-regression gate over the BENCH_serving.json trajectory.

``benchmarks/bench_serving.py`` APPENDS every sweep to a per-mode run
list; this tool compares the NEWEST run of each mode against the mode's
committed ``baseline`` and fails (exit 1) when a gated metric regresses
by more than the tolerance:

- ``tok_s`` / ``goodput_tok_s`` / ``*_tok_s`` — higher is better; gated
  at ``--tol-tok-s`` (default 0.10, i.e. fail below 90% of baseline).
  Wall-clock throughput is noisy on shared CI hosts, so CI passes a
  looser ``--tol-tok-s``; the deterministic byte metrics keep the tight
  default.
- ``mb_per_tok`` / ``kb_per_tok`` / ``*_bytes`` — lower is better
  (offload wire traffic is deterministic given the trace); gated at
  ``--tol-bytes`` (default 0.10).

Rows pair by their ``name`` field; rows present only on one side are
reported but never fail the gate (sweep points may come and go).

Accepting an intended perf change:

    python tools/bench_check.py --update-baseline

moves each mode's baseline to its newest run (commit the result).

Exit codes: 0 within tolerance (or nothing to gate), 1 regression,
2 malformed snapshot JSON.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

SNAPSHOT = Path(__file__).resolve().parent.parent / "BENCH_serving.json"

# metric -> direction ('up' = bigger is better, gate on drops;
# 'down' = smaller is better, gate on growth)
GATED = {
    "tok_s": "up",
    "goodput_tok_s": "up",
    "sim_tok_s": "up",
    "mb_per_tok": "down",
    "kb_per_tok": "down",
    # KV-cache HBM bytes per generated token: deterministic (cache
    # sizing + the workload's accepted-token count), tight 10% gate —
    # the paged cache's reason to exist
    "cache_mb_per_tok": "down",
    "prefix_hit_rate": "up",
    "req_mb_per_tok": "down",
    "max_shard_kb_per_tok": "down",
    "fused_hbm_mb": "down",
    "hbm_reduction_x": "up",
    "overlap_efficiency": "up",
    # prefetch accuracy is deterministic given the routing trace (both
    # the layer-ahead heuristic and the speculative lookahead replay the
    # same metered trace), so it keeps the tight byte tolerance
    "prefetch_acc": "up",
    "accept_rate": "up",
}
_NOISY = {"tok_s", "goodput_tok_s", "sim_tok_s",
          "overlap_efficiency"}   # wall-clock-derived


def _rows_by_name(entry):
    return {r.get("name", str(i)): r
            for i, r in enumerate(entry.get("rows", []))}


def check_mode(mode: str, traj: dict, tol_tok_s: float,
               tol_bytes: float) -> list:
    """Returns a list of failure strings for one mode's trajectory."""
    base, runs = traj.get("baseline"), traj.get("runs", [])
    if not base or not runs:
        return []
    latest = runs[-1]
    fails = []
    base_rows, new_rows = _rows_by_name(base), _rows_by_name(latest)
    for name, brow in base_rows.items():
        nrow = new_rows.get(name)
        if nrow is None:
            print(f"  {mode}/{name}: row gone from latest run (not gated)")
            continue
        for metric, direction in GATED.items():
            if metric not in brow or metric not in nrow:
                continue
            b, n = float(brow[metric]), float(nrow[metric])
            if b <= 0.0:
                continue
            tol = tol_tok_s if metric in _NOISY else tol_bytes
            if direction == "up":
                ratio = n / b
                bad = ratio < 1.0 - tol
            else:
                ratio = b / n if n > 0 else float("inf")
                bad = ratio < 1.0 - tol
            status = "FAIL" if bad else "ok"
            print(f"  {mode}/{name} {metric}: base {b:.4g} -> {n:.4g} "
                  f"({ratio:.2%} of baseline, tol {tol:.0%}) {status}")
            if bad:
                fails.append(f"{mode}/{name}/{metric}: {b:.4g} -> {n:.4g} "
                             f"exceeds {tol:.0%} regression budget")
    return fails


def update_baseline(snap: dict) -> dict:
    for mode, traj in snap.items():
        runs = traj.get("runs", [])
        if runs:
            traj["baseline"] = runs[-1]
            print(f"{mode}: baseline <- run from {runs[-1].get('time')}")
    return snap


def parse_args(argv=None):
    ap = argparse.ArgumentParser(
        description="gate the newest BENCH_serving.json run of each mode "
                    "against its committed baseline")
    ap.add_argument("--snapshot", type=Path, default=SNAPSHOT)
    ap.add_argument("--tol-tok-s", type=float, default=0.10,
                    help="allowed fractional drop in throughput metrics "
                         "(default 0.10; CI uses a looser value because "
                         "wall-clock tok/s is noisy on shared hosts)")
    ap.add_argument("--tol-bytes", type=float, default=0.10,
                    help="allowed fractional growth in bytes/token "
                         "metrics (deterministic; default 0.10)")
    ap.add_argument("--mode", default=None,
                    help="gate only this mode (default: every mode with "
                         "both a baseline and at least one run)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="move each mode's baseline to its newest run "
                         "(accepting an intended perf change); commit the "
                         "rewritten snapshot")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)

    if not args.snapshot.exists():
        print(f"no snapshot at {args.snapshot}; nothing to gate")
        return 0
    try:
        snap = json.loads(args.snapshot.read_text())
        if not isinstance(snap, dict):
            raise ValueError(f"expected a mode->trajectory object, got "
                             f"{type(snap).__name__}")
    except (OSError, ValueError) as e:
        print(f"bench-check: malformed snapshot {args.snapshot}: {e}",
              file=sys.stderr)
        return 2
    if args.update_baseline:
        snap = update_baseline(snap)
        args.snapshot.write_text(json.dumps(snap, indent=1, sort_keys=True)
                                 + "\n")
        print(f"baselines updated -> {args.snapshot}")
        return 0

    fails = []
    for mode, traj in sorted(snap.items()):
        if args.mode and mode != args.mode:
            continue
        if not isinstance(traj, dict) or "runs" not in traj:
            continue
        fails += check_mode(mode, traj, args.tol_tok_s, args.tol_bytes)
    if fails:
        print("\nbench-check FAILED:")
        for f in fails:
            print(f"  {f}")
        print("(intended change? rerun the bench, then "
              "`python tools/bench_check.py --update-baseline` and commit)")
        return 1
    print("\nbench-check ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
