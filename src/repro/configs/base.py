"""Config helpers: reduced smoke-test variants + shape applicability."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from ..config import (EncoderConfig, ModelConfig, MoEConfig, QuantConfig,
                      ShapeConfig)


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests: few layers, narrow
    width, small vocab/experts — structure (pattern, GQA ratio, MoE-ness,
    enc-dec, recurrence) preserved."""
    pat = len(cfg.block_pattern)
    layers = max(pat, 2)
    if cfg.first_layer_dense:
        layers += 1
    kv = max(1, min(cfg.num_kv_heads, 2))
    heads = max(kv * min(cfg.q_per_kv, 2), 2)
    head_dim = 32
    d_model = 128
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            d_expert=64,
            d_shared=64 if cfg.moe.d_shared else 0,
            quant=dataclasses.replace(cfg.moe.quant, rank_budget=8,
                                      hqq_iters=3),
        )
    enc = None
    if cfg.encoder is not None:
        enc = EncoderConfig(num_layers=2, d_model=d_model, num_heads=heads,
                            d_ff=192, source_len=24)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=192 if cfg.d_ff else 0,
        vocab_size=512,
        window_size=min(cfg.window_size, 16),
        lru_width=d_model if cfg.lru_width else 0,
        moe=moe,
        encoder=enc,
        quant=dataclasses.replace(cfg.quant, rank_budget=8, hqq_iters=3),
        max_position=4096,
    )


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a skip reason string."""
    if shape.name == "long_500k":
        kinds = set(cfg.block_pattern)
        subquadratic = kinds & {"recurrent", "mlstm", "slstm"} or (
            "local" in kinds and "global" in kinds)
        if not subquadratic and kinds == {"global"}:
            return ("pure full-attention arch: 500k decode KV is "
                    "quadratic-history; skipped per assignment")
    return None
