"""Griffin/RecurrentGemma recurrent block: conv1d + RG-LRU.

The RG-LRU diagonal linear recurrence
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t),
    a_t = exp(-c * softplus(Lambda) * r_t)
is evaluated with ``jax.lax.associative_scan`` for train/prefill (O(log S)
depth, no sequential bottleneck) and a single fused step for decode — O(1)
state is what makes the long_500k shape trivial for this family.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

RG_LRU_C = 8.0


def _lru_coeffs(u: jax.Array, p: Dict[str, jax.Array]):
    """u: (..., w) post-conv signal -> (a, b) of h = a*h_prev + b."""
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["rg_wa"]) + p["rg_ba"])
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["rg_wx"]) + p["rg_bx"])
    log_a = -RG_LRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u)
    return a, b


def causal_conv1d(u: jax.Array, w: jax.Array, b: jax.Array,
                  state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv along time.  u: (B, S, w); w: (cw, w)."""
    cw = w.shape[0]
    pad = state if state is not None else jnp.zeros(
        (u.shape[0], cw - 1, u.shape[-1]), u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(cw))
    return out + b


def rglru_seq(x: jax.Array, p: Dict[str, jax.Array],
              h0: Optional[jax.Array] = None,
              conv_state: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full recurrent block over a sequence.  x: (B, S, d) -> (B, S, d)."""
    u = jnp.einsum("bsd,dw->bsw", x, p["wx"]).astype(jnp.float32)
    g = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["wgate"])
                    .astype(jnp.float32))
    u_in = u
    u = causal_conv1d(u, p["conv_w"].astype(jnp.float32),
                      p["conv_b"].astype(jnp.float32), conv_state)
    a, b = _lru_coeffs(u, p)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    # associative combine of (a, b): h = a*h_prev + b
    def combine(x1, x2):
        a1, b1 = x1
        a2, b2 = x2
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = jnp.einsum("bsw,wd->bsd", h * g, p["wo"].astype(jnp.float32))
    cw = p["conv_w"].shape[0]
    new_state = {
        "h": h[:, -1].astype(jnp.float32),
        "conv": u_in[:, -(cw - 1):].astype(jnp.float32) if cw > 1
        else jnp.zeros((x.shape[0], 0, u.shape[-1]), jnp.float32),
    }
    return y.astype(x.dtype), new_state


def rglru_step(x: jax.Array, p: Dict[str, jax.Array],
               state: Dict[str, jax.Array]
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single decode step.  x: (B, 1, d); state = {h: (B,w), conv: (B,cw-1,w)}."""
    u = jnp.einsum("bsd,dw->bsw", x, p["wx"]).astype(jnp.float32)
    g = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["wgate"])
                    .astype(jnp.float32))
    cw = p["conv_w"].shape[0]
    window = jnp.concatenate([state["conv"], u], axis=1)   # (B, cw, w)
    uc = jnp.einsum("bcw,cw->bw", window,
                    p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    a, b = _lru_coeffs(uc, p)
    h = a * state["h"] + b
    y = jnp.einsum("bw,wd->bd", h * g[:, 0], p["wo"].astype(jnp.float32))
    new_state = {"h": h, "conv": window[:, 1:]}
    return y[:, None].astype(x.dtype), new_state
