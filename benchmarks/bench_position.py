"""Table 2: restoring the top-RANKED experts matters, not just any experts.

Restore ONLY rank-1 vs ONLY rank-2 (Mixtral case) — the paper finds
top-1-only hugely better (MMLU 47.5 vs 25.3).  We reproduce with held-out
NLL under 2-bit quantization by masking compensation to a specific
router-rank position.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.config import QuantConfig

from .common import compress_model, eval_nll, trained_moe


def run(quick: bool = True):
    cfg, params = trained_moe(steps=60 if quick else 200)
    rows = []
    ref = eval_nll(cfg, params, quantized=False)
    rows.append({"name": "table2/fp32", "nll": ref})

    import repro.models.moe as moe_mod
    orig = moe_mod.make_dispatch

    def restore_only_rank(rank_pos):
        def patched(info, num_experts, capacity, top_n):
            d = orig(info, num_experts, capacity, 0)
            import jax.numpy as jnp
            t, k = info.topk_idx.shape
            rank = jnp.tile(jnp.arange(k), t)
            comp = (rank == rank_pos).astype(jnp.float32)
            return d._replace(comp=comp)
        return patched

    qcfg = QuantConfig(enabled=True, bits=2, rank_budget=32,
                       top_n_restore=1, hqq_iters=20)
    cfg2, qp, _ = compress_model(cfg, params, qcfg)
    for pos, label in ((0, "only-top1"), (1, "only-top2")):
        moe_mod.make_dispatch = restore_only_rank(pos)
        try:
            jax.clear_caches()   # patched fn must not hit the jit cache
            nll = eval_nll(cfg2, qp, quantized=True)
        finally:
            moe_mod.make_dispatch = orig
        rows.append({"name": f"table2/{label}", "nll": nll})
    jax.clear_caches()
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['nll']:.4f}")
