"""flash_decode_attention (interpret) vs the jnp decode_attention oracle,
including int8-KV scale folding and ring-cache masking."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import flash_decode_attention
from repro.models.attention import decode_attention
from repro.models.kvcache import _kv_quant


def _setup(b=2, s=256, kvh=2, g=3, hd=32, filled=200, seed=0):
    rng = np.random.default_rng(seed)
    h = kvh * g
    q = jnp.asarray(rng.standard_normal((b, 1, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, kvh, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, kvh, hd)).astype(np.float32))
    pos = jnp.where(jnp.arange(s)[None, :] < filled,
                    jnp.arange(s)[None, :], -1) + jnp.zeros((b, 1), jnp.int32)
    cur = jnp.full((b,), filled - 1, jnp.int32)
    return q, k, v, pos, cur


@pytest.mark.parametrize("window", [None, 64])
def test_flash_decode_matches_oracle(window):
    q, k, v, pos, cur = _setup()
    ref = decode_attention(q, k, v, pos, cur, window=window)
    hd = q.shape[-1]
    got = flash_decode_attention(q[:, 0] / math.sqrt(hd), k, v, pos, cur,
                                 window=window, bs=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref[:, 0]), rtol=2e-5, atol=2e-5)


def test_flash_decode_int8_kv_matches_scaled_oracle():
    q, k, v, pos, cur = _setup(seed=3)
    kq, ks = _kv_quant(k)
    vq, vs = _kv_quant(v)
    ref = decode_attention(q, kq, vq, pos, cur, k_scale=ks, v_scale=vs)
    hd = q.shape[-1]
    got = flash_decode_attention(q[:, 0] / math.sqrt(hd), kq, vq, pos, cur,
                                 k_scale=ks, v_scale=vs, bs=64,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref[:, 0]),
                               rtol=1e-4, atol=1e-4)
    # and the int8 path stays close to exact attention
    exact = decode_attention(q, k, v, pos, cur)
    err = float(jnp.max(jnp.abs(got - exact[:, 0])))
    assert err < 0.05


def test_flash_decode_empty_slots_masked():
    q, k, v, pos, cur = _setup(filled=10, seed=7)
    hd = q.shape[-1]
    got = flash_decode_attention(q[:, 0] / math.sqrt(hd), k, v, pos, cur,
                                 bs=64, interpret=True)
    ref = decode_attention(q, k, v, pos, cur)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref[:, 0]),
                               rtol=2e-5, atol=2e-5)
