"""Fault tolerance: checkpoint/restart continuity, torn-write recovery,
straggler monitoring, failure injection."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.config import ModelConfig, TrainConfig
from repro.train import FailureInjector, StragglerMonitor, train


def tiny_cfg():
    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128, vocab_size=128,
        block_pattern=("global",), max_position=512)


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    mgr.save(10, tree)
    restored, man = mgr.restore(jax.tree.map(np.zeros_like, tree))
    assert man["step"] == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_keep_k_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_torn_write_falls_back(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    tree = {"x": jnp.arange(4.0)}
    mgr.save(1, tree)
    mgr.save(2, jax.tree.map(lambda a: a + 1, tree))
    # corrupt the newest checkpoint data (manifest committed, data torn)
    (mgr.dir / "step_00000002.npz").write_bytes(b"garbage")
    restored, man = mgr.restore(tree)
    assert man["step"] == 1
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.asarray(tree["x"]))


def test_failure_injection_and_restart_continuity(tmp_path):
    """Kill training mid-run, restart, assert the loss curve continues
    from the checkpoint (deterministic data => comparable history)."""
    cfg = tiny_cfg()
    tcfg = TrainConfig(total_steps=9, checkpoint_every=3, lr=1e-3,
                       warmup_steps=2, loss_chunk=0)
    # uninterrupted reference run
    ref = train(cfg, tcfg, checkpoint_dir=None, log_every=0,
                batch_shape=(2, 32))
    # crashed run
    with pytest.raises(RuntimeError, match="injected failure"):
        train(cfg, tcfg, checkpoint_dir=str(tmp_path), log_every=0,
              failure=FailureInjector(fail_at_step=7), batch_shape=(2, 32))
    # restart resumes from step 6 checkpoint
    res = train(cfg, tcfg, checkpoint_dir=str(tmp_path), log_every=0,
                batch_shape=(2, 32))
    assert res.resumed_from == 6
    steps = [h["step"] for h in res.history]
    assert steps == [6, 7, 8]
    # loss continuity: restarted losses match the uninterrupted run
    ref_by_step = {h["step"]: h["loss"] for h in ref.history}
    for h in res.history:
        assert abs(h["loss"] - ref_by_step[h["step"]]) < 2e-2, \
            (h["step"], h["loss"], ref_by_step[h["step"]])


def test_straggler_monitor_flags_and_aborts():
    mon = StragglerMonitor(threshold=2.0, warmup=2, policy="warn")
    for s in range(5):
        mon.observe(s, 0.10)
    assert mon.observe(5, 0.50)          # 5x the EWMA -> flagged
    assert mon.flagged == [5]
    mon2 = StragglerMonitor(threshold=2.0, warmup=1, policy="abort")
    mon2.observe(0, 0.1)
    mon2.observe(1, 0.1)
    with pytest.raises(TimeoutError):
        mon2.observe(2, 10.0)


def test_elastic_restore_onto_new_sharding(tmp_path):
    """Checkpoints are mesh-agnostic: restore re-shards transparently."""
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    mgr.save(1, tree)
    # single-device "new topology": just a different device_put layout
    restored, _ = mgr.restore(tree, shardings=jax.tree.map(
        lambda _: jax.devices()[0], tree))
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
