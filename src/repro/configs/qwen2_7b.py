"""qwen2-7b [dense]: 28L d=3584 28H (GQA kv=4) ff=18944 vocab=152064.
QKV bias. [arXiv:2407.10671]"""
from ..config import ModelConfig, QuantConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b", family="dense",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        head_dim=128, d_ff=18944, vocab_size=152_064,
        block_pattern=("global",), qkv_bias=True,
        rope_theta=1_000_000.0, act="silu", tie_embeddings=False,
        quant=QuantConfig(enabled=True, bits=2, rank_budget=32,
                          top_n_restore=1),
        max_position=131_072,
    )
