"""GQA attention: blockwise-banded prefill, ring-buffer decode, cross-attn.

Prefill/train uses a query-block scan so the score matrix never fully
materializes; sliding-window ('local') layers additionally restrict each
query block to a fixed-size KV *band* via dynamic_slice, cutting FLOPs and
bytes from O(S^2) to O(S * window) — the reason gemma3/recurrentgemma long
contexts stay sub-quadratic.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import os

import jax
import jax.numpy as jnp

from .layers import softcap

NEG_INF = -2.0 ** 30  # large-but-finite; keeps softmax NaN-free on empty rows


def _bulk_dtype():
    """Dtype for bulk attention tensors (q/k/v inputs and PV outputs).

    f32 by default; REPRO_ATTN_DTYPE=bf16 keeps softmax statistics in f32
    but moves the big operands (and therefore the partial-sum all-reduces
    and gathers GSPMD inserts around sharded attention) in bf16 — halves
    the collective payloads at prefill/train (hillclimb lever, Cell B/C).
    """
    return (jnp.bfloat16 if os.environ.get("REPRO_ATTN_DTYPE", "")
            .startswith("bf") else jnp.float32)


def _gqa_scores(q: jax.Array, k: jax.Array, out_dtype=jnp.float32
                ) -> jax.Array:
    """q: (B, Sq, KV, G, hd), k: (B, Skv, KV, hd) -> (B, KV, G, Sq, Skv)."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                      preferred_element_type=out_dtype)


def _gqa_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: (B, KV, G, Sq, Skv), v: (B, Skv, KV, hd) -> (B, Sq, KV, G, hd)."""
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(p.dtype))


def _mask_bias(q_pos, kv_pos, causal: bool, window: Optional[int]):
    """(B?, Sq) x (B?, Skv) position grids -> additive bias (…, Sq, Skv)."""
    valid = kv_pos[..., None, :] >= 0
    if causal:
        valid &= kv_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None and window > 0:
        valid &= kv_pos[..., None, :] > (q_pos[..., :, None] - window)
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              q_pos: jax.Array, kv_pos: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              q_block: int = 1024, attn_softcap: float = 0.0,
              scale: Optional[float] = None,
              unroll: bool = False) -> jax.Array:
    """Batched GQA attention.

    q: (B, Sq, H, hd); k/v: (B, Skv, KVH, hd); q_pos/kv_pos: (B, S*) int32
    absolute positions (-1 marks an empty KV slot).  Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    dt = _bulk_dtype()
    qg = (q.reshape(b, sq, kvh, g, hd).astype(jnp.float32) * scale).astype(dt)
    k32, v32 = k.astype(dt), v.astype(dt)

    def block_attn(qi, qpi, ki, vi, kpi):
        # the S_q x S_kv score and probability buffers are the dominant
        # HBM traffic of long-context prefill: in bf16 mode they are
        # MATERIALIZED at half width while max/exp/sum run in f32 inside
        # the fusion (flash-style numerics; the Pallas kernel keeps them
        # in VMEM entirely)
        s = _gqa_scores(qi, ki, out_dtype=dt)
        s = softcap(s, attn_softcap)
        bias = _mask_bias(qpi, kpi, causal, window).astype(dt)
        s = s + bias[:, None, None, :, :]
        if dt == jnp.float32:
            p = jax.nn.softmax(s, axis=-1)
        else:
            m = jnp.max(s.astype(jnp.float32), axis=-1, keepdims=True)
            p = jnp.exp(s.astype(jnp.float32) - m).astype(dt)
            denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
            p = (p.astype(jnp.float32) / jnp.maximum(denom, 1e-30)).astype(dt)
        return _gqa_out(p, vi).astype(dt)

    if sq <= q_block or sq % q_block:
        out = block_attn(qg, q_pos, k32, v32, kv_pos)
        return out.reshape(b, sq, h, hd).astype(q.dtype)

    nq = sq // q_block
    band = None
    if window is not None and window > 0 and skv > (window + q_block):
        band = min(skv, _round_up(window + q_block, 128))

    def step(carry, i):
        q0 = i * q_block
        qi = jax.lax.dynamic_slice_in_dim(qg, q0, q_block, axis=1)
        qpi = jax.lax.dynamic_slice_in_dim(q_pos, q0, q_block, axis=-1)
        if band is None:
            ki, vi, kpi = k32, v32, kv_pos
        else:
            # fixed-size KV band ending at this query block (sliding window)
            s0 = jnp.clip(q0 + q_block - band, 0, skv - band)
            ki = jax.lax.dynamic_slice_in_dim(k32, s0, band, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v32, s0, band, axis=1)
            kpi = jax.lax.dynamic_slice_in_dim(kv_pos, s0, band, axis=-1)
        return carry, block_attn(qi, qpi, ki, vi, kpi)

    _, blocks = jax.lax.scan(step, 0, jnp.arange(nq), unroll=unroll)
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, sq, kvh, g, hd)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_pos: jax.Array, cur_pos: jax.Array, *,
                     window: Optional[int] = None,
                     attn_softcap: float = 0.0,
                     scale: Optional[float] = None,
                     k_scale: Optional[jax.Array] = None,
                     v_scale: Optional[jax.Array] = None) -> jax.Array:
    """Step-mode attention against a (possibly ring or paged) KV cache.

    q: (B, Sq, H, hd); caches: (B, Sc, KVH, hd); kv_pos: (B, Sc) absolute
    positions with -1 for unwritten slots; cur_pos: (B,) current position,
    or (B, Sq) per-query positions (suffix prefill over a reused-prefix
    cache appends Sq > 1 tokens in one step).

    int8 KV: when k_scale/v_scale (B, Sc, KVH) are given, the caches hold
    int8 codes; the per-slot scales fold into the score matrix and the
    softmax weights — the dequantized KV never materializes, so HBM reads
    stay at the packed byte count.
    """
    b, sq, h, hd = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kvh, g, hd).astype(jnp.float32) * scale
    s = _gqa_scores(qg, k_cache.astype(jnp.float32))
    if k_scale is not None:   # (B, Sc, KVH) -> (B, KVH, 1, 1, Sc)
        s = s * jnp.moveaxis(k_scale.astype(jnp.float32), 1, -1)[:, :, None,
                                                                 None, :]
    s = softcap(s, attn_softcap)
    q_pos = cur_pos[:, None] if cur_pos.ndim == 1 else cur_pos
    bias = _mask_bias(q_pos, kv_pos, True, window)
    s = s + bias[:, None, None, :, :]
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:   # fold V scales into the softmax weights
        p = p * jnp.moveaxis(v_scale.astype(jnp.float32), 1, -1)[:, :, None,
                                                                 None, :]
    out = _gqa_out(p, v_cache.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
