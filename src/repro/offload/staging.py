"""Async expert-streaming transfer engine: staging ring + device containers.

This module turns the offload byte meter (``offload/store.py``) from
accounting fiction into a verified data path.  Components:

- :class:`DeviceTransferBackend` / :class:`FakeTransferBackend` — the
  H2D copy primitive.  ``jax.device_put`` dispatches asynchronously;
  readiness is observed via ``jax.Array.is_ready``.  The fake backend
  wraps the real copies with an injected per-copy delay and a stall
  predicate (fault-injection tests).
- :class:`StagingRing` — the per-layer double-buffered slot ring.  A
  slot walks FREE -> IN_FLIGHT -> READY -> FREE; a slot is never reused
  while its copy is in flight, and when every slot is busy further
  issues are *declined* (the store then must not meter the prefetch —
  ring capacity is a metering-visible constraint).
- :class:`ExpertStreamEngine` — per-MoE-layer coordination: a
  :class:`~.hostmem.HostExpertImage` copy source, a staging ring, and
  the mutable device *containers* (fallback-initialized
  ``CompressedExpertStack``s living inside the serving param tree) that
  streamed payloads are scattered into between scan chunks.

Oracle invariant (metered bytes == observed copies): every copy is
driven by, or reconciled with, a store metering event —

- ``store.prefetch``  -> ``on_prefetch``: the engine issues the async
  ring copy FIRST and the store meters only if the issue was accepted;
- demand miss         -> ``on_demand``: a copy staged earlier by the
  optimistic-execution fixpoint is *consumed* from the engine's ledger,
  otherwise a fresh copy is performed on the spot;
- compensator fetch   -> ``on_factors``: same ledger/fresh split for
  factor rank rows;
- staged copies the accepted trace never touched are *flushed* into the
  store as (wasted) prefetch bytes at the chunk boundary
  (``flush_unclaimed`` -> ``store.absorb_external_copy``).

Observed bytes are counted at copy *issue* time (the moment the payload
hits the link) via ``store.note_copy``, so the equality holds exactly
per store in the eviction-free regime and degrades gracefully (never
silently) under faults.  Under eviction the LRU is the accounting model
while the container is the physical state: a charged re-fetch of data
still physically present is performed as a real re-copy for honesty.

Containers are updated *functionally* (``dynamic_update_slice`` without
donation, then the layer's stacks dict is swapped in place), so every
pytree structure/shape/dtype is preserved and the jitted decode loop's
zero-recompile traced-plan contract survives streaming untouched.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hostmem import HostExpertImage, build_fallback_stacks

FREE, IN_FLIGHT, READY = "free", "in_flight", "ready"

# slot kinds
KIND_WEIGHTS, KIND_FACTORS = "w", "f"


# ---------------------------------------------------------------------------
# transfer backends
# ---------------------------------------------------------------------------

class DeviceTransferBackend:
    """Real async H2D copies via ``jax.device_put``.

    ``copy`` returns an opaque handle; ``is_ready`` observes completion
    without blocking (``jax.Array.is_ready``); ``payload`` yields the
    device pytree for integration."""

    def copy(self, host_tree, tag=None):
        return jax.device_put(host_tree)

    def is_ready(self, handle) -> bool:
        return all(leaf.is_ready() if hasattr(leaf, "is_ready") else True
                   for leaf in jax.tree_util.tree_leaves(handle))

    def payload(self, handle):
        return handle


@dataclasses.dataclass
class _FakeHandle:
    dev: Any
    tag: Any
    t0: float


class FakeTransferBackend(DeviceTransferBackend):
    """Delay/stall-injecting backend for fault tests.

    Copies still land on device (integration works normally), but
    readiness is gated: each copy reports ready only ``delay_s`` after
    issue, and copies whose ``stall`` predicate matches never report
    ready at all (a wedged DMA channel).  ``stall`` may be a callable
    over the copy tag ``(layer, expert, kind)`` or a collection of
    expert ids.  ``clock`` is injectable for deterministic tests."""

    def __init__(self, delay_s: float = 0.0, stall=None,
                 clock: Callable[[], float] = time.monotonic):
        self.delay_s = float(delay_s)
        self.clock = clock
        if stall is None:
            self._stall = lambda tag: False
        elif callable(stall):
            self._stall = stall
        else:
            stalled = set(stall)
            self._stall = lambda tag: tag is not None and tag[1] in stalled
        self.copies = 0

    def copy(self, host_tree, tag=None):
        self.copies += 1
        return _FakeHandle(super().copy(host_tree, tag), tag, self.clock())

    def is_ready(self, handle) -> bool:
        if self._stall(handle.tag):
            return False
        if (self.clock() - handle.t0) < self.delay_s:
            return False
        return super().is_ready(handle.dev)

    def payload(self, handle):
        return handle.dev


# ---------------------------------------------------------------------------
# staging ring
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StagingSlot:
    index: int
    state: str = FREE
    expert: int = -1
    kind: str = ""
    wire_bytes: int = 0
    meta: Any = None
    handle: Any = None
    t_issue: float = 0.0
    generation: int = 0      # bumped per issue (slot-reuse auditing)


class StagingRing:
    """Fixed-capacity slot ring for one layer's in-flight copies.

    State machine per slot: FREE --issue--> IN_FLIGHT --poll/ready-->
    READY --release--> FREE, with ``abandon`` the IN_FLIGHT -> FREE
    escape hatch for stalled copies.  ``try_issue`` returns None when no
    slot is FREE — the caller must treat that as "the copy cannot move",
    never queue past capacity."""

    def __init__(self, capacity: int, backend: DeviceTransferBackend,
                 clock: Callable[[], float] = time.perf_counter,
                 tag: Any = None):
        if capacity < 1:
            raise ValueError(f"ring capacity must be >= 1, got {capacity}")
        self.backend = backend
        self.clock = clock
        self.tag = tag
        self.slots = [StagingSlot(i) for i in range(capacity)]

    @property
    def capacity(self) -> int:
        return len(self.slots)

    @property
    def occupancy(self) -> int:
        return sum(1 for s in self.slots if s.state != FREE)

    def in_flight(self) -> List[StagingSlot]:
        return [s for s in self.slots if s.state == IN_FLIGHT]

    def find(self, expert: int, kind: str) -> Optional[StagingSlot]:
        for s in self.slots:
            if s.state != FREE and s.expert == expert and s.kind == kind:
                return s
        return None

    def try_issue(self, expert: int, payload, wire_bytes: int,
                  kind: str = KIND_WEIGHTS, meta=None
                  ) -> Optional[StagingSlot]:
        slot = next((s for s in self.slots if s.state == FREE), None)
        if slot is None:
            return None
        slot.handle = self.backend.copy(payload,
                                        tag=(self.tag, int(expert), kind))
        slot.state = IN_FLIGHT
        slot.expert = int(expert)
        slot.kind = kind
        slot.wire_bytes = int(wire_bytes)
        slot.meta = meta
        slot.t_issue = self.clock()
        slot.generation += 1
        return slot

    def poll(self):
        for s in self.slots:
            if (s.state == IN_FLIGHT and s.handle is not None
                    and self.backend.is_ready(s.handle)):
                s.state = READY

    def take_ready(self) -> List[StagingSlot]:
        self.poll()
        return [s for s in self.slots if s.state == READY]

    def wait(self, slot: StagingSlot, timeout_s: float) -> bool:
        """Block until ``slot``'s copy is READY; False on timeout (the
        stalled-copy degrade path)."""
        deadline = self.clock() + timeout_s
        while True:
            self.poll()
            if slot.state == READY:
                return True
            if slot.state == FREE:        # abandoned under us
                return False
            if self.clock() >= deadline:
                return False
            time.sleep(5e-4)

    def _reset(self, slot: StagingSlot):
        slot.state = FREE
        slot.expert = -1
        slot.kind = ""
        slot.wire_bytes = 0
        slot.meta = None
        slot.handle = None
        slot.t_issue = 0.0

    def release(self, slot: StagingSlot):
        assert slot.state == READY, (slot.index, slot.state)
        self._reset(slot)

    def abandon(self, slot: StagingSlot):
        """Drop a stalled IN_FLIGHT copy (handle discarded; the slot is
        immediately reusable)."""
        assert slot.state == IN_FLIGHT, (slot.index, slot.state)
        self._reset(slot)

    # -- chunk-boundary bookkeeping round-trip -----------------------------
    def snapshot(self) -> Dict:
        """Plain-data bookkeeping snapshot (handles stay with the ring);
        ``restore(snapshot())`` round-trips exactly — the serve engine
        carries ring state across scan-chunk boundaries this way."""
        return {
            "capacity": self.capacity,
            "slots": [{"index": s.index, "state": s.state,
                       "expert": s.expert, "kind": s.kind,
                       "wire_bytes": s.wire_bytes,
                       "generation": s.generation}
                      for s in self.slots],
        }

    def restore(self, snap: Dict):
        if snap["capacity"] != self.capacity:
            raise ValueError(f"snapshot capacity {snap['capacity']} != "
                             f"ring capacity {self.capacity}")
        for s, d in zip(self.slots, snap["slots"]):
            s.state = d["state"]
            s.expert = d["expert"]
            s.kind = d["kind"]
            s.wire_bytes = d["wire_bytes"]
            s.generation = d["generation"]


# ---------------------------------------------------------------------------
# container scatter (functional, shape-preserving)
# ---------------------------------------------------------------------------

@jax.jit
def _scatter_slice(container, update, starts):
    """Write ``update`` (one expert's slice, no leading expert axis) into
    ``container`` at ``starts`` (expert index first)."""
    return jax.lax.dynamic_update_slice(container, update[None, ...],
                                        starts)


def _upd(container, update, *starts):
    s = tuple(jnp.int32(x) for x in starts)
    return _scatter_slice(container, jnp.asarray(update), s)


# ---------------------------------------------------------------------------
# per-layer stream state
# ---------------------------------------------------------------------------

_NO_FACTORS = object()     # sentinel: no factor requirement in a need


class _LayerStream:
    def __init__(self, idx: int, image: HostExpertImage,
                 ring: StagingRing, containers: Dict, store):
        self.idx = idx
        self.image = image
        self.ring = ring
        # THE stacks dict inside the serving param tree: entries are
        # replaced in place after each scatter, so params stay current
        self.containers = containers
        self.store = store
        self.valid: set = set()        # experts with true weights staged
        # expert -> rank cap its staged factor rows cover (None = full);
        # tracks CONTAINER content — unlike the store's ``_comp_resident``
        # it survives LRU eviction (the bytes stay physically on device)
        self.staged_cap: Dict[int, Optional[int]] = {}
        # unclaimed staged copies awaiting a store metering event:
        # ("w", e) -> wire bytes; ("f", e) -> (wire bytes, cap)
        self.ledger: Dict[Tuple[str, int], Any] = {}

    # -- factor rank windows ----------------------------------------------
    def _resolve(self, e: int, cap, name: str) -> int:
        r = self.image.meta[name].ranks[e]
        return r if cap is None else min(r, int(cap))

    def factor_windows(self, e: int, have, cap) -> Dict[str, Tuple[int, int]]:
        """{proj: (lo, hi)} delta rank rows from ``have`` to ``cap``
        (store ``_comp_resident`` conventions: -1 absent, None full)."""
        out = {}
        for name in self.image.meta:
            lo = 0 if (have is not None and have < 0) \
                else self._resolve(e, have, name)
            hi = self._resolve(e, cap, name)
            if hi > lo:
                out[name] = (lo, hi)
        return out

    def factor_deficit(self, e: int, cap) -> Dict[str, Tuple[int, int]]:
        """Rank rows the CONTAINER is missing for expert ``e`` at ``cap``."""
        return self.factor_windows(e, self.staged_cap.get(e, -1), cap)

    def raise_staged_cap(self, e: int, cap):
        have = self.staged_cap.get(e, -1)
        if have is None:
            return
        if cap is None or (have is not None and have < 0) or cap > have:
            self.staged_cap[e] = cap


class _StoreHook:
    """Store-facing view of the engine for one MoE layer (attached to the
    layer's ``ExpertStore`` — or to every shard of its
    ``ShardedExpertStore``; expert ownership is disjoint across shards,
    so the shared per-layer engine state is race-free)."""

    __slots__ = ("eng", "layer")

    def __init__(self, eng: "ExpertStreamEngine", layer: int):
        self.eng = eng
        self.layer = layer

    def on_demand(self, store, e: int, nbytes: int):
        self.eng._on_demand(self.layer, store, e, nbytes)

    def on_factors(self, store, e: int, have, cap, nbytes: int):
        self.eng._on_factors(self.layer, store, e, have, cap, nbytes)

    def on_prefetch(self, store, e: int, nbytes: int) -> bool:
        return self.eng._on_prefetch(self.layer, store, e, nbytes)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class ExpertStreamEngine:
    """Coordinates host images, staging rings, and device containers for
    every MoE layer of a serving engine.  See the module docstring for
    the dataflow and the oracle invariant."""

    def __init__(self, stores: List, stream_cfg, policy: str = "ours",
                 backend: Optional[DeviceTransferBackend] = None,
                 clock: Callable[[], float] = time.perf_counter):
        if policy not in ("ours", "quant"):
            raise ValueError(f"streaming supports policies 'ours'/'quant', "
                             f"got {policy!r}")
        self.cfg = stream_cfg
        self.policy = policy
        self.backend = backend or DeviceTransferBackend()
        self.clock = clock
        self.layers: List[_LayerStream] = []
        for l, store in enumerate(stores):
            image = HostExpertImage(store.stacks)
            containers = build_fallback_stacks(store.stacks,
                                               stream_cfg.fallback_bits)
            jax.block_until_ready(
                jax.tree_util.tree_leaves(containers))
            ring = StagingRing(stream_cfg.ring_slots, self.backend,
                               clock=clock, tag=l)
            self.layers.append(_LayerStream(l, image, ring, containers,
                                            store))
            store.attach_engine(_StoreHook(self, l))
        # counters (engine-level; per-store attribution lives in the
        # stores' observed_copies/observed_copy_bytes)
        self.issued_copies = 0
        self.issued_bytes = 0
        self.stalls = 0
        self.stall_s = 0.0
        self.transfer_s = 0.0          # async copy issue->observed-ready
        self.sync_copy_s = 0.0         # replay-time reconciliation copies
        self.reruns = 0
        self.degraded_tokens = 0
        self.abandoned_copies = 0
        self.flushed_bytes = 0

    # -- container access ---------------------------------------------------
    def layer_containers(self, l: int) -> Dict:
        return self.layers[l].containers

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    # -- copy plumbing ------------------------------------------------------
    def _count_issue(self, nbytes: int):
        self.issued_copies += 1
        self.issued_bytes += int(nbytes)

    def _wait_handle(self, handle, timeout_s: float) -> bool:
        deadline = self.clock() + timeout_s
        while not self.backend.is_ready(handle):
            if self.clock() >= deadline:
                return False
            time.sleep(5e-4)
        return True

    def _apply_weights(self, L: _LayerStream, e: int, dev: Dict):
        for name, leaves in dev.items():
            st = L.containers[name]
            new = dataclasses.replace(
                st,
                planes=tuple(_upd(c, u, e, 0, 0)
                             for c, u in zip(st.planes, leaves["planes"])),
                scale=_upd(st.scale, leaves["scale"], e, 0, 0),
                zero=_upd(st.zero, leaves["zero"], e, 0, 0))
            L.containers[name] = new
        L.valid.add(int(e))

    def _apply_factors(self, L: _LayerStream, e: int,
                       windows: Dict[str, Tuple[int, int]], dev: Dict,
                       cap):
        for name, leaves in dev.items():
            lo, _hi = windows[name]
            st = L.containers[name]
            new = dataclasses.replace(
                st,
                u=_upd(st.u, leaves["u"], e, 0, lo),
                v=_upd(st.v, leaves["v"], e, lo, 0),
                u_scale=_upd(st.u_scale, leaves["u_scale"], e, 0, lo),
                v_scale=_upd(st.v_scale, leaves["v_scale"], e, lo, 0))
            L.containers[name] = new
        L.raise_staged_cap(int(e), cap)

    def _integrate_slot(self, L: _LayerStream, slot: StagingSlot):
        dev = self.backend.payload(slot.handle)
        self.transfer_s += max(self.clock() - slot.t_issue, 0.0)
        if slot.kind == KIND_WEIGHTS:
            self._apply_weights(L, slot.expert, dev)
        else:
            windows, cap = slot.meta
            self._apply_factors(L, slot.expert, windows, dev, cap)
        L.ring.release(slot)

    def integrate_ready(self, layer: Optional[int] = None):
        """Scatter every completed in-flight copy into its container."""
        layers = self.layers if layer is None else [self.layers[layer]]
        for L in layers:
            for slot in L.ring.take_ready():
                self._integrate_slot(L, slot)

    def _issue_ring(self, L: _LayerStream, e: int, payload,
                    wire_bytes: int, kind: str, meta=None
                    ) -> Optional[StagingSlot]:
        slot = L.ring.try_issue(e, payload, wire_bytes, kind, meta)
        if slot is None:
            # drain completed copies; a freed slot lets the issue proceed
            self.integrate_ready(L.idx)
            slot = L.ring.try_issue(e, payload, wire_bytes, kind, meta)
        if slot is not None:
            self._count_issue(wire_bytes)
        return slot

    def _copy_weights_now(self, L: _LayerStream, e: int,
                          timeout_s: Optional[float] = None,
                          stall_clock: bool = False) -> bool:
        """Immediate (blocking) weight copy outside the ring — the demand
        path.  Returns False when the copy stalled past the timeout (the
        container keeps its previous/fallback content)."""
        nb = L.store.expert_bytes(e, self.policy)
        t0 = self.clock()
        handle = self.backend.copy(L.image.weight_payload(e),
                                   tag=(L.idx, int(e), KIND_WEIGHTS))
        self._count_issue(nb)
        ok = self._wait_handle(
            handle, self.cfg.stall_timeout_s if timeout_s is None
            else timeout_s)
        dt = self.clock() - t0
        if stall_clock:
            self.stalls += 1
            self.stall_s += dt
            self.transfer_s += dt
        else:
            self.sync_copy_s += dt
        if ok:
            self._apply_weights(L, e, self.backend.payload(handle))
        else:
            self.abandoned_copies += 1
        return ok

    def _copy_factors_now(self, L: _LayerStream, e: int, windows, cap,
                          wire_bytes: int = 0,
                          stall_clock: bool = False) -> bool:
        if not windows:
            return True
        t0 = self.clock()
        handle = self.backend.copy(L.image.factor_payload(e, windows),
                                   tag=(L.idx, int(e), KIND_FACTORS))
        self._count_issue(wire_bytes)
        ok = self._wait_handle(handle, self.cfg.stall_timeout_s)
        dt = self.clock() - t0
        if stall_clock:
            self.stalls += 1
            self.stall_s += dt
            self.transfer_s += dt
        else:
            self.sync_copy_s += dt
        if ok:
            self._apply_factors(L, e, windows,
                                self.backend.payload(handle), cap)
        else:
            self.abandoned_copies += 1
        return ok

    # -- store-driven hooks (the metering events) ---------------------------
    def _on_demand(self, l: int, store, e: int, nbytes: int):
        """A demand miss the store just charged ``nbytes`` for.  Consume
        the matching optimistically-staged copy, or perform one now."""
        L = self.layers[l]
        if L.ledger.pop((KIND_WEIGHTS, e), None) is not None:
            store.note_copy(nbytes)
            return
        self._copy_weights_now(L, e)
        store.note_copy(nbytes)

    def _on_factors(self, l: int, store, e: int, have, cap, nbytes: int):
        L = self.layers[l]
        entry = L.ledger.pop((KIND_FACTORS, e), None)
        if entry is not None:
            store.note_copy(nbytes)
            return
        windows = L.factor_windows(e, have, cap)
        self._copy_factors_now(L, e, windows, cap, wire_bytes=nbytes)
        store.note_copy(nbytes)

    def _on_prefetch(self, l: int, store, e: int, nbytes: int) -> bool:
        """Async prefetch issue; False (-> the store must not meter) when
        the staging ring cannot take the copy."""
        L = self.layers[l]
        if L.ring.find(e, KIND_WEIGHTS) is not None:
            return False                       # already in flight
        slot = self._issue_ring(L, e, L.image.weight_payload(e), nbytes,
                                KIND_WEIGHTS)
        if slot is None:
            return False
        store.note_copy(nbytes)
        return True

    # -- optimistic-execution support (serve engine) ------------------------
    def plan_vectors(self, layers: int, plan, static_top_n):
        """Per-layer (top_ns, caps) from a controller plan (or static)."""
        from .store import _per_layer
        top_n = static_top_n if plan is None else plan.top_n
        caps = None if plan is None else plan.rank_cap
        return (_per_layer(top_n, layers, 1), _per_layer(caps, layers, None))

    def may_miss(self, top_ns, caps) -> bool:
        """Can the next chunk possibly route to an unstaged expert (or an
        under-staged compensator)?  False = the speculative re-run
        machinery can be skipped entirely (warm steady state)."""
        for l, L in enumerate(self.layers):
            if len(L.valid) < L.image.num_experts:
                return True
            if self.policy == "ours" and top_ns[l] > 0:
                for e in range(L.image.num_experts):
                    if L.factor_deficit(e, caps[l]):
                        return True
        return False

    def missing_for_trace(self, trace: np.ndarray, active: np.ndarray,
                          top_ns, caps) -> List[Tuple[int, int, bool, Any]]:
        """Requirements the containers cannot serve for this routing.

        ``trace``: (steps, moe_layers, B, k) routed ids; ``active``: (B,)
        live-slot mask.  Returns [(layer, expert, need_weights,
        factor_cap-or-_NO_FACTORS)] covering every active routed expert
        whose true weights are not staged, plus (policy 'ours') every
        top-n routed expert whose staged factor rows fall short of the
        layer's rank cap."""
        trace = np.asarray(trace)
        needs: Dict[Tuple[int, int], List] = {}
        for l, L in enumerate(self.layers):
            sub = trace[:, l][:, np.asarray(active, bool)]   # (steps, A, k)
            ids = np.unique(sub[sub >= 0])
            for e in ids:
                if int(e) not in L.valid:
                    needs[(l, int(e))] = [True, _NO_FACTORS]
            if self.policy == "ours" and top_ns[l] > 0:
                tn = sub[..., :top_ns[l]]
                for e in np.unique(tn[tn >= 0]):
                    if L.factor_deficit(int(e), caps[l]):
                        needs.setdefault((l, int(e)),
                                         [False, _NO_FACTORS])[1] = caps[l]
        return [(l, e, w, f) for (l, e), (w, f) in sorted(needs.items())]

    def missing_for_forward_trace(self, trace, top_n: int
                                  ) -> List[Tuple[int, int, bool, Any]]:
        """Prefill variant: ``trace`` is the forward pass's
        (moe_layers, ..., k) routing; prefill compensates at the static
        ``top_n`` with full rank."""
        arr = np.asarray(trace)
        k = arr.shape[-1]
        flat = arr.reshape(arr.shape[0], -1, k)[None]   # (1, layers, X, k)
        active = np.ones((flat.shape[2],), bool)
        layers = flat.shape[1]
        return self.missing_for_trace(flat, active, [top_n] * layers,
                                      [None] * layers)

    def demand_stage(self, needs, timeout_s: Optional[float] = None
                     ) -> List[Tuple[int, int]]:
        """Block until every need is staged (the true-miss stall path).

        Waits on in-flight ring copies first (their bytes were already
        metered at prefetch issue); fresh copies go on the ledger so the
        replay's demand/compensator charges consume them.  Returns the
        (layer, expert) pairs that could NOT be staged (stalled copies)
        — the caller serves those from the resident low-bit fallback and
        counts the affected tokens as degraded."""
        timeout = self.cfg.stall_timeout_s if timeout_s is None \
            else timeout_s
        unresolved = []
        for (l, e, need_w, f_cap) in needs:
            L = self.layers[l]
            ok = True
            if need_w and e not in L.valid:
                slot = L.ring.find(e, KIND_WEIGHTS)
                if slot is not None:
                    t0 = self.clock()
                    got = L.ring.wait(slot, timeout)
                    dt = self.clock() - t0
                    self.stalls += 1
                    self.stall_s += dt
                    if got:
                        self._integrate_slot(L, slot)
                    else:
                        L.ring.abandon(slot)
                        self.abandoned_copies += 1
                        ok = False
                else:
                    nb = L.store.expert_bytes(e, self.policy)
                    L.ledger[(KIND_WEIGHTS, e)] = nb
                    ok = self._copy_weights_now(L, e, timeout_s=timeout,
                                                stall_clock=True)
            if ok and f_cap is not _NO_FACTORS and self.policy == "ours":
                windows = L.factor_deficit(e, f_cap)
                if windows:
                    have = L.staged_cap.get(e, -1)
                    nb = (L.store.compensator_bytes(e, f_cap)
                          - (0 if have == -1
                             else L.store.compensator_bytes(e, have)))
                    L.ledger[(KIND_FACTORS, e)] = (nb, f_cap)
                    ok = self._copy_factors_now(L, e, windows, f_cap,
                                                wire_bytes=nb,
                                                stall_clock=True)
            if not ok:
                unresolved.append((l, e))
        return unresolved

    def stage_async(self, needs):
        """Degrade-mode background staging: issue what the ring can take
        now (ledgered at issue); declined issues retry on a later chunk."""
        for (l, e, need_w, f_cap) in needs:
            L = self.layers[l]
            if (need_w and e not in L.valid
                    and (KIND_WEIGHTS, e) not in L.ledger
                    and L.ring.find(e, KIND_WEIGHTS) is None):
                nb = L.store.expert_bytes(e, self.policy)
                slot = self._issue_ring(L, e, L.image.weight_payload(e),
                                        nb, KIND_WEIGHTS)
                if slot is not None:
                    L.ledger[(KIND_WEIGHTS, e)] = nb
            if (f_cap is not _NO_FACTORS and self.policy == "ours"
                    and (KIND_FACTORS, e) not in L.ledger
                    and L.ring.find(e, KIND_FACTORS) is None):
                windows = L.factor_deficit(e, f_cap)
                if windows:
                    have = L.staged_cap.get(e, -1)
                    nb = (L.store.compensator_bytes(e, f_cap)
                          - (0 if have == -1
                             else L.store.compensator_bytes(e, have)))
                    slot = self._issue_ring(
                        L, e, L.image.factor_payload(e, windows), nb,
                        KIND_FACTORS, meta=(windows, f_cap))
                    if slot is not None:
                        L.ledger[(KIND_FACTORS, e)] = (nb, f_cap)

    def flush_unclaimed(self):
        """Chunk boundary: meter staged copies the accepted trace never
        touched into their store as (wasted) prefetch traffic, keeping
        metered bytes == observed copies exact."""
        for L in self.layers:
            for key in list(L.ledger):
                kind, e = key
                if kind == KIND_WEIGHTS:
                    nb = L.ledger.pop(key)
                    moved = L.store.absorb_external_copy(e, nb)
                else:
                    nb, cap = L.ledger.pop(key)
                    moved = L.store.absorb_external_copy(
                        e, 0, comp_rank=cap, comp_bytes=nb)
                L.store.wasted_prefetch_bytes += moved
                self.flushed_bytes += moved

    # -- degraded-token accounting ------------------------------------------
    @staticmethod
    def count_affected_tokens(trace: np.ndarray, active: np.ndarray,
                              bad: Iterable[Tuple[int, int]]) -> int:
        """Active (step, slot) tokens whose routing touched any (layer,
        expert) in ``bad`` — the tokens served by the low-bit fallback."""
        trace = np.asarray(trace)
        steps, _layers, b, _k = trace.shape
        mask = np.zeros((steps, b), bool)
        for (l, e) in bad:
            mask |= (trace[:, l] == e).any(axis=-1)
        mask &= np.asarray(active, bool)[None, :]
        return int(mask.sum())

    # -- reporting ----------------------------------------------------------
    def observed_totals(self) -> Tuple[int, int]:
        copies = sum(L.store.observed_copies for L in self.layers)
        nbytes = sum(L.store.observed_copy_bytes for L in self.layers)
        return copies, nbytes

    def report(self) -> Dict:
        copies, nbytes = self.observed_totals()
        metered = sum(L.store.total_bytes for L in self.layers)
        hidden = max(self.transfer_s - self.stall_s, 0.0)
        if self.transfer_s > 0:
            eff = hidden / self.transfer_s
        else:
            eff = 1.0 if self.issued_copies else 0.0
        return {
            "enabled": True,
            "miss_policy": self.cfg.miss_policy,
            "ring_slots": self.cfg.ring_slots,
            "fallback_bits": self.cfg.fallback_bits,
            "issued_copies": self.issued_copies,
            "issued_bytes": self.issued_bytes,
            "observed_copies": copies,
            "observed_copy_bytes": nbytes,
            "metered_bytes": metered,
            "stalls": self.stalls,
            "stall_s": self.stall_s,
            "transfer_s": self.transfer_s,
            "sync_copy_s": self.sync_copy_s,
            "overlap_efficiency": eff,
            "reruns": self.reruns,
            "degraded_tokens": self.degraded_tokens,
            "abandoned_copies": self.abandoned_copies,
            "flushed_bytes": self.flushed_bytes,
            "in_flight": sum(len(L.ring.in_flight()) for L in self.layers),
            "host_nbytes": sum(L.image.host_nbytes for L in self.layers),
        }
