"""Top-level language model: embed -> stack -> head, plus the three
entry points the launcher lowers (train loss, prefill, decode step) and
``input_specs`` (ShapeDtypeStruct stand-ins for the dry-run)."""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..config import ModelConfig, ShapeConfig
from .layers import softcap
from .transformer import (ExecContext, apply_encoder, apply_stack,
                          derive_plan, init_caches, init_params)


class LMOutput(NamedTuple):
    logits: jax.Array
    aux: Dict[str, jax.Array]
    caches: Optional[Dict]
    # (moe_layers, T, k) router top-k ids when ctx.collect_trace (else None)
    trace: Optional[jax.Array] = None
    # (moe_layers, T, d) normed MoE-FFN inputs when ctx.collect_moe_inputs
    # (the offline calibration pass; else None)
    moe_inputs: Optional[jax.Array] = None


def embed_tokens(params, tokens_or_embeds, cfg: ModelConfig,
                 positions=None):
    if tokens_or_embeds.dtype in (jnp.int32, jnp.int64):
        x = params["embed"]["tok"][tokens_or_embeds]
    else:
        x = tokens_or_embeds  # modality frontend stub: precomputed embeddings
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    if cfg.abs_pos_embed and positions is not None:
        from .layers import sinusoidal_positions
        table = sinusoidal_positions(cfg.max_position, cfg.d_model)
        x = x + table[positions].astype(x.dtype)
    return x


def lm_head(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"])
    return softcap(logits, cfg.logit_softcap)


def forward(params, tokens, cfg: ModelConfig, ctx: ExecContext, *,
            positions=None, caches=None, mrope_pos=None,
            enc_embeds=None, plan=None) -> LMOutput:
    """Full-sequence forward (train / prefill).

    ``plan``: optional (num_moe_layers, 2) int32 [top_n, rank_cap]
    restoration plan (bandwidth controller); None = static QuantConfig.
    """
    b, s = tokens.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_tokens(params, tokens, cfg, positions)
    x = ctx.constrain(x, ("batch", "seq", None))
    enc_out = None
    if cfg.encoder is not None:
        enc_out = apply_encoder(params, enc_embeds, cfg, ctx)
    x, aux, new_caches, trace, moe_in = apply_stack(
        params, x, cfg, ctx, positions, caches=caches, mrope_pos=mrope_pos,
        enc_out=enc_out, plan=plan)
    from .layers import rms_norm
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head(params, x, cfg)
    return LMOutput(logits, aux, new_caches, trace, moe_in)


def decode_step(params, tokens, caches, cfg: ModelConfig, ctx: ExecContext,
                *, mrope_pos=None, plan=None) -> LMOutput:
    """One-token serve step against the KV/recurrent caches.

    ``plan``: optional (num_moe_layers, 2) int32 [top_n, rank_cap] array
    — traced data with a static shape, so per-chunk plan updates from the
    bandwidth controller never recompile the decode loop.  Under
    expert-parallel serving (``ctx.moe_ep_fn`` + ``ep_mode``) each MoE
    layer's plan row rides into the shard_map region replicated, so the
    guarantee holds on a mesh too."""
    b = tokens.shape[0]
    positions = caches["pos"][:, None]        # (B, 1) absolute position
    x = embed_tokens(params, tokens, cfg, positions)
    x, aux, new_caches, trace, moe_in = apply_stack(
        params, x, cfg, ctx, positions, caches=caches, mrope_pos=mrope_pos,
        plan=plan)
    from .layers import rms_norm
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head(params, x, cfg)
    return LMOutput(logits, aux, new_caches, trace, moe_in)


def _xent_terms_plain(params, x, targets, cfg: ModelConfig):
    """(lse, target-logit) for a chunk of hidden states (no full logits
    retained outside the chunk)."""
    logits = lm_head(params, x, cfg).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return lse, tgt


@jax.custom_vjp
def _fused_xent(w, x, targets):
    """(lse, tgt) with bf16 cotangents.

    The plain path's ``logits.astype(f32)`` makes every gradient flowing
    into the (tied) embedding and the hidden states f32 — on the 2×16×16
    mesh those are the LARGEST all-reduces of the whole train step (the
    Cell-B HLO histogram: fused f32[vocab/16, d] buckets).  The custom VJP
    recomputes the chunk's logits in the backward pass and emits
    d_x / d_W in bf16 — halving those collectives and the logits'
    memory traffic, with softmax statistics still in f32.
    """
    logits = jnp.einsum("bsd,vd->bsv", x, w,
                        preferred_element_type=jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return lse, tgt


def _fused_xent_fwd(w, x, targets):
    out = _fused_xent(w, x, targets)
    return out, (w, x, targets, out[0])


def _fused_xent_bwd(res, g):
    w, x, targets, lse = res
    g_lse, g_tgt = g
    logits = jnp.einsum("bsd,vd->bsv", x, w,
                        preferred_element_type=jnp.float32)
    p = jnp.exp(logits - lse[..., None])
    onehot = jax.nn.one_hot(targets, w.shape[0], dtype=jnp.float32)
    # d_logits = g_lse * softmax + g_tgt * onehot, carried in bf16
    d_logits = (g_lse[..., None] * p + g_tgt[..., None] * onehot
                ).astype(jnp.bfloat16)
    d_x = jnp.einsum("bsv,vd->bsd", d_logits,
                     w.astype(jnp.bfloat16)).astype(x.dtype)
    d_w = jnp.einsum("bsv,bsd->vd", d_logits,
                     x.astype(jnp.bfloat16)).astype(w.dtype)
    return d_w, d_x, None


_fused_xent.defvjp(_fused_xent_fwd, _fused_xent_bwd)


def _xent_terms(params, x, targets, cfg: ModelConfig):
    import os
    fused = (os.environ.get("REPRO_XENT", "fused") == "fused"
             and cfg.logit_softcap == 0.0)
    if fused and cfg.tie_embeddings:
        return _fused_xent(params["embed"]["tok"], x, targets)
    if fused:
        return _fused_xent(params["head"]["w"].T, x, targets)
    return _xent_terms_plain(params, x, targets, cfg)


def lm_loss(params, batch, cfg: ModelConfig, ctx: ExecContext,
            z_loss: float = 1e-4, loss_chunk: int = 0
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy + router aux + z-loss.

    ``loss_chunk`` > 0 computes the xent in sequence chunks so the peak
    logits buffer is (B, chunk, V) instead of (B, S, V) — essential for
    262k-vocab archs at 4k sequence.
    """
    b, s = batch["tokens"].shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_tokens(params, batch["tokens"], cfg, positions)
    x = ctx.constrain(x, ("batch", "seq", None))
    enc_out = None
    if cfg.encoder is not None:
        from .transformer import apply_encoder
        enc_out = apply_encoder(params, batch["enc_embeds"], cfg, ctx)
    from .transformer import apply_stack
    from .layers import rms_norm
    x, aux, _, _, _ = apply_stack(params, x, cfg, ctx, positions,
                                  mrope_pos=batch.get("mrope_pos"),
                                  enc_out=enc_out)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)

    x = x[:, :-1]
    targets = batch["tokens"][:, 1:]
    mask = batch.get("mask")
    mask = (jnp.ones_like(targets, jnp.float32) if mask is None
            else mask[:, 1:].astype(jnp.float32))
    sl = s - 1
    if loss_chunk and sl > loss_chunk:
        pad = (-sl) % loss_chunk
        if pad:  # pad to a whole number of chunks; padded slots are masked
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        nch = (sl + pad) // loss_chunk
        xc = x.reshape(b, nch, loss_chunk, -1).swapaxes(0, 1)
        tc = targets.reshape(b, nch, loss_chunk).swapaxes(0, 1)
        _, (lse, tgt) = jax.lax.scan(
            lambda c, args: (c, _xent_terms(params, args[0], args[1], cfg)),
            0, (xc, tc), unroll=ctx.scan_unroll)
        lse = lse.swapaxes(0, 1).reshape(b, sl + pad)
        tgt = tgt.swapaxes(0, 1).reshape(b, sl + pad)
    else:
        lse, tgt = _xent_terms(params, x, targets, cfg)
    nll = (lse - tgt) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    zl = z_loss * ((lse * mask) ** 2).sum() / denom
    total = loss + zl + sum(aux.values())
    metrics = {"loss": loss, "z_loss": zl, **aux, "total_loss": total}
    return total, metrics


# ---------------------------------------------------------------------------
# dry-run input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32

    def sds(shp, dt=i32):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "train":
        batch = {"tokens": sds((b, s))}
        if cfg.encoder is not None:
            batch["enc_embeds"] = sds((b, cfg.encoder.source_len,
                                       cfg.encoder.d_model), jnp.bfloat16)
        if cfg.rope_kind == "mrope":
            batch["mrope_pos"] = sds((3, b, s))
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": sds((b, s))}
        if cfg.encoder is not None:
            batch["enc_embeds"] = sds((b, cfg.encoder.source_len,
                                       cfg.encoder.d_model), jnp.bfloat16)
        if cfg.rope_kind == "mrope":
            batch["mrope_pos"] = sds((3, b, s))
        return {"batch": batch}
    # decode: one new token against a seq_len cache
    batch = {"tokens": sds((b, 1))}
    if cfg.rope_kind == "mrope":
        batch["mrope_pos"] = sds((3, b, 1))
    return {"batch": batch}


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    """Parameter ShapeDtypeStructs without allocating (dry-run)."""
    return jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.key(0))


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_len, dtype))
