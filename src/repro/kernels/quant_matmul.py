"""Pallas TPU kernel: matmul against bit-plane-packed quantized weights.

y = x @ dequant(Wq).  The packed planes are streamed HBM->VMEM at their
native sub-byte width (bits/8 bytes per weight), unpacked in VMEM with
uniform shift/mask lanes, dequantized per quantization group, and fed to
the MXU tile-by-tile.  This is the TPU-native analogue of the paper's
"transfer low-bit experts over PCIe": the HBM term of the decode roofline
drops by ~16/bits on every expert matmul.

An optional fused epilogue adds the router-guided low-rank compensation
``+ xu @ V`` (paper §3.2) on the final K step, so the compensated result
never round-trips through HBM.

Grid: (M/bm, N/bn, K/bk) with a VMEM f32 accumulator; K is the innermost
(sequential) dimension.  Constraints: bk % PACK_BLOCK == 0 (block-local
packing), bk % group_size == 0 (whole quant groups per tile).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import PallasCompilerParams

from ..core.quantize import PACK_BLOCK, PLANES


def _unpack_tile(plane_vals, bits: int, bk: int, bn: int) -> jax.Array:
    """Unpack loaded plane tiles -> (bk, bn) uint8 codes (VMEM, vectorized)."""
    out = None
    for (p, off), pk in zip(PLANES[bits], plane_vals):
        c = 8 // p
        mask = jnp.uint8((1 << p) - 1)
        blocks = pk.reshape(bk // PACK_BLOCK, PACK_BLOCK // c, bn)
        chunks = [(blocks >> (j * p)) & mask for j in range(c)]
        sub = jnp.stack(chunks, axis=1).reshape(bk, bn)
        sub = (sub << off).astype(jnp.uint8)
        out = sub if out is None else out | sub
    return out


def _dequant_tile(codes: jax.Array, scale, zero, group_size: int,
                  bk: int, bn: int) -> jax.Array:
    g = codes.astype(jnp.float32).reshape(bk // group_size, group_size, bn)
    w = (g - zero[:, None, :]) * scale[:, None, :]
    return w.reshape(bk, bn)


def _qmm_kernel(bits, group_size, n_k, bk, bn, fuse_lowrank, x_ref, *refs):
    """refs: [planes..., scale, zero, (xu, v)] + [out] + [acc scratch]."""
    n_planes = len(PLANES[bits])
    planes = refs[:n_planes]
    scale_ref, zero_ref = refs[n_planes], refs[n_planes + 1]
    pos = n_planes + 2
    if fuse_lowrank:
        xu_ref, v_ref = refs[pos], refs[pos + 1]
        pos += 2
    out_ref, acc_ref = refs[pos], refs[pos + 1]

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_tile([p[...] for p in planes], bits, bk, bn)
    w = _dequant_tile(codes, scale_ref[...], zero_ref[...], group_size, bk, bn)
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        acc = acc_ref[...]
        if fuse_lowrank:
            # rank-r compensation epilogue: acc += xu @ V (scales pre-folded)
            vd = v_ref[...].astype(jnp.float32)
            acc = acc + jnp.dot(xu_ref[...], vd,
                                preferred_element_type=jnp.float32)
        out_ref[...] = acc.astype(out_ref.dtype)


def _pallas_qmm(x, planes, scale, zero, xu, v, *, bits, group_size,
                bm, bn, bk, out_dtype, interpret):
    m, k = x.shape
    n = scale.shape[-1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bk % PACK_BLOCK == 0 and bk % group_size == 0
    n_k = k // bk
    fuse = xu is not None

    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))]
    in_specs += [pl.BlockSpec((bk // (8 // p), bn), lambda i, j, kk: (kk, j))
                 for p, _ in PLANES[bits]]
    in_specs += [pl.BlockSpec((bk // group_size, bn),
                              lambda i, j, kk: (kk, j))] * 2
    args = [x, *planes, scale, zero]
    if fuse:
        r = xu.shape[-1]
        in_specs += [pl.BlockSpec((bm, r), lambda i, j, kk: (i, 0)),
                     pl.BlockSpec((r, bn), lambda i, j, kk: (0, j))]
        args += [xu, v]

    kernel = functools.partial(_qmm_kernel, bits, group_size, n_k, bk, bn,
                               fuse)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=PallasCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name=f"quant_matmul_b{bits}" + ("_lowrank" if fuse else ""),
    )(*args)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "bm", "bn", "bk", "out_dtype", "interpret"))
def quant_matmul_pallas(x: jax.Array, planes: Tuple[jax.Array, ...],
                        scale: jax.Array, zero: jax.Array, *,
                        bits: int, group_size: int,
                        bm: int = 128, bn: int = 256, bk: int = 512,
                        out_dtype=jnp.float32, interpret: bool = False
                        ) -> jax.Array:
    """x: (M, K) @ packed (K, N) -> (M, N)."""
    return _pallas_qmm(x, planes, scale, zero, None, None, bits=bits,
                       group_size=group_size, bm=bm, bn=bn, bk=bk,
                       out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "bm", "bn", "bk", "out_dtype", "interpret"))
def lowrank_comp_matmul_pallas(x: jax.Array, planes: Tuple[jax.Array, ...],
                               scale: jax.Array, zero: jax.Array,
                               xu: jax.Array, v: jax.Array, *,
                               bits: int, group_size: int,
                               bm: int = 128, bn: int = 256, bk: int = 512,
                               out_dtype=jnp.float32, interpret: bool = False
                               ) -> jax.Array:
    """Fused y = x @ dequant(Wq) + xu @ V.

    ``xu`` is the (M, R) rank-space activation ``(x * mask) @ (U * u_scale)
    * v_scale`` computed by the ops wrapper (rank-r, negligible FLOPs);
    ``v`` is the (R, N) int8 code matrix with its scale pre-folded into xu.
    """
    return _pallas_qmm(x, planes, scale, zero, xu, v, bits=bits,
                       group_size=group_size, bm=bm, bn=bn, bk=bk,
                       out_dtype=out_dtype, interpret=interpret)
