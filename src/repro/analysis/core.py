"""Lint engine: rule registry, findings, suppression, baseline, runner.

The engine is deliberately small — rules do the thinking.  A rule is a
function ``(scope, ctx) -> List[Finding]`` registered under a stable ID
via the :func:`rule` decorator; the runner builds one :class:`RepoIndex`
over the requested paths, one :class:`JitScope` on top of it, then hands
both to every registered rule through a shared :class:`RuleContext`
(which caches per-function taint analyses so RL101–RL103 don't re-run
the fixpoint three times per function).

Findings are filtered twice before they reach the caller:

1. inline suppressions — a ``# repro-lint: disable=RL101`` (or
   ``disable=RL101,RL203`` / ``disable=all``) comment on the flagged
   line silences it at the source;
2. the committed baseline — ``tools/repro_lint_baseline.json`` entries
   keyed by ``(rule, path, stripped line content)``, so a baselined
   finding stays silenced across unrelated line-number churn but
   resurfaces the moment the flagged code itself changes.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

from .jitscope import FunctionInfo, JitScope, RepoIndex, build_scope
from .taint import TaintAnalysis

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\s]+)")

# directories never worth parsing
_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "build", "dist",
              ".cache"}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str                     # repo-relative, posix separators
    line: int
    col: int
    message: str
    content: str = ""             # stripped source line (baseline key)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"


@dataclasses.dataclass
class Rule:
    rule_id: str
    description: str
    fn: Callable[[JitScope, "RuleContext"], List[Finding]]


_REGISTRY: Dict[str, Rule] = {}


def rule(rule_id: str, description: str):
    """Register a rule function under a stable ID."""
    def wrap(fn):
        _REGISTRY[rule_id] = Rule(rule_id, description, fn)
        return fn
    return wrap


def all_rules() -> Dict[str, Rule]:
    _load_rule_modules()
    return dict(_REGISTRY)


def _load_rule_modules():
    # imported for their @rule side effects; lazy to avoid import cycles
    from . import rules_bytes, rules_jit, rules_pallas  # noqa: F401


class RuleContext:
    """Shared per-run state handed to every rule."""

    def __init__(self, index: RepoIndex, root: Path):
        self.index = index
        self.root = root
        self._taints: Dict[str, TaintAnalysis] = {}
        self._sources: Dict[str, List[str]] = {}

    # -- taint cache ---------------------------------------------------------
    def scope_taints(self, scope: JitScope):
        """Yield (qualname, FunctionInfo, TaintAnalysis) per scope member."""
        for q in sorted(scope.members):
            info = scope.index.functions.get(q)
            if info is None:
                continue
            ta = self._taints.get(q)
            if ta is None:
                ta = self._taints[q] = TaintAnalysis(info)
            yield q, info, ta

    # -- finding constructors ------------------------------------------------
    def finding(self, rule_id: str, info: FunctionInfo, node: ast.AST,
                message: str) -> Finding:
        return self.finding_at(rule_id, info.path, node, message)

    def finding_at(self, rule_id: str, path, node: ast.AST,
                   message: str) -> Finding:
        rel = self._rel(path)
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(rule_id, rel, line, col, message,
                       self.source_line(rel, line))

    # -- source access ---------------------------------------------------------
    def source_line(self, rel: str, line: int) -> str:
        lines = self._sources.get(rel)
        if lines is None:
            try:
                lines = (self.root / rel).read_text().splitlines()
            except OSError:
                lines = []
            self._sources[rel] = lines
        if 1 <= line <= len(lines):
            return lines[line - 1].strip()
        return ""

    def _rel(self, path) -> str:
        p = Path(path)
        try:
            return p.resolve().relative_to(self.root.resolve()).as_posix()
        except ValueError:
            return p.as_posix()


# ---------------------------------------------------------------------------
# suppression + baseline
# ---------------------------------------------------------------------------

def _suppressed(finding: Finding) -> bool:
    m = _SUPPRESS_RE.search(finding.content)
    if not m:
        return False
    ids = {s.strip() for s in m.group(1).split(",")}
    return "all" in ids or finding.rule in ids


class Baseline:
    """Committed list of accepted findings, content-addressed.

    An entry silences every finding with the same (rule, path, stripped
    line content) — stable across pure line-number churn, invalidated as
    soon as the flagged line itself is edited.
    """

    def __init__(self, entries: Optional[Iterable[dict]] = None):
        self._keys: Set[tuple] = set()
        for e in entries or ():
            self._keys.add((e.get("rule", ""), e.get("path", ""),
                            e.get("content", "")))

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        try:
            data = json.loads(Path(path).read_text())
        except (OSError, ValueError):
            return cls()
        if isinstance(data, dict):
            data = data.get("findings", [])
        return cls(data if isinstance(data, list) else [])

    def matches(self, finding: Finding) -> bool:
        return (finding.rule, finding.path, finding.content) in self._keys

    @staticmethod
    def dump(findings: Sequence[Finding], path: Path) -> None:
        entries = [{"rule": f.rule, "path": f.path, "content": f.content,
                    "message": f.message} for f in findings]
        entries.sort(key=lambda e: (e["path"], e["rule"], e["content"]))
        Path(path).write_text(json.dumps({"findings": entries}, indent=2)
                              + "\n")


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LintConfig:
    paths: Sequence[Path]
    root: Path
    baseline_path: Optional[Path] = None
    select: Optional[Set[str]] = None       # restrict to these rule IDs


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]                 # new, actionable
    suppressed: int = 0
    baselined: int = 0
    files: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings


def _iter_py_files(paths: Sequence[Path]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_file() and p.suffix == ".py":
            out.append(p)
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part in _SKIP_DIRS for part in f.parts):
                    out.append(f)
    return out


def run_lint(config: LintConfig) -> LintResult:
    rules = all_rules()
    if config.select:
        rules = {k: v for k, v in rules.items() if k in config.select}

    index = RepoIndex()
    files = _iter_py_files(config.paths)
    for f in files:
        index.add_file(f, config.root)
    scope = build_scope(index)
    ctx = RuleContext(index, Path(config.root))

    findings: List[Finding] = []
    for rid in sorted(rules):
        findings.extend(rules[rid].fn(scope, ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))

    live = [f for f in findings if not _suppressed(f)]
    suppressed = len(findings) - len(live)

    baselined = 0
    if config.baseline_path is not None:
        base = Baseline.load(config.baseline_path)
        kept = [f for f in live if not base.matches(f)]
        baselined = len(live) - len(kept)
        live = kept

    return LintResult(live, suppressed=suppressed, baselined=baselined,
                      files=len(files))


def lint_paths(paths: Sequence, root, baseline_path=None,
               select: Optional[Set[str]] = None) -> LintResult:
    """Convenience wrapper used by the CLI and the test suite."""
    return run_lint(LintConfig([Path(p) for p in paths], Path(root),
                               Path(baseline_path) if baseline_path else None,
                               select))
