"""Calibration stage 1: per-expert routing / activation statistics.

A calibration corpus (the deterministic Zipf-Markov synthetic stream —
``data/synthetic.py`` — or any token batches) runs through the *jitted*
forward with two first-class outputs enabled: the router trace
(``ExecContext.collect_trace``) and the normed MoE-FFN inputs
(``ExecContext.collect_moe_inputs``).  From those, one jitted reduction
per MoE layer accumulates, per expert:

- ``counts``     how many (token, slot) assignments routed to it,
- ``gate_mass``  the summed normalized gate weight of those assignments
                 (frequency x confidence — the importance signal the
                 budget allocator weights errors by),
- ``in_moment``  the diagonal second moment E[x^2] of the layer inputs
                 routed to it (whitens the w1/w3 compensator SVDs),
- ``hid_moment`` the diagonal second moment E[h^2] of its own hidden
                 activation h = act(x w1) * (x w3) (whitens w2).

Everything is accumulated in f64 on host between batches, so corpus
size only costs time, not precision.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig
from ..data.synthetic import SyntheticLM, SyntheticLMConfig
from ..models import model as lm
from ..models.transformer import ExecContext, layer_specs, unstack_params


@dataclasses.dataclass
class LayerCalibStats:
    """Accumulated statistics of one MoE layer (E experts)."""
    counts: np.ndarray        # (E,) f64 routed assignments
    gate_mass: np.ndarray     # (E,) f64 summed gate weight
    in_moment: np.ndarray     # (E, d) f64 sum of x^2 over routed tokens
    hid_moment: np.ndarray    # (E, fe) f64 sum of h^2 per expert
    tokens: int = 0           # calibration tokens seen

    # -- derived views -----------------------------------------------------
    @property
    def freq(self) -> np.ndarray:
        """(E,) routed-assignment share (sums to top_k over experts)."""
        return self.counts / max(self.tokens, 1)

    def importance(self, eps: float = 1e-3) -> np.ndarray:
        """(E,) normalized expert importance for error weighting:
        gate mass share, floored at ``eps`` so cold experts keep a
        nonzero stake (they may still be routed at serve time)."""
        total = max(float(self.gate_mass.sum()), 1e-12)
        w = self.gate_mass / total
        w = np.maximum(w, eps / len(w))
        return w / w.sum()

    def moment_for(self, proj: str) -> np.ndarray:
        """(E, K) mean input second moment for a projection's K axis:
        the layer input for w1/w3, the expert hidden for w2.  Experts
        with no routed calibration tokens fall back to an all-ones
        moment (unwhitened SVD)."""
        mom = self.in_moment if proj in ("w1", "w3") else self.hid_moment
        cnt = np.maximum(self.counts, 1.0)[:, None]
        mean = mom / cnt
        flat = mean.sum(axis=1) <= 0
        if flat.any():
            mean[flat] = 1.0
        return mean

    def merge(self, other: "LayerCalibStats") -> "LayerCalibStats":
        return LayerCalibStats(self.counts + other.counts,
                               self.gate_mass + other.gate_mass,
                               self.in_moment + other.in_moment,
                               self.hid_moment + other.hid_moment,
                               self.tokens + other.tokens)


def _zero_stats(e: int, d: int, fe: int) -> LayerCalibStats:
    return LayerCalibStats(np.zeros(e), np.zeros(e), np.zeros((e, d)),
                           np.zeros((e, fe)))


@partial(jax.jit, static_argnames=("num_experts", "act", "norm_topk"))
def _layer_reduce(x, topk, w_router, w1, w3, *, num_experts: int,
                  act: str, norm_topk: bool):
    """One MoE layer's per-expert reductions over a (T, d) input batch.

    ``topk`` is the traced router decision (T, k) from the forward —
    gates are recomputed from the same router weights (deterministic,
    identical ids; asserted in tests) because the trace carries ids only.
    """
    from ..models.layers import activation
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates = jnp.take_along_axis(probs, topk, axis=-1)        # (T, k)
    if norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    oh = jax.nn.one_hot(topk, num_experts, dtype=jnp.float32)  # (T, k, E)
    assign = oh.sum(axis=1)                                    # (T, E) 0/1
    counts = assign.sum(axis=0)                                # (E,)
    gmass = (oh * gates[..., None]).sum(axis=(0, 1))           # (E,)
    x32 = x.astype(jnp.float32)
    in_mom = jnp.einsum("te,td->ed", assign, x32 * x32)        # (E, d)
    f = activation(act)
    h = f(jnp.einsum("td,edf->etf", x32, w1.astype(jnp.float32))) \
        * jnp.einsum("td,edf->etf", x32, w3.astype(jnp.float32))
    hid_mom = jnp.einsum("te,etf->ef", assign, h * h)          # (E, fe)
    return counts, gmass, in_mom, hid_mom


def collect_calibration_stats(cfg: ModelConfig, params, *,
                              batches: int = 4,
                              batch_size: int = 8,
                              seq_len: int = 128,
                              seed: int = 0,
                              step_offset: int = 0,
                              data: Optional[SyntheticLM] = None
                              ) -> List[LayerCalibStats]:
    """Run the calibration corpus through the jitted forward and return
    one ``LayerCalibStats`` per MoE layer (global layer order — the same
    order as ``compress_moe_params``'s ``stacks_by_layer``).

    The corpus is the deterministic synthetic stream (same packing the
    training loop uses), so identical (cfg, seed, batches) always yields
    identical statistics — calibration is reproducible by construction.
    """
    if cfg.moe is None:
        raise ValueError(f"{cfg.name} has no MoE layers to calibrate")
    data = data or SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, batch_size=batch_size, seq_len=seq_len,
        seed=seed))
    ctx = ExecContext(mode="train", quantized=False, exact_capacity=True,
                      collect_trace=True, collect_moe_inputs=True)
    fwd = jax.jit(lambda p, t: lm.forward(p, t, cfg, ctx))

    # per-MoE-layer dense weights + router (unrolled order = trace order)
    up = unstack_params(params, cfg)
    moe_layers = [lp["moe"] for (lp,), spec
                  in zip(up["segments"], layer_specs(cfg))
                  if spec.ffn == "moe"]
    e = cfg.moe.num_experts
    d = cfg.d_model
    fe = cfg.moe.d_expert
    stats = [_zero_stats(e, d, fe) for _ in moe_layers]

    for bi in range(batches):
        toks = jnp.asarray(data.batch(step_offset + bi)["tokens"])
        out = fwd(params, toks)
        ntok = int(np.prod(toks.shape))
        for li, mp in enumerate(moe_layers):
            counts, gmass, in_mom, hid_mom = _layer_reduce(
                out.moe_inputs[li], out.trace[li], mp["router"],
                mp["w1"], mp["w3"], num_experts=e, act=cfg.act,
                norm_topk=cfg.moe.router_norm_topk)
            stats[li] = stats[li].merge(LayerCalibStats(
                np.asarray(counts, np.float64),
                np.asarray(gmass, np.float64),
                np.asarray(in_mom, np.float64),
                np.asarray(hid_mom, np.float64), ntok))
    return stats


def stats_summary(stats: List[LayerCalibStats]) -> Dict:
    """Compact per-layer report for CLIs / manifests."""
    return {
        "layers": len(stats),
        "tokens": stats[0].tokens if stats else 0,
        "freq": [np.round(s.freq, 4).tolist() for s in stats],
        "importance": [np.round(s.importance(), 4).tolist() for s in stats],
    }
