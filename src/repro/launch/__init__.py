"""Launchers: production mesh, dry-run, train and serve CLIs."""
