"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state; the 512-device host-platform override happens only in dryrun.py.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires XLA host device override)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_serve_mesh(ep: int = 1, devices=None) -> Optional[Mesh]:
    """Expert-parallel serving mesh: a 1-D ``('model',)`` mesh over the
    first ``ep`` devices.

    The serve engine's decode/prefill contexts map the MoE expert dim
    onto the ``model`` axis (``distributed/sharding.py`` PARAM_RULES), so
    an ``ep``-way mesh partitions each layer's experts — quantized planes,
    scales, and low-rank compensator factors included — across ``ep``
    shards.  ``ep == 1`` returns None (single-device path, no shard_map).
    On CPU, multi-device meshes need
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    if ep <= 1:
        return None
    devices = list(jax.devices() if devices is None else devices)
    if len(devices) < ep:
        raise ValueError(
            f"mesh ep={ep} needs {ep} devices but only {len(devices)} are "
            f"visible (on CPU, set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={ep})")
    return Mesh(np.asarray(devices[:ep]), ("model",))


def parse_mesh_spec(spec: str) -> Dict[str, int]:
    """Parse a ``--mesh`` serving spec like ``"ep=4"`` into a dict.

    Comma-separated ``axis=N`` entries; only ``ep`` (expert parallelism)
    is currently meaningful for serving."""
    out: Dict[str, int] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"bad --mesh entry {part!r}; expected axis=N")
        k, v = part.split("=", 1)
        out[k.strip()] = int(v)
    unknown = set(out) - {"ep"}
    if unknown:
        raise ValueError(f"unknown --mesh axes {sorted(unknown)}; "
                         f"serving supports ep=N")
    return out
