"""Jit-scope call graph: which functions execute at trace time.

Roots are the places a Python callable crosses into JAX tracing:

- ``@jax.jit`` / ``@functools.partial(jax.jit, static_argnames=...)``
  decorated functions and ``jax.jit(f)`` / ``jax.jit(lambda ...)`` calls;
- ``jax.lax.scan(body, ...)`` bodies (traced even outside jit);
- ``shard_map(body, ...)`` bodies;
- ``pl.pallas_call(kernel, ...)`` kernels, including kernels bound with
  ``functools.partial(kernel, static0, static1, ...)`` — the leading
  bound positionals are Python statics, the remaining params are refs.

Everything reachable from a root through statically-resolvable calls
(same-module functions, ``from``-imported functions, ``self.method``,
``module.func`` through the import map, nested defs) is in scope.  The
resolution is deliberately conservative: a call we cannot resolve adds
no edge, so the scope under-approximates rather than hallucinating.

Per function the scope also records which parameters are *static*
(``self``/``cls``, jit ``static_argnames``, partial-bound kernel
leaders, int/bool/str-annotated config scalars) — the seeds the taint
pass needs to tell traced values from trace-time constants.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

# Parameter names that are config/static by repo convention even when
# unannotated (threading ModelConfig/ExecContext/... through the stack).
STATIC_PARAM_NAMES = {
    "self", "cls", "cfg", "ctx", "mcfg", "scfg", "pcfg", "qcfg", "ccfg",
    "config", "mesh", "act", "impl", "policy", "axis", "axis_name", "name",
    "dtype", "out_dtype", "kernel_impl", "spec", "specs", "stack_meta",
    "rules",
}

# Annotations marking a parameter as a Python-static scalar.
STATIC_ANNOTATIONS = {"int", "bool", "str", "float"}

# Container/typing heads transparent for staticness: Sequence[int] is as
# static as int.  Anything else in an annotation (jax.Array, Dict[...,
# Array], a dataclass) keeps the parameter traced.
_STATIC_WRAPPERS = {"Optional", "Sequence", "Tuple", "List", "Iterable",
                    "FrozenSet", "Set", "tuple", "list", "set", "typing"}


def _annotation_static(ann: Optional[ast.AST]) -> bool:
    """True when every name in the annotation is a static scalar type or
    a transparent container/typing wrapper around one."""
    if ann is None:
        return False
    names = []
    for sub in ast.walk(ann):
        if isinstance(sub, ast.Name):
            names.append(sub.id)
        elif isinstance(sub, ast.Attribute):
            names.append(sub.attr)
    return bool(names) and all(
        n in STATIC_ANNOTATIONS or n in _STATIC_WRAPPERS for n in names)

_JIT_NAMES = {("jax", "jit"), ("jax.jit",), ("jit",)}


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.lax.scan' for Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class FunctionInfo:
    qualname: str                 # module-qualified, e.g. repro.kernels.ops.f
    module: str
    path: Path
    node: ast.AST                 # FunctionDef / AsyncFunctionDef / Lambda
    params: Tuple[str, ...]
    static_params: Set[str]
    root_kinds: Set[str] = dataclasses.field(default_factory=set)

    @property
    def lineno(self) -> int:
        return self.node.lineno


class _ModuleVisitor(ast.NodeVisitor):
    """Collect function defs (with nesting) and the import alias map."""

    def __init__(self, module: str, path: Path, index: "RepoIndex"):
        self.module = module
        self.path = path
        self.index = index
        self.stack: List[str] = []            # class / function nesting

    # -- imports -----------------------------------------------------------
    def visit_Import(self, node: ast.Import):
        for a in node.names:
            self.index.imports[self.module][a.asname or a.name] = a.name

    def visit_ImportFrom(self, node: ast.ImportFrom):
        base = node.module or ""
        if node.level:                          # resolve relative imports
            parts = self.module.split(".")
            # level 1 = current package (drop the module segment itself)
            parts = parts[: len(parts) - node.level]
            base = ".".join(parts + ([base] if base else []))
        for a in node.names:
            if a.name == "*":
                continue
            self.index.imports[self.module][a.asname or a.name] = \
                f"{base}.{a.name}" if base else a.name

    # -- defs --------------------------------------------------------------
    def _qual(self, name: str) -> str:
        return ".".join([self.module] + self.stack + [name])

    def _add_function(self, node, name: str):
        a = node.args
        params = tuple(p.arg for p in
                       list(getattr(a, "posonlyargs", [])) + a.args
                       + a.kwonlyargs)
        static = {p for p in params if p in STATIC_PARAM_NAMES}
        for p in list(getattr(a, "posonlyargs", [])) + a.args + a.kwonlyargs:
            if _annotation_static(getattr(p, "annotation", None)):
                static.add(p.arg)
        info = FunctionInfo(self._qual(name), self.module, self.path, node,
                            params, static)
        self.index.functions[info.qualname] = info
        # short names resolve most-locally: record every visible alias
        self.index.by_module.setdefault(self.module, {})
        scope_key = ".".join([self.module] + self.stack)
        self.index.local_names.setdefault(scope_key, {})[name] = info.qualname
        if not self.stack:
            self.index.by_module[self.module][name] = info.qualname
        elif len(self.stack) == 1:  # class method or 1-deep nested def
            self.index.by_module[self.module].setdefault(
                f"{self.stack[0]}.{name}", info.qualname)

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self._add_function(node, node.name)
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node: ast.ClassDef):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()


class RepoIndex:
    """Parsed view of the lint roots: functions, imports, modules."""

    def __init__(self):
        self.functions: Dict[str, FunctionInfo] = {}
        self.by_module: Dict[str, Dict[str, str]] = {}
        self.local_names: Dict[str, Dict[str, str]] = {}
        self.imports: Dict[str, Dict[str, str]] = {}
        self.trees: Dict[str, ast.Module] = {}          # module -> AST
        self.module_paths: Dict[str, Path] = {}
        self._lambda_n = 0

    # -- construction ------------------------------------------------------
    @staticmethod
    def module_name(path: Path, root: Path) -> str:
        rel = path.resolve().relative_to(root.resolve())
        parts = list(rel.with_suffix("").parts)
        if parts and parts[0] == "src":
            parts = parts[1:]
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    def add_file(self, path: Path, root: Path):
        try:
            tree = ast.parse(path.read_text(), filename=str(path))
        except SyntaxError:
            return                              # surfaced by the runner
        module = self.module_name(path, root)
        self.trees[module] = tree
        self.module_paths[module] = path
        self.imports.setdefault(module, {})
        self.by_module.setdefault(module, {})
        _ModuleVisitor(module, path, self).visit(tree)

    def add_lambda(self, node: ast.Lambda, module: str,
                   static: Set[str]) -> FunctionInfo:
        self._lambda_n += 1
        params = tuple(p.arg for p in node.args.args)
        info = FunctionInfo(f"{module}.<lambda{self._lambda_n}>", module,
                            self.module_paths[module], node, params,
                            static | {p for p in params
                                      if p in STATIC_PARAM_NAMES})
        self.functions[info.qualname] = info
        return info

    # -- resolution --------------------------------------------------------
    def resolve_call(self, func: ast.AST, caller: FunctionInfo
                     ) -> Optional[str]:
        """Resolve a call target to a known function qualname, or None."""
        if isinstance(func, ast.Name):
            return self._resolve_name(func.id, caller)
        if isinstance(func, ast.Attribute):
            base = func.value
            if isinstance(base, ast.Name):
                if base.id in ("self", "cls"):
                    # method on the (syntactically) enclosing class
                    parts = caller.qualname.split(".")
                    for cut in range(len(parts) - 1, 0, -1):
                        cand = ".".join(parts[:cut] + [func.attr])
                        if cand in self.functions:
                            return cand
                    return None
                target = self.imports.get(caller.module, {}).get(base.id)
                if target:                       # module alias: lm.forward
                    cand = f"{target}.{func.attr}"
                    if cand in self.functions:
                        return cand
                    # from-imported module object (import x.y as z)
                    return self.by_module.get(target, {}).get(func.attr) \
                        and f"{target}.{func.attr}" or None
            return None
        return None

    def _resolve_name(self, name: str, caller: FunctionInfo) -> Optional[str]:
        # innermost enclosing scope outward (nested defs shadow globals)
        parts = caller.qualname.split(".")
        for cut in range(len(parts), 0, -1):
            scope = ".".join(parts[:cut])
            hit = self.local_names.get(scope, {}).get(name)
            if hit:
                return hit
        hit = self.by_module.get(caller.module, {}).get(name)
        if hit:
            return hit
        target = self.imports.get(caller.module, {}).get(name)
        if target and target in self.functions:  # from m import f
            return target
        return None


# ---------------------------------------------------------------------------
# root discovery
# ---------------------------------------------------------------------------

def _static_argnames(call: ast.Call) -> Set[str]:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            try:
                v = ast.literal_eval(kw.value)
            except ValueError:
                return set()
            return {v} if isinstance(v, str) else set(v)
    return set()


def _is_jit(node: ast.AST) -> Tuple[bool, Set[str]]:
    """(is a jax.jit expression, static_argnames) for decorators/calls."""
    if _dotted(node) in ("jax.jit", "jit"):
        return True, set()
    if isinstance(node, ast.Call):
        head = _dotted(node.func)
        if head in ("jax.jit", "jit"):
            return True, _static_argnames(node)
        if head in ("functools.partial", "partial") and node.args:
            inner = _dotted(node.args[0])
            if inner in ("jax.jit", "jit"):
                return True, _static_argnames(node)
    return False, set()


def _callable_ref(node: ast.AST) -> Tuple[Optional[ast.AST], int]:
    """Unwrap ``functools.partial(f, a, b)`` -> (f-expr, n bound args)."""
    if isinstance(node, ast.Call) and \
            _dotted(node.func) in ("functools.partial", "partial") and \
            node.args:
        return node.args[0], len(node.args) - 1
    return node, 0


class JitScope:
    """The set of functions that run at trace time, with root metadata."""

    def __init__(self, index: RepoIndex):
        self.index = index
        self.members: Set[str] = set()
        self.roots: Dict[str, Set[str]] = {}     # qualname -> root kinds

    def __contains__(self, qualname: str) -> bool:
        return qualname in self.members

    def info(self, qualname: str) -> FunctionInfo:
        return self.index.functions[qualname]

    # -- discovery ---------------------------------------------------------
    def build(self) -> "JitScope":
        work: List[str] = []

        def add_root(qualname: Optional[str], kind: str,
                     extra_static: Optional[Set[str]] = None,
                     n_bound: int = 0):
            if qualname is None or qualname not in self.index.functions:
                return
            info = self.index.functions[qualname]
            info.root_kinds.add(kind)
            if extra_static:
                info.static_params |= extra_static
            if n_bound:
                info.static_params |= set(info.params[:n_bound])
            self.roots.setdefault(qualname, set()).add(kind)
            if qualname not in self.members:
                self.members.add(qualname)
                work.append(qualname)

        # decorator roots
        for q, info in list(self.index.functions.items()):
            for dec in getattr(info.node, "decorator_list", []):
                jit, statics = _is_jit(dec)
                if jit:
                    add_root(q, "jit", statics)

        # call-site roots: jax.jit(f), lax.scan(body,...), shard_map(body),
        # pl.pallas_call(kernel, ...)
        for module, tree in self.index.trees.items():
            owner = _ModuleOwners(self.index, module)
            for call, enclosing in owner.calls(tree):
                head = _dotted(call.func)
                if head is None:
                    continue
                tail = head.split(".")[-1]
                if tail == "jit" and head in ("jax.jit", "jit") and call.args:
                    self._root_arg(call.args[0], enclosing, "jit",
                                   _static_argnames(call), add_root)
                elif tail == "scan" and head.endswith(("lax.scan", "jax.lax.scan")) \
                        or head == "scan":
                    if call.args:
                        self._root_arg(call.args[0], enclosing, "scan",
                                       set(), add_root)
                elif tail == "shard_map":
                    fn = call.args[0] if call.args else None
                    for kw in call.keywords:
                        if kw.arg == "f":
                            fn = kw.value
                    if fn is not None:
                        self._root_arg(fn, enclosing, "shard_map", set(),
                                       add_root)
                elif tail == "pallas_call" and call.args:
                    self._root_arg(call.args[0], enclosing, "pallas",
                                   set(), add_root)

        # closure over resolvable calls + nested defs
        seen = set(work)
        while work:
            q = work.pop()
            info = self.index.functions[q]
            # nested defs only trace when referenced; still cheap to include
            for child_q, child in self.index.functions.items():
                if child_q != q and child_q.startswith(q + ".") and \
                        "." not in child_q[len(q) + 1:]:
                    if child_q not in self.members:
                        self.members.add(child_q)
                    if child_q not in seen:
                        seen.add(child_q)
                        work.append(child_q)
            for node in ast.walk(info.node):
                if not isinstance(node, ast.Call):
                    continue
                target = self.index.resolve_call(node.func, info)
                if target and target not in self.members:
                    self.members.add(target)
                    work.append(target)
        return self

    def _root_arg(self, fn_expr: ast.AST, enclosing: Optional[FunctionInfo],
                  kind: str, statics: Set[str], add_root):
        fn_expr, n_bound = _callable_ref(fn_expr)
        if isinstance(fn_expr, ast.Lambda):
            module = enclosing.module if enclosing else None
            if module is None:
                return
            info = self.index.add_lambda(fn_expr, module, statics)
            info.root_kinds.add(kind)
            self.roots.setdefault(info.qualname, set()).add(kind)
            self.members.add(info.qualname)
            # lambda bodies: add resolvable callees
            for node in ast.walk(fn_expr.body):
                if isinstance(node, ast.Call):
                    target = self.index.resolve_call(node.func, info)
                    if target and target not in self.members:
                        self.members.add(target)
                        self._extend(target)
            return
        if isinstance(fn_expr, (ast.Name, ast.Attribute)):
            caller = enclosing or _module_level_caller(self.index, kind)
            if caller is None:
                return
            target = self.index.resolve_call(fn_expr, caller) \
                if isinstance(fn_expr, ast.Attribute) else \
                self.index._resolve_name(fn_expr.id, caller)
            add_root(target, kind, statics, n_bound)

    def _extend(self, qualname: str):
        """BFS continuation for lambda callees found after the main loop."""
        work = [qualname]
        while work:
            q = work.pop()
            info = self.index.functions[q]
            for node in ast.walk(info.node):
                if isinstance(node, ast.Call):
                    t = self.index.resolve_call(node.func, info)
                    if t and t not in self.members:
                        self.members.add(t)
                        work.append(t)


class _ModuleOwners:
    """Yield (Call, enclosing FunctionInfo|None) pairs for a module tree."""

    def __init__(self, index: RepoIndex, module: str):
        self.index = index
        self.module = module

    def calls(self, tree: ast.Module):
        out = []

        def walk(node, owner_qual: List[str]):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                owner_qual = owner_qual + [node.name]
            if isinstance(node, ast.Call):
                q = ".".join([self.module] + owner_qual)
                info = None
                # innermost enclosing *function*
                while q:
                    cand = self.index.functions.get(q)
                    if cand is not None and not isinstance(cand.node,
                                                           ast.ClassDef):
                        info = cand
                        break
                    q = q.rpartition(".")[0]
                out.append((node, info))
            for child in ast.iter_child_nodes(node):
                walk(child, owner_qual)

        walk(tree, [])
        return out


def _module_level_caller(index: RepoIndex, module: str
                         ) -> Optional[FunctionInfo]:
    return None


def build_scope(index: RepoIndex) -> JitScope:
    return JitScope(index).build()
