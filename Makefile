# Repo verification targets.
#
#   make tier1   fast correctness gate (excludes @pytest.mark.slow)
#   make tier1-dist      multi-device tier: the @pytest.mark.dist tests
#                        run IN-PROCESS on 8 forced host devices
#   make test    full suite, including slow/benchmarks-adjacent tests
#   make bench-smoke     quick continuous-batching serving sweep
#   make bench-ep        expert-parallel shard-count sweep (8 host devices)
#   make bench-frontier  bandwidth-budget frontier sweep (controller)
#   make docs-check      every doc cross-reference resolves
#   make serve-example   live-decode offload + controller report

PY = PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: tier1 tier1-dist test bench-smoke bench-ep bench-frontier \
	docs-check serve-example

# dist-marked tests are excluded here only to avoid running them twice
# in CI — tier1-dist runs exactly those, in-process on 8 host devices;
# the full `make test` / `pytest -x -q` gate still covers both.
tier1:
	$(PY) -m pytest -x -q -m "not slow and not dist"

tier1-dist:
	REPRO_HOST_DEVICES=8 $(PY) -m pytest -x -q -m "dist and not slow"

test:
	$(PY) -m pytest -q

bench-smoke:
	$(PY) benchmarks/bench_serving.py --quick

bench-ep:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) benchmarks/bench_serving.py --quick --mesh ep=8

bench-frontier:
	$(PY) benchmarks/bench_serving.py --quick --frontier

docs-check:
	python tools/docs_check.py

serve-example:
	$(PY) examples/serve_offload.py
