"""Fused (custom-VJP, bf16-cotangent) chunked cross entropy vs the plain
f32 path: values exact, gradients within bf16 tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import ExecContext, init_params, lm_loss


def _cfg(tie=True):
    return ModelConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=1, head_dim=32, d_ff=128, vocab_size=256,
        block_pattern=("global",), tie_embeddings=tie, max_position=256)


def _run(mode, tie):
    os.environ["REPRO_XENT"] = mode
    try:
        cfg = _cfg(tie)
        params = init_params(jax.random.key(0), cfg, jnp.float32)
        toks = jnp.asarray(
            np.random.default_rng(0).integers(0, 256, (2, 33)), jnp.int32)
        ctx = ExecContext(mode="train")

        def loss(p):
            return lm_loss(p, {"tokens": toks}, cfg, ctx, loss_chunk=16)

        (val, _), grads = jax.value_and_grad(loss, has_aux=True)(params)
        return float(val), grads
    finally:
        os.environ.pop("REPRO_XENT", None)


def test_fused_xent_matches_plain():
    for tie in (True, False):
        v_plain, g_plain = _run("plain", tie)
        v_fused, g_fused = _run("fused", tie)
        assert abs(v_plain - v_fused) < 1e-4, (tie, v_plain, v_fused)
        gp = jax.tree.leaves(g_plain)
        gf = jax.tree.leaves(g_fused)
        for a, b in zip(gp, gf):
            denom = float(jnp.abs(a).max()) + 1e-6
            err = float(jnp.abs(a - b).max()) / denom
            assert err < 2e-2, (tie, a.shape, err)   # bf16 cotangents
