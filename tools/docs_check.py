"""Docs cross-reference checker (``make docs-check``).

Validates that the documentation graph has no dangling edges:

1. every local markdown link ``[text](target)`` in every ``*.md`` file
   resolves to an existing file (anchors stripped, URLs skipped);
2. every bare ``*.md`` path mentioned anywhere — in the docs themselves
   or in source docstrings/comments (``src/``, ``benchmarks/``,
   ``examples/``, ``tests/``, ``tools/``) — resolves against the repo
   root or the mentioning file's directory.

Generated artifacts that are legitimately referenced before they exist
(e.g. the roofline table the dry-run writes) live in ``GENERATED``.

Exit status 0 = clean; 1 = dangling references (one ``file:line`` diag
per offence).  No dependencies beyond the stdlib.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# write-targets referenced before they exist (not checked in)
GENERATED = {"experiments/roofline.md"}
SKIP_DIRS = {".git", ".github", "__pycache__", ".claude", "experiments"}

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
MD_MENTION = re.compile(r"[A-Za-z0-9_./-]*[A-Za-z0-9_-]\.md\b")


def repo_files(suffix: str):
    for p in sorted(ROOT.rglob(f"*{suffix}")):
        if not SKIP_DIRS.intersection(p.relative_to(ROOT).parts):
            yield p


def resolves(target: str, base: Path) -> bool:
    t = target.split("#", 1)[0].split("§", 1)[0].strip()
    if not t or t in GENERATED:
        return True
    return (ROOT / t).exists() or (base.parent / t).resolve().exists()


def _in_url(line: str, start: int) -> bool:
    """True when the match at ``start`` is the tail of a URL."""
    head = line[:start].split()
    return bool(head) and "://" in head[-1]


def check() -> int:
    problems = []
    for md in repo_files(".md"):
        rel = md.relative_to(ROOT)
        for i, line in enumerate(md.read_text().splitlines(), 1):
            for m in MD_LINK.finditer(line):
                target = m.group(1)
                if "://" in target or target.startswith(("#", "mailto:")):
                    continue
                if not resolves(target, md):
                    problems.append(f"{rel}:{i}: broken link -> {target}")
            for m in MD_MENTION.finditer(line):
                if _in_url(line, m.start()):
                    continue
                if not resolves(m.group(0), md):
                    problems.append(
                        f"{rel}:{i}: dangling doc reference "
                        f"-> {m.group(0)}")
    for py in repo_files(".py"):
        rel = py.relative_to(ROOT)
        for i, line in enumerate(py.read_text().splitlines(), 1):
            for m in MD_MENTION.finditer(line):
                if _in_url(line, m.start()):
                    continue
                if not resolves(m.group(0), py):
                    problems.append(
                        f"{rel}:{i}: docstring references missing doc "
                        f"-> {m.group(0)}")
    for p in problems:
        print(p)
    n_md = sum(1 for _ in repo_files(".md"))
    n_py = sum(1 for _ in repo_files(".py"))
    status = "FAILED" if problems else "ok"
    print(f"docs-check {status}: {n_md} md + {n_py} py files, "
          f"{len(problems)} dangling reference(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(check())
