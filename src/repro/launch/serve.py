"""Serving CLI: ``python -m repro.launch.serve --arch <id> [...]``.

Boots the reduced config on CPU (or full config on a real pod), randomly
initializes or restores weights, optionally applies the offline
compression pipeline, and serves a batch of synthetic requests through
the engine — reporting tokens/s and, with --offload, the metered wire
bytes per policy.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..registry import get_config
from ..models import init_params
from ..serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full_config)
    if cfg.encoder is not None or cfg.rope_kind == "mrope":
        print(f"note: {cfg.name} needs frontend inputs; serving the "
              f"text-only path")
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    eng = ServeEngine(cfg, params)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    res = eng.generate(prompts, max_new=args.max_new)
    print(f"{cfg.name}: prefill {res.prefill_s * 1e3:.0f}ms, "
          f"decode {res.decode_tokens_per_s:.1f} tok/s "
          f"({args.batch}x{args.max_new} tokens)")


if __name__ == "__main__":
    main()
