"""gemma3-1b [dense]: 26L d=1152 4H (GQA kv=1) ff=6912 vocab=262144.
5:1 local:global interleave, 128k context. [hf:google/gemma-3-1b-pt]"""
from ..config import ModelConfig, QuantConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1,
        head_dim=256, d_ff=6912, vocab_size=262_144,
        block_pattern=("local",) * 5 + ("global",),
        window_size=512,
        rope_theta=1_000_000.0, rope_local_theta=10_000.0,
        act="gelu_tanh", tie_embeddings=True, scale_embed=True,
        post_attn_norm=True,
        quant=QuantConfig(enabled=True, bits=2, rank_budget=32,
                          top_n_restore=1),
        max_position=131_072,
    )
