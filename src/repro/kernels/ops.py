"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy (``impl``):
  'auto'              pallas on TPU, ref elsewhere (CPU dry-run lowers real
                      einsum FLOPs rather than interpreter scaffolding)
  'pallas'            compiled Mosaic kernel (TPU)
  'pallas_interpret'  kernel body executed by the Pallas interpreter on CPU
                      (used by tests to validate the kernel against ref)
  'ref'               pure-jnp oracle

Wrappers pad M to the tile size and slice back, fold the compensator factor
scales into the rank-space activation, and expose QuantizedTensor /
CompressedExpertStack-level entry points.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.quantize import QuantizedTensor
from . import ref as ref_ops
from .quant_matmul import lowrank_comp_matmul_pallas, quant_matmul_pallas

_ENV = "REPRO_KERNEL_IMPL"


def default_impl() -> str:
    env = os.environ.get(_ENV)
    if env and env != "auto":           # 'auto' = platform-based selection
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


IMPLS = ("pallas", "pallas_interpret", "ref")


def resolve_impl(impl: Optional[str] = None) -> str:
    """Resolve an ``impl`` request ('auto'/None, 'pallas', 'pallas_interpret',
    'ref') to the concrete implementation that will run, honouring the
    ``REPRO_KERNEL_IMPL`` env override.  This is the single dispatch policy
    shared by the kernel wrappers below and the model-level ExpertBackend."""
    impl = impl or "auto"
    resolved = default_impl() if impl == "auto" else impl
    if resolved not in IMPLS:
        raise ValueError(
            f"unknown kernel impl {resolved!r} (from "
            f"{'$' + _ENV if impl == 'auto' else 'impl argument'}); "
            f"expected one of {('auto',) + IMPLS}")
    return resolved


_pick = resolve_impl


def _pad_m(x: jax.Array, bm: int):
    m = x.shape[0]
    pm = (-m) % bm
    if pm:
        x = jnp.pad(x, ((0, pm), (0, 0)))
    return x, m


def _tile_sizes(m: int, k: int, n: int, bm: int, bn: int, bk: int):
    """Clamp tiles to the problem and keep pack/group divisibility."""
    bm = min(bm, max(8, m))
    bk = min(bk, k)
    bn = min(bn, n)
    while k % bk:
        bk //= 2
    while n % bn:
        bn //= 2
    return bm, bn, bk


def quant_matmul(x: jax.Array, qt: QuantizedTensor, *,
                 impl: Optional[str] = None, out_dtype=None,
                 bm: int = 128, bn: int = 256, bk: int = 512) -> jax.Array:
    """y = x @ dequant(qt);  x: (M, K) -> (M, N)."""
    out_dtype = out_dtype or x.dtype
    impl = _pick(impl)
    if impl == "ref":
        return ref_ops.quant_matmul_ref(x, qt.planes, qt.scale, qt.zero,
                                        qt.bits, qt.group_size, out_dtype)
    k, n = qt.shape
    bm, bn, bk = _tile_sizes(x.shape[0], k, n, bm, bn, bk)
    xp, m = _pad_m(x, bm)
    y = quant_matmul_pallas(xp, qt.planes, qt.scale, qt.zero,
                            bits=qt.bits, group_size=qt.group_size,
                            bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                            interpret=(impl == "pallas_interpret"))
    return y[:m]


def lowrank_comp_matmul(x: jax.Array, qt: QuantizedTensor,
                        u: jax.Array, v: jax.Array,
                        u_scale: jax.Array, v_scale: jax.Array,
                        mask: Optional[jax.Array] = None, *,
                        impl: Optional[str] = None, out_dtype=None,
                        rank_cap: Optional[jax.Array] = None,
                        bm: int = 128, bn: int = 256, bk: int = 512
                        ) -> jax.Array:
    """Router-guided compensated matmul (paper §3.2).

    y = x @ dequant(qt) + ((x * mask) @ (U u_s)) diag(v_s) @ V_codes

    ``rank_cap`` (traced scalar, None = full padded rank) zeroes rank
    dims >= cap in the rank-space activation — the bandwidth controller's
    runtime rank truncation, a mask rather than a re-SVD, applied before
    the kernel so the fused Pallas path needs no shape change.
    """
    out_dtype = out_dtype or x.dtype
    impl = _pick(impl)
    if impl == "ref":
        return ref_ops.lowrank_comp_matmul_ref(
            x, qt.planes, qt.scale, qt.zero, qt.bits, qt.group_size,
            u, v, u_scale, v_scale, mask, out_dtype, rank_cap=rank_cap)
    # rank-space activation with both factor scales folded in (rank-r cost)
    xf = x.astype(jnp.float32)
    if mask is not None:
        xf = xf * mask[:, None].astype(jnp.float32)
    ud = u.astype(jnp.float32) * u_scale          # (K, R)
    xu = jnp.dot(xf, ud, preferred_element_type=jnp.float32)
    if rank_cap is not None:
        xu = xu * (jnp.arange(u.shape[-1]) < rank_cap).astype(jnp.float32)
    xu = xu * v_scale[None, :, 0]                 # fold (R,1) v_scale
    k, n = qt.shape
    bm, bn, bk = _tile_sizes(x.shape[0], k, n, bm, bn, bk)
    xp, m = _pad_m(x, bm)
    xup, _ = _pad_m(xu, bm)
    y = lowrank_comp_matmul_pallas(
        xp, qt.planes, qt.scale, qt.zero, xup, v,
        bits=qt.bits, group_size=qt.group_size, bm=bm, bn=bn, bk=bk,
        out_dtype=out_dtype, interpret=(impl == "pallas_interpret"))
    return y[:m]


def compensated_matmul_stack(x: jax.Array, stack, mask: jax.Array, *,
                             impl: Optional[str] = None, out_dtype=None,
                             rank_cap: Optional[jax.Array] = None
                             ) -> jax.Array:
    """vmap of lowrank_comp_matmul over an expert stack.

    x: (E, C, K), stack: CompressedExpertStack, mask: (E, C) -> (E, C, N).
    ``rank_cap`` (traced scalar shared by all experts of the layer) caps
    the compensator rank via the padded-factor mask.
    """
    out_dtype = out_dtype or x.dtype

    def one(xe, planes, scale, zero, u, v, us, vs, me):
        qt = QuantizedTensor(planes, scale, zero, stack.bits,
                             stack.group_size, stack.shape[1:])
        return lowrank_comp_matmul(xe, qt, u, v, us, vs, me, impl=impl,
                                   out_dtype=out_dtype, rank_cap=rank_cap)

    return jax.vmap(one)(x, stack.planes, stack.scale, stack.zero,
                         stack.u, stack.v, stack.u_scale, stack.v_scale,
                         mask)
