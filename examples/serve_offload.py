"""Serving example: continuous-batching generation with live offload
metering and the runtime bandwidth-budget controller.

Trains and compresses a tiny MoE, then drives the scheduler/chunk
serving model end to end: requests queue into a fixed pool of decode
slots, one compiled ``lax.scan`` chunk decodes all slots at once, and
between chunks the scheduler retires finished requests and refills
their slots — compiled shapes never change while traffic comes and
goes.  Because there are more requests than slots, the per-layer
``ExpertStore`` LRU + layer-ahead prefetcher are metered under genuine
multi-request contention: bytes/token (demand + compensator +
prefetch), cache hit rate, and prefetch accuracy all come from live
interleaved decode, not a replayed simulator trace.

The same workload is then re-served under a wire-byte budget: the
bandwidth controller retunes the per-layer (top_n, rank_cap)
restoration plan between chunks until the metered bytes/token meet the
budget (no recompile — the plan is traced data).  Finally the fig-7
event-driven simulator projects one request's live trace onto the
paper's GPU-only and GPU-NDP hardware profiles.

Run:  PYTHONPATH=src python examples/serve_offload.py
"""
import jax
import numpy as np

from repro.config import (ControlConfig, ModelConfig, MoEConfig, QuantConfig,
                          TrainConfig)
from repro.core.quantize import packed_nbytes
from repro.models import init_params
from repro.models.transformer import compress_moe_params
from repro.offload import (GPU_NDP, GPU_ONLY, LayerSpecSim, simulate_decode)
from repro.serve import Request, ServeEngine
from repro.train import train


def main():
    cfg = ModelConfig(
        name="serve-moe", family="moe", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=0, vocab_size=512,
        block_pattern=("global",), max_position=2048,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=256,
                      quant=QuantConfig(enabled=True, bits=2,
                                        rank_budget=32, top_n_restore=1)))
    res = train(cfg, TrainConfig(total_steps=40, lr=2e-3, warmup_steps=10,
                                 checkpoint_every=10 ** 9, loss_chunk=0),
                log_every=0, batch_shape=(8, 128))
    params = res.state.params

    # --- compress for serving (offline pipeline, DESIGN.md) --------------
    qparams, cfg_q, stacks_by_layer = compress_moe_params(params, cfg)

    # --- continuous-batching serving + live offload metering -------------
    # 6 ragged requests on 2 decode slots: the scheduler interleaves them,
    # and attach_offload meters the engine's own routing decisions (with
    # inactive slots masked) straight into the per-layer stores
    eng = ServeEngine(cfg_q, qparams, quantized=True)
    eng.attach_offload(stacks_by_layer, policy="ours", cache_capacity=2)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, 512, (int(l),), dtype=np.int32),
                    max_new=16)
            for i, l in enumerate(rng.integers(8, 25, 6))]
    stats = eng.serve(reqs, num_slots=2, chunk=4)
    lat = stats.latency_percentiles((50.0, 95.0))
    print(f"served {len(stats.results)} requests on {stats.num_slots} slots "
          f"({stats.chunks} chunks of {stats.chunk} steps, CPU emulation): "
          f"{stats.tokens_per_s:.1f} tok/s, "
          f"latency p50 {lat[50.0] * 1e3:.0f}ms p95 {lat[95.0] * 1e3:.0f}ms")

    rep = stats.offload_report
    print(f"live offload ({rep['policy']}): "
          f"{rep['bytes_per_token'] / 2**20:.2f} MiB/token "
          f"(prefetch {rep['prefetch_bytes'] / 2**20:.2f} MiB, "
          f"wasted {rep['wasted_prefetch_bytes'] / 2**20:.2f} MiB), "
          f"cache hit {rep['hit_rate']:.0%}, "
          f"prefetch accuracy {rep['prefetch_accuracy']:.0%}")
    for r in stats.results[:3]:
        print(f"  req {r.uid}: {r.prompt_len}+{r.gen_tokens} tokens, "
              f"{r.offload_bytes / max(r.gen_tokens, 1) / 2**20:.2f} "
              f"MiB/token attributed, latency {r.latency_s * 1e3:.0f}ms")

    # --- the same workload under a bandwidth budget ----------------------
    # fresh stores (comparable counters), then ask the controller for 60%
    # of the static operating point: it trims per-layer (top_n, rank_cap)
    # between scan chunks until the metered bytes/token meet the budget
    budget = 0.6 * rep["bytes_per_token"]
    eng.attach_offload(stacks_by_layer, policy="ours", cache_capacity=2)
    eng.attach_controller(ControlConfig(enabled=True, bytes_per_token=budget))
    stats_b = eng.serve(reqs, num_slots=2, chunk=4)
    hist = eng.controller.history
    tail = hist[len(hist) // 2:] or hist
    meas = float(np.mean([h.bytes_per_token for h in tail]))
    plan = eng.controller.plan().summary()
    print(f"budgeted ({budget / 2**20:.2f} MiB/token): converged tail "
          f"{meas / 2**20:.2f} MiB/token after {len(hist)} chunk updates, "
          f"plan mean top_n {plan['mean_top_n']:.2f} "
          f"rank_cap {plan['mean_rank_cap']:.1f}, "
          f"{stats_b.tokens_per_s:.1f} tok/s")

    # --- projected device throughput (paper fig-7 hardware profiles) -----
    # feed the simulator the LIVE decode trace of one scheduled request
    trace = stats.results[0].trace                    # (steps, layers, k)
    d, fe, e = 4096, 14336, 8   # Mixtral-8x7B expert dims
    spec = LayerSpecSim(
        d, fe, e, 2,
        bytes_fp16=3 * d * fe * 2,
        bytes_quant=3 * (packed_nbytes(2, d, fe) + (d // 64) * fe * 4),
        comp_bytes=[32 * (d + fe)] * e)
    big_trace = np.tile(trace % e, (8, 16, 1))[:64, :32, :]
    for prof, policy in ((GPU_ONLY, "fp16"), (GPU_ONLY, "ours"),
                         (GPU_NDP, "ours_ndp")):
        r = simulate_decode(big_trace, spec, prof, policy, top_n=1,
                            num_layers=32)
        print(f"  {prof.name:16s} {policy:9s} {r.tokens_per_s:8.2f} tok/s  "
              f"{r.transfer_bytes_per_token / 2**20:7.1f} MiB/tok")


if __name__ == "__main__":
    main()
