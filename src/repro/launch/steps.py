"""Step-function builders: train / prefill / serve, with shardings.

Everything the launcher (and the dry-run) lowers comes from here, so real
training, serving, and the AOT dry-run share one code path.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..config import (ModelConfig, ParallelConfig, QuantConfig, ShapeConfig,
                      TrainConfig)
from ..core.quantize import PLANES, packed_rows
from ..core.pipeline import CompressedExpertStack
from ..distributed.moe_parallel import make_moe_ep_fn
from ..distributed.sharding import (CACHE_RULES, PARAM_RULES, constraint_fn,
                                    mesh_spec, tree_shardings)
from ..models import model as lm
from ..models.transformer import ExecContext, derive_plan, init_caches, \
    init_params
from ..optim.adamw import OptState, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: OptState


# ---------------------------------------------------------------------------
# abstract quantized parameters (serving with the paper's technique)
# ---------------------------------------------------------------------------

def make_abstract_stack(prefix: Tuple[int, ...], e: int, k: int, n: int,
                        qcfg: QuantConfig) -> CompressedExpertStack:
    g = qcfg.group_size
    r = max(qcfg.rank_budget, 1)
    planes = tuple(jnp.zeros(prefix + (e, packed_rows(p, k), n), jnp.uint8)
                   for p, _ in PLANES[qcfg.bits])
    f_dt = jnp.bfloat16 if qcfg.factor_bits >= 16 else jnp.int8
    s_dt = jnp.bfloat16 if qcfg.scale_dtype == "bf16" else jnp.float32
    return CompressedExpertStack(
        planes=planes,
        scale=jnp.zeros(prefix + (e, k // g, n), s_dt),
        zero=jnp.zeros(prefix + (e, k // g, n), s_dt),
        u=jnp.zeros(prefix + (e, k, r), f_dt),
        v=jnp.zeros(prefix + (e, r, n), f_dt),
        u_scale=jnp.zeros(prefix + (e, 1, r), jnp.float32),
        v_scale=jnp.zeros(prefix + (e, r, 1), jnp.float32),
        bits=qcfg.bits, group_size=g, shape=(e, k, n),
        ranks=(r,) * e, pad_rank=r, factor_bits=qcfg.factor_bits)


def quantize_params_structure(params, cfg: ModelConfig):
    """Swap raw FFN/expert weights for compressed-stack placeholders
    (shape-true; used under eval_shape for the dry-run and by the offline
    pipeline as the target structure)."""
    plan = derive_plan(cfg)
    new_segs = []
    for si, seg in enumerate(plan):
        pos_params = []
        for pi, spec in enumerate(seg.layers):
            p = dict(params["segments"][si][pi])
            if spec.ffn == "moe" and cfg.moe.quant.enabled:
                mp = dict(p["moe"])
                qc = cfg.moe.quant
                e, fe = cfg.moe.num_experts, cfg.moe.d_expert
                prefix = tuple(mp["w1"].shape[:-3])
                mp["stacks"] = {
                    "w1": make_abstract_stack(prefix, e, cfg.d_model, fe, qc),
                    "w3": make_abstract_stack(prefix, e, cfg.d_model, fe, qc),
                    "w2": make_abstract_stack(prefix, e, fe, cfg.d_model, qc),
                }
                for k in ("w1", "w2", "w3"):
                    mp.pop(k, None)
                p["moe"] = mp
            elif spec.ffn == "dense" and cfg.quant.enabled and cfg.d_ff:
                qc = cfg.quant
                prefix = tuple(p["ffn"]["w1"].shape[:-2])
                stacks = {
                    "w1": make_abstract_stack(prefix, 1, cfg.d_model,
                                              cfg.d_ff, qc),
                    "w2": make_abstract_stack(prefix, 1, cfg.d_ff,
                                              cfg.d_model, qc),
                }
                if cfg.gated_ffn:
                    stacks["w3"] = make_abstract_stack(prefix, 1, cfg.d_model,
                                                       cfg.d_ff, qc)
                p["ffn"] = {"stacks": stacks}
            pos_params.append(p)
        new_segs.append(tuple(pos_params))
    out = dict(params)
    out["segments"] = tuple(new_segs)
    return out


def abstract_serve_params(cfg: ModelConfig, quantized: bool,
                          dtype=jnp.bfloat16):
    def build(key):
        params = init_params(key, cfg, dtype)
        return quantize_params_structure(params, cfg) if quantized else params

    return jax.eval_shape(build, jax.random.key(0))


# ---------------------------------------------------------------------------
# contexts & parallel config
# ---------------------------------------------------------------------------

def parallel_for_shape(shape: ShapeConfig,
                       base: Optional[ParallelConfig] = None,
                       cfg: Optional[ModelConfig] = None,
                       model_axis: int = 16) -> ParallelConfig:
    pcfg = base or ParallelConfig()
    rules = dict(pcfg.rules)
    rules["batch"] = ("pod", "data")
    # KV sequence mops up whatever batch left over (long_500k: everything).
    # When the arch's kv_heads already divide the model axis, leave model to
    # the heads (avoids partial-softmax all-reduces); otherwise the seq dim
    # takes it (gemma3-1b kv=1, qwen kv=4).
    if cfg is not None and cfg.num_kv_heads % model_axis == 0:
        rules["kv_seq"] = ("pod", "data")
    else:
        rules["kv_seq"] = ("pod", "data", "model")
    rules["seq"] = ()
    if shape.kind == "train":
        # FSDP over the data axis on top of TP/EP over model: weights and
        # optimizer state shard both ways (ZeRO-3-style); GSPMD inserts the
        # per-layer all-gathers inside the scan.
        rules["embed"] = ("data",)
        rules["expert_mlp"] = ("data",)
        rules["lowrank"] = ("data",)
    return dataclasses.replace(pcfg, rules=tuple(rules.items()))


def make_context(cfg: ModelConfig, mode: str, *, quantized: bool = False,
                 mesh: Optional[Mesh] = None,
                 pcfg: Optional[ParallelConfig] = None,
                 remat: bool = False, exact_capacity: bool = False,
                 scan_unroll: bool = False,
                 remat_policy: str = "full",
                 kernel_impl: Optional[str] = None,
                 collect_trace: bool = False,
                 collect_moe_inputs: bool = False) -> ExecContext:
    pcfg = pcfg or ParallelConfig()
    ep_mode = "none"
    moe_fn = None
    if mesh is not None and cfg.moe is not None:
        ep_mode = "replicated" if mode == "step" else "a2a"
        moe_fn = make_moe_ep_fn(mesh, pcfg)
    heads_ok = seq_ok = False
    if mesh is not None and "model" in mesh.shape:
        mp = mesh.shape["model"]
        heads_ok = cfg.num_heads % mp == 0 and cfg.num_kv_heads % mp == 0
        seq_ok = not heads_ok
    return ExecContext(mode=mode, quantized=quantized, ep_mode=ep_mode,
                       mesh=mesh, constrain=constraint_fn(mesh, pcfg),
                       moe_ep_fn=moe_fn, remat=remat,
                       exact_capacity=exact_capacity,
                       scan_unroll=scan_unroll,
                       remat_policy=remat_policy,
                       attn_heads_sharded=heads_ok,
                       attn_seq_sharded=seq_ok,
                       kernel_impl=kernel_impl,
                       collect_trace=collect_trace,
                       collect_moe_inputs=collect_moe_inputs)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    mesh: Optional[Mesh] = None,
                    pcfg: Optional[ParallelConfig] = None,
                    param_dtype=jnp.bfloat16, scan_unroll: bool = False,
                    remat_policy: str = "full"):
    ctx = make_context(cfg, "train", mesh=mesh, pcfg=pcfg, remat=True,
                       scan_unroll=scan_unroll, remat_policy=remat_policy)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        def loss_fn(p):
            return lm.lm_loss(p, batch, cfg, ctx, z_loss=tcfg.z_loss,
                              loss_chunk=tcfg.loss_chunk)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        params, opt, om = adamw_update(grads, state.opt, tcfg, param_dtype)
        return TrainState(params, opt), {**metrics, **om}

    return train_step, ctx


def make_prefill_step(cfg: ModelConfig, *, quantized: bool = False,
                      mesh: Optional[Mesh] = None,
                      pcfg: Optional[ParallelConfig] = None,
                      scan_unroll: bool = False):
    ctx = make_context(cfg, "prefill", quantized=quantized, mesh=mesh,
                       pcfg=pcfg, scan_unroll=scan_unroll)

    def prefill_step(params, caches, batch):
        out = lm.forward(params, batch["tokens"], cfg, ctx, caches=caches,
                         mrope_pos=batch.get("mrope_pos"),
                         enc_embeds=batch.get("enc_embeds"))
        return out.logits[:, -1], out.caches

    return prefill_step, ctx


def make_serve_step(cfg: ModelConfig, *, quantized: bool = False,
                    mesh: Optional[Mesh] = None,
                    pcfg: Optional[ParallelConfig] = None,
                    scan_unroll: bool = False):
    ctx = make_context(cfg, "step", quantized=quantized, mesh=mesh, pcfg=pcfg,
                       scan_unroll=scan_unroll)

    def serve_step(params, caches, batch):
        out = lm.decode_step(params, batch["tokens"], caches, cfg, ctx,
                             mrope_pos=batch.get("mrope_pos"))
        return out.logits[:, 0], out.caches

    return serve_step, ctx


# ---------------------------------------------------------------------------
# abstract inputs + shardings per (arch, shape) cell
# ---------------------------------------------------------------------------

def cell_abstract(cfg: ModelConfig, shape: ShapeConfig, *,
                  quantized: bool = False, tcfg: Optional[TrainConfig] = None,
                  param_dtype=jnp.bfloat16):
    """(abstract args tree, step builder kwargs) for one dry-run cell."""
    specs = lm.input_specs(cfg, shape)
    if shape.kind == "train":
        params = jax.eval_shape(lambda k: init_params(k, cfg, param_dtype),
                                jax.random.key(0))
        opt = jax.eval_shape(adamw_init, params)
        return {"state": TrainState(params, opt), "batch": specs["batch"]}
    params = abstract_serve_params(cfg, quantized, param_dtype)
    max_len = shape.seq_len
    caches = jax.eval_shape(
        lambda: init_caches(cfg, shape.global_batch, max_len, jnp.bfloat16))
    return {"params": params, "caches": caches, "batch": specs["batch"]}


def cell_shardings(mesh: Mesh, abstract: Dict, pcfg: ParallelConfig):
    """NamedSharding tree matching cell_abstract output."""
    out = {}
    for k, v in abstract.items():
        if k == "batch":
            def batch_shard(path, leaf):
                name = str(path[-1].key) if hasattr(path[-1], "key") else ""
                if name == "mrope_pos":
                    logical = (None, "batch") + (None,) * (leaf.ndim - 2)
                else:
                    logical = ("batch",) + (None,) * (leaf.ndim - 1)
                return NamedSharding(
                    mesh, mesh_spec(mesh, logical, leaf.shape, pcfg))
            out[k] = jax.tree_util.tree_map_with_path(batch_shard, v)
        elif k == "caches":
            out[k] = tree_shardings(mesh, v, pcfg, CACHE_RULES + PARAM_RULES)
        else:
            out[k] = tree_shardings(mesh, v, pcfg, PARAM_RULES)
    return out
