"""Shared primitive layers: norms, embeddings, rotary variants, inits."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x


# ---------------------------------------------------------------------------
# Rotary embeddings (default, gemma dual-theta, M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd), positions: (B, S) absolute positions."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                           # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, ...] = (16, 24, 24)) -> jax.Array:
    """Qwen2-VL multimodal rotary: positions3 (3, B, S) = (t, h, w) ids.

    head_dim/2 frequency slots are partitioned into ``sections`` (t,h,w);
    each section rotates by its own position stream.  Text tokens carry
    t == h == w so this degrades exactly to 1-D RoPE for pure text.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                           # (hd/2,)
    sec = jnp.cumsum(jnp.asarray((0,) + sections))
    slot = jnp.arange(hd // 2)
    which = jnp.searchsorted(sec[1:], slot, side="right")   # (hd/2,) in {0,1,2}
    pos = positions3[which]                                 # (hd/2, B, S)
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal table (length, dim)."""
    log_timescale = math.log(10_000.0) / (dim // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(dim // 2, dtype=jnp.float32))
    ang = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size: Optional[int] = None,
               dtype=jnp.float32) -> jax.Array:
    """Truncated-normal fan-in init (std = 1/sqrt(fan_in))."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
