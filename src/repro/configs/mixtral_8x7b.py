"""Mixtral-8x7B (paper reference model, Table 1): 32L hidden (4096,14336),
8 experts top-2.  Paper setting: R_avg=32, top-n=1, INT2/INT3 + HQQ."""
from ..config import ModelConfig, MoEConfig, QuantConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=0, vocab_size=32_000,
        block_pattern=("global",),
        rope_theta=1_000_000.0, act="silu", tie_embeddings=False,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=14336,
                      router_norm_topk=True,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=32,
                                        top_n_restore=1)),
        max_position=32_768,
    )
