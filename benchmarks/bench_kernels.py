"""Kernel microbenchmarks (CPU timing is indicative only; the TPU story is
the packed-byte traffic, reported as `derived`).

For each bit width: quant_matmul wire bytes vs fp16, and the fused
low-rank epilogue's marginal cost at the paper's rank budgets.

``run_fused`` benchmarks the tentpole fused decode kernel against the
unfused op-sequence at decode shapes: HBM bytes from ``cost_analysis``
of the compiled unfused XLA graph vs the tile-aware analytic bound of
the single fused ``pallas_call`` (``launch/roofline.py::
fused_hbm_bytes``), plus wall-clock timing — the fused side is only
timed where the Mosaic kernel actually compiles (TPU); on CPU the row
carries the byte reduction, which is device-independent.  Rows append
to the BENCH_serving.json trajectory (mode ``kernels``) so
``tools/bench_check.py`` gates the reduction like any serving metric.

Run:  PYTHONPATH=src python -m benchmarks.bench_kernels [--quick]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import QuantConfig
from repro.core import quantize
from repro.core.pipeline import compress_expert_stack
from repro.core.quantize import packed_nbytes
from repro.kernels import ops
from repro.kernels.autotune import choose_tiles
from repro.launch.roofline import fused_hbm_bytes

from .common import timed


def run(quick: bool = True):
    rows = []
    rng = np.random.default_rng(0)
    m, k, n = (64, 1024, 1024) if quick else (256, 4096, 4096)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    fp16_bytes = k * n * 2
    for bits in (2, 3, 4, 8):
        qt = quantize(w, bits, 64)
        us = timed(lambda: ops.quant_matmul(x, qt, impl="ref"))
        wire = packed_nbytes(bits, k, n) + (k // 64) * n * 4
        rows.append({"name": f"kernel/quant_matmul_int{bits}",
                     "us_per_call": us,
                     "derived": f"wire_reduction={fp16_bytes / wire:.2f}x"})
    qt = quantize(w, 2, 64)
    for rank in (16, 32, 128):
        u = jnp.asarray(rng.integers(-127, 127, (k, rank)).astype(np.int8))
        v = jnp.asarray(rng.integers(-127, 127, (rank, n)).astype(np.int8))
        us_ = jnp.ones((1, rank), jnp.float32) * 0.01
        vs_ = jnp.ones((rank, 1), jnp.float32) * 0.01
        mask = jnp.ones((m,), jnp.float32)
        us = timed(lambda: ops.lowrank_comp_matmul(
            x, qt, u, v, us_, vs_, mask, impl="ref"))
        extra = rank * (k + n)
        rows.append({"name": f"kernel/lowrank_fused_r{rank}",
                     "us_per_call": us,
                     "derived": f"comp_bytes_pct="
                                f"{100 * extra / (packed_nbytes(2, k, n)):.1f}%"})
    return rows


# ---------------------------------------------------------------------------
# fused decode kernel vs the unfused op-sequence (tentpole comparison)
# ---------------------------------------------------------------------------

def _cost_bytes(jitted, *args) -> float:
    ca = jitted.lower(*args).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float((ca or {}).get("bytes accessed", 0.0))


def run_fused(quick: bool = True):
    """Decode-shape comparison of the single fused ``pallas_call`` against
    the unfused XLA op-sequence (dequant matmul -> compensator GEMM ->
    add -> gate multiply), per bit width.

    HBM bytes: ``cost_analysis`` of the compiled unfused graph (which
    round-trips the dequantized weights and every intermediate) vs the
    tile-aware analytic bound of the fused kernel.  Timing: the unfused
    sequence times everywhere; the fused Mosaic kernel only on TPU (the
    interpreter's wall-clock is not the kernel's).
    """
    rows = []
    rng = np.random.default_rng(0)
    e, c = (4, 8) if quick else (8, 8)                # decode block: C ~ 8
    k, n = (512, 1024) if quick else (4096, 14336)
    on_tpu = jax.default_backend() == "tpu"
    for bits in (2, 4):
        qcfg = QuantConfig(enabled=True, bits=bits, group_size=64,
                           rank_budget=16, top_n_restore=1, hqq_iters=2)
        w = jnp.asarray(rng.standard_normal((e, k, n)), jnp.float32) * 0.05
        stack, _ = compress_expert_stack(w, qcfg)
        xe = jnp.asarray(rng.standard_normal((e, c, k)), jnp.float32)
        me = jnp.ones((e, c), jnp.float32)
        ge = jnp.asarray(rng.random((e, c)), jnp.float32)

        def unfused(xe, ge):
            # today's op-sequence: dequant+comp matmul stack, then the
            # gate-weighted combine as a separate elementwise pass
            ye = ops.compensated_matmul_stack(xe, stack, me, impl="ref",
                                              out_dtype=jnp.float32)
            return ye * ge[..., None]

        def fused(xe, ge):
            return ops.fused_expert_matmul(
                xe, stack, me, gates=ge,
                impl="pallas" if on_tpu else "ref",
                out_dtype=jnp.float32)

        juf = jax.jit(unfused)
        unfused_b = _cost_bytes(juf, xe, ge)
        bm, bn, bk = choose_tiles("fused", bits=stack.bits,
                                  group_size=stack.group_size,
                                  rank=stack.pad_rank, m=c, k=k, n=n)
        fused_b = fused_hbm_bytes(e, c, k, n, stack.bits, stack.group_size,
                                  stack.pad_rank, bm, bn, bk)
        row = {"name": f"kernel/fused_decode_b{bits}",
               "unfused_hbm_mb": unfused_b / 2 ** 20,
               "fused_hbm_mb": fused_b / 2 ** 20,
               "hbm_reduction_x": unfused_b / max(fused_b, 1.0),
               "tiles": f"{bm}x{bn}x{bk}",
               "us_unfused": timed(lambda: juf(xe, ge))}
        if on_tpu:
            jf = jax.jit(fused)
            row["us_fused"] = timed(lambda: jf(xe, ge))
            row["speedup_x"] = row["us_unfused"] / max(row["us_fused"], 1e-9)
        rows.append(row)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-snapshot", action="store_true",
                    help="skip appending the fused rows to the "
                         "BENCH_serving.json trajectory")
    args = ap.parse_args()
    for r in run(quick=args.quick):
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    fused_rows = run_fused(quick=args.quick)
    for r in fused_rows:
        extra = ",".join(f"{k}={v:.3f}" if isinstance(v, float)
                         else f"{k}={v}" for k, v in r.items()
                         if k != "name")
        print(f"{r['name']},{extra}", flush=True)
    if not args.no_snapshot:
        from .bench_serving import write_snapshot
        write_snapshot("kernels", fused_rows, args.quick,
                       meta={"backend": jax.default_backend()})


if __name__ == "__main__":
    main()
