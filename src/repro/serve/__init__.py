"""Serving: batched engine (prefill + decode), continuous-batching request
scheduler, runtime bandwidth-budget controller, speculative decoding,
sampling, router-trace export."""
from .controller import (BandwidthController, ControllerPlan,
                         ControllerRecord, static_plan)
from .engine import (GenerationResult, ServeEngine, ServeStats, bucket_len,
                     router_trace, sample)
from .paging import PagePool, PoolStats, prefix_page_hashes
from .scheduler import Request, RequestResult, Scheduler, synthetic_workload
from .speculative import (DraftModelDrafter, NGramDrafter, accept_drafts,
                          make_drafter, mask_banned)
