"""BEAM-LRC: Bandwidth-Efficient Adaptive MoE via Low-Rank Compensation.

A production-grade JAX training/inference framework reproducing and
extending the paper's router-guided precision-restoration technique.
"""
__version__ = "0.1.0"
