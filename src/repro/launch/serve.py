"""Serving CLI: ``python -m repro.launch.serve --arch <id> [...]``.

Boots the reduced config on CPU (or full config on a real pod), randomly
initializes or restores weights, and serves synthetic traffic through
the engine:

- default: one fixed batch (``--batch`` x ``--prompt-len``), reporting
  prefill latency and decode tokens/s;
- ``--requests N``: a continuous-batching workload of N ragged-length
  requests (optionally arriving at ``--rate`` req/s) scheduled onto
  ``--slots`` decode slots in ``--chunk``-step scan chunks, reporting
  throughput and p50/p95 request latency.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..registry import get_config
from ..models import init_params
from ..serve import ServeEngine, synthetic_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--requests", type=int, default=0,
                    help="serve N scheduled requests instead of one batch")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="offered load in requests/s (0 = all at t=0)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full_config)
    if cfg.encoder is not None or cfg.rope_kind == "mrope":
        print(f"note: {cfg.name} needs frontend inputs; serving the "
              f"text-only path")
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    eng = ServeEngine(cfg, params)

    if args.requests > 0:
        reqs = synthetic_workload(
            args.requests, cfg.vocab_size, rate=args.rate,
            max_new=args.max_new, min_len=max(args.prompt_len // 2, 1),
            max_len=args.prompt_len, seed=args.seed)
        stats = eng.serve(reqs, num_slots=args.slots, chunk=args.chunk,
                          seed=args.seed)
        lat = stats.latency_percentiles((50.0, 95.0))
        print(f"{cfg.name}: {args.requests} requests on {args.slots} slots "
              f"(chunk {args.chunk}, rate "
              f"{args.rate if args.rate > 0 else 'closed-loop'}): "
              f"{stats.tokens_per_s:.1f} tok/s, "
              f"latency p50 {lat[50.0] * 1e3:.0f}ms "
              f"p95 {lat[95.0] * 1e3:.0f}ms, "
              f"{stats.chunks} chunks, compiles {eng.num_compiles}")
        return

    prompts = np.random.default_rng(args.seed).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    res = eng.generate(prompts, max_new=args.max_new)
    print(f"{cfg.name}: prefill {res.prefill_s * 1e3:.0f}ms, "
          f"decode {res.decode_tokens_per_s:.1f} tok/s "
          f"({args.batch}x{args.max_new} tokens)")


if __name__ == "__main__":
    main()
