"""RL2xx canonical wire-byte accounting rules.

The bandwidth-accuracy claims of the paper reproduction hinge on ONE
pair of formulas — ``core/quantize.py::quant_wire_bytes`` /
``factor_wire_bytes`` — backing compression, offload metering, the
bandwidth controller, and the serialized artifacts alike (PR 5
consolidated them; these rules keep them consolidated).

RL201 handrolled-wire-bytes   arithmetic deriving bytes from a bit-width
                              or rank outside ``core/quantize.py``:
                              either dividing a bits-bearing expression
                              by 8, or the ``8 // plane_width``
                              values-per-byte idiom.  Kernel modules
                              (``kernels/quant_matmul.py``,
                              ``kernels/ref.py``) are exempt for the
                              latter only — they implement the packed
                              *layout*, not byte *accounting*.
RL202 scale-wire-bytes        referencing ``SCALE_WIRE_BYTES`` outside
                              ``core/quantize.py`` — scale/zero wire
                              cost is an implementation detail of the
                              canonical formulas; composing with it
                              elsewhere re-derives what
                              ``quant_wire_bytes`` already owns.
"""
from __future__ import annotations

import ast
from typing import List

from .core import Finding, rule
from .jitscope import _dotted

# the module that owns byte accounting, and the modules allowed the
# values-per-byte layout idiom (they implement pack/unpack itself)
CANONICAL = ("core/quantize.py",)
LAYOUT_OK = ("kernels/quant_matmul.py", "kernels/ref.py")

BITS_NAMES = {"bits", "factor_bits", "nbits", "bitwidth", "bit_width",
              "store_bits"}


def _mentions_bits(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in BITS_NAMES:
            return True
        if isinstance(sub, ast.Attribute) and sub.attr in BITS_NAMES:
            return True
    return False


def _path_matches(path: str, suffixes) -> bool:
    norm = path.replace("\\", "/")
    return any(norm.endswith(s) for s in suffixes)


@rule("RL201", "hand-rolled bits/rank -> bytes arithmetic outside "
               "core/quantize.py")
def rl201(scope, ctx) -> List[Finding]:
    out = []
    for module, tree in ctx.index.trees.items():
        path = str(ctx.index.module_paths[module])
        if _path_matches(path, CANONICAL):
            continue
        layout_ok = _path_matches(path, LAYOUT_OK)
        for node in ast.walk(tree):
            if not isinstance(node, ast.BinOp) or \
                    not isinstance(node.op, (ast.Div, ast.FloorDiv)):
                continue
            # <expr-with-bits> // 8 : a wire-byte formula re-derivation
            if isinstance(node.right, ast.Constant) and \
                    node.right.value == 8 and _mentions_bits(node.left):
                out.append(ctx.finding_at(
                    "RL201", ctx.index.module_paths[module], node,
                    "bits-to-bytes arithmetic outside core/quantize.py; "
                    "use quant_wire_bytes/factor_wire_bytes/packed_nbytes "
                    "so metering and compression cannot drift"))
                continue
            # 8 // p : the values-per-byte packing idiom (layout modules
            # implement it; everyone else must call the canonical helpers)
            if not layout_ok and isinstance(node.left, ast.Constant) and \
                    node.left.value == 8 and \
                    isinstance(node.op, ast.FloorDiv):
                out.append(ctx.finding_at(
                    "RL201", ctx.index.module_paths[module], node,
                    "`8 // plane_width` packed-layout arithmetic outside "
                    "the kernel layout modules; byte counts must come "
                    "from core/quantize.py (packed_nbytes / "
                    "quant_wire_bytes)"))
    return out


@rule("RL202", "SCALE_WIRE_BYTES referenced outside core/quantize.py")
def rl202(scope, ctx) -> List[Finding]:
    out = []
    for module, tree in ctx.index.trees.items():
        path = str(ctx.index.module_paths[module])
        if _path_matches(path, CANONICAL):
            continue
        for node in ast.walk(tree):
            name = None
            if isinstance(node, ast.Name) and node.id == "SCALE_WIRE_BYTES":
                name = node.id
            elif isinstance(node, ast.Attribute) and \
                    node.attr == "SCALE_WIRE_BYTES":
                name = node.attr
            elif isinstance(node, ast.ImportFrom) and \
                    any(a.name == "SCALE_WIRE_BYTES" for a in node.names):
                name = "SCALE_WIRE_BYTES"
            if name:
                out.append(ctx.finding_at(
                    "RL202", ctx.index.module_paths[module], node,
                    "scale/zero wire cost is owned by quant_wire_bytes/"
                    "factor_wire_bytes; composing with SCALE_WIRE_BYTES "
                    "elsewhere re-derives canonical accounting"))
    return out
