"""Offloading emulation: expert store, LRU cache, bandwidth cost models,
layer-ahead prefetch, and the fig-7 event-driven throughput simulator."""
from .bandwidth import GPU_NDP, GPU_ONLY, TPU_V5E_OFFLOAD, HardwareProfile
from .cache import *  # noqa
from .prefetch import LayerAheadPrefetcher, PrefetchStats
from .simulator import LayerSpecSim, SimResult, make_router_trace, simulate_decode
from .store import (ExpertCache, ExpertStore, FetchStats,
                    ShardedExpertStore, make_expert_stores,
                    meter_decode_trace, offload_report, replay_decode_trace,
                    snapshot_offload)
