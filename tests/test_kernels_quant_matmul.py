"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hqq_quantize, quantize
from repro.kernels import ops


def _mats(rng, m, k, n, dtype):
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32), dtype)
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    return x, w


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (96, 512, 384),
                                   (33, 256, 128)])
def test_quant_matmul_matches_ref(bits, m, k, n):
    rng = np.random.default_rng(bits * 1000 + m)
    x, w = _mats(rng, m, k, n, jnp.float32)
    qt = quantize(w, bits, 64)
    y_ref = ops.quant_matmul(x, qt, impl="ref")
    y_pl = ops.quant_matmul(x, qt, impl="pallas_interpret",
                            bm=32, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_dtypes(dtype):
    rng = np.random.default_rng(7)
    x, w = _mats(rng, 64, 256, 256, dtype)
    qt = hqq_quantize(w, 4, 64, iters=5)
    y_ref = ops.quant_matmul(x, qt, impl="ref", out_dtype=jnp.float32)
    y_pl = ops.quant_matmul(x, qt, impl="pallas_interpret",
                            out_dtype=jnp.float32, bm=32, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=5e-3, atol=5e-2)


@pytest.mark.parametrize("bits", [2, 3])
@pytest.mark.parametrize("rank", [8, 32, 96])
def test_lowrank_fused_matches_ref(bits, rank):
    rng = np.random.default_rng(rank)
    m, k, n = 64, 384, 256
    x, w = _mats(rng, m, k, n, jnp.float32)
    qt = quantize(w, bits, 64)
    u = jnp.asarray(rng.integers(-127, 127, (k, rank)).astype(np.int8))
    v = jnp.asarray(rng.integers(-127, 127, (rank, n)).astype(np.int8))
    us = jnp.asarray(rng.random((1, rank)).astype(np.float32) * 0.01)
    vs = jnp.asarray(rng.random((rank, 1)).astype(np.float32) * 0.01)
    mask = jnp.asarray((rng.random(m) < 0.5).astype(np.float32))
    y_ref = ops.lowrank_comp_matmul(x, qt, u, v, us, vs, mask, impl="ref")
    y_pl = ops.lowrank_comp_matmul(x, qt, u, v, us, vs, mask,
                                   impl="pallas_interpret",
                                   bm=32, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


def test_mask_semantics_match_dense_reconstruction():
    """Masked low-rank == reconstructing W_hat for selected tokens only."""
    rng = np.random.default_rng(0)
    m, k, n, r = 16, 128, 128, 16
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    qt = quantize(w, 2, 64)
    from repro.core import dequantize
    u = jnp.asarray(rng.standard_normal((k, r)).astype(np.float32) * 0.05)
    v = jnp.asarray(rng.standard_normal((r, n)).astype(np.float32) * 0.05)
    ones_s = jnp.ones((1, r), jnp.float32), jnp.ones((r, 1), jnp.float32)
    mask = jnp.asarray(([1.0] * 7 + [0.0] * 9), jnp.float32)
    y = ops.lowrank_comp_matmul(x, qt, u, v, *ones_s, mask, impl="ref")
    w_deq = dequantize(qt)
    w_hat = w_deq + u @ v
    expect = jnp.where(mask[:, None] > 0, x @ w_hat, x @ w_deq)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               rtol=1e-5, atol=1e-4)


def test_pallas_padding_path():
    """M not divisible by bm exercises the pad/slice wrapper."""
    rng = np.random.default_rng(3)
    x, w = _mats(rng, 50, 256, 128, jnp.float32)
    qt = quantize(w, 4, 64)
    y_ref = ops.quant_matmul(x, qt, impl="ref")
    y_pl = ops.quant_matmul(x, qt, impl="pallas_interpret", bm=32)
    assert y_pl.shape == (50, 128)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)
