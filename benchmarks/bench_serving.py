"""Continuous-batching serving benchmark: offered-load + frontier sweeps.

Default mode drives the ``ServeEngine.serve`` scheduler with Poisson
request arrivals at increasing offered loads and reports, per rate:

- decode throughput (accepted tokens/s over the whole run) AND goodput
  (tokens per busy second + the busy fraction): under open-loop
  arrivals the wall-clock number folds idle inter-arrival time into the
  denominator, so only goodput compares engine capacity across rates,
- request latency p50 / p95 (wall-clock, arrival -> completion),
- live offload wire bytes/token from the metered per-layer expert stores
  (demand + compensator + prefetch after the ride-the-cache accounting
  fixes), plus the mean per-request attributed bytes/token.

``--frontier`` sweeps the *bandwidth-accuracy frontier* instead: the
runtime budget controller (serve/controller.py) serves the same workload
under a range of bytes/token budgets and each row reports the measured
bytes/token against its target, tokens/s, the converged per-layer
(top_n, rank_cap) plan, a weight-space restoration-error proxy, and the
event-driven simulator's projection of the same adaptive policy onto the
paper's GPU-only and GPU-NDP hardware profiles (convergence within 10%
of the budget is the acceptance bar on both).

The traffic is genuinely interleaved: ragged prompt lengths, more
requests than slots, slots refilled from the queue between scan chunks —
the expert-cache hit rates reflect multi-request contention, not one
fixed batch.  Self-contained (tiny randomly-initialized MoE, cheap
compression) so ``make bench-smoke`` stays fast.

``--stream`` serves the same workload through the async expert-streaming
engine (offload/staging.py) under eviction pressure and reports the
compute/transfer overlap efficiency next to the metered-bytes oracle
(observed ring-copy bytes == metered wire bytes, asserted), with the
streamed decode checked token-identical to the resident baseline.

``--paged`` serves the same ragged workload against the bucketed-
contiguous cache, the paged cache, and the paged cache with shared-
prefix reuse, reporting cache HBM bytes/token (gated 'down') and the
prefix hit rate — with token identity between all three asserted.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py --quick
      PYTHONPATH=src python benchmarks/bench_serving.py --quick --frontier
      PYTHONPATH=src python benchmarks/bench_serving.py --quick --stream
      PYTHONPATH=src python benchmarks/bench_serving.py --quick --paged
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ControlConfig, ModelConfig, MoEConfig, QuantConfig
from repro.models import init_params
from repro.models.transformer import compress_moe_params, unstack_params
from repro.offload import GPU_NDP, GPU_ONLY, LayerSpecSim, simulate_decode
from repro.serve import ServeEngine, synthetic_workload


def _engine(offload: bool = True, keep_weights: bool = False,
            ep: int = 1, cache_capacity: int = 3,
            impl: Optional[str] = None):
    """Tiny compressed-MoE serve engine (optionally with the original
    expert weights retained for restoration-error reporting; ``ep`` > 1
    serves expert-parallel on a ``make_serve_mesh`` mesh; ``impl``
    pins the kernel dispatch policy, e.g. 'pallas' to benchmark the
    fused decode kernel)."""
    from repro.launch.mesh import make_serve_mesh
    cfg = ModelConfig(
        name="serve-bench-moe", family="moe", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0, vocab_size=256,
        block_pattern=("global",), max_position=2048,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=128,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=16,
                                        top_n_restore=1, hqq_iters=2)))
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    mesh = make_serve_mesh(ep)
    if not offload:
        return ServeEngine(cfg, params, mesh=mesh, kernel_impl=impl)
    weights_by_layer = [
        {k: np.asarray(seg[0]["moe"][k]) for k in ("w1", "w2", "w3")}
        for seg in unstack_params(params, cfg)["segments"]]
    qparams, cfg_q, stacks_by_layer = compress_moe_params(params, cfg)
    eng = ServeEngine(cfg_q, qparams, quantized=True, mesh=mesh,
                      kernel_impl=impl)
    eng.attach_offload(stacks_by_layer, policy="ours",
                       cache_capacity=cache_capacity)
    if keep_weights:
        return eng, stacks_by_layer, weights_by_layer
    return eng


def run(quick: bool = True, rates: Optional[Tuple[float, ...]] = None,
        offload: bool = True, impl: Optional[str] = None) -> List[Dict]:
    n = 8 if quick else 32
    max_new = 12 if quick else 32
    rates = rates if rates is not None else ((0.0, 4.0) if quick
                                             else (0.0, 2.0, 8.0, 32.0))
    eng = _engine(offload=offload, impl=impl)
    slots = 2 if quick else 4
    # warm the compiled prefill/decode loop (same slot count as the sweep)
    # so the sweep measures steady state, not the first-bucket compile
    eng.serve(synthetic_workload(2, eng.cfg.vocab_size, max_new=max_new,
                                 seed=99),
              num_slots=slots, chunk=4)
    rows = []
    for rate in rates:
        stats = eng.serve(
            synthetic_workload(n, eng.cfg.vocab_size, rate=rate,
                               max_new=max_new),
            num_slots=slots, chunk=4)
        lat = stats.latency_percentiles((50.0, 95.0))
        row = {
            "name": f"serving/rate-{rate:g}",
            "offered_rps": rate,
            "tok_s": stats.tokens_per_s,
            # goodput = tokens per BUSY second: under open-loop arrivals
            # the wall-clock tok_s folds idle inter-arrival time into the
            # denominator (rate-4 looks 50x slower than rate-0 on the same
            # engine); goodput is the load-invariant capacity number
            "goodput_tok_s": stats.goodput_tokens_per_s,
            "busy_frac": stats.busy_frac,
            "p50_ms": lat[50.0] * 1e3,
            "p95_ms": lat[95.0] * 1e3,
            "requests": float(len(stats.results)),
            "chunks": float(stats.chunks),
            "cache_mb_per_tok": stats.cache_hbm_bytes_per_token / 2 ** 20,
        }
        rep = stats.offload_report
        if rep is not None:
            per_req = [r.offload_bytes / max(r.gen_tokens, 1)
                       for r in stats.results]
            row.update({
                "mb_per_tok": rep["bytes_per_token"] / 2 ** 20,
                "hit_rate": rep["hit_rate"],
                "prefetch_acc": rep["prefetch_accuracy"],
                "req_mb_per_tok": float(np.mean(per_req)) / 2 ** 20,
            })
        rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# paged KV cache (--paged): HBM bytes/token vs the bucketed baseline
# ---------------------------------------------------------------------------

def run_paged(quick: bool = True) -> List[Dict]:
    """Paged-KV-cache sweep: the same ragged workload served three ways —
    bucketed-contiguous baseline, paged, and paged with shared-prefix
    reuse (every request carrying a common system prompt).

    Token identity between all paged rows and their contiguous baseline
    is asserted here (not just in the test tier), so the bench never
    reports HBM savings won by serving different tokens.  Gated columns:
    ``cache_mb_per_tok`` (down — the paged cache's reason to exist) and
    ``prefix_hit_rate`` (up, prefix row only).
    """
    n = 8 if quick else 24
    max_new = 12 if quick else 32
    slots, chunk, ps = 2, 4, 16
    eng = _engine(offload=False)
    vocab = eng.cfg.vocab_size

    def workload(prefix_len: int = 0):
        reqs = synthetic_workload(n, vocab, max_new=max_new)
        if prefix_len:
            sysp = np.arange(1, prefix_len + 1, dtype=np.int32) % vocab
            for r in reqs:
                r.tokens = np.concatenate([sysp, np.asarray(r.tokens)])
        return reqs

    def serve_warm(reqs_fn, **kw):
        # each cache layout (and pool envelope) compiles its own decode
        # loop: serve the workload once to warm it, measure the re-serve
        eng.serve(reqs_fn(), num_slots=slots, chunk=chunk, **kw)
        return eng.serve(reqs_fn(), num_slots=slots, chunk=chunk, **kw)

    def row(name, stats):
        pr = stats.page_report or {}
        return {
            "name": f"paged/{name}",
            "tok_s": stats.tokens_per_s,
            "cache_mb": stats.cache_hbm_bytes / 2 ** 20,
            "cache_mb_per_tok": stats.cache_hbm_bytes_per_token / 2 ** 20,
            "prefill_tokens": float(stats.prefill_tokens),
            "prefix_hit_rate": pr.get("prefix_hit_rate", 0.0),
            "peak_shared_ref": float(pr.get("peak_shared_ref", 0)),
            "chunks": float(stats.chunks),
        }

    def toks(stats):
        return [r.tokens.tolist() for r in stats.results]

    base = serve_warm(workload)
    paged = serve_warm(workload, page_size=ps)
    assert toks(paged) == toks(base), "paged decode diverged from bucketed"
    assert paged.cache_hbm_bytes < base.cache_hbm_bytes, (
        "paged cache must hold strictly less HBM than the bucketed pool")

    # shared-system-prompt traffic: prefix reuse vs the same paged run
    pfx = 4 * ps if quick else 8 * ps
    pwork = lambda: workload(pfx)
    pbase = serve_warm(pwork, page_size=ps)
    pre = serve_warm(pwork, page_size=ps, prefix_cache=True)
    assert toks(pre) == toks(pbase), "prefix reuse diverged from paged"
    assert pre.page_report["peak_shared_ref"] >= 2
    assert pre.prefill_tokens < pbase.prefill_tokens, (
        "shared-span prefill was not reused")
    return [row("contiguous", base), row("paged", paged),
            row("prefix-base", pbase), row("prefix", pre)]


# ---------------------------------------------------------------------------
# async expert streaming (--stream): compute/transfer overlap sweep
# ---------------------------------------------------------------------------

def run_stream(quick: bool = True) -> List[Dict]:
    """Async expert-streaming sweep: resident baseline vs streamed decode.

    Serves the same workload twice — once all-resident (plain offload
    metering) and once through the ``ExpertStreamEngine`` staging ring —
    under eviction pressure (``cache_capacity < num_experts``) so the
    layer-ahead prefetcher actually issues ring copies whose transfer
    time can hide behind compute.  Reports per row:

    - ``overlap_efficiency`` — fraction of observed transfer time hidden
      behind decode compute (``(transfer_s - stall_s) / transfer_s``);
      gated 'up' by ``tools/bench_check.py``,
    - the metered-bytes oracle (``observed == metered`` wire bytes, the
      streaming tier's exactness invariant) surfaced as both columns,
    - stall/rerun counters and tokens/s.

    Token-identity between the streamed and resident runs is asserted
    here (not just in the test tier) so the bench never reports overlap
    won by serving wrong tokens.
    """
    from repro.config import StreamConfig

    n = 8 if quick else 24
    max_new = 12 if quick else 32
    slots, chunk = 2, 4

    def workload(seed=0):
        return synthetic_workload(n, 256, max_new=max_new, seed=seed)

    def serve_once(stream: bool):
        # cache_capacity=3 < 8 experts: eviction pressure makes the
        # prefetcher re-fetch evicted experts through the async ring
        eng = _engine(offload=True, cache_capacity=3)
        if stream:
            eng.attach_streaming(StreamConfig(enabled=True, ring_slots=2))
        eng.serve(synthetic_workload(2, eng.cfg.vocab_size, max_new=max_new,
                                     seed=99), num_slots=slots, chunk=chunk)
        stats = eng.serve(workload(), num_slots=slots, chunk=chunk)
        return eng, stats

    _, base = serve_once(stream=False)
    eng, stats = serve_once(stream=True)

    base_toks = [r.tokens.tolist() for r in base.results]
    strm_toks = [r.tokens.tolist() for r in stats.results]
    # warm-up traffic differs between the two runs, but the measured
    # workload must decode identically token-for-token
    assert strm_toks == base_toks, "streamed decode diverged from resident"
    rep = stats.offload_report
    assert rep["observed_copy_bytes"] == rep["total_bytes"], (
        "metered-bytes oracle violated in bench run")
    sr = stats.stream_report
    return [{
        "name": "stream/overlap",
        "tok_s": stats.tokens_per_s,
        "goodput_tok_s": stats.goodput_tokens_per_s,
        "overlap_efficiency": sr["overlap_efficiency"],
        "kb_per_tok": rep["bytes_per_token"] / 2 ** 10,
        "observed_kb": rep["observed_copy_bytes"] / 2 ** 10,
        "metered_kb": rep["total_bytes"] / 2 ** 10,
        "observed_copies": float(sr["issued_copies"]),
        "stalls": float(sr["stalls"]),
        "stall_ms": sr["stall_s"] * 1e3,
        "reruns": float(sr["reruns"]),
        "degraded_tokens": float(sr["degraded_tokens"]),
        "resident_tok_s": base.tokens_per_s,
        "chunks": float(stats.chunks),
    }]


# ---------------------------------------------------------------------------
# speculative decoding (--spec): lookahead vs layer-ahead prefetch
# ---------------------------------------------------------------------------

def run_spec(quick: bool = True) -> List[Dict]:
    """Speculative-serving sweep: the same closed-loop ragged workload
    served plain (layer-ahead prefetch heuristic) and through draft/
    verify rounds (verify-trace lookahead prefetch), with temperature-0
    token identity asserted between every arm.

    Two drafter arms bracket the subsystem: the backoff n-gram is the
    zero-cost realistic drafter (acceptance is whatever the workload's
    stream statistics give), and the windowed self-draft is the
    idealized high-acceptance drafter that isolates prefetcher quality
    from drafter quality — the stand-in for the distilled drafters real
    deployments pair with the target.  The self-draft arm's lookahead
    ``prefetch_acc`` beating the baseline's layer-ahead heuristic on the
    same workload is the subsystem's reason to exist, asserted here and
    gated 'up' (with ``accept_rate``) by ``tools/bench_check.py``.
    ``draft_overhead_kb`` is the attributable wasted-speculation wire
    traffic (warms issued for rejected positions).
    """
    from repro.serve.speculative import DraftModelDrafter

    n = 8 if quick else 24
    max_new = 12 if quick else 32
    slots, chunk, spec_k = 2, 4, 3

    def workload():
        return synthetic_workload(n, 256, max_new=max_new)

    def serve_arm(drafter=None):
        # fresh engine per arm: the expert LRU and prefetcher state are
        # workload-dependent, so every arm must start cold to compare
        eng = _engine(offload=True)
        if drafter == "self":
            # window covers the longest prompt (synthetic_workload's
            # max_len=24) plus the whole generation, so self-draft
            # proposals see full context and acceptance approaches 1
            drafter = DraftModelDrafter.self_draft(
                eng.cfg, eng.params, window=24 + max_new,
                quantized=True, kernel_impl=eng.kernel_impl)
        k = 0 if drafter is None else spec_k
        return eng.serve(workload(), num_slots=slots, chunk=chunk,
                         spec_k=k, drafter=drafter)

    base = serve_arm()
    ref = {r.uid: r.tokens.tolist() for r in base.results}
    rep = base.offload_report
    rows = [{
        "name": "spec/baseline",
        "tok_s": base.tokens_per_s,
        "mb_per_tok": rep["bytes_per_token"] / 2 ** 20,
        "hit_rate": rep["hit_rate"],
        "prefetch_acc": rep["prefetch_accuracy"],
        "chunks": float(base.chunks),
    }]
    for arm in ("ngram", "self"):
        stats = serve_arm(arm)
        toks = {r.uid: r.tokens.tolist() for r in stats.results}
        assert toks == ref, f"speculative decode ({arm}) diverged " \
                            f"from the non-speculative baseline"
        sp = stats.spec_report
        srep = stats.offload_report
        rows.append({
            "name": f"spec/{arm}-k{spec_k}",
            "tok_s": stats.tokens_per_s,
            "mb_per_tok": srep["bytes_per_token"] / 2 ** 20,
            "hit_rate": srep["hit_rate"],
            "prefetch_acc": sp["lookahead_accuracy"],
            "accept_rate": sp["acceptance_rate"],
            "draft_overhead_kb": sp["draft_overhead_bytes"] / 2 ** 10,
            "rounds": float(sp["rounds"]),
            "chunks": float(stats.chunks),
        })
    la_base, la_spec = rows[0]["prefetch_acc"], rows[-1]["prefetch_acc"]
    assert la_spec > la_base, (
        f"self-draft lookahead prefetch accuracy {la_spec:.3f} does not "
        f"beat the layer-ahead baseline {la_base:.3f}")
    return rows


# ---------------------------------------------------------------------------
# expert-parallel shard-count sweep (--mesh ep=N)
# ---------------------------------------------------------------------------

def run_ep_sweep(max_ep: int, quick: bool = True) -> List[Dict]:
    """Serve the same workload at shard counts 1, 2, ..., max_ep (powers
    of two) and report tokens/s, total bytes/token, and the hottest
    shard link's share — the scaling view of expert-parallel serving.
    Total bytes/token should be flat across rows (conservation) while
    the hottest link's bytes/token drops as experts spread.
    """
    n = 8 if quick else 24
    max_new = 12 if quick else 32
    eps, ep = [], 1
    while ep <= max_ep:
        eps.append(ep)
        ep *= 2
    rows = []
    for ep in eps:
        # capacity covers each shard's residents so byte totals compare
        # across rows (eviction-free regime; see ARCHITECTURE.md)
        eng = _engine(offload=True, ep=ep, cache_capacity=8)
        eng.serve(synthetic_workload(2, eng.cfg.vocab_size, max_new=max_new,
                                     seed=99), num_slots=2, chunk=4)
        stats = eng.serve(
            synthetic_workload(n, eng.cfg.vocab_size, max_new=max_new),
            num_slots=2, chunk=4)
        rep = stats.offload_report
        rows.append({
            "name": f"serving/ep-{ep}",
            "ep": float(rep["ep"]),
            "tok_s": stats.tokens_per_s,
            "kb_per_tok": rep["bytes_per_token"] / 2 ** 10,
            "max_shard_kb_per_tok": rep["max_shard_bytes_per_token"] / 2 ** 10,
            "hit_rate": rep["hit_rate"],
            "chunks": float(stats.chunks),
        })
    return rows


# ---------------------------------------------------------------------------
# bandwidth-accuracy frontier (runtime budget controller)
# ---------------------------------------------------------------------------

def _restoration_error(stacks_by_layer, weights_by_layer, plan,
                       top_k: int) -> float:
    """Weight-space restoration-error proxy of a plan.

    Per layer: experts within the plan's top-n see the rank-capped
    compensated residual, the remaining activated experts the plain
    quantization residual; the two relative errors mix by the expected
    restored share ``top_n / top_k``.  Mean over projections and layers.
    """
    errs = []
    for l, (stacks, ws) in enumerate(zip(stacks_by_layer, weights_by_layer)):
        share = min(int(plan.top_n[l]) / top_k, 1.0)
        cap = int(plan.rank_cap[l])
        per_proj = []
        for name, stack in stacks.items():
            w = np.asarray(ws[name], np.float32)
            e = w.shape[0]
            resid = w - np.asarray(stack.dequantize_all())
            u = (np.asarray(stack.u, np.float32)
                 * np.asarray(stack.u_scale, np.float32))
            v = (np.asarray(stack.v, np.float32)
                 * np.asarray(stack.v_scale, np.float32))
            u = u * (np.arange(stack.pad_rank) < cap)[None, None, :]
            comp = np.einsum("ekr,ern->ekn", u, v)
            nw = np.maximum(
                np.linalg.norm(w.reshape(e, -1), axis=1), 1e-12)
            e_q = np.linalg.norm(resid.reshape(e, -1), axis=1) / nw
            e_c = np.linalg.norm((resid - comp).reshape(e, -1), axis=1) / nw
            per_proj.append(share * e_c.mean() + (1.0 - share) * e_q.mean())
        errs.append(np.mean(per_proj))
    return float(np.mean(errs))


def _sim_profiles(trace: np.ndarray, frac: float) -> List[Dict]:
    """Project the adaptive policy onto the paper's hardware profiles.

    ``frac`` places the budget between each profile's own reachable floor
    (restoration off) and ceiling (full top-k restoration) so the target
    is attainable on that link; reports the controller's convergence.
    """
    d, fe, e = 4096, 14336, 8      # Mixtral-8x7B expert dims
    from repro.core.quantize import packed_nbytes
    spec = LayerSpecSim(
        d, fe, e, 2,
        bytes_fp16=3 * d * fe * 2,
        bytes_quant=3 * (packed_nbytes(2, d, fe) + (d // 64) * fe * 4),
        comp_bytes=[32 * (d + fe)] * e,
        ranks=[32] * e)
    big = np.tile(trace % e, (32, 16, 1))[:320, :8, :]
    out = []
    for prof, policy, static in ((GPU_ONLY, "ours_adaptive", "ours"),
                                 (GPU_NDP, "ours_adaptive_ndp", "ours_ndp")):
        # endpoints from the settled (warm-cache) tail so target and
        # measurement live in the same regime
        lo = simulate_decode(big, spec, prof, static, top_n=0, num_layers=8)
        hi = simulate_decode(big, spec, prof, static, top_n=spec.top_k,
                             num_layers=8)
        target = (lo.tail_bytes_per_token
                  + frac * (hi.tail_bytes_per_token
                            - lo.tail_bytes_per_token))
        r = simulate_decode(
            big, spec, prof, policy, top_n=1, num_layers=8,
            control=ControlConfig(enabled=True, bytes_per_token=target,
                                  gain=0.3))
        # judge convergence on the settled tail, not the transient from
        # the static starting point
        out.append({
            "profile": prof.name,
            "target_mb_per_tok": target / 2 ** 20,
            "sim_mb_per_tok": r.tail_bytes_per_token / 2 ** 20,
            "sim_err": (abs(r.tail_bytes_per_token - target)
                        / max(target, 1.0)),
            "sim_tok_s": r.tokens_per_s,
            "sim_mean_top_n": r.mean_top_n,
            "sim_mean_rank_cap": r.mean_rank_cap,
        })
    return out


def run_frontier(quick: bool = True,
                 budget_fracs: Optional[Tuple[float, ...]] = None
                 ) -> List[Dict]:
    """Sweep bytes/token budgets across the controllable range and report
    the frontier: budget vs measured bytes/token vs restoration error vs
    tokens/s, live (metered engine) and projected (both hardware
    profiles via the event-driven simulator)."""
    eng, stacks_by_layer, weights_by_layer = _engine(offload=True,
                                                     keep_weights=True)
    top_k = eng.cfg.moe.top_k
    n = 16 if quick else 32
    max_new = 12 if quick else 24
    slots, chunk = 2, 4

    def workload(seed):
        return synthetic_workload(n, eng.cfg.vocab_size, max_new=max_new,
                                  seed=seed)

    def tail_rate(controller):
        hist = controller.history
        tail = hist[len(hist) // 2:] or hist
        return float(np.mean([h.bytes_per_token for h in tail]))

    # warm the compiled loop, then measure the reachable byte range from
    # settled (warm-cache) tails: ceiling = static full restoration (an
    # unbudgeted controller only records telemetry), floor = the plan
    # driven to zero restoration by a ~zero budget
    eng.serve(synthetic_workload(2, eng.cfg.vocab_size, max_new=max_new,
                                 seed=99), num_slots=slots, chunk=chunk)
    eng.attach_controller(ControlConfig(enabled=True))
    base = eng.serve(workload(1), num_slots=slots, chunk=chunk)
    ceil = tail_rate(eng.controller)
    eng.attach_offload(stacks_by_layer, policy="ours", cache_capacity=3)
    eng.attach_controller(ControlConfig(enabled=True, bytes_per_token=1.0,
                                        gain=0.4))
    eng.serve(workload(1), num_slots=slots, chunk=chunk)
    floor = tail_rate(eng.controller)
    fracs = budget_fracs or ((0.3, 0.9) if quick else (0.2, 0.5, 0.8, 1.0))

    live_trace = base.results[0].trace                 # (steps, layers, k)
    rows = []
    for frac in fracs:
        budget = floor + frac * (ceil - floor)
        # fresh host-side stores + controller; the compiled loops persist
        eng.attach_offload(stacks_by_layer, policy="ours", cache_capacity=3)
        eng.attach_controller(ControlConfig(enabled=True,
                                            bytes_per_token=budget,
                                            gain=0.4))
        # same workload as the endpoint runs: the frontier is "same
        # traffic, different budgets", and endpoints calibrated on one
        # routing trace only bound budgets for that trace
        stats = eng.serve(workload(1), num_slots=slots, chunk=chunk)
        measured = tail_rate(eng.controller)
        plan = eng.controller.plan()
        row = {
            "name": f"frontier/budget-{frac:g}",
            "budget_kb_per_tok": budget / 2 ** 10,
            "live_kb_per_tok": measured / 2 ** 10,
            "live_err": abs(measured - budget) / max(budget, 1.0),
            "tok_s": stats.tokens_per_s,
            "mean_top_n": plan.summary()["mean_top_n"],
            "mean_rank_cap": plan.summary()["mean_rank_cap"],
            "restoration_err": _restoration_error(
                stacks_by_layer, weights_by_layer, plan, top_k),
        }
        for sim in _sim_profiles(live_trace, frac):
            p = "ndp" if "ndp" in sim["profile"] else "gpu"
            row[f"{p}_sim_err"] = sim["sim_err"]
            row[f"{p}_sim_tok_s"] = sim["sim_tok_s"]
            row[f"{p}_sim_mean_top_n"] = sim["sim_mean_top_n"]
        rows.append(row)
    return rows


SNAPSHOT = Path(__file__).resolve().parent.parent / "BENCH_serving.json"
MAX_RUNS = 20          # trajectory depth kept per mode


def _load_snapshot(path: Path) -> Dict:
    snap = {}
    if path.exists():
        try:
            snap = json.loads(path.read_text())
        except ValueError:
            snap = {}
        if not isinstance(snap, dict):
            snap = {}
    # migrate the pre-trajectory layout ({mode: {rows, time, quick}}):
    # the old single snapshot becomes the mode's baseline
    for mode, entry in list(snap.items()):
        if isinstance(entry, dict) and "rows" in entry:
            snap[mode] = {"baseline": entry, "runs": []}
    return snap


def write_snapshot(mode: str, rows: List[Dict], quick: bool,
                   path: Path = SNAPSHOT, meta: Optional[Dict] = None):
    """Append the sweep to the ``BENCH_serving.json`` trajectory.

    Layout per mode: ``{"baseline": run, "runs": [run, ...]}``.  Every
    invocation APPENDS to ``runs`` (capped at the newest ``MAX_RUNS``);
    the ``baseline`` is only ever moved by
    ``tools/bench_check.py --update-baseline``.  The first run of a mode
    seeds its baseline.  ``tools/bench_check.py`` gates CI on the newest
    run regressing >10% against the baseline."""
    snap = _load_snapshot(path)
    entry = {
        "time": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "quick": quick,
        "rows": [{k: (round(v, 6) if isinstance(v, float) else v)
                  for k, v in r.items()} for r in rows],
    }
    if meta:
        entry.update(meta)
    traj = snap.setdefault(mode, {"baseline": None, "runs": []})
    traj.setdefault("runs", []).append(entry)
    traj["runs"] = traj["runs"][-MAX_RUNS:]
    if not traj.get("baseline"):
        traj["baseline"] = entry
    path.write_text(json.dumps(snap, indent=1, sort_keys=True) + "\n")
    print(f"snapshot -> {path} ({mode}: {len(traj['runs'])} runs)",
          flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-offload", action="store_true")
    ap.add_argument("--frontier", action="store_true",
                    help="sweep bytes/token budgets through the runtime "
                         "controller instead of offered load")
    ap.add_argument("--stream", action="store_true",
                    help="async expert-streaming sweep: overlap efficiency "
                         "+ metered-bytes oracle vs the resident baseline")
    ap.add_argument("--paged", action="store_true",
                    help="paged-KV-cache sweep: cache HBM bytes/token and "
                         "prefix reuse vs the bucketed-contiguous "
                         "baseline (token identity asserted)")
    ap.add_argument("--spec", action="store_true",
                    help="speculative-decoding sweep: draft/verify rounds "
                         "with lookahead expert prefetch vs the layer-"
                         "ahead heuristic on the same workload (token "
                         "identity asserted)")
    ap.add_argument("--mesh", default="",
                    help="'ep=N': sweep expert-parallel shard counts 1..N "
                         "(CPU needs XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--no-snapshot", action="store_true",
                    help="skip writing the BENCH_serving.json snapshot")
    ap.add_argument("--impl", default=None,
                    choices=("auto", "pallas", "pallas_interpret", "ref"),
                    help="kernel dispatch policy for the engine (default: "
                         "auto — pallas on TPU, the benchmarked serving "
                         "path; ref elsewhere)")
    args = ap.parse_args()
    if args.mesh:
        from repro.launch.mesh import parse_mesh_spec
        mode = "ep-sweep"
        rows = run_ep_sweep(parse_mesh_spec(args.mesh).get("ep", 1),
                            quick=args.quick)
    elif args.stream:
        mode = "stream"
        rows = run_stream(quick=args.quick)
    elif args.spec:
        mode = "spec"
        rows = run_spec(quick=args.quick)
    elif args.paged:
        mode = "paged"
        rows = run_paged(quick=args.quick)
    elif args.frontier:
        mode = "frontier"
        rows = run_frontier(quick=args.quick)
    else:
        mode = "offered-load"
        rows = run(quick=args.quick, offload=not args.no_offload,
                   impl=args.impl)
    for r in rows:
        extra = ",".join(f"{k}={v:.3f}" if isinstance(v, float) else f"{k}={v}"
                         for k, v in r.items() if k != "name")
        print(f"{r['name']},{extra}", flush=True)
    if not args.no_snapshot:
        from repro.kernels.ops import resolve_impl
        write_snapshot(mode, rows, args.quick,
                       meta={"impl": resolve_impl(args.impl)})


if __name__ == "__main__":
    main()
