"""Pallas TPU kernel: flash-decode attention over an (optionally int8) KV
cache.

Decode's second dominant HBM stream (after expert weights) is the KV
cache.  This kernel streams KV blocks HBM->VMEM once, keeps the online-
softmax state (m, l, acc) in VMEM scratch, and — when the cache is int8 —
folds the per-(slot, head) scales into the score/probability domain so the
dequantized cache never materializes: KV traffic is exactly the packed
bytes (~1.06 B/elem incl. scales vs 2 for bf16).

Grid: (B, KVH, S/bs); GQA handled by evaluating all G = H/KVH query heads
of the kv-head per block.  Ring caches pass ``kv_pos`` (-1 = empty slot)
and masking is pure position arithmetic — no sorting after wraparound.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import PallasCompilerParams

NEG = -2.0 ** 30


def _kernel(n_s, bs, window, q_ref, k_ref, v_ref, ks_ref, vs_ref,
            pos_ref, cur_ref, o_ref, m_ref, l_ref, acc_ref):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # (bs, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)        # (bs, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (G, bs)
    if ks_ref is not None:
        s = s * ks_ref[0, :, 0].astype(jnp.float32)[None, :]
    pos = pos_ref[0]                                 # (bs,)
    cur = cur_ref[0, 0]
    valid = (pos >= 0) & (pos <= cur)
    if window is not None and window > 0:
        valid &= pos > cur - window
    s = jnp.where(valid[None, :], s, NEG)

    m_prev = m_ref[...]                              # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                           # (G, bs)
    if vs_ref is not None:
        pv = p * vs_ref[0, :, 0].astype(jnp.float32)[None, :]
    else:
        pv = p
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        pv, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bs", "interpret"))
def flash_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           kv_pos: jax.Array, cur_pos: jax.Array,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None, *,
                           window: Optional[int] = None, bs: int = 512,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, hd) pre-scaled by 1/sqrt(hd); k/v: (B, S, KVH, hd);
    kv_pos: (B, S); cur_pos: (B,); scales: (B, S, KVH) for int8 KV.
    Returns (B, H, hd)."""
    b, h, hd = q.shape
    s_len, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    bs = min(bs, s_len)
    assert s_len % bs == 0, (s_len, bs)
    n_s = s_len // bs
    qg = q.reshape(b, kvh, g, hd)

    grid = (b, kvh, n_s)
    in_specs = [
        pl.BlockSpec((1, g, hd), lambda bb, kk, ss: (bb * kvh + kk, 0, 0)),
        pl.BlockSpec((1, bs, 1, hd), lambda bb, kk, ss: (bb, ss, kk, 0)),
        pl.BlockSpec((1, bs, 1, hd), lambda bb, kk, ss: (bb, ss, kk, 0)),
    ]
    args = [qg.reshape(b * kvh, g, hd), k, v]
    use_scales = k_scale is not None
    if use_scales:
        in_specs += [pl.BlockSpec((1, bs, 1), lambda bb, kk, ss: (bb, ss, kk)),
                     pl.BlockSpec((1, bs, 1), lambda bb, kk, ss: (bb, ss, kk))]
        args += [k_scale, v_scale]
    in_specs += [pl.BlockSpec((1, bs), lambda bb, kk, ss: (bb, ss)),
                 pl.BlockSpec((1, 1), lambda bb, kk, ss: (bb, 0))]
    args += [kv_pos, cur_pos[:, None]]

    kernel = functools.partial(
        _kernel, n_s, bs, window) if use_scales else functools.partial(
        _wrap_noscale, n_s, bs, window)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, g, hd), lambda bb, kk, ss: (bb * kvh + kk,
                                                               0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, g, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, hd), jnp.float32)],
        compiler_params=PallasCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="flash_decode" + ("_kv8" if use_scales else ""),
    )(*args)
    return out.reshape(b, kvh, g, hd).reshape(b, h, hd)


def _wrap_noscale(n_s, bs, window, q_ref, k_ref, v_ref, pos_ref, cur_ref,
                  o_ref, m_ref, l_ref, acc_ref):
    _kernel(n_s, bs, window, q_ref, k_ref, v_ref, None, None, pos_ref,
            cur_ref, o_ref, m_ref, l_ref, acc_ref)
