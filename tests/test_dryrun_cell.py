"""Dry-run machinery integration test: one real cell on the production
512-device host mesh, in a subprocess (conftest keeps this process at one
device)."""
import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).parent.parent

SCRIPT = """
from repro.launch.dryrun import run_cell
import json
rec = run_cell("whisper-base", "decode_32k", False, verbose=False)
print("REC:" + json.dumps({
    "status": rec["status"],
    "dominant": rec["roofline"]["dominant"],
    "flops": rec["roofline"]["flops_dev"],
    "wire": rec["roofline"]["wire_bytes_dev"],
    "note": rec["roofline"]["note"],
    "temp": rec["memory_analysis"]["temp_bytes"],
}))
"""


@pytest.fixture(scope="module")
def cell():
    env = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, env=env,
                          cwd=REPO, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("REC:")][0]
    return json.loads(line[4:])


def test_cell_compiles(cell):
    assert cell["status"] == "ok"


def test_roofline_terms_sane(cell):
    assert cell["flops"] > 1e8            # loop-corrected, not body-once
    assert cell["note"].startswith(("extrapolated", "exact"))
    assert cell["temp"] and cell["temp"] < 16 * 2 ** 30   # fits v5e HBM


def test_decode_is_memory_bound(cell):
    # the paper's premise: decode under weight streaming is memory-bound
    assert cell["dominant"] == "memory"
