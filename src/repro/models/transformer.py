"""Layer-stack engine: plan derivation, parameter init, scanned forward.

A model is a sequence of *segments*; each segment is a short pattern of
heterogeneous layers (e.g. gemma3's 5 local + 1 global) repeated ``repeat``
times via ``lax.scan`` — one trace per distinct layer kind regardless of
depth, which keeps dry-run compiles of 62-layer models fast and HLO small.
Remainder layers that don't fill a pattern become repeat-1 segments.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..config import ModelConfig
from .attention import attention, decode_attention
from .ffn import ffn_apply, ffn_apply_quantized
from .kvcache import (TRASH_PAGE, claim_slot, init_attn_cache,
                      init_mlstm_cache, init_paged_attn_cache,
                      init_rglru_cache, init_slstm_cache, paged_claim,
                      paged_gather, paged_reset, paged_seed_prefix,
                      paged_update_attn_cache, prefill_attn_cache,
                      reset_slot, update_attn_cache)
from .layers import (apply_mrope, apply_rope, dense_init, embed_init,
                     rms_norm, softcap)
from .moe import moe_apply
from .rglru import rglru_seq, rglru_step
from .xlstm import mlstm_chunkwise, mlstm_step, slstm_seq


class LayerSpec(NamedTuple):
    mixer: str          # global | local | recurrent | mlstm | slstm
    ffn: str            # dense | moe | none
    cross: bool = False # enc-dec decoder cross-attention


class Segment(NamedTuple):
    layers: Tuple[LayerSpec, ...]
    repeat: int


@dataclasses.dataclass
class ExecContext:
    """Runtime execution knobs threaded through the forward pass."""
    mode: str = "train"              # train | prefill | step
    quantized: bool = False          # serve on compressed experts/FFNs
    ep_mode: str = "none"            # none | a2a | replicated
    mesh: Any = None
    constrain: Callable = staticmethod(lambda x, axes: x)
    moe_ep_fn: Optional[Callable] = None   # injected by distributed layer
    remat: bool = False
    q_block: int = 1024
    mlstm_chunk: int = 256
    exact_capacity: bool = False     # drop-free MoE (tests / tiny batches)
    scan_unroll: bool = False        # unroll every scan (cost-analysis pass)
    # prefill/train attention parallelism: shard q heads over `model` when
    # they divide; otherwise shard fresh K/V along seq (partial-softmax) so
    # attention FLOPs never replicate across the model axis
    attn_heads_sharded: bool = False
    attn_seq_sharded: bool = False
    remat_policy: str = "full"       # full | dots (save matmul outputs)
    # expert-backend dispatch: None/'auto' -> REPRO_KERNEL_IMPL policy;
    # 'ref' | 'pallas' | 'pallas_interpret' force an implementation
    kernel_impl: Optional[str] = None
    # return per-MoE-layer top-k routing as a first-class forward output
    collect_trace: bool = False
    # return per-MoE-layer FFN inputs (T, d) as a first-class output —
    # the offline calibration pass (calib/stats.py) feeds on these to
    # accumulate routing frequency / gate mass / input second moments
    collect_moe_inputs: bool = False


# ---------------------------------------------------------------------------
# plan derivation
# ---------------------------------------------------------------------------

def layer_specs(cfg: ModelConfig) -> List[LayerSpec]:
    cross = cfg.encoder is not None
    specs = []
    for i in range(cfg.num_layers):
        mixer = cfg.layer_kind(i)
        if mixer in ("mlstm", "slstm"):
            ffn = "none"
        elif cfg.moe is not None and cfg.is_moe_layer(i) and not (
                i == 0 and cfg.first_layer_dense):
            ffn = "moe"
        else:
            ffn = "dense"
        specs.append(LayerSpec(mixer, ffn, cross))
    return specs


def derive_plan(cfg: ModelConfig) -> Tuple[Segment, ...]:
    specs = layer_specs(cfg)
    if cfg.force_unroll_plan:
        return tuple(Segment((s,), 1) for s in specs)
    p = len(cfg.block_pattern)
    segments: List[Segment] = []
    i = 0
    n = len(specs)
    while i < n:
        # try the full block pattern first
        if p > 1 and i + p <= n:
            pat = tuple(specs[i:i + p])
            r = 1
            while i + (r + 1) * p <= n and tuple(specs[i + r * p:i + (r + 1) * p]) == pat:
                r += 1
            if r >= 1 and all(specs[i + j * p:i + (j + 1) * p] == list(pat)
                              for j in range(r)):
                segments.append(Segment(pat, r))
                i += r * p
                continue
        # fall back to run-length of identical single layers
        r = 1
        while i + r < n and specs[i + r] == specs[i]:
            r += 1
        segments.append(Segment((specs[i],), r))
        i += r
    return tuple(segments)


# ---------------------------------------------------------------------------
# parameter init (single layer, then vmapped stacks)
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, cross: bool, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), d, dtype),
        "wk": dense_init(ks[1], (d, kv, hd), d, dtype),
        "wv": dense_init(ks[2], (d, kv, hd), d, dtype),
        "wo": dense_init(ks[3], (h, hd, d), h * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    if cross:
        p["cross_wq"] = dense_init(ks[4], (d, h, hd), d, dtype)
        p["cross_wk"] = dense_init(ks[5], (cfg.encoder.d_model, h, hd),
                                   cfg.encoder.d_model, dtype)
        p["cross_wv"] = dense_init(ks[6], (cfg.encoder.d_model, h, hd),
                                   cfg.encoder.d_model, dtype)
        p["cross_wo"] = dense_init(ks[7], (h, hd, d), h * hd, dtype)
        p["cross_norm"] = jnp.zeros((d,), dtype)
    return p


def _init_ffn(key, d: int, ff: int, gated: bool, dtype):
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], (d, ff), d, dtype),
         "w2": dense_init(ks[1], (ff, d), ff, dtype)}
    if gated:
        p["w3"] = dense_init(ks[2], (d, ff), d, dtype)
    return p


def _init_moe(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d, fe = cfg.d_model, m.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.num_experts), d, jnp.float32),
        "w1": dense_init(ks[1], (m.num_experts, d, fe), d, dtype),
        "w3": dense_init(ks[2], (m.num_experts, d, fe), d, dtype),
        "w2": dense_init(ks[3], (m.num_experts, fe, d), fe, dtype),
    }
    if m.num_shared_experts:
        fs = (m.d_shared or m.d_expert) * m.num_shared_experts
        p["shared"] = _init_ffn(ks[4], d, fs, True, dtype)
    return p


def _init_rglru(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        "wx": dense_init(ks[0], (d, w), d, dtype),
        "wgate": dense_init(ks[1], (d, w), d, dtype),
        "conv_w": dense_init(ks[2], (cfg.conv1d_width, w), cfg.conv1d_width,
                             jnp.float32),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "rg_wa": dense_init(ks[3], (w, w), w, jnp.float32),
        "rg_ba": jnp.zeros((w,), jnp.float32),
        "rg_wx": dense_init(ks[4], (w, w), w, jnp.float32),
        "rg_bx": jnp.zeros((w,), jnp.float32),
        # init recurrence a^c in (0.9, 0.999): lam = softplus^-1(-log a)
        "lam": jnp.full((w,), 0.65, jnp.float32),
        "wo": dense_init(ks[5], (w, d), w, dtype),
    }


def _init_mlstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = 2 * d
    nh = cfg.num_heads
    hd = di // nh
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], (d, 2 * di), d, dtype),      # (u, z gate)
        "wq": dense_init(ks[1], (di, nh, hd), di, dtype),
        "wk": dense_init(ks[2], (di, nh, hd), di, dtype),
        "wv": dense_init(ks[3], (di, nh, hd), di, dtype),
        "w_if": dense_init(ks[4], (di, 2 * nh), di, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((nh,)), 3.0 * jnp.ones((nh,))]),
        "w_down": dense_init(ks[5], (di, d), di, dtype),
        "out_norm": jnp.zeros((di,), dtype),
    }


def _init_slstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    ks = jax.random.split(key, 7)
    ff = int(d * 4 / 3 / 64 + 1) * 64
    return {
        "w_zifo": dense_init(ks[0], (d, 4, nh, hd), d, dtype),
        "b_zifo": jnp.zeros((4, nh, hd), jnp.float32),
        "rz": dense_init(ks[1], (nh, hd, hd), hd, jnp.float32),
        "ri": dense_init(ks[2], (nh, hd, hd), hd, jnp.float32),
        "rf": dense_init(ks[3], (nh, hd, hd), hd, jnp.float32),
        "ro": dense_init(ks[4], (nh, hd, hd), hd, jnp.float32),
        "out_norm": jnp.zeros((d,), dtype),
        "ffn": _init_ffn(ks[5], d, ff, True, dtype),
        "ffn_norm": jnp.zeros((d,), dtype),
    }


def init_layer(key, spec: LayerSpec, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"pre_norm": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.post_attn_norm:
        p["post_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if spec.mixer in ("global", "local"):
        p["attn"] = _init_attn(ks[0], cfg, spec.cross, dtype)
    elif spec.mixer == "recurrent":
        p["rglru"] = _init_rglru(ks[0], cfg, dtype)
    elif spec.mixer == "mlstm":
        p["mlstm"] = _init_mlstm(ks[0], cfg, dtype)
        return p  # self-contained block
    elif spec.mixer == "slstm":
        p["slstm"] = _init_slstm(ks[0], cfg, dtype)
        return p
    if spec.ffn != "none":
        p["ffn_norm"] = jnp.zeros((cfg.d_model,), dtype)
        if cfg.post_attn_norm:
            p["post_ffn_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if spec.ffn == "dense":
        p["ffn"] = _init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_ffn,
                             dtype)
    elif spec.ffn == "moe":
        p["moe"] = _init_moe(ks[1], cfg, dtype)
    return p


def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    plan = derive_plan(cfg)
    keys = jax.random.split(key, len(plan) + 4)
    params: Dict[str, Any] = {
        "embed": {"tok": embed_init(keys[0], (cfg.vocab_size, cfg.d_model),
                                    dtype)},
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": dense_init(keys[1], (cfg.d_model,
                                                    cfg.vocab_size),
                                          cfg.d_model, dtype)}
    segs = []
    for si, seg in enumerate(plan):
        skeys = jax.random.split(keys[2 + si], seg.repeat)
        pos_params = []
        for pi, spec in enumerate(seg.layers):
            def one(k, spec=spec):
                return init_layer(jax.random.fold_in(k, pi), spec, cfg, dtype)
            if seg.repeat == 1:
                pos_params.append(one(skeys[0]))
            else:
                pos_params.append(jax.vmap(one)(skeys))
        segs.append(tuple(pos_params))
    params["segments"] = tuple(segs)
    if cfg.encoder is not None:
        params["encoder"] = init_encoder_params(keys[-1], cfg, dtype)
    return params


def init_encoder_params(key, cfg: ModelConfig, dtype) -> Dict:
    e = cfg.encoder
    ks = jax.random.split(key, e.num_layers + 1)

    def one(k):
        kk = jax.random.split(k, 2)
        return {
            "pre_norm": jnp.zeros((e.d_model,), dtype),
            "attn": {
                "wq": dense_init(kk[0], (e.d_model, e.num_heads,
                                         e.d_model // e.num_heads),
                                 e.d_model, dtype),
                "wk": dense_init(jax.random.fold_in(kk[0], 1),
                                 (e.d_model, e.num_heads,
                                  e.d_model // e.num_heads), e.d_model, dtype),
                "wv": dense_init(jax.random.fold_in(kk[0], 2),
                                 (e.d_model, e.num_heads,
                                  e.d_model // e.num_heads), e.d_model, dtype),
                "wo": dense_init(jax.random.fold_in(kk[0], 3),
                                 (e.num_heads, e.d_model // e.num_heads,
                                  e.d_model), e.d_model, dtype),
            },
            "ffn_norm": jnp.zeros((e.d_model,), dtype),
            "ffn": _init_ffn(kk[1], e.d_model, e.d_ff, False, dtype),
        }

    stacked = jax.vmap(one)(ks[:e.num_layers])
    return {"layers": stacked, "final_norm": jnp.zeros((e.d_model,), dtype)}


def unstack_params(params, cfg: ModelConfig):
    """Convert scanned (stacked) segment params into the unrolled per-layer
    layout matching ``force_unroll_plan=True`` — required before offline
    compression, whose per-layer compensator ranks break scan homogeneity."""
    plan = derive_plan(cfg)
    new_segs = []
    for si, seg in enumerate(plan):
        seg_params = params["segments"][si]
        for r in range(seg.repeat):
            for pi in range(len(seg.layers)):
                lp = seg_params[pi]
                if seg.repeat > 1:
                    lp = jax.tree.map(lambda x: x[r], lp)
                new_segs.append((lp,))
    out = dict(params)
    out["segments"] = tuple(new_segs)
    return out


def compress_moe_params(params, cfg: ModelConfig, qcfg=None, plan=None,
                        stats=None):
    """Offline-compress every MoE layer's experts for quantized serving.

    Runs the full pipeline (DESIGN.md) over the routed-expert stacks of
    each MoE layer and swaps w1/w3/w2 for ``CompressedExpertStack``s.
    Returns ``(qparams, cfg_q, stacks_by_layer)``: the *unrolled* param
    tree (per-layer compensator ranks break scan homogeneity), the
    matching ``force_unroll_plan`` config, and the per-layer stacks
    dicts the offload ``ExpertStore``s are built from.  One helper
    shared by ``launch/serve.py``, benchmarks, examples, and tests so
    the compressed-param layout has a single definition.

    ``plan`` (a ``calib.CompressionPlan``) pins per-expert bits and
    per-projection ranks per MoE layer from the offline budget
    allocator; ``stats`` (per-MoE-layer ``calib.LayerCalibStats``)
    makes the compensator SVDs activation-weighted.  Both None keeps the
    paper's kurtosis-guided uniform-bit path bit-identically.
    """
    from ..core.pipeline import compress_ffn_weights
    qcfg = qcfg or cfg.moe.quant
    up = unstack_params(params, cfg)
    specs = layer_specs(cfg)
    segs, stacks_by_layer = [], []
    li = 0
    for (lp,), spec in zip(up["segments"], specs):
        lp = dict(lp)
        if spec.ffn == "moe":
            alloc = plan.layers[li] if plan is not None else None
            lstats = stats[li] if stats is not None else None
            mp = dict(lp["moe"])
            stacks, _ = compress_ffn_weights(mp["w1"], mp["w2"], mp["w3"],
                                             qcfg, allocation=alloc,
                                             stats=lstats)
            stacks_by_layer.append(stacks)
            mp["stacks"] = stacks
            for k in ("w1", "w2", "w3"):
                mp.pop(k)
            lp["moe"] = mp
            li += 1
        segs.append((lp,))
    qparams = dict(up)
    qparams["segments"] = tuple(segs)
    return (qparams, dataclasses.replace(cfg, force_unroll_plan=True),
            stacks_by_layer)


def apply_compressed_stacks(params, cfg: ModelConfig, stacks_by_layer):
    """Swap precompressed ``CompressedExpertStack`` dicts into the MoE
    layers of a freshly-initialized param tree — the artifact boot path
    (``launch/serve.py --artifact``): no HQQ / SVD runs, the stacks come
    straight off disk.  Returns ``(qparams, cfg_q)`` in exactly the
    layout ``compress_moe_params`` produces, so serving from an artifact
    is bit-identical to serving from in-memory compression of the same
    plan."""
    up = unstack_params(params, cfg)
    specs = layer_specs(cfg)
    n_moe = sum(1 for s in specs if s.ffn == "moe")
    if n_moe != len(stacks_by_layer):
        raise ValueError(f"artifact has {len(stacks_by_layer)} MoE layers; "
                         f"config {cfg.name} has {n_moe}")
    segs = []
    li = 0
    for (lp,), spec in zip(up["segments"], specs):
        lp = dict(lp)
        if spec.ffn == "moe":
            mp = dict(lp["moe"])
            mp["stacks"] = stacks_by_layer[li]
            for k in ("w1", "w2", "w3"):
                mp.pop(k)
            lp["moe"] = mp
            li += 1
        segs.append((lp,))
    qparams = dict(up)
    qparams["segments"] = tuple(segs)
    return qparams, dataclasses.replace(cfg, force_unroll_plan=True)


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Dict:
    plan = derive_plan(cfg)

    def one_cache(spec: LayerSpec):
        if spec.mixer in ("global", "local"):
            length = (min(cfg.window_size, max_len)
                      if spec.mixer == "local" else max_len)
            c = init_attn_cache(batch, length, cfg.num_kv_heads, cfg.head_dim,
                                dtype, kv_bits=cfg.kv_bits)
            if spec.cross:
                e = cfg.encoder
                c["cross_k"] = jnp.zeros((batch, e.source_len, cfg.num_heads,
                                          cfg.head_dim), dtype)
                c["cross_v"] = jnp.zeros((batch, e.source_len, cfg.num_heads,
                                          cfg.head_dim), dtype)
            return c
        if spec.mixer == "recurrent":
            return init_rglru_cache(batch, cfg.lru_width or cfg.d_model,
                                    cfg.conv1d_width)
        if spec.mixer == "mlstm":
            di = 2 * cfg.d_model
            return init_mlstm_cache(batch, cfg.num_heads, di // cfg.num_heads)
        if spec.mixer == "slstm":
            return init_slstm_cache(batch, cfg.num_heads,
                                    cfg.d_model // cfg.num_heads)
        raise ValueError(spec.mixer)

    segs = []
    for seg in plan:
        pos = []
        for spec in seg.layers:
            c = one_cache(spec)
            if seg.repeat > 1:
                c = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (seg.repeat,) + x.shape), c)
            pos.append(c)
        segs.append(tuple(pos))
    return {"segments": tuple(segs), "pos": jnp.zeros((batch,), jnp.int32)}


def init_paged_caches(cfg: ModelConfig, num_slots: int, num_pages: int,
                      page_size: int, max_blocks: int,
                      dtype=jnp.bfloat16) -> Dict:
    """Slotted serve cache with *paged* global-attention layers.

    Global layers get a (num_pages, page_size, ...) physical pool plus a
    (num_slots, max_blocks) block table — each layer owns its own pool
    buffers, but all layers share one logical page-id space, so the host
    allocator hands out a single page list per request.  Local ring
    caches are already window-bounded (no padded-prefill waste to
    reclaim) and recurrent/xLSTM states are O(1), so those stay in their
    contiguous slot-indexed form.
    """
    plan = derive_plan(cfg)

    def one_cache(spec: LayerSpec):
        if spec.mixer == "global":
            if spec.cross:
                raise NotImplementedError("paged cache with cross-attention")
            return init_paged_attn_cache(num_slots, num_pages, page_size,
                                         max_blocks, cfg.num_kv_heads,
                                         cfg.head_dim, dtype,
                                         kv_bits=cfg.kv_bits)
        if spec.mixer == "local":
            length = min(cfg.window_size, max_blocks * page_size)
            return init_attn_cache(num_slots, length, cfg.num_kv_heads,
                                   cfg.head_dim, dtype, kv_bits=cfg.kv_bits)
        if spec.mixer == "recurrent":
            return init_rglru_cache(num_slots, cfg.lru_width or cfg.d_model,
                                    cfg.conv1d_width)
        if spec.mixer == "mlstm":
            di = 2 * cfg.d_model
            return init_mlstm_cache(num_slots, cfg.num_heads,
                                    di // cfg.num_heads)
        if spec.mixer == "slstm":
            return init_slstm_cache(num_slots, cfg.num_heads,
                                    cfg.d_model // cfg.num_heads)
        raise ValueError(spec.mixer)

    segs = []
    for seg in plan:
        pos = []
        for spec in seg.layers:
            c = one_cache(spec)
            if seg.repeat > 1:
                c = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (seg.repeat,) + x.shape), c)
            pos.append(c)
        segs.append(tuple(pos))
    return {"segments": tuple(segs),
            "pos": jnp.zeros((num_slots,), jnp.int32)}


# ---------------------------------------------------------------------------
# slot-indexed cache ops (continuous-batching scheduler)
# ---------------------------------------------------------------------------

def _map_segments(cfg: ModelConfig, fn, *cache_trees):
    """Apply ``fn(layer_cache..., batch_axis)`` to every per-layer cache
    dict; scanned segments carry a leading repeat axis, so their batch
    axis is 1 instead of 0."""
    plan = derive_plan(cfg)
    segs = []
    for si, seg in enumerate(plan):
        ax = 1 if seg.repeat > 1 else 0
        pos = []
        for pi in range(len(seg.layers)):
            pos.append(fn(*[t["segments"][si][pi] for t in cache_trees], ax))
        segs.append(tuple(pos))
    return tuple(segs)


def cache_claim_slot(cfg: ModelConfig, caches: Dict, req_caches: Dict,
                     slot: int) -> Dict:
    """Write a batch-1 prefilled cache into batch row ``slot`` of a slotted
    cache (same cfg / cache length); the slot's absolute position comes
    along from ``req_caches['pos']``."""
    segs = _map_segments(
        cfg, lambda g, r, ax: claim_slot(g, r, slot, ax), caches, req_caches)
    pos = jax.lax.dynamic_update_slice_in_dim(
        caches["pos"], req_caches["pos"].astype(jnp.int32), slot, 0)
    return {"segments": segs, "pos": pos}


def cache_reset_slot(cfg: ModelConfig, caches: Dict, slot: int) -> Dict:
    """Clear batch row ``slot`` back to the empty state (pos planes -1)."""
    segs = _map_segments(cfg, lambda g, ax: reset_slot(g, slot, ax), caches)
    pos = jax.lax.dynamic_update_slice_in_dim(
        caches["pos"], jnp.zeros((1,), jnp.int32), slot, 0)
    return {"segments": segs, "pos": pos}


def cache_claim_slot_paged(cfg: ModelConfig, caches: Dict, req_caches: Dict,
                           slot, pages, write_mask) -> Dict:
    """Paged twin of ``cache_claim_slot``: paged layers map ``pages`` into
    their block-table row and scatter the request's contiguous prefilled
    chunks into the pool; non-paged layers (local rings, recurrent state)
    claim their slot row as before.  ``slot``/``pages``/``write_mask``
    are traced, so one compile serves every admission of a given
    prompt-length bucket."""
    def claim(g, r, ax: int):
        if "block" in g:
            if ax == 1:   # scanned segment: map over the repeat axis
                return jax.vmap(
                    lambda gc, rc: paged_claim(gc, rc, slot, pages,
                                               write_mask))(g, r)
            return paged_claim(g, r, slot, pages, write_mask)
        return claim_slot(g, r, slot, ax)

    segs = _map_segments(cfg, claim, caches, req_caches)
    pos = jax.lax.dynamic_update_slice_in_dim(
        caches["pos"], req_caches["pos"].astype(jnp.int32), slot, 0)
    return {"segments": segs, "pos": pos}


def cache_reset_slot_paged(cfg: ModelConfig, caches: Dict, slot) -> Dict:
    """Paged twin of ``cache_reset_slot``: paged layers only unmap the
    slot's block-table row (page contents are rewritten on next claim)."""
    def reset(g, ax: int):
        if "block" in g:
            if ax == 1:
                return jax.vmap(lambda gc: paged_reset(gc, slot))(g)
            return paged_reset(g, slot)
        return reset_slot(g, slot, ax)

    segs = _map_segments(cfg, reset, caches)
    pos = jax.lax.dynamic_update_slice_in_dim(
        caches["pos"], jnp.zeros((1,), jnp.int32), slot, 0)
    return {"segments": segs, "pos": pos}


def cache_seed_prefix(cfg: ModelConfig, req_caches: Dict, caches: Dict,
                      pages) -> Dict:
    """Seed a batch-1 contiguous request cache with the shared-prefix
    pages of a paged serve cache (``pages``: (max_blocks,) page ids, -1
    past the shared span), so a suffix-only prefill attends over reused
    prefix KV without recomputing it.  Only paged (global) layers seed;
    prefix reuse requires an all-global plan, so there is nothing to
    seed elsewhere."""
    def seed(r, g, ax: int):
        if "block" not in g:
            return r
        if ax == 1:
            return jax.vmap(
                lambda rc, gc: paged_seed_prefix(rc, gc, pages))(r, g)
        return paged_seed_prefix(r, g, pages)

    segs = _map_segments(cfg, seed, req_caches, caches)
    return {"segments": segs, "pos": req_caches["pos"]}


def mask_cache_padding(cfg: ModelConfig, caches: Dict, plen: jax.Array
                       ) -> Dict:
    """Invalidate cache entries written by right-padded prefill tokens.

    ``plen``: (B,) true prompt lengths.  Attention position planes at
    absolute positions >= plen become -1 (the decode-attention "empty"
    sentinel), and the per-row decode position is pinned to plen — so a
    prompt padded up to its length bucket decodes exactly like an unpadded
    one.  Recurrent states have no per-position plane and cannot be
    unpolluted this way; callers only right-pad attention-only plans."""
    def mask(c, ax):
        if not (isinstance(c, dict) and "pos" in c):
            return c
        if "block" in c:   # paged pos plane is pool-shaped, not per-slot
            return c
        lim = plen[None, :, None] if ax == 1 else plen[:, None]
        out = dict(c)
        out["pos"] = jnp.where(c["pos"] >= lim, -1, c["pos"])
        return out

    segs = _map_segments(cfg, mask, caches)
    return {"segments": segs, "pos": plen.astype(jnp.int32)}


def cache_rollback(cfg: ModelConfig, caches: Dict, new_len: jax.Array
                   ) -> Dict:
    """Roll a slotted cache back to ``new_len`` (B,) committed tokens.

    Speculative decoding's verify pass appends KV for every drafted
    token; rejection keeps only a per-row accepted prefix.  Attention
    entries at absolute positions >= new_len are invalidated (pos -> -1)
    AND their K/V payloads (plus int8 scales) are zeroed — fresh cache
    planes are zero-filled and, under an all-'global' plan with enough
    ring headroom, append-only, so the rolled-back cache is bit-identical
    to one that never saw the rejected suffix.

    Paged layers mask the pool through the block table: each mapped page
    takes the min ``new_len`` over its owner slots.  Refcount-shared
    prefix pages hold only positions below every owner's prompt length
    (<= any new_len), so they are untouched, and the trash page is
    exempted from the scatter so out-of-range verify writes parked there
    don't leak a limit onto it.  Recurrent / local-ring states have no
    per-position plane and cannot roll back; callers gate speculation to
    all-'global' mixer plans.
    """
    new_len = new_len.astype(jnp.int32)

    def wipe(out, bad):
        out["pos"] = jnp.where(bad, -1, out["pos"])
        for kk in ("k", "v"):
            out[kk] = jnp.where(bad[..., None, None],
                                jnp.zeros_like(out[kk]), out[kk])
        for kk in ("k_scale", "v_scale"):
            # dict-key membership on a static plane name, not traced:
            if kk in out:  # repro-lint: disable=RL102
                out[kk] = jnp.where(bad[..., None],
                                    jnp.zeros_like(out[kk]), out[kk])
        return out

    def roll(c, ax):
        if not (isinstance(c, dict) and "pos" in c):
            return c
        out = dict(c)
        if "block" in c:
            imax = jnp.iinfo(jnp.int32).max

            def pool_mask(blk, pos):
                # per-page limit = min new_len over owner slots; unmapped
                # block entries (-1) land on the trash page, which is
                # reset to "no limit" afterwards
                lim = jnp.full((pos.shape[0],), imax, jnp.int32)
                lim = lim.at[jnp.maximum(blk, 0)].min(
                    jnp.broadcast_to(new_len[:, None], blk.shape))
                lim = lim.at[TRASH_PAGE].set(imax)
                return pos >= lim[:, None]

            # ax is the segment's static batch axis (derive_plan), not
            # traced:
            if ax == 1:  # repro-lint: disable=RL102
                # scanned segment: map over the repeat axis
                bad = jax.vmap(pool_mask)(c["block"], c["pos"])
            else:
                bad = pool_mask(c["block"], c["pos"])
            return wipe(out, bad)
        lim = new_len[None, :, None] if ax == 1 else new_len[:, None]
        return wipe(out, c["pos"] >= lim)

    segs = _map_segments(cfg, roll, caches)
    return {"segments": segs, "pos": new_len}


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _project_qkv(x, ap, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, ap["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, ap["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, ap["wv"])
    if "bq" in ap:
        q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
    return q, k, v


def _rope(q, k, cfg: ModelConfig, kind: str, positions, mrope_pos):
    if cfg.rope_kind == "none":
        return q, k
    theta = cfg.rope_theta
    if kind == "local" and cfg.rope_local_theta:
        theta = cfg.rope_local_theta
    if cfg.rope_kind == "mrope" and mrope_pos is not None:
        return (apply_mrope(q, mrope_pos, theta),
                apply_mrope(k, mrope_pos, theta))
    return apply_rope(q, positions, theta), apply_rope(k, positions, theta)


def _attn_layer(x, ap, cfg: ModelConfig, ctx: ExecContext, spec: LayerSpec,
                positions, cache, mrope_pos, enc_out):
    window = cfg.window_size if spec.mixer == "local" else None
    q, k, v = _project_qkv(x, ap, cfg)
    q, k = _rope(q, k, cfg, spec.mixer, positions, mrope_pos)
    if ctx.mode != "step":
        if ctx.attn_heads_sharded:
            q = ctx.constrain(q, ("batch", None, "heads", None))
            k = ctx.constrain(k, ("batch", None, "kv_heads", None))
            v = ctx.constrain(v, ("batch", None, "kv_heads", None))
        elif ctx.attn_seq_sharded:
            k = ctx.constrain(k, ("batch", "kv_seq", None, None))
            v = ctx.constrain(v, ("batch", "kv_seq", None, None))
    new_cache = cache
    if ctx.mode == "step":
        new_cache = dict(cache)
        kv_keys = ("k", "v", "pos") + (("k_scale", "v_scale")
                                       if "k_scale" in cache else ())
        if "block" in cache:
            # paged: scatter through the block table, then gather each
            # slot's logical view back out of the pool — block-table
            # contents are data, so one compile covers every length mix
            upd = paged_update_attn_cache(
                {kk: cache[kk] for kk in kv_keys + ("block",)},
                k, v, positions)
            new_cache.update(upd)
            kf, vf, posf, ksf, vsf = paged_gather(upd)
            out = decode_attention(q, kf, vf, posf, positions,
                                   window=window, k_scale=ksf, v_scale=vsf)
        else:
            upd = update_attn_cache({kk: cache[kk] for kk in kv_keys},
                                    k, v, positions)
            new_cache.update(upd)
            out = decode_attention(q, upd["k"], upd["v"], upd["pos"],
                                   positions, window=window,
                                   k_scale=upd.get("k_scale"),
                                   v_scale=upd.get("v_scale"))
    else:
        out = attention(q, k, v, positions, positions, causal=True,
                        window=window, q_block=ctx.q_block,
                        unroll=ctx.scan_unroll)
        if ctx.mode == "prefill" and cache is not None:
            new_cache = dict(cache)
            kv_keys = ("k", "v", "pos") + (("k_scale", "v_scale")
                                           if "k_scale" in cache else ())
            upd = prefill_attn_cache({kk: cache[kk] for kk in kv_keys},
                                     k, v, positions)
            new_cache.update(upd)
    y = jnp.einsum("bshk,hkd->bsd", out, ap["wo"])
    # cross-attention (enc-dec decoder)
    if spec.cross:
        xc = rms_norm(x + y, ap["cross_norm"], cfg.norm_eps)
        qc = jnp.einsum("bsd,dhk->bshk", xc, ap["cross_wq"])
        if ctx.mode == "step":
            ck, cv = cache["cross_k"], cache["cross_v"]
        else:
            ck = jnp.einsum("bsd,dhk->bshk", enc_out, ap["cross_wk"])
            cv = jnp.einsum("bsd,dhk->bshk", enc_out, ap["cross_wv"])
            if ctx.mode == "prefill" and new_cache is not None:
                new_cache["cross_k"] = ck.astype(new_cache["cross_k"].dtype)
                new_cache["cross_v"] = cv.astype(new_cache["cross_v"].dtype)
        src = ck.shape[1]
        src_pos = jnp.broadcast_to(jnp.arange(src), (ck.shape[0], src))
        co = attention(qc, ck, cv,
                       jnp.zeros_like(positions) + src,  # no causal masking
                       src_pos, causal=False, q_block=ctx.q_block,
                       unroll=ctx.scan_unroll)
        y = y + jnp.einsum("bshk,hkd->bsd", co, ap["cross_wo"])
    return y, new_cache


def _mlstm_block(x, p, cfg: ModelConfig, ctx: ExecContext, cache):
    mp = p["mlstm"]
    h_in = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    u, z = jnp.split(jnp.einsum("bsd,de->bse", h_in, mp["w_up"]), 2, axis=-1)
    q = jnp.einsum("bse,ehk->bshk", u, mp["wq"])
    k = jnp.einsum("bse,ehk->bshk", u, mp["wk"])
    v = jnp.einsum("bse,ehk->bshk", u, mp["wv"])
    gates = jnp.einsum("bse,eg->bsg", u.astype(jnp.float32), mp["w_if"])
    gates = gates + mp["b_if"]
    nh = cfg.num_heads
    log_i, log_f = gates[..., :nh], jax.nn.log_sigmoid(gates[..., nh:])
    state = cache
    if ctx.mode == "step":
        h, new_state = mlstm_step(q, k, v, log_i, log_f, state)
    else:
        h, new_state = mlstm_chunkwise(q, k, v, log_i, log_f,
                                       state if ctx.mode == "prefill" else None,
                                       chunk=ctx.mlstm_chunk,
                                       unroll=ctx.scan_unroll)
    b, s = x.shape[0], x.shape[1]
    h = h.reshape(b, s, -1)
    h = rms_norm(h, mp["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h, mp["w_down"])
    return x + out, (new_state if ctx.mode in ("prefill", "step") else cache)


def _slstm_block(x, p, cfg: ModelConfig, ctx: ExecContext, cache):
    sp = p["slstm"]
    h_in = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    pre = jnp.einsum("bsd,dghk->bsghk", h_in, sp["w_zifo"]) + sp["b_zifo"]
    rec = {k: sp[k] for k in ("rz", "ri", "rf", "ro")}
    state = cache if ctx.mode in ("prefill", "step") else None
    h, new_state = slstm_seq(pre, rec, state)
    b, s = x.shape[0], x.shape[1]
    h = h.reshape(b, s, -1)
    h = rms_norm(h, sp["out_norm"], cfg.norm_eps)
    x = x + h
    # post-cell gated FFN
    hf = rms_norm(x, sp["ffn_norm"], cfg.norm_eps)
    x = x + ffn_apply(hf, sp["ffn"], cfg.act, True)
    return x, (new_state if ctx.mode in ("prefill", "step") else cache)


def apply_layer(x, p, spec: LayerSpec, cfg: ModelConfig, ctx: ExecContext,
                positions, cache, mrope_pos=None, enc_out=None,
                plan_row=None):
    """One transformer layer.  Returns (x, aux, new_cache, trace, moe_in).

    ``trace`` is the (T, k) top-k expert ids of this layer's router when
    ``ctx.collect_trace`` is set and the layer is MoE, else None (static).
    ``moe_in`` is the (T, d) normed MoE-FFN input when
    ``ctx.collect_moe_inputs`` is set (calibration pass), else None.
    ``plan_row`` is this layer's (2,) int32 [top_n, rank_cap] row of the
    bandwidth controller's restoration plan (None = static QuantConfig).
    """
    aux = {}
    if spec.mixer == "mlstm":
        x, nc = _mlstm_block(x, p, cfg, ctx, cache)
        return x, aux, nc, None, None
    if spec.mixer == "slstm":
        x, nc = _slstm_block(x, p, cfg, ctx, cache)
        return x, aux, nc, None, None

    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    if spec.mixer in ("global", "local"):
        y, nc = _attn_layer(h, p["attn"], cfg, ctx, spec, positions, cache,
                            mrope_pos, enc_out)
    elif spec.mixer == "recurrent":
        if ctx.mode == "step":
            y, new_state = rglru_step(h, p["rglru"], cache)
        else:
            y, new_state = rglru_seq(
                h, p["rglru"],
                h0=cache["h"] if (ctx.mode == "prefill" and cache) else None,
                conv_state=cache["conv"] if (ctx.mode == "prefill" and cache)
                else None)
        nc = new_state if ctx.mode in ("prefill", "step") else cache
    if cfg.post_attn_norm:
        y = rms_norm(y, p["post_norm"], cfg.norm_eps)
    x = x + y

    if spec.ffn == "none":
        return x, aux, nc, None, None
    trace = None
    moe_in = None
    h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    if spec.ffn == "dense":
        if ctx.quantized and "stacks" in p.get("ffn", {}):
            y = ffn_apply_quantized(h, p["ffn"]["stacks"], cfg.act,
                                    cfg.gated_ffn, impl=ctx.kernel_impl)
        else:
            y = ffn_apply(h, p["ffn"], cfg.act, cfg.gated_ffn)
    else:  # moe
        mp = p["moe"]
        if ctx.moe_ep_fn is not None and ctx.ep_mode != "none":
            # topk: (b, s, k); the controller's plan row rides into the
            # shard_map region as replicated data (no recompile on change)
            y, aux, topk = ctx.moe_ep_fn(h, mp, cfg, ctx, plan_row)
        else:
            b, s, d = h.shape
            y2, aux, info = moe_apply(
                h.reshape(-1, d), mp, cfg.moe, act=cfg.act,
                quantized=ctx.quantized and "stacks" in mp,
                exact_capacity=ctx.exact_capacity, impl=ctx.kernel_impl,
                plan=plan_row)
            y = y2.reshape(b, s, d)
            topk = info.topk_idx.reshape(b, s, -1)
        if ctx.collect_trace:
            trace = topk.reshape(-1, topk.shape[-1]).astype(jnp.int32)
        if ctx.collect_moe_inputs:
            moe_in = h.reshape(-1, h.shape[-1]).astype(jnp.float32)
        if "shared" in mp:
            y = y + ffn_apply(h, mp["shared"], cfg.act, True)
    if cfg.post_attn_norm:
        y = rms_norm(y, p["post_ffn_norm"], cfg.norm_eps)
    return x + y, aux, nc, trace, moe_in


# ---------------------------------------------------------------------------
# stack application (scan over segment repeats)
# ---------------------------------------------------------------------------

def _remat(fn, ctx: ExecContext):
    if not ctx.remat:
        return fn
    if ctx.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _zero_aux():
    return {"load_balance": jnp.zeros((), jnp.float32),
            "router_z": jnp.zeros((), jnp.float32)}


def _merge_aux(a, b):
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0.0) + v
    return out


def apply_stack(params, x, cfg: ModelConfig, ctx: ExecContext, positions,
                caches=None, mrope_pos=None, enc_out=None, plan=None):
    """Run all segments.  Returns (x, aux, new_caches, trace, moe_inputs).

    ``trace`` is the stacked (moe_layers, T, k) router top-k ids in global
    layer order when ``ctx.collect_trace`` is set (None otherwise) — the
    first-class replacement for hooking ``moe.route``.  ``moe_inputs``
    is the stacked (moe_layers, T, d) normed MoE-FFN inputs in the same
    order when ``ctx.collect_moe_inputs`` is set (the calibration pass).

    ``plan`` is the bandwidth controller's (num_moe_layers, 2) int32
    [top_n, rank_cap] array in the same global MoE-layer order as the
    trace.  It is *data*, not structure: the array threads into scanned
    segments as scan xs, so runtime plan updates reuse the compiled fn.
    """
    seg_plan_all = derive_plan(cfg)
    aux = _zero_aux()
    new_segs = []
    traces: List[jax.Array] = []
    moe_ins: List[jax.Array] = []
    use_cache = caches is not None and ctx.mode in ("prefill", "step")
    moe_off = 0

    for si, seg in enumerate(seg_plan_all):
        seg_params = params["segments"][si]
        seg_caches = (caches["segments"][si] if use_cache
                      else tuple(None for _ in seg.layers))
        n_moe = sum(1 for spec in seg.layers if spec.ffn == "moe")
        seg_plan = None
        if plan is not None and n_moe:
            cnt = n_moe * seg.repeat
            # global order interleaves positions within each repeat
            # (matches _unstack_scan_traces), so the reshape below lines
            # plan rows up with the scanned repeats
            seg_plan = plan[moe_off:moe_off + cnt]
            moe_off += cnt
            if seg.repeat > 1:
                seg_plan = seg_plan.reshape(seg.repeat, n_moe, 2)

        def group(x, gp, gc, gpl):
            dtype0 = x.dtype
            ga = _zero_aux()
            ncs = []
            trs = []
            mis = []
            mi = 0
            for pi, spec in enumerate(seg.layers):
                row = None
                if gpl is not None and spec.ffn == "moe":
                    row = gpl[mi]
                    mi += 1
                x, a, nc, tr, m_in = apply_layer(x, gp[pi], spec, cfg, ctx,
                                                 positions,
                                                 gc[pi] if use_cache else None,
                                                 mrope_pos, enc_out,
                                                 plan_row=row)
                x = x.astype(dtype0)  # keep scan carry dtype stable
                ga = _merge_aux(ga, a)
                ncs.append(nc if use_cache else 0)
                if tr is not None:
                    trs.append(tr)
                if m_in is not None:
                    mis.append(m_in)
            return x, ga, tuple(ncs), tuple(trs), tuple(mis)

        if seg.repeat == 1:
            x, ga, nc, trs, mis = group(x, seg_params, seg_caches, seg_plan)
            aux = _merge_aux(aux, ga)
            new_segs.append(nc)
            traces.extend(trs)
            moe_ins.extend(mis)
        elif use_cache:
            # the plan (when present) rides the scan as an extra xs leaf
            xs = (seg_params, seg_caches) + (
                (seg_plan,) if seg_plan is not None else ())

            def body_c(carry, xs):
                gp, gc, *gpl = xs
                fn = _remat(group, ctx)
                xo, ga, nc, trs, mis = fn(carry, gp, gc,
                                          gpl[0] if gpl else None)
                return xo, (ga, nc, trs, mis)

            x, (gas, ncs, trs, mis) = jax.lax.scan(body_c, x, xs,
                                                   unroll=ctx.scan_unroll)
            aux = _merge_aux(aux, jax.tree.map(jnp.sum, gas))
            new_segs.append(ncs)
            traces.extend(_unstack_scan_traces(trs))
            moe_ins.extend(_unstack_scan_traces(mis))
        else:
            dummy = tuple(None for _ in seg.layers)
            xs = (seg_params,) + (
                (seg_plan,) if seg_plan is not None else ())

            def body(carry, xs):
                gp, *gpl = xs
                fn = _remat(group, ctx)
                xo, ga, _, trs, mis = fn(carry, gp, dummy,
                                         gpl[0] if gpl else None)
                return xo, (ga, trs, mis)

            x, (gas, trs, mis) = jax.lax.scan(body, x, xs,
                                              unroll=ctx.scan_unroll)
            aux = _merge_aux(aux, jax.tree.map(jnp.sum, gas))
            new_segs.append(0)
            traces.extend(_unstack_scan_traces(trs))
            moe_ins.extend(_unstack_scan_traces(mis))

    new_caches = None
    if use_cache:
        new_caches = {"segments": tuple(new_segs), "pos": positions[:, -1] + 1}
    trace = jnp.stack(traces, axis=0) if traces else None
    moe_inputs = jnp.stack(moe_ins, axis=0) if moe_ins else None
    return x, aux, new_caches, trace, moe_inputs


def _unstack_scan_traces(trs) -> List[jax.Array]:
    """Scan-stacked per-position traces -> flat global layer order.

    ``trs`` is a tuple (one per MoE position in the segment pattern) of
    (repeat, T, k) arrays; global order interleaves positions within each
    repeat: [rep0/pos0, rep0/pos1, ..., rep1/pos0, ...].
    """
    # tuple emptiness test, not array truthiness:
    if not trs:  # repro-lint: disable=RL102
        return []
    stacked = jnp.stack(trs, axis=1)          # (repeat, npos, T, k)
    r, p, t, k = stacked.shape
    return list(stacked.reshape(r * p, t, k))


def apply_encoder(params, embeds, cfg: ModelConfig, ctx: ExecContext):
    """Whisper-style bidirectional encoder over stub frame embeddings."""
    e = cfg.encoder
    dtype = params["encoder"]["layers"]["ffn"]["w1"].dtype
    x = embeds.astype(dtype)
    src = x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(src), (x.shape[0], src))

    def body(carry, lp):
        h = rms_norm(carry, lp["pre_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])
        o = attention(q, k, v, pos, pos, causal=False, q_block=ctx.q_block,
                      unroll=ctx.scan_unroll)
        carry = carry + jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        h = rms_norm(carry, lp["ffn_norm"], cfg.norm_eps)
        carry = carry + ffn_apply(h, lp["ffn"], "gelu", False)
        return carry.astype(dtype), 0

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"],
                        unroll=ctx.scan_unroll)
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)
