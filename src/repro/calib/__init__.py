"""Offline calibration & heterogeneous precision-allocation pipeline.

Three stages (each usable alone; ``launch/compress.py`` chains them):

1. ``stats``    — run a calibration corpus through the jitted forward
                  (first-class router trace + MoE-input collection) and
                  accumulate per-expert routing frequency, gate mass,
                  and input/hidden second moments per MoE layer.
2. ``allocate`` — water-filling/knapsack allocation of per-expert
                  bit-widths and per-(projection, expert) compensator
                  ranks under a global wire-byte budget, with the
                  kurtosis heuristic demoted to one pluggable scorer.
3. ``artifact`` — serialize the resulting ``CompressionPlan`` +
                  compressed stacks so every serving path boots from
                  disk (config/checksum-checked) instead of
                  recompressing at startup.
"""
from .stats import (LayerCalibStats, collect_calibration_stats,
                    stats_summary)
from .allocate import (SCORERS, CompressionPlan, LayerAllocation,
                       allocate_budget, moe_weights_by_layer,
                       plan_wire_bytes, stacks_wire_bytes, uniform_plan,
                       weighted_restoration_error)
from .artifact import (config_fingerprint, load_compression_artifact,
                       save_compression_artifact)
