"""whisper-base [audio]: enc-dec, 6L decoder + 6L encoder, d=512 8H
ff=2048 vocab=51865.  Conv/audio frontend is a STUB: input_specs provides
precomputed (B, 1500, 512) frame embeddings.  [arXiv:2212.04356]"""
from ..config import EncoderConfig, ModelConfig, QuantConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio",
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
        head_dim=64, d_ff=2048, vocab_size=51_865,
        block_pattern=("global",), gated_ffn=False, act="gelu",
        rope_kind="none", abs_pos_embed=True, tie_embeddings=True,
        encoder=EncoderConfig(num_layers=6, d_model=512, num_heads=8,
                              d_ff=2048, source_len=1500),
        frontend="audio_stub",
        quant=QuantConfig(enabled=True, bits=3, rank_budget=16,
                          top_n_restore=1),
        max_position=65_536,
    )
