"""Dense FFN (gated SwiGLU / plain GELU MLP) and its quantized-compensated
form — the degenerate static (E=1) case of the paper's technique used for
the dense assigned archs (DESIGN.md §5)."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..core.pipeline import CompressedExpertStack
from ..kernels import ops
from .layers import activation


def ffn_apply(x: jax.Array, p: Dict[str, jax.Array], act: str = "silu",
              gated: bool = True) -> jax.Array:
    """x: (..., d); params w1 (d, ff), [w3 (d, ff)], w2 (ff, d)."""
    f = activation(act)
    h = jnp.einsum("...d,df->...f", x, p["w1"])
    h = f(h) * jnp.einsum("...d,df->...f", x, p["w3"]) if gated else f(h)
    return jnp.einsum("...f,fd->...d", h, p["w2"])


def ffn_apply_quantized(x: jax.Array, stacks: Dict[str, CompressedExpertStack],
                        act: str = "silu", gated: bool = True,
                        compensate: bool = True,
                        impl: Optional[str] = None) -> jax.Array:
    """Static quantize-then-compensate FFN (single-expert stacks, E=1).

    ``compensate=False`` gives the uniform-quantization baseline.
    """
    shp = x.shape
    xf = x.reshape(-1, shp[-1])
    m = xf.shape[0]
    mask = jnp.ones((m,), jnp.float32) if compensate else jnp.zeros((m,), jnp.float32)

    def proj(name, inp):
        st = stacks[name]
        from ..core.quantize import QuantizedTensor
        qt = QuantizedTensor(tuple(p[0] for p in st.planes), st.scale[0],
                             st.zero[0], st.bits, st.group_size, st.shape[1:])
        return ops.lowrank_comp_matmul(
            inp, qt, st.u[0], st.v[0], st.u_scale[0], st.v_scale[0],
            mask, impl=impl, out_dtype=x.dtype)

    f = activation(act)
    h = proj("w1", xf)
    h = f(h) * proj("w3", xf) if gated else f(h)
    y = proj("w2", h.astype(x.dtype))
    return y.reshape(*shp[:-1], y.shape[-1])
