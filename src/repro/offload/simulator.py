"""Event-driven throughput simulator for offloaded MoE decoding (Fig 7).

Replays real router traces (from the JAX model) through a two-resource
pipeline — transfer link and compute device — with double buffering:
layer l+1's expert fetch overlaps layer l's compute, exactly the
Mixtral-Offloading execution model.  Policies:

  fp16       Mixtral-Offloading: fetch fp16 experts on demand
  quant      HOBBIT-style low-bit uniform fetch
  ours       BEAM-LRC: low-bit fetch + top-n compensators (paper)
  *_ndp      MoNDE-style: cold experts execute on the NDP in low precision,
             only top-n compensated experts run on the fast device

Reported tokens/s is per request stream (batch 1 decode, the paper's
setting), with expert compute times from the hardware profile.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .bandwidth import GPU_NDP, GPU_ONLY, HardwareProfile
from .store import ExpertCache


@dataclasses.dataclass
class LayerSpecSim:
    """Static per-layer description of the MoE being served."""
    d_model: int
    d_expert: int
    num_experts: int
    top_k: int
    bytes_fp16: int          # per expert, all projections
    bytes_quant: int         # per expert, packed low-bit + scales
    comp_bytes: Sequence[int]  # per expert compensator bytes (true ranks)


@dataclasses.dataclass
class SimResult:
    tokens_per_s: float
    transfer_bytes_per_token: float
    transfer_time_frac: float
    cache_hit_rate: float
    compute_time_frac: float


def expert_flops(spec: LayerSpecSim) -> float:
    return 2.0 * 3 * spec.d_model * spec.d_expert


def simulate_decode(trace: np.ndarray, spec: LayerSpecSim,
                    profile: HardwareProfile, policy: str, *,
                    top_n: int = 1, cache_capacity: int = 2,
                    num_layers: int = 32, prefetch: bool = False
                    ) -> SimResult:
    """trace: (tokens, layers, top_k) routed expert ids.

    Two-resource pipeline (link, device).  On-demand mode (default,
    Mixtral-Offloading semantics): a layer's fetch is issued only after the
    previous layer computed (the router decides what to fetch).  With
    ``prefetch=True`` the fetch may start as soon as the link is free
    (oracle layer-ahead prediction).
    """
    ndp = policy.endswith("_ndp")
    base_policy = policy.replace("_ndp", "")
    caches = [ExpertCache(cache_capacity) for _ in range(num_layers)]
    t_link = 0.0      # link busy-until
    t_dev = 0.0       # device busy-until
    busy_link = 0.0
    busy_dev = 0.0
    total_bytes = 0
    eflops = expert_flops(spec)

    tokens = trace.shape[0]
    for tok in range(tokens):
        for layer in range(trace.shape[1]):
            cache = caches[layer % num_layers]
            experts = trace[tok, layer]
            move = 0
            dev_flops = 0.0
            dev_bytes = 0.0
            ndp_time = 0.0
            for rank, e in enumerate(experts):
                e = int(e)
                restored = base_policy == "ours" and rank < top_n
                if ndp and not restored:
                    # cold expert executes near-data in low precision
                    ndp_time += profile.ndp_compute_time(
                        eflops, spec.bytes_quant)
                    continue
                nbytes = (spec.bytes_fp16 if base_policy == "fp16"
                          else spec.bytes_quant)
                if restored:
                    nbytes += int(spec.comp_bytes[e])
                if not cache.access(e, nbytes):
                    move += nbytes
                dev_flops += eflops
                dev_bytes += nbytes
            # fetch issue time: on-demand waits for the router (= prev
            # layer's compute); prefetch only for the link itself
            issue = t_link if prefetch else max(t_link, t_dev)
            tt = profile.transfer_time(move) if move else 0.0
            t_ready = issue + tt
            t_link = t_ready
            busy_link += tt
            # device: compute is max(flop-time, weight-streaming from HBM)
            comp = max(profile.compute_time(dev_flops),
                       profile.hbm_time(dev_bytes))
            start = max(t_ready, t_dev)
            t_dev = start + comp + ndp_time
            busy_dev += comp + ndp_time
            total_bytes += move
    wall = max(t_link, t_dev)
    hit = float(np.mean([c.stats.hit_rate for c in caches]))
    return SimResult(
        tokens_per_s=tokens / wall if wall > 0 else float("inf"),
        transfer_bytes_per_token=total_bytes / tokens,
        transfer_time_frac=busy_link / wall if wall else 0.0,
        cache_hit_rate=hit,
        compute_time_frac=busy_dev / wall if wall else 0.0)


def make_router_trace(probs_fn, tokens: int, layers: int, top_k: int,
                      seed: int = 0, skew: float = 0.0,
                      num_experts: int = 8) -> np.ndarray:
    """Synthetic fallback trace with controllable router skew (benchmarks
    prefer real traces exported from the JAX model)."""
    rng = np.random.default_rng(seed)
    out = np.zeros((tokens, layers, top_k), np.int64)
    base = rng.dirichlet(np.ones(num_experts) * (1.0 - skew + 0.05),
                         size=layers)
    for t in range(tokens):
        for l in range(layers):
            p = base[l] + rng.dirichlet(np.ones(num_experts)) * 0.3
            p /= p.sum()
            out[t, l] = np.argsort(-p)[:top_k]
    return out
