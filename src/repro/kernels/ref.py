"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the numerical ground truth the kernels are validated against in
``tests/test_kernels_*.py`` and the path the multi-pod dry-run lowers (so
cost_analysis reports real FLOPs, not interpreter scaffolding).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.quantize import PACK_BLOCK, unpack_bits


def dequant_ref(planes: Tuple[jax.Array, ...], scale: jax.Array,
                zero: jax.Array, bits: int, group_size: int,
                dtype=jnp.float32) -> jax.Array:
    """(planes, scale, zero) -> dense (K, N) weights."""
    q = unpack_bits(planes, bits).astype(jnp.float32)
    k, n = q.shape
    g = q.reshape(k // group_size, group_size, n)
    w = (g - zero[:, None, :]) * scale[:, None, :]
    return w.reshape(k, n).astype(dtype)


def quant_matmul_ref(x: jax.Array, planes: Tuple[jax.Array, ...],
                     scale: jax.Array, zero: jax.Array, bits: int,
                     group_size: int, out_dtype=jnp.float32) -> jax.Array:
    """y = x @ dequant(Wq);  x: (M, K) -> (M, N)."""
    from ..core.restoration import compute_dtype
    dt = compute_dtype()
    w = dequant_ref(planes, scale, zero, bits, group_size, dtype=dt)
    return jnp.dot(x.astype(dt), w,
                   preferred_element_type=jnp.float32).astype(out_dtype)


def lowrank_comp_matmul_ref(x: jax.Array, planes: Tuple[jax.Array, ...],
                            scale: jax.Array, zero: jax.Array, bits: int,
                            group_size: int,
                            u: jax.Array, v: jax.Array,
                            u_scale: jax.Array, v_scale: jax.Array,
                            mask: Optional[jax.Array],
                            out_dtype=jnp.float32,
                            rank_cap: Optional[jax.Array] = None) -> jax.Array:
    """y = x @ dequant(Wq) + ((x*mask) @ U) @ V  — paper §3.2 restoration.

    u: (K, R) codes, u_scale: (1, R);  v: (R, N) codes, v_scale: (R, 1);
    mask: (M,) 0/1 per-token compensation gate (None = all tokens);
    rank_cap: traced scalar ceiling on the compensator rank (None = R).
    Factors are rank-padded, so the cap is a 0/1 mask over the rank-space
    activation — rank_cap >= the true rank is bit-exact identity.
    """
    y = quant_matmul_ref(x, planes, scale, zero, bits, group_size)
    xf = x.astype(jnp.float32)
    if mask is not None:
        xf = xf * mask[:, None].astype(jnp.float32)
    ud = u.astype(jnp.float32) * u_scale
    xu = jnp.dot(xf, ud, preferred_element_type=jnp.float32)
    if rank_cap is not None:
        xu = xu * (jnp.arange(u.shape[-1]) < rank_cap).astype(jnp.float32)
    vd = v.astype(jnp.float32) * v_scale
    y = y + jnp.dot(xu, vd, preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


def fused_expert_matmul_ref(xe: jax.Array, planes: Tuple[jax.Array, ...],
                            scale: jax.Array, zero: jax.Array, bits: int,
                            group_size: int,
                            u: jax.Array, v: jax.Array,
                            u_scale: jax.Array, v_scale: jax.Array,
                            me: jax.Array,
                            ge: Optional[jax.Array] = None,
                            rank_cap: Optional[jax.Array] = None,
                            out_dtype=jnp.float32) -> jax.Array:
    """Oracle for the fused decode kernel: per-expert compensated matmul
    with the gate-weighted combine epilogue folded in.

    xe: (E, C, K) dispatched tokens;  planes[i]: (E, K//c_i, N);
    scale/zero: (E, K//G, N);  u: (E, K, R);  v: (E, R, N);
    me: (E, C) top-n compensation mask;  ge: (E, C) router gates (None =
    unweighted);  rank_cap: traced scalar ceiling (None = full pad rank).

    Per-expert TRUE bit widths need no special handling here: hetero
    stacks store sub-width codes in a shared container whose upper bit
    planes are zero, so unpacking at the container width is bit-exact
    (the kernel masks those planes explicitly; this oracle relies on the
    container invariant).
    """
    def one(xe_e, planes_e, scale_e, zero_e, u_e, v_e, us_e, vs_e, me_e):
        return lowrank_comp_matmul_ref(
            xe_e, planes_e, scale_e, zero_e, bits, group_size,
            u_e, v_e, us_e, vs_e, me_e, jnp.float32, rank_cap=rank_cap)

    ye = jax.vmap(one)(xe, planes, scale, zero, u, v, u_scale, v_scale, me)
    if ge is not None:
        ye = ye * ge[..., None].astype(ye.dtype)
    return ye.astype(out_dtype)
