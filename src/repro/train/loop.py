"""Fault-tolerant training loop.

Production behaviors, all exercised by tests on CPU:
- checkpoint/restart: atomic saves every N steps, auto-resume from the
  latest valid checkpoint (torn writes fall back one step);
- failure injection: a ``FailureInjector`` can kill the loop at a chosen
  step; the restart test asserts loss-curve continuity;
- straggler monitor: per-step wall-clock EWMA with a deadline policy
  (warn / abort) — on real pods this feeds the controller that evicts
  slow hosts; here it logs and counts;
- deterministic data: batch(step) is a pure function, so resume replays
  the exact stream (no data-loader state in the checkpoint).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..config import ModelConfig, TrainConfig
from ..data.synthetic import SyntheticLM, SyntheticLMConfig
from ..launch.steps import TrainState, make_train_step
from ..models.transformer import init_params
from ..optim.adamw import adamw_init


class StragglerMonitor:
    """EWMA step-time tracker with a relative deadline policy."""

    def __init__(self, threshold: float = 3.0, warmup: int = 5,
                 policy: str = "warn"):
        self.threshold = threshold
        self.warmup = warmup
        self.policy = policy
        self.ewma: Optional[float] = None
        self.seen = 0
        self.flagged: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        self.seen += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = (self.seen > self.warmup
                and dt > self.threshold * self.ewma)
        if slow:
            self.flagged.append(step)
            if self.policy == "abort":
                raise TimeoutError(
                    f"step {step} took {dt:.3f}s > "
                    f"{self.threshold}x EWMA {self.ewma:.3f}s")
        self.ewma = 0.9 * self.ewma + 0.1 * dt
        return slow


class FailureInjector:
    """Deterministic crash injection for restart tests."""

    def __init__(self, fail_at_step: Optional[int] = None):
        self.fail_at_step = fail_at_step

    def maybe_fail(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class TrainResult:
    state: Any
    history: List[Dict[str, float]]
    resumed_from: Optional[int]
    straggler_flags: List[int]


def train(cfg: ModelConfig, tcfg: TrainConfig, *,
          data: Optional[SyntheticLM] = None,
          checkpoint_dir: Optional[str] = None,
          mesh=None, pcfg=None,
          failure: Optional[FailureInjector] = None,
          straggler: Optional[StragglerMonitor] = None,
          log_every: int = 10,
          param_dtype=jnp.float32,
          batch_shape=(8, 128),
          init_fn=None) -> TrainResult:
    data = data or SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, batch_size=batch_shape[0],
        seq_len=batch_shape[1], seed=tcfg.seed))
    ckpt = CheckpointManager(checkpoint_dir, tcfg.keep_checkpoints) \
        if checkpoint_dir else None
    straggler = straggler or StragglerMonitor()

    step_fn, _ = make_train_step(cfg, tcfg, mesh=mesh, pcfg=pcfg,
                                 param_dtype=param_dtype)
    step_fn = jax.jit(step_fn, donate_argnums=(0,))

    # init or resume
    resumed_from = None
    start = 0
    if init_fn is not None:
        params = init_fn(jax.random.key(tcfg.seed))
    else:
        params = init_params(jax.random.key(tcfg.seed), cfg, param_dtype)
    state = TrainState(params, adamw_init(params))
    if ckpt and ckpt.latest_step() is not None:
        state, man = ckpt.restore(state)
        start = man["step"]
        resumed_from = start

    history: List[Dict[str, float]] = []
    for step in range(start, tcfg.total_steps):
        if failure:
            failure.maybe_fail(step)
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, metrics = step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.time() - t0
        straggler.observe(step, dt)
        metrics.update(step=step, dt=dt)
        history.append(metrics)
        if log_every and step % log_every == 0:
            print(f"step {step:5d} loss={metrics['loss']:.4f} "
                  f"gnorm={metrics['grad_norm']:.3f} {dt * 1e3:.0f}ms",
                  flush=True)
        if ckpt and (step + 1) % tcfg.checkpoint_every == 0:
            ckpt.save(step + 1, state, extra={"loss": metrics["loss"]})
    if ckpt:
        ckpt.save(tcfg.total_steps, state,
                  extra={"loss": history[-1]["loss"] if history else None})
    return TrainResult(state, history, resumed_from, straggler.flagged)
