"""§Perf report: compare hillclimb variants against each cell's baseline.

Reads experiments/perf/*.json (tagged dry-run artifacts produced by
``repro.launch.dryrun --opt ...``) and prints per-cell iteration tables:
three roofline terms, the dominant one, and the delta vs baseline.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from pathlib import Path

PERF = Path("experiments/perf")


def load():
    cells = defaultdict(dict)
    for f in sorted(PERF.glob("*.json")):
        rec = json.loads(f.read_text())
        parts = rec["cell"].split("|")
        key = "|".join(parts[:3])
        tag = parts[3] if len(parts) > 3 else "baseline"
        cells[key][tag] = rec
    return cells


def fmt_row(tag, rec, base=None):
    r = rec["roofline"]
    terms = (r["t_compute"], r["t_memory"], r["t_collective"])
    dom = max(terms)
    line = (f"| {tag:28s} | {terms[0]*1e3:10.2f} | {terms[1]*1e3:10.2f} "
            f"| {terms[2]*1e3:10.2f} | {r['dominant']:10s} ")
    if base is not None:
        b = base["roofline"]
        bdom = max(b["t_compute"], b["t_memory"], b["t_collective"])
        line += f"| {100 * (dom - bdom) / bdom:+7.1f}% |"
    else:
        line += "| baseline |"
    return line


def main():
    cells = load()
    for key, variants in cells.items():
        print(f"\n### {key}")
        print("| variant | compute ms | memory ms | collective ms | "
              "dominant | Δ dominant |")
        print("|---|---|---|---|---|---|")
        base = variants.get("baseline")
        if base:
            print(fmt_row("baseline", base))
        for tag, rec in sorted(variants.items()):
            if tag == "baseline":
                continue
            print(fmt_row(tag, rec, base))


def run(quick: bool = True):
    out = []
    for key, variants in load().items():
        base = variants.get("baseline")
        if not base:
            continue
        b = base["roofline"]
        bdom = max(b["t_compute"], b["t_memory"], b["t_collective"])
        for tag, rec in variants.items():
            r = rec["roofline"]
            dom = max(r["t_compute"], r["t_memory"], r["t_collective"])
            out.append({"name": f"perf/{key}/{tag}",
                        "dom_ms": dom * 1e3,
                        "delta_pct": 100 * (dom - bdom) / bdom})
    return out


if __name__ == "__main__":
    main()
