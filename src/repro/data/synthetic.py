"""Deterministic synthetic LM data: a Zipfian Markov stream with enough
structure (bigram dependencies) that a small model measurably learns —
perplexity drops well below unigram entropy — so compression benchmarks
can report honest quality deltas.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class SyntheticLMConfig:
    vocab_size: int = 512
    seq_len: int = 128
    batch_size: int = 8
    zipf_a: float = 1.2          # unigram skew
    markov_states: int = 4       # bigram structure (few states = learnable)
    seed: int = 0


class SyntheticLM:
    """Stateless, shardable token stream: batch i is a pure function of
    (seed, step, i), so restarts and elastic re-sharding reproduce the
    exact stream."""

    def __init__(self, cfg: SyntheticLMConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # unigram Zipf over vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (ranks ** -cfg.zipf_a)
        self.unigram /= self.unigram.sum()
        # each "state" (prev token % states) has its own permuted Zipf
        self.perms = np.stack([rng.permutation(v)
                               for _ in range(cfg.markov_states)])

    def _token_probs(self, prev: np.ndarray) -> np.ndarray:
        state = prev % self.cfg.markov_states
        return self.unigram[np.argsort(self.perms[state], axis=-1)]

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, 0xBEA]))
        toks = np.zeros((cfg.batch_size, cfg.seq_len), np.int32)
        toks[:, 0] = rng.choice(cfg.vocab_size, size=cfg.batch_size,
                                p=self.unigram)
        for t in range(1, cfg.seq_len):
            p = self._token_probs(toks[:, t - 1])
            u = rng.random((cfg.batch_size, 1))
            toks[:, t] = (p.cumsum(axis=-1) < u).sum(axis=-1)
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

    def entropy_floor(self) -> float:
        """Per-token entropy of the conditional distribution (nats) — the
        best achievable loss; useful to judge training progress."""
        p = self.unigram
        return float(-(p * np.log(p)).sum())
