"""Link/compute cost models for the offloading simulator (paper §4.1).

Hardware profiles mirror the paper's two deployments — GPU-only (H100 +
PCIe to host DDR) and GPU-NDP (H100 + 512 GB/s near-data device) — plus a
TPU v5e host-offload profile for the TPU adaptation.  Times are analytic
(bytes / effective_bandwidth, flops / peak) and feed an event-driven
simulator, the same methodology as MoNDE's Ramulator-backed evaluation at
the granularity the paper reports (tokens/s).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareProfile:
    name: str
    compute_flops: float          # dense bf16/fp16 peak of the fast device
    hbm_bw: float                 # fast-device memory bandwidth
    link_bw: float                # host<->device transfer bandwidth
    link_latency: float = 8e-6    # per-transfer latency
    ndp_bw: float = 0.0           # near-data device internal bandwidth
    ndp_flops: float = 0.0        # near-data compute (low-bit GEMV-class)

    def transfer_time(self, nbytes: float) -> float:
        return self.link_latency + nbytes / self.link_bw

    def compute_time(self, flops: float) -> float:
        return flops / self.compute_flops

    def hbm_time(self, nbytes: float) -> float:
        return nbytes / self.hbm_bw

    def ndp_compute_time(self, flops: float, nbytes: float) -> float:
        """NDP executes low-bit experts in memory: bandwidth-dominated."""
        t_bw = nbytes / self.ndp_bw if self.ndp_bw else float("inf")
        t_fl = flops / self.ndp_flops if self.ndp_flops else 0.0
        return max(t_bw, t_fl)


# paper §4.1: H100 PCIe (989.4 TFLOPS, 80 GB HBM3); PCIe gen5 x16
# sustains ~25 GB/s effective in Mixtral-Offloading-style pipelines.
GPU_ONLY = HardwareProfile(
    name="gpu-only-h100",
    compute_flops=989.4e12, hbm_bw=3.35e12, link_bw=25e9)

# paper §4.1: NDP device with 512 GB/s internal bandwidth, 512 GB capacity.
GPU_NDP = HardwareProfile(
    name="gpu-ndp-h100",
    compute_flops=989.4e12, hbm_bw=3.35e12, link_bw=25e9,
    ndp_bw=512e9, ndp_flops=16e12)

# TPU v5e adaptation: host DRAM offload over ~100 GB/s host link;
# chip constants per the assignment.
TPU_V5E_OFFLOAD = HardwareProfile(
    name="tpu-v5e-offload",
    compute_flops=197e12, hbm_bw=819e9, link_bw=100e9)

PROFILES = {p.name: p for p in (GPU_ONLY, GPU_NDP, TPU_V5E_OFFLOAD)}
