"""Expert-parallel sharded serving: the PR-4 headline invariants.

One multi-device script (via the shared ``dist_run`` fixture) serves the
same request set through ``ServeEngine`` across the full parity matrix

    ``REPRO_KERNEL_IMPL`` in {ref, pallas_interpret}
  x arch in {MoE (E=8, k=2), dense-degenerate (E=1, k=1)}
  x shard counts {1, 2, 8}

and the tests pin:

- token-identical decode across shard counts AND kernel impls, with
  allclose per-token logprobs (the psum/a2a reduction order may differ
  in low-order bits; the sampled streams may not);
- conserved offload metering: total wire bytes, metered tokens, and
  cache hit/miss counts are IDENTICAL across shard counts (per-shard
  caches large enough to hold their residents — eviction-free regime,
  where the per-expert residency state decomposes exactly over any
  expert partition), and the per-shard bytes sum to the total;
- the bandwidth controller drives the plan under sharding with ZERO new
  decode-scan compiles across plan/budget changes, and a a sharded serve
  with per-shard metering feeds chunk updates at every boundary;
- (PR 5) a calibrated heterogeneous-precision artifact saved on a
  1-device mesh restores into ep=2 / ep=8 serving token-identically,
  with the per-expert (heterogeneous-bit) wire bytes conserved EXACTLY
  across shard counts and per-shard bytes summing to the total.
"""
import textwrap

import numpy as np
import pytest

pytestmark = pytest.mark.dist

IMPLS = ("ref", "pallas_interpret")
ARCHS = ("moe", "dense_e1")
EPS = (1, 2, 8)

SCRIPT = textwrap.dedent("""
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import ControlConfig, ModelConfig, MoEConfig, \\
        QuantConfig
    from repro.launch.mesh import make_serve_mesh
    from repro.models import init_params
    from repro.models.transformer import compress_moe_params
    from repro.serve import ServeEngine, synthetic_workload

    def make_cfg(e, k):
        return ModelConfig(
            name=f"ep-serve-{e}", family="moe", num_layers=2, d_model=64,
            num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0, vocab_size=64,
            block_pattern=("global",), max_position=512,
            moe=MoEConfig(num_experts=e, top_k=k, d_expert=64,
                          quant=QuantConfig(enabled=True, bits=2,
                                            rank_budget=8, top_n_restore=1,
                                            hqq_iters=2)))

    prompts = [np.random.default_rng(i).integers(0, 64, (5 + 3 * i,))
               for i in range(3)]
    results = {}

    for arch, (e, k) in (("moe", (8, 2)), ("dense_e1", (1, 1))):
        cfg = make_cfg(e, k)
        params = init_params(jax.random.key(0), cfg, jnp.float32)
        qparams, cfg_q, stacks = compress_moe_params(params, cfg)
        for impl in ("ref", "pallas_interpret"):
            for ep in (1, 2, 8):
                eng = ServeEngine(cfg_q, qparams, quantized=True,
                                  kernel_impl=impl, mesh=make_serve_mesh(ep))
                # eviction-free regime: per-shard capacity >= residents at
                # every shard count, so byte totals must conserve exactly
                eng.attach_offload(stacks, policy="ours", cache_capacity=8,
                                   prefetch=False)
                stats = eng.generate_many(prompts, max_new=6, num_slots=2,
                                          chunk=3)
                rep = stats.offload_report
                results[f"{arch}/{impl}/ep{ep}"] = {
                    "tokens": np.concatenate(
                        [r.tokens for r in stats.results]).tolist(),
                    "logprobs": np.concatenate(
                        [r.logprobs for r in stats.results]).tolist(),
                    "total_bytes": rep["total_bytes"],
                    "metered_tokens": rep["tokens"],
                    "hits_misses": [int(1e9 * rep["hit_rate"])],
                    "per_shard_bytes": rep["per_shard_bytes"],
                    "ep": rep["ep"],
                    "shard_bytes": (stats.shard_bytes.tolist()
                                    if stats.shard_bytes is not None
                                    else None),
                }

    # controller under sharding: plan moves, decode scan never recompiles
    cfg = make_cfg(8, 2)
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    qparams, cfg_q, stacks = compress_moe_params(params, cfg)
    eng = ServeEngine(cfg_q, qparams, quantized=True, mesh=make_serve_mesh(2))
    eng.attach_offload(stacks, policy="ours", cache_capacity=2)
    eng.attach_controller(ControlConfig(enabled=True, bytes_per_token=1.0,
                                        gain=0.5))
    wl = lambda: synthetic_workload(5, 64, max_new=8, seed=3)
    s1 = eng.serve(wl(), num_slots=2, chunk=4)
    compiles_warm = eng.num_compiles["decode"]
    # a very different budget => different per-chunk plans, same compile
    eng.attach_offload(stacks, policy="ours", cache_capacity=2)
    eng.attach_controller(ControlConfig(enabled=True,
                                        bytes_per_token=50_000.0, gain=0.5))
    s2 = eng.serve(wl(), num_slots=2, chunk=4)
    results["controller"] = {
        "plan_moved": bool(not (s1.plan_trace == s1.plan_trace[0]).all()),
        "plans_differ_across_budgets": bool(
            not (s2.plan_trace == s1.plan_trace).all()),
        "decode_compiles_warm": compiles_warm,
        "decode_compiles_after": eng.num_compiles["decode"],
        "controller_updates": len(eng.controller.history),
        "chunks": s2.chunks,
    }

    # calibrated heterogeneous artifact: save once (1-device mesh),
    # restore into every shard count (extends the parity matrix)
    import tempfile
    from repro.calib import (allocate_budget, collect_calibration_stats,
                             load_compression_artifact,
                             moe_weights_by_layer,
                             save_compression_artifact, uniform_plan)
    from repro.models.transformer import apply_compressed_stacks
    cfg = make_cfg(8, 2)
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    cstats = collect_calibration_stats(cfg, params, batches=1,
                                       batch_size=2, seq_len=32)
    weights = moe_weights_by_layer(params, cfg)
    qcfg = cfg.moe.quant
    plan = allocate_budget(
        weights, qcfg, uniform_plan(weights, qcfg, 3, 4).spent_bytes,
        stats=cstats)
    qparams, cfg_q, stacks = compress_moe_params(params, cfg, plan=plan,
                                                 stats=cstats)
    tmp = tempfile.mkdtemp()
    save_compression_artifact(tmp, cfg, stacks, plan=plan)
    loaded, _, _ = load_compression_artifact(tmp, cfg)
    qp_art, _ = apply_compressed_stacks(params, cfg, loaded)
    for label, prm, stk, eps in (("mem", qparams, stacks, (1,)),
                                 ("art", qp_art, loaded, (1, 2, 8))):
        for ep in eps:
            eng = ServeEngine(cfg_q, prm, quantized=True,
                              mesh=make_serve_mesh(ep))
            eng.attach_offload(stk, policy="ours", cache_capacity=8,
                               prefetch=False)
            st = eng.generate_many(prompts, max_new=4, num_slots=2, chunk=2)
            rep = st.offload_report
            store0 = eng._stores[0]
            results[f"artifact/{label}/ep{ep}"] = {
                "tokens": np.concatenate(
                    [r.tokens for r in st.results]).tolist(),
                "logprobs": np.concatenate(
                    [r.logprobs for r in st.results]).tolist(),
                "total_bytes": rep["total_bytes"],
                "per_shard_bytes": rep["per_shard_bytes"],
                "expert_bytes": [store0.expert_bytes(e, "ours")
                                 for e in range(8)],
            }
    print("RESULTS:" + json.dumps(results))
""")


@pytest.fixture(scope="module")
def serve_results(dist_run):
    return dist_run(SCRIPT, timeout=580)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("arch", ARCHS)
def test_sharded_serve_token_identical(serve_results, arch, impl):
    """ep=2 / ep=8 decode must reproduce the ep=1 token stream exactly,
    with allclose per-token logprobs."""
    base = serve_results[f"{arch}/{impl}/ep1"]
    for ep in EPS[1:]:
        got = serve_results[f"{arch}/{impl}/ep{ep}"]
        assert got["tokens"] == base["tokens"], (arch, impl, ep)
        np.testing.assert_allclose(got["logprobs"], base["logprobs"],
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_cross_impl_token_identical(serve_results, arch):
    """ref and pallas_interpret backends agree token-for-token at every
    shard count (the dispatch policy changes kernels, not results)."""
    for ep in EPS:
        a = serve_results[f"{arch}/ref/ep{ep}"]
        b = serve_results[f"{arch}/pallas_interpret/ep{ep}"]
        assert a["tokens"] == b["tokens"], (arch, ep)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("arch", ARCHS)
def test_metered_bytes_conserved_across_shard_counts(serve_results, arch,
                                                     impl):
    """Total wire bytes/token, metered tokens, and hit rates are identical
    across shard counts in the eviction-free regime; per-shard bytes sum
    exactly to the total (the ServeStats reduction loses nothing)."""
    base = serve_results[f"{arch}/{impl}/ep1"]
    assert base["total_bytes"] > 0
    for ep in EPS:
        got = serve_results[f"{arch}/{impl}/ep{ep}"]
        assert got["total_bytes"] == base["total_bytes"], (arch, impl, ep)
        assert got["metered_tokens"] == base["metered_tokens"]
        assert got["hits_misses"] == base["hits_misses"]
        assert sum(got["per_shard_bytes"]) == got["total_bytes"]
        assert got["shard_bytes"] == got["per_shard_bytes"]


def test_moe_experts_actually_spread_across_shards(serve_results):
    """At ep=8 the MoE arch's traffic crosses several distinct links —
    the partition is real, not one shard doing all the work."""
    got = serve_results["moe/ref/ep8"]
    assert got["ep"] == 8 and len(got["per_shard_bytes"]) == 8
    assert sum(1 for b in got["per_shard_bytes"] if b > 0) >= 4
    # E=1 cannot partition: the engine falls back to a single store
    assert serve_results["dense_e1/ref/ep8"]["ep"] == 1


def test_artifact_restores_bit_identically_on_one_device(serve_results):
    """Booting the saved calibrated artifact reproduces in-memory
    compression of the same plan exactly (tokens, logprobs, bytes)."""
    mem = serve_results["artifact/mem/ep1"]
    art = serve_results["artifact/art/ep1"]
    assert art["tokens"] == mem["tokens"]
    assert art["logprobs"] == mem["logprobs"]
    assert art["total_bytes"] == mem["total_bytes"] > 0


def test_artifact_sharded_serving_token_identical(serve_results):
    """A 1-device-saved artifact restored into ep=2 / ep=8 serving
    decodes the identical token stream."""
    base = serve_results["artifact/art/ep1"]
    for ep in (2, 8):
        got = serve_results[f"artifact/art/ep{ep}"]
        assert got["tokens"] == base["tokens"], ep
        np.testing.assert_allclose(got["logprobs"], base["logprobs"],
                                   rtol=1e-4, atol=1e-4)


def test_artifact_hetero_bytes_conserved_across_shards(serve_results):
    """The calibrated plan's heterogeneous per-expert wire bytes flow
    through the sharded metering with EXACT conservation: totals match
    at every shard count and per-shard bytes sum to the total."""
    base = serve_results["artifact/art/ep1"]
    # the allocation is really heterogeneous, or this test proves nothing
    assert len(set(base["expert_bytes"])) > 1
    for ep in (1, 2, 8):
        got = serve_results[f"artifact/art/ep{ep}"]
        assert got["total_bytes"] == base["total_bytes"]
        assert sum(got["per_shard_bytes"]) == got["total_bytes"]
        assert got["expert_bytes"] == base["expert_bytes"]


def test_controller_moves_plan_without_decode_recompile(serve_results):
    """Under an ep=2 mesh the budget controller changes the per-chunk
    restoration plan (both within a serve and across budgets) while the
    compiled decode scan is reused — plan is data, not shape."""
    c = serve_results["controller"]
    assert c["plan_moved"]
    assert c["plans_differ_across_budgets"]
    assert c["decode_compiles_after"] == c["decode_compiles_warm"]
    assert c["controller_updates"] >= c["chunks"]
