"""Batched serving engine: chunked prefill + jitted streaming decode loop
+ continuous-batching request scheduling.

The decode loop is a single ``lax.scan`` over steps: sampling happens
on-device (no per-token host round-trip), cache buffers are donated into
the loop, and the per-step router trace is a first-class output of the
forward pass (``ExecContext.collect_trace``).

Compiled shapes are *bucketed* so they survive ragged traffic:

- cache lengths round up to powers of two, so every (prompt, max_new)
  pair in a bucket reuses the same compiled prefill + decode loop;
- prompts right-pad to a power-of-two length and the padded cache slots
  are invalidated (``mask_cache_padding``: pos = -1) so padded decode is
  bit-identical to unpadded;
- ``serve``/``generate_many`` run the decode scan in fixed-size chunks
  over a slot-indexed cache: between chunks the ``serve/scheduler.py``
  scheduler retires finished requests and refills their slots from the
  queue — many requests, one resident compiled loop.

When expert stores are attached (``attach_offload``), every generated
step's routing decisions are replayed into the per-layer metered
``ExpertStore`` + ``LayerAheadPrefetcher``, so wire bytes / cache hits /
prefetch accuracy come from live serving rather than only the synthetic
simulator; inactive scheduler slots are masked (expert id -1) before
metering.

With a serving mesh (``mesh=make_serve_mesh(ep)``) the same entry
points run expert-parallel: experts partition over the mesh's ``model``
axis, the decode scan executes the MoE layers under shard_map
(resident-expert partials + psum), the offload meter splits into
per-shard stores whose link bytes reduce into ``ServeStats``, and the
controller can budget either the aggregate or the hottest shard link
(``ControlConfig.budget_scope``).  See ARCHITECTURE.md
§Expert-parallel sharded serving.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ControlConfig, ModelConfig, ParallelConfig, ServeConfig
from ..distributed.moe_parallel import ep_size
from ..distributed.sharding import (CACHE_RULES, PARAM_RULES,
                                    tree_constraint, tree_shardings)
from ..models import model as lm
from ..models.transformer import (ExecContext, cache_claim_slot,
                                  cache_claim_slot_paged, cache_reset_slot_paged,
                                  cache_rollback, cache_seed_prefix,
                                  init_caches, init_paged_caches, layer_specs,
                                  mask_cache_padding)
from ..launch.steps import make_context
from .controller import BandwidthController, ControllerPlan
from .paging import PagePool, prefix_page_hashes
from .scheduler import Request, RequestResult, Scheduler
from .speculative import accept_drafts, make_drafter, mask_banned

PROMPT_BUCKET_MIN = 16     # smallest padded-prompt length
CACHE_BUCKET_MIN = 32      # smallest bucketed cache length


def bucket_len(n: int, minimum: int = CACHE_BUCKET_MIN) -> int:
    """Round ``n`` up to the next power of two (>= minimum) — the length
    buckets that keep jit cache keys finite under ragged traffic."""
    return max(minimum, 1 << max(int(n) - 1, 0).bit_length())


def _round_up(n: int, m: int) -> int:
    return (n + m - 1) // m * m


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray             # (B, max_new)
    logprobs: Optional[np.ndarray]
    prefill_s: float
    decode_s: float
    steps: int
    # (steps, moe_layers, B, k) decode-time router decisions (None when the
    # model has no MoE layers)
    router_trace: Optional[np.ndarray] = None
    # live offload metering (attach_offload): bytes/token, hit rate, ...
    offload_report: Optional[Dict[str, float]] = None
    # async streaming engine counters (attach_streaming): overlap
    # efficiency, stalls, degraded tokens, observed copies, ...
    stream_report: Optional[Dict] = None

    @property
    def decode_tokens_per_s(self) -> float:
        b = self.tokens.shape[0]
        return b * self.steps / self.decode_s if self.decode_s else 0.0

    def request_trace(self, b: int = 0) -> Optional[np.ndarray]:
        """(steps, layers, k) routing of one request stream — the shape the
        offload simulator and fig-7 benchmarks consume."""
        if self.router_trace is None:
            return None
        return self.router_trace[:, :, b, :]


@dataclasses.dataclass
class ServeStats:
    """Outcome of one continuous-batching ``serve`` run."""
    results: List[RequestResult]       # submission order
    num_slots: int
    chunk: int
    total_s: float
    prefill_s: float
    decode_s: float
    chunks: int
    generated_tokens: int              # accepted tokens across requests
    offload_report: Optional[Dict] = None
    # (total_steps, moe_layers, num_slots, k) with -1 on inactive slots
    router_trace: Optional[np.ndarray] = None
    # (chunks, moe_layers, 2) per-chunk controller plan [top_n, rank_cap]
    # (None when no bandwidth controller is attached)
    plan_trace: Optional[np.ndarray] = None
    # (ep,) wire bytes that crossed each expert-parallel shard's link
    # (the per-shard reduction; length 1 on the single-device path)
    shard_bytes: Optional[np.ndarray] = None
    # async streaming counters (attach_streaming): overlap efficiency,
    # transfer/stall seconds, degraded tokens, observed copies, ...
    stream_report: Optional[Dict] = None
    # device bytes held by the serve run's KV/recurrent cache (every
    # plane, incl. page pools + block tables on the paged path) — the
    # HBM-side cost the paged cache exists to shrink
    cache_hbm_bytes: int = 0
    # padded prompt tokens pushed through prefill (suffix-only prefills
    # count only their suffix, so shared-prefix reuse shows up here)
    prefill_tokens: int = 0
    # page-pool accounting (paged runs): allocs/frees, prefix hit rate,
    # peak shared refcount, evictions (None on the contiguous path)
    page_report: Optional[Dict] = None
    # speculative decoding (serve(spec_k=)): draft acceptance rate,
    # lookahead prefetch accuracy, draft overhead bytes (None = spec off)
    spec_report: Optional[Dict] = None

    def __post_init__(self):
        # zero-token requests carry first_token_s = NaN (an explicit
        # sentinel, excluded from percentiles); any *negative* finite
        # latency is a scheduler timing bug and must never leak out
        for r in self.results:
            if r.latency_s < 0:
                raise AssertionError(
                    f"negative latency {r.latency_s} for uid {r.uid}")
            if np.isfinite(r.first_token_s) and r.ttft_s < 0:
                raise AssertionError(
                    f"negative ttft {r.ttft_s} for uid {r.uid}")

    @property
    def cache_hbm_bytes_per_token(self) -> float:
        return (self.cache_hbm_bytes / self.generated_tokens
                if self.generated_tokens else 0.0)

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / self.total_s if self.total_s else 0.0

    @property
    def busy_s(self) -> float:
        """Engine busy time: prefill + decode compute, excluding the idle
        gaps where the scheduler sat waiting on request arrivals."""
        return self.prefill_s + self.decode_s

    @property
    def goodput_tokens_per_s(self) -> float:
        """Accepted tokens per *busy* second.  Under open-loop (rated)
        traffic the wall-clock ``tokens_per_s`` folds arrival idle time
        into the denominator and collapses as the offered rate drops;
        goodput is the engine-capacity view that stays comparable across
        offered loads."""
        return (self.generated_tokens / self.busy_s) if self.busy_s else 0.0

    @property
    def busy_frac(self) -> float:
        return self.busy_s / self.total_s if self.total_s else 0.0

    def latency_percentiles(self, qs: Sequence[float] = (50.0, 95.0)
                            ) -> Dict[float, float]:
        lat = [r.latency_s for r in self.results]
        return {q: float(np.percentile(lat, q)) for q in qs} if lat else {}

    def ttft_percentiles(self, qs: Sequence[float] = (50.0, 95.0)
                         ) -> Dict[float, float]:
        """First-token latency percentiles over requests that emitted at
        least one token (NaN-sentinel zero-budget requests excluded)."""
        tt = [r.ttft_s for r in self.results if np.isfinite(r.ttft_s)]
        return {q: float(np.percentile(tt, q)) for q in qs} if tt else {}


def sample(logits: jax.Array, key, temperature: float) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1) \
        .astype(jnp.int32)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig = None,
                 quantized: bool = False, collect_router_trace: bool = True,
                 kernel_impl: Optional[str] = None,
                 cache_dtype: Optional[Any] = None,
                 mesh: Optional[Any] = None,
                 pcfg: Optional[ParallelConfig] = None):
        """``mesh``: optional expert-parallel serving mesh
        (``launch.mesh.make_serve_mesh``).  Expert weights — quantized
        planes, scales, and low-rank compensator factors — are partitioned
        over the mesh's ``model`` axis, prefill dispatches tokens to their
        expert shards via all_to_all and decode runs resident-expert
        partials + psum under ``shard_map`` (``distributed/moe_parallel``),
        all inside the same jitted entry points as the single-device path.
        The expert-FFN implementation inside each shard still follows the
        ``REPRO_KERNEL_IMPL`` / ``kernel_impl`` dispatch policy."""
        self.cfg = cfg
        self.scfg = scfg or ServeConfig()
        self.quantized = quantized
        self.kernel_impl = kernel_impl
        self.mesh = mesh
        self.pcfg = pcfg or ParallelConfig()
        self.ep = ep_size(mesh)
        if mesh is not None:
            # partition params by the logical-axis rules (expert dim and
            # compressed stacks onto the EP axis; small leaves replicate)
            params = jax.device_put(
                params, tree_shardings(mesh, jax.eval_shape(lambda: params),
                                       self.pcfg))
        self.params = params
        # KV caches follow the model's compute dtype (bf16 params must not
        # silently double KV memory with f32 caches); overridable, e.g.
        # cache_dtype=jnp.float32 for f32 accumulation studies.
        self.cache_dtype = (jnp.asarray(params["embed"]["tok"]).dtype
                            if cache_dtype is None else cache_dtype)
        # trace collection is free inside the scan (a few int32s per step);
        # it feeds GenerationResult.router_trace and the offload meter.
        # Gate on the PLAN's MoE layers (cfg.moe alone isn't enough: e.g.
        # first_layer_dense or recurrent-only patterns yield no MoE FFNs)
        specs = layer_specs(cfg)
        has_moe = any(s.ffn == "moe" for s in specs)
        self.collect_router_trace = collect_router_trace and has_moe
        # right-padded prefill is only exact when every mixer attends with
        # a full-length position-masked cache: recurrent states and local
        # ring buffers can't invalidate padding after the fact
        self._pad_prompts = all(s.mixer == "global" for s in specs)
        self._stores = None            # per-MoE-layer ExpertStore
        self._prefetcher = None
        self._offload_policy = "ours"
        self._controller = None        # BandwidthController (attach_controller)
        self._stream = None            # ExpertStreamEngine (attach_streaming)
        self._prefill_traced = None    # lazy trace-collecting prefill jit
        self._prefill_ctx = make_context(cfg, "prefill", quantized=quantized,
                                         exact_capacity=True,
                                         kernel_impl=kernel_impl,
                                         mesh=mesh, pcfg=self.pcfg)
        self._step_ctx = make_context(
            cfg, "step", quantized=quantized, exact_capacity=True,
            kernel_impl=kernel_impl, mesh=mesh, pcfg=self.pcfg,
            collect_trace=self.collect_router_trace)

        @jax.jit
        def prefill(params, caches, tokens, plen):
            """Prefill a (possibly right-padded) prompt batch.

            ``plen``: (B,) true prompt lengths.  Padding-written cache
            slots are invalidated (pos = -1) and the last-real-token
            logits are gathered per row, so two prompt lengths in the
            same bucket share one compile and decode identically."""
            out = lm.forward(params, tokens, cfg, self._prefill_ctx,
                             caches=caches)
            caches = mask_cache_padding(cfg, out.caches, plen)
            logits = jnp.take_along_axis(
                out.logits, (plen - 1)[:, None, None], axis=1)[:, 0]
            return self._pin_logits(logits), self._pin_caches(caches)

        def decode_loop(params, caches, logits0, key, plan, max_new,
                        temperature):
            """scan over decode steps: sample on device, step, stack trace.

            ``temperature`` is static (it selects the greedy/categorical
            branch in ``sample``) and read per call, so mutating
            ``scfg.temperature`` between generates takes effect.  The
            final RNG key is returned so chunked serving threads one key
            stream across scan chunks.  ``plan`` is the bandwidth
            controller's (moe_layers, 2) [top_n, rank_cap] array (None =
            static restoration): traced data with a static shape, so the
            per-chunk plan updates never recompile this loop."""

            def body(carry, _):
                logits, caches, key = carry
                key, k2 = jax.random.split(key)
                nxt = sample(logits, k2, temperature)
                out = lm.decode_step(params, nxt[:, None], caches, cfg,
                                     self._step_ctx, plan=plan)
                lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
                lp_tok = jnp.take_along_axis(lp, nxt[:, None], axis=-1)[:, 0]
                ys = (nxt, lp_tok)
                if self.collect_router_trace:
                    ys = ys + (out.trace,)        # (moe_layers, B, k)
                return (out.logits[:, 0], out.caches, key), ys

            (logits, caches, key), ys = jax.lax.scan(
                body, (logits0, caches, key), xs=None, length=max_new)
            return self._pin_logits(logits), self._pin_caches(caches), key, ys

        @functools.partial(jax.jit, donate_argnums=(0, 2))
        def claim(caches, req_caches, logits, req_logits, slot):
            """Donated slot claim: writes one request's prefilled cache and
            last-token logits into row ``slot`` in place (``slot`` is a
            traced scalar, so admissions to any slot share one compile)."""
            caches = cache_claim_slot(cfg, caches, req_caches, slot)
            logits = jax.lax.dynamic_update_slice_in_dim(
                logits, req_logits.astype(logits.dtype), slot, 0)
            return self._pin_caches(caches), self._pin_logits(logits)

        @functools.partial(jax.jit, donate_argnums=(0, 2))
        def claim_paged(caches, req_caches, logits, req_logits, slot, pages,
                        write_mask):
            """Paged slot claim: ``slot``/``pages``/``write_mask`` are all
            traced, so one compile serves every admission of a given
            request-cache length."""
            caches = cache_claim_slot_paged(cfg, caches, req_caches, slot,
                                            pages, write_mask)
            logits = jax.lax.dynamic_update_slice_in_dim(
                logits, req_logits.astype(logits.dtype), slot, 0)
            return self._pin_caches(caches), self._pin_logits(logits)

        @functools.partial(jax.jit, donate_argnums=(0,))
        def reset_paged(caches, slot):
            """Unmap a retired slot's block-table row so its garbage
            decode writes land on the trash page instead of pages the
            host allocator has already handed to another request."""
            return self._pin_caches(cache_reset_slot_paged(cfg, caches, slot))

        @functools.partial(jax.jit, donate_argnums=(0,))
        def seed_prefix(req_caches, caches, pages):
            """Pull shared-prefix pages out of the pool into the leading
            span of a fresh batch-1 request cache (suffix prefill seed)."""
            return cache_seed_prefix(cfg, req_caches, caches, pages)

        @functools.partial(jax.jit, donate_argnums=(1,))
        def prefill_suffix(params, req_caches, tokens, start, plen):
            """Append-only prefill of a prompt *suffix* over a cache whose
            leading ``start`` positions were seeded from reused prefix
            pages: step-mode forward with explicit (B, S) positions writes
            and attends the suffix in one pass, so the shared span's
            prefill FLOPs are paid once per unique prefix.  Padded suffix
            tokens land at positions >= plen and are invalidated after."""
            s = tokens.shape[1]
            positions = start[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
            out = lm.forward(params, tokens, cfg, self._step_ctx,
                             positions=positions, caches=req_caches)
            caches2 = mask_cache_padding(cfg, out.caches, plen)
            logits = jnp.take_along_axis(
                out.logits, (plen - start - 1)[:, None, None], axis=1)[:, 0]
            return self._pin_logits(logits), self._pin_caches(caches2)

        def spec_round(params, caches, logits0, key, plan, t1, draft,
                       temperature):
            """One speculative draft/verify round (serve/speculative.py).

            ``t1``: (S,) this round's first token — already sampled (by
            the PREVIOUS round's bonus sample, or from the claim logits
            on admission) and fed back as data, so the host-side drafter
            conditioned its ``draft`` (S, k) proposals on it.  One
            batched step-mode forward scores all k+1 round positions;
            acceptance is computed on device and only the final (S,)
            accepted lengths cross to the host scheduler (no per-token
            sync).  The cache commits the accepted prefix and rolls the
            rejected suffix back to a bit-identical never-drafted state
            (``cache_rollback``).

            The round ends with the bonus sample: the NEXT round's first
            token, drawn from the carry distribution with the first
            rejected draft banned — the exact residual of point-mass
            rejection sampling, so temperature > 0 stays
            distribution-preserving (and at temperature 0 a rejected
            draft is never the argmax, so banning it changes nothing).
            """
            s, k = draft.shape
            key, k1, k2 = jax.random.split(key, 3)
            toks = jnp.concatenate([t1.astype(jnp.int32)[:, None],
                                    draft.astype(jnp.int32)],
                                   axis=1)                    # (S, k+1)
            pos0 = caches["pos"]
            positions = (pos0[:, None]
                         + jnp.arange(k + 1, dtype=jnp.int32)[None])
            out = lm.forward(params, toks, cfg, self._step_ctx,
                             positions=positions, caches=caches, plan=plan)
            la = out.logits.astype(jnp.float32)               # (S, k+1, V)
            acc_d = accept_drafts(la[:, :-1], draft, k2, temperature)
            acc_len = 1 + acc_d.sum(axis=1).astype(jnp.int32) # in [1, k+1]
            # carry = the distribution after the last accepted token; for
            # a rejection at draft i it is la[:, i] — exactly the
            # distribution that rejected draft i, so banning that token
            # from the bonus sample realizes the residual
            carry = jnp.take_along_axis(
                la, (acc_len - 1)[:, None, None], axis=1)[:, 0]
            first_rej = jnp.take_along_axis(
                draft, jnp.minimum(acc_len - 1, k - 1)[:, None], axis=1)[:, 0]
            banned = jnp.where(acc_len > k, -1,
                               first_rej).astype(jnp.int32)
            t1_next = sample(mask_banned(carry, banned), k1, temperature)
            caches2 = cache_rollback(cfg, out.caches, pos0 + acc_len)
            # per-token logprobs under the raw (unmasked, untempered)
            # target distributions — the non-speculative loop's
            # convention; t1's distribution is ``logits0``, the carry
            # that produced it
            lp0 = jax.nn.log_softmax(logits0.astype(jnp.float32), axis=-1)
            lp_t1 = jnp.take_along_axis(
                lp0, t1.astype(jnp.int32)[:, None], axis=-1)[:, 0]
            lpd = jax.nn.log_softmax(la[:, :-1], axis=-1)
            lp_dr = jnp.take_along_axis(
                lpd, draft[..., None].astype(jnp.int32), axis=-1)[..., 0]
            lps = jnp.concatenate([lp_t1[:, None], lp_dr], axis=1)
            trace = None
            if self.collect_router_trace:
                # (moe_layers, S*(k+1), kr) row-major over (S, k+1) ->
                # (round_steps=k+1, moe_layers, S, kr), the layout
                # record_chunk / replay_spec_round consume
                tr = out.trace
                trace = tr.reshape(tr.shape[0], s, k + 1, tr.shape[-1]) \
                    .transpose(2, 0, 1, 3)
            ys = (toks, lps, trace, acc_len, t1_next)
            return (self._pin_logits(carry), self._pin_caches(caches2),
                    key, ys)

        self._prefill = prefill
        # the same decode body, wrapped twice: the donating loop is the
        # steady-state path (cache buffers reused in place); the
        # NON-donating twin runs the streaming fixpoint's speculative
        # attempts — a rejected attempt must leave the input caches
        # valid for the re-run, which donation would invalidate
        self._decode_loop = jax.jit(
            decode_loop, static_argnames=("max_new", "temperature"),
            donate_argnums=(1,))
        self._decode_loop_spec = jax.jit(
            decode_loop, static_argnames=("max_new", "temperature"))
        # spec rounds get the same two wrappings; the draft operand's
        # (S, k) shape keys the jit cache, so one compile serves every
        # round of a given (slots, spec_k) serve call
        self._spec_round = jax.jit(
            spec_round, static_argnames=("temperature",),
            donate_argnums=(1,))
        self._spec_round_nd = jax.jit(
            spec_round, static_argnames=("temperature",))
        self._claim = claim
        self._claim_paged = claim_paged
        self._reset_paged = reset_paged
        self._seed_prefix = seed_prefix
        self._prefill_suffix = prefill_suffix

    # -- compile accounting ------------------------------------------------
    @property
    def num_compiles(self) -> Dict[str, int]:
        """Compiled-variant counts of the two jitted entry points (-1 if
        the jax internal is unavailable) — the regression hook pinning
        'one bucket, one compile'."""
        def size(f):
            try:
                return int(f._cache_size())
            except Exception:
                return -1
        return {"prefill": size(self._prefill),
                "decode": size(self._decode_loop)}

    # -- offload wiring ----------------------------------------------------
    def attach_offload(self, stacks_by_layer: List[Dict],
                       policy: str = "ours",
                       cache_capacity: Optional[int] = None,
                       prefetch: bool = True, ep: Optional[int] = None):
        """Meter every generated token's expert fetches through per-layer
        host-side ``ExpertStore``s (LRU device cache + compensator bytes).

        ``ep`` (default: the engine mesh's expert-parallel degree)
        partitions each layer's store into per-shard sub-stores matching
        the device-side expert placement: each shard meters only its
        resident experts' wire bytes over its own device LRU, and the
        per-shard counters reduce into ``ServeStats`` (``shard_bytes``,
        ``offload_report['per_shard_bytes']``) and feed the bandwidth
        controller's ``budget_scope``."""
        from ..offload.store import make_expert_stores
        from ..offload.prefetch import LayerAheadPrefetcher
        cap = (self.scfg.cache_experts if cache_capacity is None
               else cache_capacity)
        self._stores = make_expert_stores(
            stacks_by_layer, ep=self.ep if ep is None else ep,
            cache_capacity=cap)
        self._offload_policy = policy
        if prefetch:
            self._prefetcher = LayerAheadPrefetcher(
                len(stacks_by_layer), self.cfg.moe.top_k)
        if self.scfg.control.enabled:
            # ServeConfig-driven controller: budgeted serving without a
            # separate attach_controller call (which can still override)
            self.attach_controller(self.scfg.control)
        if self.scfg.stream.enabled:
            self.attach_streaming()
        return self

    def attach_streaming(self, stream=None, backend=None) -> "ServeEngine":
        """Turn the metered offload into a real streamed data path.

        The MoE layers' serving stacks are pointer-swapped for
        fallback-initialized device *containers* (same pytree / shapes /
        dtypes — the jitted loops never recompile); an
        ``ExpertStreamEngine`` stages true expert payloads into them from
        pinned host images, driven by the stores' metering events, with a
        per-layer ring of async H2D copies for the prefetcher's
        layer-ahead predictions.  Decode runs optimistically on the
        current containers and blocks only on a true miss
        (``StreamConfig.miss_policy='block'``: stage + re-run until the
        routing is fully served, token-identical to all-resident;
        ``'degrade'``: accept the chunk served by the resident low-bit
        fallback and stage in the background).

        ``stream``: ``StreamConfig`` override (default ``scfg.stream``);
        ``backend``: transfer backend override (fault injection).
        Requires ``attach_offload`` on the LIVE serving stacks, the
        single-device path (store-level ``ep`` sharding still applies),
        and an 'ours'/'quant' fetch policy.
        """
        from ..offload.staging import ExpertStreamEngine
        stream = stream or self.scfg.stream
        if self._stores is None:
            raise ValueError("attach_offload must be called before "
                             "attach_streaming (the stream engine is "
                             "driven by its metered stores)")
        if self.mesh is not None:
            raise ValueError("streaming requires the single-device serving "
                             "path; expert-parallel byte accounting still "
                             "works via attach_offload(ep=...)")
        if not self.collect_router_trace:
            raise ValueError("streaming detects misses from the router "
                             "trace; collect_router_trace must be on")
        if self._offload_policy not in ("ours", "quant"):
            raise ValueError("streaming moves compressed containers; fetch "
                             f"policy {self._offload_policy!r} unsupported")
        moe_params = [lp["moe"] for seg in self.params["segments"]
                      for lp in seg
                      if isinstance(lp, dict) and isinstance(lp.get("moe"),
                                                             dict)
                      and "stacks" in lp["moe"]]
        if len(moe_params) != len(self._stores):
            raise ValueError(f"{len(moe_params)} compressed MoE layers in "
                             f"params vs {len(self._stores)} stores")
        for mp, store in zip(moe_params, self._stores):
            if mp["stacks"] is not store.stacks:
                raise ValueError("attach_offload was given stacks that are "
                                 "not the live serving stacks; streaming "
                                 "must stage into the containers the "
                                 "decode loop reads")
        self._stream = ExpertStreamEngine(self._stores, stream,
                                          policy=self._offload_policy,
                                          backend=backend)
        for li, mp in enumerate(moe_params):
            mp["stacks"] = self._stream.layer_containers(li)
        return self

    @property
    def stream(self):
        return self._stream

    def attach_controller(self, control: ControlConfig
                          ) -> "ServeEngine":
        """Close the loop from offload metering to restoration intensity.

        Requires ``attach_offload`` (the controller reads the stores'
        byte counters and derives its rank ladder from their stacks).
        With no budget set (``target_bytes_per_token == 0``) the plan
        stays pinned at the static ``top_n_restore`` / full-rank point
        and decode + metering are bit-identical to the uncontrolled path.
        """
        if self._stores is None:
            raise ValueError("attach_offload must be called before "
                             "attach_controller (it provides the metered "
                             "stores the controller feeds on)")
        self._controller = BandwidthController.from_stacks(
            [s.stacks for s in self._stores], self.cfg.moe.top_k, control,
            static_top_n=self.cfg.moe.quant.top_n_restore)
        return self

    @property
    def controller(self) -> Optional[BandwidthController]:
        return self._controller

    def _current_plan(self) -> Optional[ControllerPlan]:
        return self._controller.plan() if self._controller else None

    @staticmethod
    def _plan_device(plan: Optional[ControllerPlan]):
        return None if plan is None else jnp.asarray(plan.as_array())

    def _shard_totals(self) -> np.ndarray:
        """(ep,) cumulative wire bytes per expert-parallel shard link,
        reduced over layers (length 1 for unsharded stores)."""
        if not self._stores:
            return np.zeros((1,), np.int64)
        return sum(np.asarray(s.shard_totals, np.int64)
                   for s in self._stores)

    # -- mesh placement / sharding pins ------------------------------------
    def _pin_caches(self, caches):
        """Rule-derived sharding constraint on (traced) cache outputs —
        the same rules their initial placement uses, so every chunked
        call of the jitted entry points sees one fixed cache-sharding
        signature (one compile per bucket, no propagation churn)."""
        if self.mesh is None:
            return caches
        return tree_constraint(self.mesh, caches, self.pcfg,
                               CACHE_RULES + PARAM_RULES)

    def _logits_sharding(self, shape):
        """Rule-derived logits sharding (batch logical, rest replicated)
        — single definition shared by the output pin and the initial
        placement so the two can never diverge into a recompile."""
        from jax.sharding import NamedSharding
        from ..distributed.sharding import mesh_spec
        return NamedSharding(self.mesh, mesh_spec(
            self.mesh, ("batch",) + (None,) * (len(shape) - 1), shape,
            self.pcfg))

    def _pin_logits(self, logits):
        if self.mesh is None:
            return logits
        return jax.lax.with_sharding_constraint(
            logits, self._logits_sharding(logits.shape))

    def _place_replicated(self, x):
        """Commit a host-created array (RNG key, zeros logits) to the
        serving mesh replicated — an uncommitted single-device input
        would give the first chunked call a different sharding signature
        than the loop's own (mesh-sharded) outputs and cost one spurious
        recompile."""
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec
        return jax.device_put(x, NamedSharding(self.mesh, PartitionSpec()))

    def _make_caches(self, batch: int, cache_len: int):
        """Fresh caches, placed by the CACHE_RULES shardings when serving
        on a mesh (committed placement: the first jitted call already has
        the fixpoint input sharding)."""
        caches = init_caches(self.cfg, batch, max_len=cache_len,
                             dtype=self.cache_dtype)
        if self.mesh is not None:
            caches = jax.device_put(
                caches, tree_shardings(self.mesh,
                                       jax.eval_shape(lambda: caches),
                                       self.pcfg, CACHE_RULES + PARAM_RULES))
        return caches

    def _make_paged_caches(self, num_slots: int, num_pages: int,
                           page_size: int, max_blocks: int):
        caches = init_paged_caches(self.cfg, num_slots, num_pages,
                                   page_size, max_blocks,
                                   dtype=self.cache_dtype)
        if self.mesh is not None:
            caches = jax.device_put(
                caches, tree_shardings(self.mesh,
                                       jax.eval_shape(lambda: caches),
                                       self.pcfg, CACHE_RULES + PARAM_RULES))
        return caches

    def _meter_offload(self, trace: np.ndarray,
                       plan: Optional[ControllerPlan] = None
                       ) -> Dict[str, float]:
        """Feed decode routing (steps, layers, B, k) into the stores."""
        from ..offload.store import meter_decode_trace
        top_n = (self.cfg.moe.quant.top_n_restore if plan is None
                 else plan.top_n)
        return meter_decode_trace(
            self._stores, trace, policy=self._offload_policy,
            top_n=top_n,
            rank_caps=None if plan is None else plan.rank_cap,
            prefetcher=self._prefetcher)

    # -- prefill helpers ---------------------------------------------------
    def _pad_prompt(self, prompt_tokens: np.ndarray) -> np.ndarray:
        """Right-pad prompts to their length bucket (id 0; the padded cache
        slots are invalidated after prefill)."""
        b, plen = prompt_tokens.shape
        if not self._pad_prompts:
            return prompt_tokens
        lp = bucket_len(plen, PROMPT_BUCKET_MIN)
        if lp == plen:
            return prompt_tokens
        out = np.zeros((b, lp), np.int32)
        out[:, :plen] = prompt_tokens
        return out

    def _prefill_request(self, req: Request, cache_len: int):
        """(last-token logits (1, V), batch-1 prefilled cache) for one
        request, against a fresh cache of the serve run's bucket length."""
        toks = self._pad_prompt(np.asarray(req.tokens,
                                           np.int32).reshape(1, -1))
        plen = jnp.full((1,), req.prompt_len, jnp.int32)
        if self._stream is not None:
            return self._prefill_streamed(toks, plen, cache_len)
        caches = self._make_caches(1, cache_len)
        return self._prefill(self.params, caches, jnp.asarray(toks), plen)

    def _prefill_streamed(self, toks: np.ndarray, plen, cache_len: int):
        """Prefill under streaming: run optimistically on the current
        containers, stage every expert the prompt's routing touched that
        is not yet resident (at the static top_n, full rank), and re-run
        until the routing is fully served by true weights — so a streamed
        request's FIRST sampled token already matches the all-resident
        path.  Prefill always blocks on its stages (it is off the decode
        critical path); a stalled copy degrades the prefill after
        ``stall_timeout_s`` like any other miss."""
        eng = self._stream
        if self._prefill_traced is None:
            ctx = make_context(self.cfg, "prefill", quantized=self.quantized,
                               exact_capacity=True,
                               kernel_impl=self.kernel_impl, mesh=self.mesh,
                               pcfg=self.pcfg, collect_trace=True)

            @jax.jit
            def prefill_traced(params, caches, tokens, plen):
                out = lm.forward(params, tokens, self.cfg, ctx,
                                 caches=caches)
                caches = mask_cache_padding(self.cfg, out.caches, plen)
                logits = jnp.take_along_axis(
                    out.logits, (plen - 1)[:, None, None], axis=1)[:, 0]
                return (self._pin_logits(logits), self._pin_caches(caches),
                        out.trace)

            self._prefill_traced = prefill_traced
        top_n = (self.cfg.moe.quant.top_n_restore
                 if self.cfg.moe is not None else 0)
        b = toks.shape[0]
        lg = rc = None
        for _ in range(eng.cfg.max_reruns + 1):
            caches = self._make_caches(b, cache_len)
            lg, rc, tr = self._prefill_traced(self.params, caches,
                                              jnp.asarray(toks), plen)
            needs = eng.missing_for_forward_trace(np.asarray(tr), top_n)
            if not needs:
                return lg, rc
            unresolved = eng.demand_stage(needs)
            eng.reruns += 1
            if unresolved:
                break          # stalled copies: serve this prefill degraded
        return lg, rc

    def _admit_paged(self, req: Request, pool: PagePool, caches, slot: int,
                     slot_pages: Dict[int, List[int]], *, max_blocks: int,
                     page_size: int, ring_len: int, use_prefix: bool):
        """Admit one request into the paged cache.

        Maps a page list (shared prefix pages first, fresh pages after),
        runs prefill — full, or suffix-only over a prefix seeded straight
        from the shared physical pages — and returns ``(logits,
        req_caches, claim_operands)`` for ``_claim_paged``.  Host-side
        only; the device work is the prefill itself plus the claim the
        caller issues.
        """
        ps = page_size
        plen = req.prompt_len
        plen_pad = (bucket_len(plen, PROMPT_BUCKET_MIN)
                    if self._pad_prompts else plen)
        need = -(-(plen_pad + req.max_new + 1) // ps)
        shared: List[int] = []
        hashes: List[bytes] = []
        if use_prefix:
            hashes = prefix_page_hashes(
                np.asarray(req.tokens).reshape(-1).tolist(), ps)
            hit = pool.lookup(hashes)
            # keep at least the final prompt token in the suffix so the
            # suffix prefill yields the last-token logits decode starts
            # from
            shared = hit[:min(len(hit), (plen - 1) // ps)]
            # retain BEFORE alloc: alloc may LRU-evict parked pages, and
            # the matched run must not be its own victim
            pool.retain(shared)
        n_sh = len(shared)
        fresh = pool.alloc(need - n_sh)
        page_list = list(shared) + fresh
        pages = np.full((max_blocks,), -1, np.int32)
        pages[:need] = page_list
        write_mask = np.zeros((max_blocks,), bool)
        write_mask[n_sh:need] = True     # shared pages are read-only

        # request-cache length: page-aligned prompt capacity, raised to
        # the serve cache's ring length so local layers claim 1:1
        req_len = max(_round_up(plen_pad, ps), _round_up(ring_len, ps))
        start = n_sh * ps
        if start > 0:
            seed = np.full((max_blocks,), -1, np.int32)
            seed[:n_sh] = shared
            rc = self._seed_prefix(self._make_caches(1, req_len), caches,
                                   jnp.asarray(seed))
            suf = np.asarray(req.tokens, np.int32).reshape(-1)[start:]
            # pad the suffix to page granularity — never past req_len, so
            # padded steps cannot ring-wrap onto the seeded prefix
            spad = _round_up(len(suf), ps)
            toks = np.zeros((1, spad), np.int32)
            toks[0, :len(suf)] = suf
            lg, rc = self._prefill_suffix(
                self.params, rc, jnp.asarray(toks),
                jnp.full((1,), start, jnp.int32),
                jnp.full((1,), plen, jnp.int32))
            n_prefill = spad
        else:
            lg, rc = self._prefill_request(req, req_len)
            n_prefill = plen_pad
        if use_prefix:
            # publish every full prompt page (fresh ones get their
            # content from the claim below; register is first-writer-wins)
            for j in range(n_sh, plen // ps):
                pool.register(page_list[j], hashes[j])
        slot_pages[slot] = page_list
        return lg, rc, {"pages": jnp.asarray(pages),
                        "write_mask": jnp.asarray(write_mask),
                        "prefill_tokens": n_prefill}

    def _run_chunk(self, caches, logits, key, plan, steps: int, active):
        """One decode chunk under streaming.

        Warm steady state (``may_miss`` False) runs the donating loop
        untouched.  Otherwise: optimistic execution on the current
        containers through the NON-donating twin, then — on a true miss —
        either stage-and-re-run to a fixpoint (miss_policy 'block':
        accepted chunk is token-identical to all-resident) or accept the
        fallback-served chunk and stage asynchronously for later chunks
        ('degrade').  Returns ``((logits, caches, key, ys), degraded)``.
        """
        eng = self._stream
        eng.integrate_ready()
        top_ns, caps = eng.plan_vectors(
            len(self._stores), plan,
            self.cfg.moe.quant.top_n_restore if self.cfg.moe else 0)
        plan_dev = self._plan_device(plan)
        temp = self.scfg.temperature
        if not eng.may_miss(top_ns, caps):
            return self._decode_loop(self.params, caches, logits, key,
                                     plan_dev, steps, temp), 0
        out = needs = None
        for _ in range(eng.cfg.max_reruns + 1):
            out = self._decode_loop_spec(self.params, caches, logits, key,
                                         plan_dev, steps, temp)
            tr = np.asarray(out[3][2])
            needs = eng.missing_for_trace(tr, active, top_ns, caps)
            if not needs:
                return out, 0
            if eng.cfg.miss_policy == "degrade":
                eng.stage_async(needs)
                break
            unresolved = eng.demand_stage(needs)
            eng.reruns += 1
            if unresolved:
                bad = set(unresolved)
                needs = [n for n in needs if (n[0], n[1]) in bad]
                break
        degraded = eng.count_affected_tokens(
            np.asarray(out[3][2]), active,
            [(l, e) for (l, e, _w, _f) in needs])
        eng.degraded_tokens += degraded
        return out, degraded

    def _run_spec_round(self, caches, logits, key, plan, t1, draft,
                        active):
        """One speculative verify round under streaming — ``_run_chunk``
        for spec rounds.  The miss check covers the FULL round trace
        (which positions survive rejection is unknown before the verify
        runs, and under 'block' the accepted prefix must be
        token-identical to all-resident), and re-runs are exact: the
        same key and draft reproduce the same round."""
        eng = self._stream
        eng.integrate_ready()
        top_ns, caps = eng.plan_vectors(
            len(self._stores), plan,
            self.cfg.moe.quant.top_n_restore if self.cfg.moe else 0)
        plan_dev = self._plan_device(plan)
        temp = self.scfg.temperature
        if not eng.may_miss(top_ns, caps):
            return self._spec_round(self.params, caches, logits, key,
                                    plan_dev, t1, draft, temp), 0
        out = needs = None
        for _ in range(eng.cfg.max_reruns + 1):
            out = self._spec_round_nd(self.params, caches, logits, key,
                                      plan_dev, t1, draft, temp)
            tr = np.asarray(out[3][2])
            needs = eng.missing_for_trace(tr, active, top_ns, caps)
            if not needs:
                return out, 0
            if eng.cfg.miss_policy == "degrade":
                eng.stage_async(needs)
                break
            unresolved = eng.demand_stage(needs)
            eng.reruns += 1
            if unresolved:
                bad = set(unresolved)
                needs = [n for n in needs if (n[0], n[1]) in bad]
                break
        degraded = eng.count_affected_tokens(
            np.asarray(out[3][2]), active,
            [(l, e) for (l, e, _w, _f) in needs])
        eng.degraded_tokens += degraded
        return out, degraded

    # -- generation (one fixed batch) --------------------------------------
    def generate(self, prompt_tokens: np.ndarray, max_new: int = 32,
                 seed: int = 0) -> GenerationResult:
        cfg = self.cfg
        b, plen = prompt_tokens.shape
        padded = self._pad_prompt(np.asarray(prompt_tokens, np.int32))
        cache_len = bucket_len(padded.shape[1] + max_new + 1)
        plen_arr = jnp.full((b,), plen, jnp.int32)
        t0 = time.time()
        if self._stream is not None:
            logits, caches = self._prefill_streamed(padded, plen_arr,
                                                    cache_len)
        else:
            caches = self._make_caches(b, cache_len)
            logits, caches = self._prefill(
                self.params, caches, jnp.asarray(padded), plen_arr)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        plan = self._current_plan()
        key = self._place_replicated(jax.random.key(seed))
        t1 = time.time()
        if self._stream is not None:
            (logits, caches, _key, ys), _deg = self._run_chunk(
                caches, logits, key, plan, max_new, np.ones((b,), bool))
        else:
            logits, caches, _key, ys = self._decode_loop(
                self.params, caches, logits, key,
                self._plan_device(plan), max_new, self.scfg.temperature)
        logits.block_until_ready()
        t_decode = time.time() - t1

        toks = np.asarray(ys[0]).T                    # (B, max_new)
        logprobs = np.asarray(ys[1]).T                # (B, max_new)
        trace = (np.asarray(ys[2])
                 if self.collect_router_trace and ys[2] is not None else None)
        report = None
        if trace is not None and self._stores:
            if self._stream is not None:
                # replay the accepted routing (ledgered stages are
                # consumed), then flush staged-but-unrouted copies as
                # wasted prefetch INSIDE the report window, so the report
                # covers every byte the chunk put on the link
                from ..offload.store import (offload_report,
                                             replay_decode_trace,
                                             snapshot_offload)
                top_n = (cfg.moe.quant.top_n_restore if plan is None
                         else plan.top_n)
                snap = snapshot_offload(self._stores, self._prefetcher)
                ntok, _sb = replay_decode_trace(
                    self._stores, trace, policy=self._offload_policy,
                    top_n=top_n,
                    rank_caps=None if plan is None else plan.rank_cap,
                    prefetcher=self._prefetcher)
                self._stream.flush_unclaimed()
                report = offload_report(self._stores, self._prefetcher,
                                        snap, ntok, self._offload_policy)
            else:
                report = self._meter_offload(trace, plan)
        if report is not None and self._controller is not None:
            self._controller.update(report["total_bytes"], report["tokens"],
                                    shard_bytes=report["per_shard_bytes"])
        return GenerationResult(
            toks, logprobs, t_prefill, t_decode, max_new,
            router_trace=trace, offload_report=report,
            stream_report=(self._stream.report()
                           if self._stream is not None else None))

    # -- continuous-batching serving ---------------------------------------
    def serve(self, requests: Iterable[Request], *,
              num_slots: Optional[int] = None, chunk: Optional[int] = None,
              seed: int = 0, page_size: Optional[int] = None,
              prefix_cache: Optional[bool] = None,
              pool_pages: Optional[int] = None,
              spec_k: Optional[int] = None, drafter=None) -> ServeStats:
        """Serve a request workload through the continuous-batching loop.

        One slot-indexed cache of ``num_slots`` rows and one compiled
        ``chunk``-step decode scan stay resident for the whole workload;
        between chunks the scheduler retires finished requests (EOS /
        max-token) and refills their slots from the arrival queue.
        Requests with future ``arrival_s`` wait in the queue (offered-load
        benchmarking); latencies are wall-clock from arrival.

        ``page_size`` (default ``scfg.page_size``; 0 = off) switches the
        cache's global-attention layers to block-table paging: capacity
        is allocated in page quanta per request instead of one
        power-of-two bucket for the whole mix, block tables are traced
        data (still exactly one compiled decode signature), and
        ``prefix_cache`` refcount-shares the physical pages of common
        prompt prefixes so their prefill runs once.  ``pool_pages``
        overrides the allocatable pool size (excluding the trash page).

        With a bandwidth controller attached, each chunk decodes under
        the controller's current (moe_layers, 2) restoration plan (traced
        data — no recompile), the chunk's metered wire bytes feed
        ``controller.update`` at the chunk boundary, and the per-chunk
        plans come back as ``ServeStats.plan_trace``.

        ``spec_k`` (default ``scfg.spec.k``; 0 = off) switches the
        decode chunk for speculative draft/verify *rounds*: a drafter
        (``'ngram'`` | ``'model'`` | a reset_slot/observe/propose_all
        object; default from ``scfg.spec``) proposes ``spec_k`` tokens
        per slot, one batched verify pass scores all spec_k+1 round
        positions, rejection sampling commits a per-slot prefix
        (token-identical to the non-speculative loop at temperature 0),
        and the rejected cache suffix rolls back bit-exactly.  The
        verify trace warms the expert stores through a
        ``LookaheadPrefetcher`` — exact in-round routing rather than the
        layer-ahead guess — and ``ServeStats.spec_report`` carries the
        acceptance rate, lookahead accuracy, and wasted-speculation
        bytes.  Requires an all-'global' attention plan (recurrent /
        ring states cannot roll back rejected suffixes).
        """
        from ..offload.store import (offload_report, replay_decode_trace,
                                     replay_spec_round, snapshot_offload)
        from ..offload.prefetch import LookaheadPrefetcher
        cfg = self.cfg
        num_slots = num_slots or self.scfg.num_slots
        chunk = chunk or self.scfg.chunk_steps
        ps = self.scfg.page_size if page_size is None else page_size
        use_prefix = (self.scfg.prefix_cache if prefix_cache is None
                      else prefix_cache)
        paged = ps > 0
        spec_k = self.scfg.spec.k if spec_k is None else spec_k
        spec_on = spec_k > 0
        spec_pf = None
        if spec_on:
            if not self._pad_prompts or cfg.encoder is not None:
                raise ValueError("speculative decoding needs an all-'global' "
                                 "decoder-only attention plan: recurrent and "
                                 "local-ring states cannot roll back a "
                                 "rejected draft suffix")
            if drafter is None:
                drafter = self.scfg.spec.drafter
            if isinstance(drafter, str):
                drafter = make_drafter(
                    dataclasses.replace(self.scfg.spec, drafter=drafter,
                                        k=spec_k),
                    cfg, target_params=self.params,
                    target_quantized=self.quantized,
                    kernel_impl=self.kernel_impl)
            next_t1 = np.zeros((num_slots,), np.int32)
            adm_key = jax.random.key(seed + 1)   # admission bonus samples
            spec_drafted = spec_acc = 0
            chunk = spec_k + 1          # round length, for stats/reporting
            if self._stores:
                spec_pf = LookaheadPrefetcher(len(self._stores),
                                              cfg.moe.top_k)
        pf_used = spec_pf if spec_on else self._prefetcher
        reqs = list(requests)
        order = [r.uid for r in reqs]       # results in submission order
        reqs = sorted(reqs, key=lambda r: r.arrival_s)
        if not reqs:
            return ServeStats([], num_slots, chunk, 0.0, 0.0, 0.0, 0, 0)

        def padded_plen(r: Request) -> int:
            return (bucket_len(r.prompt_len, PROMPT_BUCKET_MIN)
                    if self._pad_prompts else r.prompt_len)

        pool = None
        slot_pages: Dict[int, List[int]] = {}
        if paged:
            if ps & (ps - 1):
                raise ValueError(f"page_size must be a power of two: {ps}")
            if use_prefix and not self._pad_prompts:
                raise ValueError("prefix_cache needs an all-global "
                                 "attention plan (recurrent / ring states "
                                 "cannot seed from reused pages)")
            if use_prefix and self._stream is not None:
                raise ValueError("prefix_cache under expert streaming is "
                                 "unsupported (suffix prefill bypasses the "
                                 "stage-and-rerun fixpoint)")
            # per-request page need; +1 matches the contiguous headroom
            needs = sorted((-(-(padded_plen(r) + r.max_new + 1) // ps)
                            for r in reqs), reverse=True)
            max_blocks = needs[0]
            # pool: the num_slots largest concurrent residents (plus the
            # reserved trash page) — strictly less HBM than bucketing
            # every slot to the global worst case
            n_alloc = (pool_pages if pool_pages
                       else min(sum(needs[:num_slots]),
                                num_slots * max_blocks))
            caches = self._make_paged_caches(num_slots, 1 + n_alloc, ps,
                                             max_blocks)
            pool = PagePool(1 + n_alloc, ps)
            specs = layer_specs(cfg)
            ring_len = (min(cfg.window_size, max_blocks * ps)
                        if any(s.mixer == "local" for s in specs) else 0)
        else:
            # spec_k extra headroom: a verify pass may append up to spec_k
            # rejected positions past a slot's final token, and the ring
            # must absorb them without wrapping onto live entries (the
            # rollback can only restore what the write didn't destroy)
            cache_len = bucket_len(
                max(bucket_len(r.prompt_len, PROMPT_BUCKET_MIN) + r.max_new
                    for r in reqs) + 1 + (spec_k if spec_on else 0))
            caches = self._make_caches(num_slots, cache_len)
        cache_hbm = int(sum(x.nbytes for x in jax.tree.leaves(caches)))
        self._page_pool = pool              # test/introspection handle
        sched = Scheduler(num_slots)
        for r in reqs:
            sched.submit(r)

        key = self._place_replicated(jax.random.key(seed))
        logits = None
        top_n = cfg.moe.quant.top_n_restore if cfg.moe is not None else 1
        snap = (snapshot_offload(self._stores, pf_used)
                if self._stores else None)
        traces: List[np.ndarray] = []
        plans: List[np.ndarray] = []
        prefill_s = decode_s = 0.0
        chunks = generated = metered_tokens = prefill_tok = 0
        t0 = time.perf_counter()
        while sched.has_work():
            now = time.perf_counter() - t0
            admits = sched.admit(now)
            if not admits and sched.num_active == 0:
                # idle: nothing resident, next request hasn't arrived yet
                # — sleep the exact gap once (the old 0.25 s cap spun the
                # loop awake repeatedly under sparse offered load)
                gap = max(sched.next_arrival() - now, 0.0)
                time.sleep(gap + 1e-4)
                continue
            for slot, req in admits:
                tp = time.perf_counter()
                if paged:
                    lg, rc, claim_args = self._admit_paged(
                        req, pool, caches, slot, slot_pages,
                        max_blocks=max_blocks, page_size=ps,
                        ring_len=ring_len, use_prefix=use_prefix)
                    prefill_tok += claim_args.pop("prefill_tokens")
                else:
                    lg, rc = self._prefill_request(req, cache_len)
                    claim_args = None
                    prefill_tok += padded_plen(req)
                if logits is None:
                    logits = jnp.zeros((num_slots,) + lg.shape[1:], lg.dtype)
                    if self.mesh is not None:
                        logits = jax.device_put(
                            logits, self._logits_sharding(logits.shape))
                if paged:
                    caches, logits = self._claim_paged(
                        caches, rc, logits, lg, jnp.int32(slot),
                        claim_args["pages"], claim_args["write_mask"])
                else:
                    caches, logits = self._claim(caches, rc, logits, lg,
                                                 jnp.int32(slot))
                if spec_on:
                    # sample the new tenant's first token from its claim
                    # logits now (the non-speculative loop does this as
                    # its first scan step), so the drafter can condition
                    # its first proposals on it
                    adm_key, k1 = jax.random.split(adm_key)
                    t1_new = int(np.asarray(
                        sample(lg, k1, self.scfg.temperature))[0])
                    next_t1[slot] = t1_new
                    # rebind the slot's draft history to the new tenant;
                    # no residual carries across requests
                    drafter.reset_slot(slot, np.asarray(req.tokens))
                    drafter.observe(slot, np.asarray([t1_new]))
                prefill_s += time.perf_counter() - tp

            plan = self._current_plan()
            td = time.perf_counter()
            if spec_on:
                draft_np = drafter.propose_all(num_slots, spec_k)
                draft_dev = jnp.asarray(draft_np, jnp.int32)
                t1_dev = jnp.asarray(next_t1)
                if self._stream is not None:
                    (logits, caches, key, ys), _deg = self._run_spec_round(
                        caches, logits, key, plan, t1_dev, draft_dev,
                        sched.active_mask())
                else:
                    logits, caches, key, ys = self._spec_round(
                        self.params, caches, logits, key,
                        self._plan_device(plan), t1_dev, draft_dev,
                        self.scfg.temperature)
            elif self._stream is not None:
                (logits, caches, key, ys), _deg = self._run_chunk(
                    caches, logits, key, plan, chunk, sched.active_mask())
            else:
                logits, caches, key, ys = self._decode_loop(
                    self.params, caches, logits, key,
                    self._plan_device(plan), chunk, self.scfg.temperature)
            logits.block_until_ready()
            decode_s += time.perf_counter() - td
            chunks += 1
            if plan is not None:
                plans.append(plan.as_array())

            if spec_on:
                # round outputs are already slot-major (S, k+1); acc_len
                # crosses to the host HERE, once per round, as one (S,)
                # array — never a per-token sync inside the jitted round
                toks = np.asarray(ys[0])
                lps = np.asarray(ys[1])
                tr = (np.asarray(ys[2]) if self.collect_router_trace
                      else None)
                acc_len = np.asarray(ys[3])
                next_t1 = np.array(ys[4])   # writable: admits reset entries
            else:
                toks = np.asarray(ys[0]).T                   # (S, chunk)
                lps = np.asarray(ys[1]).T
                tr = (np.asarray(ys[2]) if self.collect_router_trace
                      else None)
                acc_len = None
            uid_map = sched.uid_by_slot()
            live_mask = sched.active_mask()
            now = time.perf_counter() - t0
            # per-step times interpolate from the chunk's decode start, so
            # first-token stamps land on their step instead of quantizing
            # to the chunk boundary
            accepted = sched.record_chunk(toks, lps, tr, now,
                                          t_start=td - t0,
                                          valid_len=acc_len)  # (chunk, S)
            generated += int(accepted.sum())
            if spec_on:
                live_after = sched.uid_by_slot()
                for i in uid_map:
                    spec_drafted += spec_k
                    spec_acc += int(acc_len[i]) - 1
                    # toks[i, 0] (the round's t1) was observed when it
                    # was sampled — at admission or as the previous
                    # round's bonus token — so only the accepted draft
                    # suffix is new to the drafter here
                    n_new = int(accepted[:, i].sum())
                    if n_new > 1:
                        drafter.observe(i, toks[i, 1:n_new])
                    if live_after.get(i) == uid_map[i]:
                        # slot survives the round: the bonus token it
                        # will commit next round conditions proposals now
                        drafter.observe(i, np.asarray([next_t1[i]]))
            if paged:
                live = sched.uid_by_slot()
                for slot_i, uid in uid_map.items():
                    if live.get(slot_i) != uid:   # retired this chunk
                        pool.release(slot_pages.pop(slot_i))
                        # unmap before the next chunk decodes: the freed
                        # pages may be re-allocated, and a dead slot keeps
                        # scan-stepping (its writes must hit the trash
                        # page, not the new tenant)
                        caches = self._reset_paged(caches,
                                                   jnp.int32(slot_i))
            if tr is not None:
                masked = np.where(accepted[:, None, :, None], tr,
                                  -1).astype(tr.dtype)
                traces.append(masked)
                if self._stores:
                    before = sum(s.total_bytes for s in self._stores)
                    shard_before = self._shard_totals()
                    if spec_on:
                        # lookahead warms cover every LIVE round position
                        # (rejected ones included — that is the wasted
                        # speculation the report attributes); demand
                        # metering stays accepted-only
                        full = np.where(live_mask[None, None, :, None], tr,
                                        -1).astype(tr.dtype)
                        ntok, slot_bytes, _ohb = replay_spec_round(
                            self._stores, full, accepted,
                            policy=self._offload_policy,
                            top_n=top_n if plan is None else plan.top_n,
                            rank_caps=(None if plan is None
                                       else plan.rank_cap),
                            lookahead=spec_pf)
                    else:
                        ntok, slot_bytes = replay_decode_trace(
                            self._stores, masked,
                            policy=self._offload_policy,
                            top_n=top_n if plan is None else plan.top_n,
                            rank_caps=(None if plan is None
                                       else plan.rank_cap),
                            prefetcher=self._prefetcher)
                    metered_tokens += ntok
                    sched.add_slot_bytes(slot_bytes, uid_map)
                    if self._stream is not None:
                        # staged copies the accepted routing never
                        # touched become wasted prefetch THIS chunk, so
                        # the controller's `moved` sees every byte the
                        # chunk put on the link
                        self._stream.flush_unclaimed()
                    if self._controller is not None:
                        # chunk boundary: the chunk's wire bytes (demand +
                        # compensator + prefetch) close the control loop;
                        # per-shard deltas feed the per_shard budget scope
                        moved = sum(s.total_bytes
                                    for s in self._stores) - before
                        self._controller.update(
                            moved, ntok,
                            shard_bytes=self._shard_totals() - shard_before)

        total_s = time.perf_counter() - t0
        if pool is not None:
            pool.check_leaks()     # every retire released its pages
        report = (offload_report(self._stores, pf_used, snap,
                                 metered_tokens, self._offload_policy)
                  if snap is not None and traces else None)
        spec_report = None
        if spec_on:
            spec_report = {
                "spec_k": spec_k,
                "drafter": type(drafter).__name__,
                "rounds": chunks,
                "drafted_tokens": spec_drafted,
                "accepted_draft_tokens": spec_acc,
                # verify-pass acceptance (EOS / max_new scheduler trims
                # excluded): the drafter-quality number
                "acceptance_rate": spec_acc / max(spec_drafted, 1),
                "lookahead_accuracy": (spec_pf.stats.accuracy
                                       if spec_pf is not None else None),
                "lookahead_prefetch_bytes": (spec_pf.bytes_issued
                                             if spec_pf is not None else 0),
                "draft_overhead_bytes": (spec_pf.bytes_wasted
                                         if spec_pf is not None else 0),
            }
        by_uid = {res.uid: res for res in sched.finished}
        results = [by_uid[u] for u in order]
        return ServeStats(results, num_slots, chunk, total_s, prefill_s,
                          decode_s, chunks, generated,
                          cache_hbm_bytes=cache_hbm,
                          prefill_tokens=prefill_tok,
                          page_report=(pool.report() if pool is not None
                                       else None),
                          spec_report=spec_report,
                          offload_report=report,
                          router_trace=(np.concatenate(traces)
                                        if traces else None),
                          plan_trace=(np.stack(plans) if plans else None),
                          shard_bytes=(np.asarray(report["per_shard_bytes"],
                                                  np.int64)
                                       if report is not None else None),
                          stream_report=(self._stream.report()
                                         if self._stream is not None
                                         else None))

    def generate_many(self, prompts: Sequence[np.ndarray],
                      max_new: int = 32, *,
                      eos_id: Optional[int] = None,
                      num_slots: Optional[int] = None,
                      chunk: Optional[int] = None,
                      seed: int = 0) -> ServeStats:
        """Serve a list of ragged prompts (all arriving at t=0) through the
        continuous-batching loop; results come back in submission order."""
        reqs = [Request(uid=i, tokens=np.asarray(p, np.int32).reshape(-1),
                        max_new=max_new, eos_id=eos_id)
                for i, p in enumerate(prompts)]
        return self.serve(reqs, num_slots=num_slots, chunk=chunk, seed=seed)

    def score(self, tokens: np.ndarray) -> float:
        """Mean next-token NLL (perplexity proxy) under the serving path."""
        ctx = make_context(self.cfg, "train", quantized=self.quantized,
                           exact_capacity=True,
                           kernel_impl=self.kernel_impl)
        out = lm.forward(self.params, jnp.asarray(tokens), self.cfg, ctx)
        logits = out.logits[:, :-1].astype(jnp.float32)
        tgt = jnp.asarray(tokens)[:, 1:]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        sel = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        return float(jnp.mean(lse - sel))


@functools.lru_cache(maxsize=64)
def _trace_forward(cfg: ModelConfig, quantized: bool,
                   kernel_impl: Optional[str]):
    """One jitted trace-collecting forward per (cfg, quantized, impl) —
    re-jitting a fresh lambda per call would recompile every time."""
    ctx = make_context(cfg, "train", quantized=quantized,
                       exact_capacity=True, collect_trace=True,
                       kernel_impl=kernel_impl)
    return jax.jit(lambda p, t: lm.forward(p, t, cfg, ctx).trace)


def router_trace(cfg: ModelConfig, params, tokens: np.ndarray,
                 quantized: bool = False,
                 kernel_impl: Optional[str] = None) -> np.ndarray:
    """Export per-token routing decisions (tokens, moe_layers, k).

    Runs the jitted forward pass with ``collect_trace`` — the trace is a
    first-class model output, so this works under jit/scan with no
    ``disable_jit`` or ``moe.route`` hook.  The compiled function is
    cached per (cfg, quantized, kernel_impl), so repeated exports reuse
    one executable instead of recompiling a fresh lambda per call.
    """
    fn = _trace_forward(cfg, quantized, kernel_impl)
    out = fn(params, jnp.asarray(tokens))
    # (moe_layers, T, k) -> (T, layers, k)
    return np.asarray(out).transpose(1, 0, 2)
