"""Distribution layer: logical sharding rules, EP shard_map, collectives."""
