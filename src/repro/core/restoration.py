"""Router-guided error compensation (paper §3.2).

Per token, only the top-n (n < k) experts by router score receive their
low-rank compensators; every other activated expert runs on plain
dequantized low-bit weights.  Under jit/SPMD the per-token selectivity is a
0/1 mask folded into the low-rank branch:

    y_e = x @ Q^-1(Q(W_e))  +  ((x * m_e) @ U_e) @ V_e

which is bit-identical to reconstructing W_hat_e = Q^-1(Q(W_e)) + U_e V_e
for selected tokens and using the plain dequantized weight otherwise.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .pipeline import CompressedExpertStack


def compute_dtype():
    """Dequant/compensation compute+materialization dtype.

    f32 by default; REPRO_COMPENSATED_DTYPE=bf16 halves the bytes of every
    materialized dequantized weight on the ref path (hillclimb lever —
    the Pallas kernel never materializes at all)."""
    import os
    return (jnp.bfloat16 if os.environ.get("REPRO_COMPENSATED_DTYPE", "")
            .startswith("bf") else jnp.float32)


def topn_mask(topk_idx: jax.Array, n: int, num_experts: int) -> jax.Array:
    """(..., k) descending-score expert ids -> (..., E) 0/1 top-n mask."""
    n = min(n, topk_idx.shape[-1])
    sel = topk_idx[..., :n]
    return jax.nn.one_hot(sel, num_experts, dtype=jnp.float32).sum(axis=-2)


def topn_mask_from_scores(router_probs: jax.Array, k: int, n: int
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Router probs (..., E) -> (topk_vals, topk_idx, topn mask (..., E))."""
    vals, idx = jax.lax.top_k(router_probs, k)
    return vals, idx, topn_mask(idx, n, router_probs.shape[-1])


def compensated_expert_ffn(x: jax.Array, stack_w1: CompressedExpertStack,
                           stack_w3: Optional[CompressedExpertStack],
                           stack_w2: CompressedExpertStack,
                           comp_mask: jax.Array,
                           act=jax.nn.silu,
                           dtype=jnp.bfloat16,
                           rank_cap: Optional[jax.Array] = None) -> jax.Array:
    """Gated-FFN over *expert-stacked* inputs with masked compensation.

    x:         (E, C, d)   tokens dispatched per expert (capacity C)
    comp_mask: (E, C)      1.0 where this expert is within the token's top-n
    rank_cap:  traced scalar ceiling on the compensator rank (None = full
               padded rank).  Factors are rank-padded with true ranks
               tracked, so the cap is a 0/1 mask over the rank-space
               activation; cap >= the padded rank is bit-exact identity.
    returns    (E, C, d)

    Reference (einsum) composition; the Pallas path fuses dequant+lowrank
    per expert (see repro.kernels.ops) and is numerically validated against
    this in tests.
    """
    dt = compute_dtype()
    x32 = x.astype(dt)
    m = comp_mask[..., None].astype(dt)

    def proj(stack: CompressedExpertStack, inp: jax.Array) -> jax.Array:
        w = stack.dequantize_all(dt)                     # (E, K, N)
        y = jnp.einsum("eck,ekn->ecn", inp, w,
                       preferred_element_type=jnp.float32).astype(dt)
        u = (stack.u.astype(jnp.float32) * stack.u_scale).astype(dt)
        v = (stack.v.astype(jnp.float32) * stack.v_scale).astype(dt)
        xu = jnp.einsum("eck,ekr->ecr", inp * m, u,
                        preferred_element_type=jnp.float32).astype(dt)
        if rank_cap is not None:
            xu = xu * (jnp.arange(stack.pad_rank) < rank_cap).astype(dt)
        return y + jnp.einsum("ecr,ern->ecn", xu, v,
                              preferred_element_type=jnp.float32).astype(dt)

    h1 = proj(stack_w1, x32)
    if stack_w3 is not None:
        h = act(h1) * proj(stack_w3, x32)
    else:
        h = act(h1)
    return proj(stack_w2, h).astype(dtype)


def restoration_wire_bytes(stacks: dict, topk_idx, n: int,
                           top_k: int) -> dict:
    """Bandwidth accounting for one MoE layer invocation.

    Returns bytes moved under (a) fp16 offload, (b) uniform low-bit,
    (c) BEAM-LRC low-bit + top-n compensators — used by the offload
    simulator and the fig-7 benchmark.
    """
    import numpy as np
    idx = np.asarray(topk_idx).reshape(-1, topk_idx.shape[-1])
    any_stack = next(iter(stacks.values()))
    E = any_stack.shape[0]
    activated = np.unique(idx)                       # experts fetched at all
    restored = np.unique(idx[:, :n])                 # experts needing factors
    b_fp16 = sum(s.fp16_wire_bytes for s in stacks.values()) * len(activated)
    b_quant = sum(s.expert_wire_bytes(int(e), False)
                  for s in stacks.values() for e in activated)
    b_ours = sum(s.expert_wire_bytes(int(e), bool(e in restored))
                 for s in stacks.values() for e in activated)
    return {"fp16": int(b_fp16), "quant": int(b_quant), "ours": int(b_ours),
            "activated": len(activated), "restored": len(restored)}
