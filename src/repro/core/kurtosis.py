"""Kurtosis-guided rank allocation (paper §3.1, step 1).

Experts with heavier-tailed weight distributions (higher kurtosis) incur
larger quantization residuals and therefore receive larger compensator
ranks.  Ranks come from a fixed bucket set and are assigned greedily in
descending-kurtosis order under the global budget ``sum(r_i) <= N * R_avg``.

This heuristic is the *default* (no-corpus) allocation.  With a
calibration corpus, ``calib/allocate.py`` subsumes it: kurtosis becomes
one pluggable importance scorer (``SCORERS['kurtosis']``) inside a
wire-byte-budgeted knapsack that also assigns per-expert bit-widths —
see EXPERIMENTS.md §Calibration methodology.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..config import RANK_BUCKETS


def kurtosis(w: jax.Array) -> jax.Array:
    """Pearson kurtosis over all elements of ``w`` (paper eq. in §3.1)."""
    w = w.astype(jnp.float32).reshape(-1)
    mu = jnp.mean(w)
    var = jnp.mean((w - mu) ** 2)
    return jnp.mean((w - mu) ** 4) / jnp.maximum(var, 1e-12) ** 2


def allocate_ranks(kurt: Sequence[float], rank_budget: int,
                   buckets: Tuple[int, ...] = RANK_BUCKETS,
                   max_rank: int | None = None) -> np.ndarray:
    """Greedy bucket assignment under ``sum(r) <= N * rank_budget``.

    Traverses experts in descending kurtosis; each gets the largest bucket
    that keeps the running total within budget (paper's literal policy —
    concentrates rank on the hardest experts, many get r=0).

    ``max_rank`` caps buckets at min(m, n) of the weight matrices.
    """
    kurt = np.asarray(kurt, dtype=np.float64)
    n = len(kurt)
    budget = n * rank_budget
    usable = sorted((b for b in buckets
                     if max_rank is None or b <= max_rank), reverse=True)
    order = np.argsort(-kurt, kind="stable")
    ranks = np.zeros(n, dtype=np.int64)
    spent = 0
    for idx in order:
        for b in usable:
            if spent + b <= budget:
                ranks[idx] = b
                spent += b
                break
    return ranks


def uniform_ranks(n: int, rank_budget: int,
                  buckets: Tuple[int, ...] = RANK_BUCKETS) -> np.ndarray:
    """Ablation baseline: same bucket rank for every expert (<= budget)."""
    feasible = [b for b in buckets if b <= rank_budget]
    r = max(feasible) if feasible else 0
    return np.full(n, r, dtype=np.int64)
