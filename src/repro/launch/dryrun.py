import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices form the 16x16 (single-pod) and 2x16x16 (multi-pod)
meshes; each cell AOT-compiles its step function from ShapeDtypeStructs
(no allocation), prints memory/cost analysis, and derives roofline terms.

Usage:
  python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..config import SHAPES
from ..registry import ASSIGNED, get_config
from ..configs.base import supports_shape
from .mesh import make_production_mesh
from .roofline import collective_wire_bytes, derive_terms, model_flops
from .steps import (cell_abstract, cell_shardings, make_prefill_step,
                    make_serve_step, make_train_step, parallel_for_shape)


def count_params(tree) -> int:
    return sum(int(jnp.prod(jnp.array(l.shape))) if l.shape else 1
               for l in jax.tree.leaves(tree))


def _lower_cell(cfg, shape, mesh, pcfg, use_q, scan_unroll=False):
    """Lower + compile one cell; returns (compiled, abstract)."""
    abstract = cell_abstract(cfg, shape, quantized=use_q)
    shardings = cell_shardings(mesh, abstract, pcfg)
    if shape.kind == "train":
        from ..config import TrainConfig
        step, _ = make_train_step(cfg, TrainConfig(), mesh=mesh, pcfg=pcfg,
                                  scan_unroll=scan_unroll,
                                  remat_policy=("dots" if os.environ.get(
                                      "REPRO_REMAT_POLICY") == "dots"
                                      else "full"))
        args = (abstract["state"], abstract["batch"])
        in_sh = (shardings["state"], shardings["batch"])
        out_sh = (shardings["state"], None)
        donate = (0,)
    else:
        mk = make_prefill_step if shape.kind == "prefill" else make_serve_step
        step, _ = mk(cfg, quantized=use_q, mesh=mesh, pcfg=pcfg,
                     scan_unroll=scan_unroll)
        args = (abstract["params"], abstract["caches"], abstract["batch"])
        in_sh = (shardings["params"], shardings["caches"], shardings["batch"])
        out_sh = (None, shardings["caches"])
        donate = (1,)
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        compiled = jitted.lower(*args).compile()
    return compiled, abstract



def _cost_analysis(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on new jax, a per-computation
    list of dicts on 0.4.x — normalize to one dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def cost_pass(cfg, shape, mesh, pcfg, use_q):
    """XLA's cost_analysis counts loop bodies ONCE, so scanned stacks
    undercount FLOPs/bytes by the trip count.  This pass lowers the model
    at two reduced depths (one and two pattern groups) with every scan
    UNROLLED and extrapolates linearly to the full depth — exact because
    per-group cost is uniform; embed/head/encoder/loss land in the
    intercept."""
    import dataclasses as dc
    p_len = len(cfg.block_pattern)
    extra = 1 if cfg.first_layer_dense else 0
    l1, l2 = p_len + extra, 2 * p_len + extra
    if cfg.num_layers <= l2:  # shallow model: single exact unrolled pass
        compiled, _ = _lower_cell(cfg, shape, mesh, pcfg, use_q,
                                  scan_unroll=True)
        cost = _cost_analysis(compiled)
        wire = collective_wire_bytes(compiled.as_text(), 16).get("total", 0.0)
        return {"flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "wire": wire, "method": "exact_unrolled"}
    vals = []
    for L in (l1, l2):
        cfg_l = dc.replace(cfg, num_layers=L)
        compiled, _ = _lower_cell(cfg_l, shape, mesh, pcfg, use_q,
                                  scan_unroll=True)
        cost = _cost_analysis(compiled)
        wire = collective_wire_bytes(compiled.as_text(), 16).get("total", 0.0)
        vals.append((float(cost.get("flops", 0.0)),
                     float(cost.get("bytes accessed", 0.0)), wire))
    out = {}
    for i, key in enumerate(("flops", "bytes", "wire")):
        slope = (vals[1][i] - vals[0][i]) / (l2 - l1)
        out[key] = vals[0][i] + slope * (cfg.num_layers - l1)
    out["method"] = f"extrapolated_L{l1}_L{l2}"
    return out


OPTS = ("bf16dq", "kv8", "scalesbf16", "cf1", "noq", "rematdots", "attnbf16")


def apply_opts(cfg, opts):
    """Hillclimb variants (EXPERIMENTS.md §Perf):
      bf16dq     dequant/compensation materializes bf16 instead of f32
                 (env-based; the TPU Pallas kernel never materializes)
      kv8        int8 KV cache with fused per-slot scales
      scalesbf16 bf16 storage for quantization scale/zero planes
      cf1        MoE capacity factor 1.25 -> 1.0 (smaller a2a payload)
      noq        serve on bf16 weights (paper-baseline comparison)
    """
    import dataclasses as dc
    os.environ.pop("REPRO_COMPENSATED_DTYPE", None)
    os.environ.pop("REPRO_REMAT_POLICY", None)
    os.environ.pop("REPRO_ATTN_DTYPE", None)
    if not opts:
        return cfg
    if "bf16dq" in opts:
        os.environ["REPRO_COMPENSATED_DTYPE"] = "bf16"
    if "rematdots" in opts:
        os.environ["REPRO_REMAT_POLICY"] = "dots"
    if "attnbf16" in opts:
        os.environ["REPRO_ATTN_DTYPE"] = "bf16"
    if "kv8" in opts:
        cfg = dc.replace(cfg, kv_bits=8)
    if "scalesbf16" in opts:
        if cfg.moe:
            cfg = dc.replace(cfg, moe=dc.replace(
                cfg.moe, quant=dc.replace(cfg.moe.quant,
                                          scale_dtype="bf16")))
        cfg = dc.replace(cfg, quant=dc.replace(cfg.quant,
                                               scale_dtype="bf16"))
    if "cf1" in opts and cfg.moe:
        cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=1.0))
    return cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             quantized: str = "auto", out_dir=None, verbose=True,
             pcfg_override=None, tag: str = "", cost_corrected: bool = True,
             opts=()):
    cfg = apply_opts(get_config(arch), opts)
    if "noq" in opts:
        quantized = "off"
    if opts and not tag:
        tag = "+".join(sorted(opts))
    shape = SHAPES[shape_name]
    skip = supports_shape(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}|{shape_name}|{mesh_name}" + (f"|{tag}" if tag else "")
    if skip:
        return {"cell": cell_id, "status": "skipped", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    pcfg = pcfg_override or parallel_for_shape(shape, cfg=cfg)

    # quantized serving: the paper's technique applies at inference time
    has_q = (cfg.moe.quant.enabled if cfg.moe else cfg.quant.enabled)
    use_q = (has_q and shape.kind != "train") if quantized == "auto" \
        else (quantized == "on")

    t0 = time.time()
    compiled, abstract = _lower_cell(cfg, shape, mesh, pcfg, use_q)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    n_dev = mesh.size

    # pass B: loop-corrected flops/bytes/wire (see cost_pass docstring)
    t1 = time.time()
    if cost_corrected:
        cost = cost_pass(cfg, shape, mesh, pcfg, use_q)
        cost_src = cost["method"]
    else:
        ca = _cost_analysis(compiled)
        cost = {"flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
                "wire": collective_wire_bytes(hlo, 16).get("total", 0.0)}
        cost_src = "scanned_uncorrected"
    t_cost = time.time() - t1

    n_params = count_params(abstract["state"].params
                            if shape.kind == "train"
                            else abstract["params"])
    # quantized trees pack sub-byte planes, so leaf counts undercount
    # logical N: use analytic counts for MoE/quantized cells
    if cfg.moe is not None:
        active = cfg.num_active_params
    elif use_q:
        active = cfg.num_params
    else:
        active = n_params
    mf = model_flops(cfg, shape, active)
    mem_dev = None
    try:
        mem_dev = (mem.argument_size_in_bytes + mem.output_size_in_bytes +
                   mem.temp_size_in_bytes)
    except Exception:
        pass
    terms = derive_terms(arch, shape_name, mesh_name,
                         cost={"flops": cost["flops"],
                               "bytes accessed": cost["bytes"]},
                         hlo_text="", n_devices=n_dev,
                         model_flops_global=mf, mem_per_device=mem_dev,
                         default_group=16, wire_override=cost["wire"])
    terms.note = cost_src
    coll = collective_wire_bytes(hlo, 16)
    coll["schedule_note"] = "per-trace counts (loop bodies once); " \
                            "wire total in roofline is loop-corrected"
    rec = {
        "cell": cell_id, "status": "ok", "quantized": bool(use_q),
        "n_devices": n_dev, "params": n_params,
        "compile_s": round(t_compile, 1), "cost_pass_s": round(t_cost, 1),
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "cost_analysis": {k: cost.get(k) for k in
                          ("flops", "bytes accessed") if k in cost},
        "collectives": coll,
        "roofline": terms.to_dict(),
    }
    if verbose:
        ma = rec["memory_analysis"]
        gb = lambda x: f"{x / 2 ** 30:.2f}GiB" if x else "?"
        print(f"[{cell_id}] OK q={int(use_q)} "
              f"args={gb(ma['argument_bytes'])} temp={gb(ma['temp_bytes'])} "
              f"flops/dev={terms.flops_dev:.3e} bytes/dev={terms.bytes_dev:.3e} "
              f"wire/dev={terms.wire_bytes_dev:.3e} dom={terms.dominant} "
              f"t=({terms.t_compute*1e3:.2f},{terms.t_memory*1e3:.2f},"
              f"{terms.t_collective*1e3:.2f})ms "
              f"useful={terms.useful_ratio:.2f} "
              f"compile={t_compile:.0f}s", flush=True)
    if out_dir:
        out_dir = Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        fn = cell_id.replace("|", "_").replace("/", "-") + \
            ("_q" if use_q else "") + ".json"
        (out_dir / fn).write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "pod2", "both"])
    ap.add_argument("--quantized", default="auto",
                    choices=["auto", "on", "off"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-cost-pass", action="store_true",
                    help="skip the loop-corrected cost pass (faster)")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose result JSON already exists")
    ap.add_argument("--opt", default="",
                    help="comma-separated hillclimb variants "
                         "(bf16dq,kv8,scalesbf16,cf1,noq)")
    args = ap.parse_args()
    opts = tuple(o for o in args.opt.split(",") if o)

    archs = list(ASSIGNED) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"pod": [False], "pod2": [True], "both": [False, True]}[args.mesh]

    results, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                if args.skip_existing:
                    mesh_name = "2x16x16" if mp else "16x16"
                    stem = f"{arch}_{shape}_{mesh_name}".replace("/", "-")
                    hits = list(Path(args.out).glob(stem + "*.json"))
                    if hits:
                        print(f"[{arch}|{shape}|{mesh_name}] exists, skip",
                              flush=True)
                        continue
                try:
                    rec = run_cell(arch, shape, mp, quantized=args.quantized,
                                   out_dir=args.out,
                                   cost_corrected=not args.no_cost_pass,
                                   opts=opts)
                    results.append(rec)
                    if rec["status"] == "skipped":
                        print(f"[{rec['cell']}] SKIP: {rec['reason']}",
                              flush=True)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[{arch}|{shape}|mp={mp}] FAIL: {e}", flush=True)
                    traceback.print_exc()
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    print(f"\ndry-run summary: {ok} ok, {sk} skipped, {len(failures)} failed")
    if failures:
        for f in failures:
            print("  FAIL:", f)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
