"""Distribution layer on a small in-process mesh.

Runs through the shared ``dist_run`` conftest fixture: with
``REPRO_HOST_DEVICES=8`` set (``make tier1-dist`` / the dist CI job) the
script executes in-process on 8 host devices; otherwise it runs in a
subprocess with the XLA host-device override forced — either way the
distributed tier actually executes, it never skips."""
import textwrap

import pytest

pytestmark = pytest.mark.dist

SCRIPT = textwrap.dedent("""
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.config import ModelConfig, MoEConfig, ParallelConfig, \\
        QuantConfig, TrainConfig
    from repro.distributed.sharding import (PARAM_RULES, mesh_spec,
                                            tree_shardings)
    from repro.distributed.collectives import compressed_psum_grads
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.steps import TrainState, make_context, make_train_step
    from repro.models import forward, init_params
    from repro.models.transformer import ExecContext
    from repro.optim.adamw import adamw_init

    results = {}
    mesh = make_debug_mesh(data=2, model=4)
    pcfg = ParallelConfig()

    cfg = ModelConfig(
        name="tiny-moe", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=256,
        block_pattern=("global",), max_position=512,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=64,
                      quant=QuantConfig(enabled=True, bits=2,
                                        rank_budget=8, hqq_iters=2)))

    params = init_params(jax.random.key(0), cfg, jnp.float32)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, 256, (4, 16)), jnp.int32)

    # 1) single-device reference (no mesh)
    ref = forward(params, tokens, cfg,
                  make_context(cfg, "train", exact_capacity=True))

    # 2) EP a2a path under the mesh must match numerically
    ctx = make_context(cfg, "train", mesh=mesh, pcfg=pcfg,
                       exact_capacity=True)
    shardings = tree_shardings(mesh, jax.eval_shape(lambda: params), pcfg)
    params_sh = jax.device_put(params, shardings)
    with mesh:
        out = jax.jit(lambda p, t: forward(p, t, cfg, ctx).logits)(
            params_sh, tokens)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.logits.astype(jnp.float32))))
    results["ep_vs_single_max_err"] = err

    # 3) sharding rules: expert dim actually sharded over 'model'
    spec = shardings["segments"][0][0]["moe"]["w1"].spec
    results["moe_w1_spec"] = str(spec)

    # 4) train step end-to-end on the mesh
    tcfg = TrainConfig(total_steps=2, loss_chunk=0)
    step_fn, _ = make_train_step(cfg, tcfg, mesh=mesh, pcfg=pcfg,
                                 param_dtype=jnp.float32)
    state = TrainState(params_sh, adamw_init(params_sh))
    with mesh:
        state, m = jax.jit(step_fn)(state, {"tokens": tokens})
    results["train_loss"] = float(m["loss"])
    results["train_grad_norm"] = float(m["grad_norm"])

    # 5) compressed int8 psum vs exact psum
    grads = {"a": jnp.full((64, 64), 0.5, jnp.float32),
             "b": jnp.arange(-8.0, 8.0)}
    comp = compressed_psum_grads(grads, mesh, ("data", "model"), seed=0)
    rel = float(jnp.max(jnp.abs(comp["a"] - 0.5) / 0.5))
    results["psum_rel_err"] = rel

    # 6) decode path: EP-replicated (psum combine) must match single-device
    from repro.models import decode_step, init_caches
    from repro.models import forward as fwd
    caches = init_caches(cfg, 4, max_len=24, dtype=jnp.float32)
    pre_ctx = make_context(cfg, "prefill", exact_capacity=True)
    pre = fwd(params, tokens[:, :-1], cfg, pre_ctx, caches=caches)
    ref_step = decode_step(params, tokens[:, -1:], pre.caches, cfg,
                           make_context(cfg, "step", exact_capacity=True))
    step_ctx = make_context(cfg, "step", mesh=mesh, pcfg=pcfg,
                            exact_capacity=True)
    with mesh:
        got = jax.jit(lambda p, c, t: decode_step(
            p, t, c, cfg, step_ctx).logits)(params_sh, pre.caches,
                                            tokens[:, -1:])
    results["decode_ep_max_err"] = float(jnp.max(jnp.abs(
        got.astype(jnp.float32) - ref_step.logits.astype(jnp.float32))))
    print("RESULTS:" + json.dumps(results))
""")


@pytest.fixture(scope="module")
def mesh_results(dist_run):
    return dist_run(SCRIPT)


def test_ep_matches_single_device(mesh_results):
    assert mesh_results["ep_vs_single_max_err"] < 5e-3


def test_expert_dim_sharded(mesh_results):
    assert "model" in mesh_results["moe_w1_spec"]


def test_train_step_on_mesh(mesh_results):
    assert mesh_results["train_loss"] > 0
    assert mesh_results["train_grad_norm"] > 0


def test_compressed_psum_accuracy(mesh_results):
    assert mesh_results["psum_rel_err"] < 0.02


def test_decode_ep_replicated_matches_single(mesh_results):
    assert mesh_results["decode_ep_max_err"] < 5e-3
