"""Data pipeline: deterministic synthetic streams + packing utilities."""
from .synthetic import SyntheticLM, SyntheticLMConfig
