"""§Roofline: aggregate the dry-run JSONs into the per-cell table.

Reads experiments/dryrun/*.json, prints a markdown table with the three
terms, the dominant bottleneck, MODEL_FLOPS/HLO ratio, and memory fit —
and writes experiments/roofline.md for EXPERIMENTS.md inclusion.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.config import SHAPES
from repro.registry import ASSIGNED, get_config
from repro.configs.base import supports_shape

DRYRUN = Path("experiments/dryrun")
HBM_PER_CHIP = 16 * 2 ** 30   # v5e


def load_cells(mesh: str = "16x16"):
    rows = []
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            skip = supports_shape(cfg, shape)
            stem = f"{arch}_{shape.name}_{mesh}"
            hits = sorted(DRYRUN.glob(stem + "*.json"))
            if skip:
                rows.append({"arch": arch, "shape": shape.name,
                             "status": "SKIP", "note": skip})
                continue
            if not hits:
                rows.append({"arch": arch, "shape": shape.name,
                             "status": "MISSING"})
                continue
            rec = json.loads(hits[-1].read_text())
            r = rec["roofline"]
            ma = rec["memory_analysis"]
            resident = (ma["argument_bytes"] or 0) + (ma["temp_bytes"] or 0)
            rows.append({
                "arch": arch, "shape": shape.name, "status": "ok",
                "q": rec["quantized"],
                "t_compute_ms": r["t_compute"] * 1e3,
                "t_memory_ms": r["t_memory"] * 1e3,
                "t_collective_ms": r["t_collective"] * 1e3,
                "dominant": r["dominant"],
                "useful": r["useful_ratio"],
                "resident_gib": resident / 2 ** 30,
                "fits": resident <= HBM_PER_CHIP,
                "note": r.get("note", ""),
            })
    return rows


def to_markdown(rows) -> str:
    out = ["| arch | shape | q | compute ms | memory ms | coll ms | "
           "dominant | useful | GiB/chip | fits |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"{r['status']} | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {int(r['q'])} "
            f"| {r['t_compute_ms']:.2f} | {r['t_memory_ms']:.2f} "
            f"| {r['t_collective_ms']:.2f} | {r['dominant']} "
            f"| {r['useful']:.2f} | {r['resident_gib']:.1f} "
            f"| {'yes' if r['fits'] else 'NO'} |")
    return "\n".join(out)


def run(quick: bool = True):
    rows = load_cells()
    ok = [r for r in rows if r["status"] == "ok"]
    return [{"name": f"roofline/{r['arch']}/{r['shape']}",
             "dominant": r["dominant"],
             "t_dom_ms": max(r["t_compute_ms"], r["t_memory_ms"],
                             r["t_collective_ms"])} for r in ok]


def main():
    rows = load_cells()
    md = to_markdown(rows)
    Path("experiments").mkdir(exist_ok=True)
    Path("experiments/roofline.md").write_text(md + "\n")
    print(md)
    ok = sum(r["status"] == "ok" for r in rows)
    sk = sum(r["status"] == "SKIP" for r in rows)
    ms = sum(r["status"] == "MISSING" for r in rows)
    print(f"\n{ok} ok / {sk} skipped / {ms} missing (single-pod table)")


if __name__ == "__main__":
    main()
