"""Dispatch/combine round-trip invariants + three-path MoE consistency.

In-process: dropped assignments (slot >= capacity) contribute exactly
zero, and the dispatch ``comp`` mask matches ``topn_mask`` semantics.
Subprocess (4 host devices): ``moe_apply`` / ``moe_apply_ep_a2a`` /
``moe_apply_ep_replicated`` produce the same outputs and the same router
trace, dense and quantized."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MoEConfig, QuantConfig
from repro.core.restoration import topn_mask
from repro.models.moe import (Dispatch, combine_tokens, dispatch_tokens,
                              make_dispatch, route)


def _info(t=16, d=32, e=8, k=2, seed=0):
    rng = np.random.default_rng(seed)
    mcfg = MoEConfig(num_experts=e, top_k=k, d_expert=d)
    x2 = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, e)), jnp.float32)
    return x2, route(x2, w, mcfg), mcfg


def test_dispatch_combine_roundtrip_identity():
    """Identity expert + exact capacity: combine(dispatch(x)) == x (the
    normalized gates sum to 1, nothing is dropped)."""
    x2, info, mcfg = _info()
    t = x2.shape[0]
    disp = make_dispatch(info, mcfg.num_experts, t, top_n=1)
    xe, _ = dispatch_tokens(x2, disp, mcfg.num_experts)
    y = combine_tokens(xe, disp, t)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x2),
                               rtol=1e-5, atol=1e-5)


def test_dropped_assignments_contribute_zero():
    """With capacity < demand, every assignment whose slot >= C must add
    exactly zero to the combined output."""
    x2, info, mcfg = _info(t=16, e=4)
    t, k = info.topk_idx.shape
    cap = 2  # far below demand: 16*2/4 = 8 avg assignments per expert
    disp = make_dispatch(info, mcfg.num_experts, cap, top_n=1)
    ye = jnp.ones((mcfg.num_experts, cap, x2.shape[1]), jnp.float32)
    y = np.asarray(combine_tokens(ye, disp, t))
    # expected: each token accumulates gate * 1 for its KEPT assignments
    slot = np.asarray(disp.slot)
    gates = np.asarray(disp.gates)
    expect = np.zeros((t,), np.float32)
    kept = 0
    for a in range(t * k):
        if slot[a] < cap:
            expect[a // k] += gates[a]
            kept += 1
    assert 0 < kept < t * k          # some kept, some genuinely dropped
    np.testing.assert_allclose(y[:, 0], expect, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("top_n", [0, 1, 2])
def test_comp_mask_matches_topn_mask(top_n):
    """The per-(expert, slot) comp mask scattered by dispatch must agree
    with ``topn_mask`` over (token, expert): an assignment is compensated
    iff its expert is within the token's top-n."""
    x2, info, mcfg = _info(t=24, e=8)
    t, k = info.topk_idx.shape
    disp = make_dispatch(info, mcfg.num_experts, t, top_n=top_n)
    _, me = dispatch_tokens(x2, disp, mcfg.num_experts)
    tm = np.asarray(topn_mask(info.topk_idx, top_n, mcfg.num_experts))
    me, e_idx = np.asarray(me), np.asarray(disp.e_idx)
    slot, t_idx = np.asarray(disp.slot), np.asarray(disp.t_idx)
    for a in range(t * k):
        assert me[e_idx[a], slot[a]] == tm[t_idx[a], e_idx[a]]


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.config import MoEConfig, QuantConfig
    from repro.core import compress_ffn_weights
    from repro.distributed.sharding import shard_map
    from repro.models.moe import (moe_apply, moe_apply_ep_a2a,
                                  moe_apply_ep_replicated)

    E, D, FE, T = 8, 64, 128, 32
    mcfg = MoEConfig(num_experts=E, top_k=2, d_expert=FE,
                     capacity_factor=4.0,
                     quant=QuantConfig(enabled=True, bits=2, rank_budget=8,
                                       top_n_restore=1, hqq_iters=2))
    rng = np.random.default_rng(0)
    router = jnp.asarray(rng.standard_normal((D, E)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((E, D, FE)), jnp.float32) * 0.1
    w3 = jnp.asarray(rng.standard_normal((E, D, FE)), jnp.float32) * 0.1
    w2 = jnp.asarray(rng.standard_normal((E, FE, D)), jnp.float32) * 0.1
    stacks, _ = compress_ffn_weights(w1, w2, w3, mcfg.quant)
    x2 = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)

    mesh = Mesh(np.array(jax.devices()[:4]).reshape(4), ("model",))

    def pspec(leaf):
        return P(*(["model"] + [None] * (leaf.ndim - 1)))

    results = {}
    for name, quantized in (("dense", False), ("quant", True)):
        params = {"router": router}
        if quantized:
            params["stacks"] = stacks
        else:
            params.update(w1=w1, w3=w3, w2=w2)
        y_ref, _, info = moe_apply(x2, params, mcfg, quantized=quantized,
                                   exact_capacity=True)
        topk_ref = np.asarray(info.topk_idx)

        pspecs = jax.tree.map(pspec, params)
        pspecs["router"] = P(None, None)

        def a2a(x, p):
            y, _, i = moe_apply_ep_a2a(x, p, mcfg, quantized=quantized)
            return y, i.topk_idx
        y_a, topk_a = shard_map(
            a2a, mesh=mesh, in_specs=(P("model", None), pspecs),
            out_specs=(P("model", None), P("model", None)),
            check_vma=False)(x2, params)

        def rep(x, p):
            y, _, i = moe_apply_ep_replicated(x, p, mcfg,
                                              quantized=quantized)
            return y, i.topk_idx
        y_r, topk_r = shard_map(
            rep, mesh=mesh, in_specs=(P(None, None), pspecs),
            out_specs=(P(None, None), P(None, None)),
            check_vma=False)(x2, params)

        results[name] = {
            "a2a_err": float(jnp.max(jnp.abs(y_a - y_ref))),
            "rep_err": float(jnp.max(jnp.abs(y_r - y_ref))),
            "a2a_topk_equal": bool((np.asarray(topk_a) == topk_ref).all()),
            "rep_topk_equal": bool((np.asarray(topk_r) == topk_ref).all()),
        }
    print("RESULTS:" + json.dumps(results))
""")


@pytest.fixture(scope="module")
def three_path_results():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src",
             "JAX_PLATFORMS": "cpu"},
        cwd=__import__("pathlib").Path(__file__).parent.parent, timeout=500)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULTS:")][0]
    return json.loads(line[len("RESULTS:"):])


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["dense", "quant"])
def test_three_paths_agree(three_path_results, kind):
    r = three_path_results[kind]
    assert r["a2a_err"] < 5e-4, r
    assert r["rep_err"] < 5e-4, r
    assert r["a2a_topk_equal"] and r["rep_topk_equal"]
