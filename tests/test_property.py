"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import RANK_BUCKETS
from repro.core import (allocate_ranks, pack_bits, packed_nbytes, quantize,
                        dequantize, unpack_bits)
from repro.core.kurtosis import uniform_ranks
from repro.models.moe import (Dispatch, RoutingInfo, combine_tokens,
                              dispatch_tokens, make_dispatch, route)
from repro.config import MoEConfig

SETTINGS = dict(max_examples=25, deadline=None)


@given(bits=st.sampled_from([1, 2, 3, 4, 8]),
       k=st.integers(1, 8).map(lambda x: x * 64),
       n=st.integers(1, 4).map(lambda x: x * 8),
       seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_pack_unpack_is_identity(bits, k, n, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(0, 1 << bits, (k, n)).astype(np.uint8))
    assert np.array_equal(np.asarray(unpack_bits(pack_bits(q, bits), bits)),
                          np.asarray(q))


@given(bits=st.sampled_from([1, 2, 3, 4, 8]),
       block=st.sampled_from([8, 16, 32, 64]),
       m=st.integers(1, 6),
       n=st.integers(1, 24),
       seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_pack_unpack_roundtrip_all_blocks(bits, block, m, n, seed):
    """Round trip holds for every bit width at every packing block and
    K-shapes that are NOT multiples of the default PACK_BLOCK (e.g.
    K=24 at block=8), and the packed size matches the exact wire-byte
    formula regardless of block."""
    k = m * block
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(0, 1 << bits, (k, n)).astype(np.uint8))
    planes = pack_bits(q, bits, block=block)
    back = unpack_bits(planes, bits, block=block)
    assert np.array_equal(np.asarray(back), np.asarray(q))
    assert sum(p.size for p in planes) == packed_nbytes(bits, k, n)


@given(group=st.sampled_from([16, 32, 64]),
       cols=st.integers(1, 24),
       seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_quantize_dequantize_error_monotone_in_bits(group, cols, seed):
    """At a fixed group size, more bits never hurt: the groupwise-RTN
    reconstruction error is (strongly) decreasing along the supported
    ladder 1 -> 2 -> 3 -> 4 -> 8.  The per-group error bound halves per
    extra bit; 0.95 leaves room for rounding luck without ever letting a
    real monotonicity break through."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((128, cols)).astype(np.float32))
    errs = []
    for bits in (1, 2, 3, 4, 8):
        qt = quantize(w, bits, group)
        errs.append(float(jnp.linalg.norm(w - dequantize(qt))))
    for lo, hi in zip(errs[1:], errs[:-1]):
        assert lo <= 0.95 * hi + 1e-7, errs


@given(bits=st.sampled_from([2, 3, 4, 8]), seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_dequant_within_group_range(bits, seed):
    """Dequantized values never leave the [min, max] of their group."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((128, 32)).astype(np.float32))
    qt = quantize(w, bits, 64)
    deq = np.asarray(dequantize(qt))
    wg = np.asarray(w).reshape(2, 64, 32)
    dg = deq.reshape(2, 64, 32)
    lo = wg.min(1, keepdims=True) - 1e-4
    hi = wg.max(1, keepdims=True) + 1e-4
    assert ((dg >= lo) & (dg <= hi)).all()


@given(n=st.integers(1, 64), budget=st.sampled_from([0, 16, 32, 64, 128]),
       seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_rank_allocation_invariants(n, budget, seed):
    rng = np.random.default_rng(seed)
    kurt = rng.uniform(1, 100, n)
    ranks = allocate_ranks(kurt, budget)
    assert ranks.sum() <= n * budget
    assert all(r in RANK_BUCKETS for r in ranks)
    # monotone: a higher-kurtosis expert never gets less rank than a lower
    # one *when traversal order is unambiguous* (strictly sorted kurtosis)
    order = np.argsort(-kurt, kind="stable")
    sorted_ranks = ranks[order]
    assert all(sorted_ranks[i] >= sorted_ranks[i + 1]
               for i in range(n - 1))


@given(t=st.integers(1, 40), e=st.sampled_from([4, 8, 16]),
       k=st.integers(1, 4), seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_moe_dispatch_combine_no_drop_is_lossless(t, e, k, seed):
    """At capacity >= T the dispatch/combine round trip equals the dense
    gate-weighted sum of expert outputs (identity experts)."""
    k = min(k, e)
    rng = np.random.default_rng(seed)
    d = 16
    x = jnp.asarray(rng.standard_normal((t, d)).astype(np.float32))
    w_router = jnp.asarray(rng.standard_normal((d, e)).astype(np.float32))
    mcfg = MoEConfig(num_experts=e, top_k=k, d_expert=8)
    info = route(x, w_router, mcfg)
    disp = make_dispatch(info, e, capacity=t, top_n=1)
    xe, me = dispatch_tokens(x, disp, e)
    y = combine_tokens(xe, disp, t)          # identity experts
    expect = x * np.asarray(info.gates.sum(-1))[:, None]
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)
    # top-n mask covers exactly t slots (one per token, n=1)
    assert float(me.sum()) == t


@given(seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_router_gates_normalized(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((12, 16)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32))
    mcfg = MoEConfig(num_experts=8, top_k=2, d_expert=8,
                     router_norm_topk=True)
    info = route(x, w, mcfg)
    np.testing.assert_allclose(np.asarray(info.gates.sum(-1)),
                               np.ones(12), rtol=1e-5)
    # descending order
    g = np.asarray(info.gates)
    assert (g[:, :-1] >= g[:, 1:] - 1e-6).all()
