"""Serving: batched engine (prefill + decode), sampling, router-trace export."""
from .engine import GenerationResult, ServeEngine, router_trace, sample
