"""Batched serving engine: chunked prefill + jitted streaming decode loop.

The decode loop is a single ``lax.scan`` over steps compiled once per
``max_new``: sampling happens on-device (no per-token host round-trip),
cache buffers are donated into the loop, and the per-step router trace is
a first-class output of the forward pass (``ExecContext.collect_trace``)
— no ``disable_jit`` + ``moe.route`` monkey-patching.

When expert stores are attached (``attach_offload``), every generated
step's routing decisions are replayed into the per-layer metered
``ExpertStore`` + ``LayerAheadPrefetcher``, so wire bytes / cache hits /
prefetch accuracy come from live serving rather than only the synthetic
simulator.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig, ServeConfig
from ..models import model as lm
from ..models.transformer import ExecContext, init_caches
from ..launch.steps import make_context


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray             # (B, max_new)
    logprobs: Optional[np.ndarray]
    prefill_s: float
    decode_s: float
    steps: int
    # (steps, moe_layers, B, k) decode-time router decisions (None when the
    # model has no MoE layers)
    router_trace: Optional[np.ndarray] = None
    # live offload metering (attach_offload): bytes/token, hit rate, ...
    offload_report: Optional[Dict[str, float]] = None

    @property
    def decode_tokens_per_s(self) -> float:
        b = self.tokens.shape[0]
        return b * self.steps / self.decode_s if self.decode_s else 0.0

    def request_trace(self, b: int = 0) -> Optional[np.ndarray]:
        """(steps, layers, k) routing of one request stream — the shape the
        offload simulator and fig-7 benchmarks consume."""
        if self.router_trace is None:
            return None
        return self.router_trace[:, :, b, :]


def sample(logits: jax.Array, key, temperature: float) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1) \
        .astype(jnp.int32)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig = None,
                 quantized: bool = False, collect_router_trace: bool = True,
                 kernel_impl: Optional[str] = None):
        self.cfg = cfg
        self.scfg = scfg or ServeConfig()
        self.params = params
        self.quantized = quantized
        self.kernel_impl = kernel_impl
        # trace collection is free inside the scan (a few int32s per step);
        # it feeds GenerationResult.router_trace and the offload meter.
        # Gate on the PLAN's MoE layers (cfg.moe alone isn't enough: e.g.
        # first_layer_dense or recurrent-only patterns yield no MoE FFNs)
        from ..models.transformer import layer_specs
        has_moe = any(s.ffn == "moe" for s in layer_specs(cfg))
        self.collect_router_trace = collect_router_trace and has_moe
        self._stores = None            # per-MoE-layer ExpertStore
        self._prefetcher = None
        self._offload_policy = "ours"
        self._prefill_ctx = make_context(cfg, "prefill", quantized=quantized,
                                         exact_capacity=True,
                                         kernel_impl=kernel_impl)
        self._step_ctx = make_context(
            cfg, "step", quantized=quantized, exact_capacity=True,
            kernel_impl=kernel_impl,
            collect_trace=self.collect_router_trace)

        @jax.jit
        def prefill(params, caches, tokens):
            out = lm.forward(params, tokens, cfg, self._prefill_ctx,
                             caches=caches)
            return out.logits[:, -1], out.caches

        @functools.partial(jax.jit,
                           static_argnames=("max_new", "temperature"),
                           donate_argnums=(1,))
        def decode_loop(params, caches, logits0, key, max_new, temperature):
            """scan over decode steps: sample on device, step, stack trace.

            ``temperature`` is static (it selects the greedy/categorical
            branch in ``sample``) and read per call, so mutating
            ``scfg.temperature`` between generates takes effect."""

            def body(carry, _):
                logits, caches, key = carry
                key, k2 = jax.random.split(key)
                nxt = sample(logits, k2, temperature)
                out = lm.decode_step(params, nxt[:, None], caches, cfg,
                                     self._step_ctx)
                lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
                lp_tok = jnp.take_along_axis(lp, nxt[:, None], axis=-1)[:, 0]
                ys = (nxt, lp_tok)
                if self.collect_router_trace:
                    ys = ys + (out.trace,)        # (moe_layers, B, k)
                return (out.logits[:, 0], out.caches, key), ys

            (logits, caches, _), ys = jax.lax.scan(
                body, (logits0, caches, key), xs=None, length=max_new)
            return logits, caches, ys

        self._prefill = prefill
        self._decode_loop = decode_loop

    # -- offload wiring ----------------------------------------------------
    def attach_offload(self, stacks_by_layer: List[Dict],
                       policy: str = "ours",
                       cache_capacity: Optional[int] = None,
                       prefetch: bool = True):
        """Meter every generated token's expert fetches through per-layer
        host-side ``ExpertStore``s (LRU device cache + compensator bytes)."""
        from ..offload.store import ExpertStore
        from ..offload.prefetch import LayerAheadPrefetcher
        cap = (self.scfg.cache_experts if cache_capacity is None
               else cache_capacity)
        self._stores = [ExpertStore(stacks, cache_capacity=cap)
                        for stacks in stacks_by_layer]
        self._offload_policy = policy
        if prefetch:
            self._prefetcher = LayerAheadPrefetcher(
                len(stacks_by_layer), self.cfg.moe.top_k)
        return self

    def _meter_offload(self, trace: np.ndarray) -> Dict[str, float]:
        """Feed decode routing (steps, layers, B, k) into the stores."""
        from ..offload.store import meter_decode_trace
        return meter_decode_trace(
            self._stores, trace, policy=self._offload_policy,
            top_n=self.cfg.moe.quant.top_n_restore,
            prefetcher=self._prefetcher)

    # -- generation --------------------------------------------------------
    def generate(self, prompt_tokens: np.ndarray, max_new: int = 32,
                 seed: int = 0) -> GenerationResult:
        cfg = self.cfg
        b, plen = prompt_tokens.shape
        caches = init_caches(cfg, b, max_len=plen + max_new + 8,
                             dtype=jnp.float32)
        t0 = time.time()
        logits, caches = self._prefill(self.params,
                                       caches, jnp.asarray(prompt_tokens))
        logits.block_until_ready()
        t_prefill = time.time() - t0

        t1 = time.time()
        logits, caches, ys = self._decode_loop(
            self.params, caches, logits, jax.random.key(seed), max_new,
            self.scfg.temperature)
        logits.block_until_ready()
        t_decode = time.time() - t1

        toks = np.asarray(ys[0]).T                    # (B, max_new)
        logprobs = np.asarray(ys[1]).T                # (B, max_new)
        trace = (np.asarray(ys[2])
                 if self.collect_router_trace and ys[2] is not None else None)
        report = (self._meter_offload(trace)
                  if trace is not None and self._stores else None)
        return GenerationResult(toks, logprobs, t_prefill, t_decode, max_new,
                                router_trace=trace, offload_report=report)

    def score(self, tokens: np.ndarray) -> float:
        """Mean next-token NLL (perplexity proxy) under the serving path."""
        ctx = make_context(self.cfg, "train", quantized=self.quantized,
                           exact_capacity=True,
                           kernel_impl=self.kernel_impl)
        out = lm.forward(self.params, jnp.asarray(tokens), self.cfg, ctx)
        logits = out.logits[:, :-1].astype(jnp.float32)
        tgt = jnp.asarray(tokens)[:, 1:]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        sel = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        return float(jnp.mean(lse - sel))


def router_trace(cfg: ModelConfig, params, tokens: np.ndarray,
                 quantized: bool = False,
                 kernel_impl: Optional[str] = None) -> np.ndarray:
    """Export per-token routing decisions (tokens, moe_layers, k).

    Runs the jitted forward pass with ``collect_trace`` — the trace is a
    first-class model output, so this works under jit/scan with no
    ``disable_jit`` or ``moe.route`` hook.
    """
    ctx = make_context(cfg, "train", quantized=quantized,
                       exact_capacity=True, collect_trace=True,
                       kernel_impl=kernel_impl)
    out = jax.jit(lambda p, t: lm.forward(p, t, cfg, ctx).trace)(
        params, jnp.asarray(tokens))
    # (moe_layers, T, k) -> (T, layers, k)
    return np.asarray(out).transpose(1, 0, 2)
