"""repro-lint engine + rules: every rule fires on a seeded violation
fixture, stays quiet on the real tree, and the suppression/baseline
mechanisms behave (src/repro/analysis/, tools/repro_lint.py)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import Baseline, all_rules, lint_paths

REPO = Path(__file__).resolve().parent.parent


def run_fixture(tmp_path, files, select=None):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return lint_paths([tmp_path], tmp_path, select=select)


def rules_of(result):
    return sorted({f.rule for f in result.findings})


# ---------------------------------------------------------------------------
# seeded violations: each rule must fire on its fixture
# ---------------------------------------------------------------------------

FIXTURES = {
    "RL101": {"m.py": """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            y = jnp.sum(x)
            return float(y) + y.item()
        """},
    "RL102": {"m.py": """
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
        """},
    "RL103": {"m.py": """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x, n):
            return jnp.zeros(n) + x
        """},
    "RL104": {"m.py": """
        import jax

        @jax.jit
        def f(x):
            return jax.device_get(x)
        """},
    "RL201": {"pipeline.py": """
        def wire(bits, k, n):
            return k * n * bits // 8
        """},
    "RL202": {"meter.py": """
        from repro.core.quantize import SCALE_WIRE_BYTES

        def scales(k, g, n):
            return (k // g) * n * SCALE_WIRE_BYTES
        """},
    "RL301": {"kernels/autotune.py": """
        DEFAULT_TABLE = {
            ("fused", 3, 64, 32, 128): (32, 256, 96),
        }
        """},
    "RL302": {"kernels/autotune.py": """
        DEFAULT_TABLE = {
            ("fused", 3, 64, 32, 128): (1024, 4096, 8192),
        }
        """},
    "RL303": {"kernels/k.py": """
        from jax.experimental import pallas as pl

        def kern(planes_ref, o_ref):
            o_ref[...] = planes_ref[...]

        def launch(planes, x, bk=128):
            return pl.pallas_call(kern)(planes[0])
        """},
    "RL401": {"m.py": """
        import jax
        from repro.distributed.sharding import tree_constraint

        @jax.jit
        def step(caches, x):
            caches = advance(caches, x)
            return caches
        """},
}


@pytest.mark.parametrize("rule_id", sorted(FIXTURES))
def test_rule_fires_on_fixture(tmp_path, rule_id):
    result = run_fixture(tmp_path, FIXTURES[rule_id], select={rule_id})
    assert rule_id in rules_of(result), \
        f"{rule_id} silent on its seeded violation"


# ---------------------------------------------------------------------------
# precision: known-legal idioms must NOT fire
# ---------------------------------------------------------------------------

def test_static_idioms_stay_quiet(tmp_path):
    result = run_fixture(tmp_path, {"m.py": """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(params, x, temperature: float = 1.0, cfg=None):
            b, s = x.shape                  # shape access is static
            if temperature <= 0.0:          # float-annotated scalar
                x = x * 2
            if "bias" in params:            # pytree key membership
                x = x + params["bias"]
            if cfg is None:                 # identity test
                x = -x
            for i in range(s):              # range over a static dim
                x = x + i
            return jnp.zeros((b, s)) + x    # static shape tuple
        """})
    assert result.findings == [], [f.render() for f in result.findings]


def test_pack_guard_silences_rl303(tmp_path):
    result = run_fixture(tmp_path, {"kernels/k.py": """
        from jax.experimental import pallas as pl

        PACK_BLOCK = 64

        def kern(planes_ref, o_ref):
            o_ref[...] = planes_ref[...]

        def launch(planes, x, bk=128):
            assert bk % PACK_BLOCK == 0
            return pl.pallas_call(kern)(planes[0])
        """}, select={"RL303"})
    assert result.findings == []


# ---------------------------------------------------------------------------
# the real tree is clean
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_real_tree_is_clean():
    result = lint_paths([REPO / "src", REPO / "tools", REPO / "benchmarks"],
                        REPO,
                        baseline_path=REPO / "tools" /
                        "repro_lint_baseline.json")
    assert result.ok, "\n".join(f.render() for f in result.findings)


def test_all_rules_registered():
    ids = set(all_rules())
    assert {"RL101", "RL102", "RL103", "RL104", "RL201", "RL202",
            "RL301", "RL302", "RL303", "RL401"} <= ids


# ---------------------------------------------------------------------------
# suppression + baseline
# ---------------------------------------------------------------------------

def test_inline_suppression(tmp_path):
    src = FIXTURES["RL102"]["m.py"].replace(
        "if x > 0:", "if x > 0:  # repro-lint: disable=RL102")
    result = run_fixture(tmp_path, {"m.py": src}, select={"RL102"})
    assert result.findings == []
    assert result.suppressed == 1


def test_baseline_roundtrip(tmp_path):
    result = run_fixture(tmp_path, FIXTURES["RL102"], select={"RL102"})
    assert result.findings
    bpath = tmp_path / "baseline.json"
    Baseline.dump(result.findings, bpath)

    again = lint_paths([tmp_path], tmp_path, baseline_path=bpath,
                       select={"RL102"})
    assert again.findings == []
    assert again.baselined == len(result.findings)

    # editing the flagged line invalidates its baseline entry
    m = tmp_path / "m.py"
    m.write_text(m.read_text().replace("if x > 0:", "if x > 1:"))
    edited = lint_paths([tmp_path], tmp_path, baseline_path=bpath,
                        select={"RL102"})
    assert edited.findings and edited.baselined == 0


def test_corrupt_baseline_is_ignored(tmp_path):
    bpath = tmp_path / "baseline.json"
    bpath.write_text("{not json")
    result = run_fixture(tmp_path, FIXTURES["RL102"], select={"RL102"})
    # corrupt baseline -> empty baseline -> findings still reported
    again = lint_paths([tmp_path], tmp_path, baseline_path=bpath,
                       select={"RL102"})
    assert rules_of(again) == rules_of(result) == ["RL102"]


# ---------------------------------------------------------------------------
# CLI exit codes
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cli_exit_codes(tmp_path):
    (tmp_path / "bad.py").write_text(textwrap.dedent(
        FIXTURES["RL102"]["m.py"]))
    (tmp_path / "ok.py").write_text("x = 1\n")
    script = str(REPO / "tools" / "repro_lint.py")

    dirty = subprocess.run(
        [sys.executable, script, "--root", str(tmp_path), "--baseline",
         "none", "--select", "RL102", "bad.py"], capture_output=True,
        text=True)
    assert dirty.returncode == 1, dirty.stdout + dirty.stderr
    assert "RL102" in dirty.stdout

    clean = subprocess.run(
        [sys.executable, script, "--root", str(tmp_path), "--baseline",
         "none", "--select", "RL102", "ok.py"], capture_output=True,
        text=True)
    assert clean.returncode == 0, clean.stdout + clean.stderr

    missing = subprocess.run(
        [sys.executable, script, "--root", str(tmp_path), "--baseline",
         "none", "nonexistent_dir"], capture_output=True, text=True)
    assert missing.returncode == 2
