"""Batched serving engine: chunked prefill + decode loop + sampling.

Runs the same ``make_prefill_step``/``make_serve_step`` functions the
dry-run lowers, so what we benchmark is what we'd deploy.  Supports the
paper's quantized+compensated serving path and (optionally) a metered
offload emulation that replays the router trace into an ExpertStore.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig, ServeConfig
from ..models import model as lm
from ..models.transformer import ExecContext, init_caches
from ..launch.steps import make_context


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray             # (B, max_new)
    logprobs: Optional[np.ndarray]
    prefill_s: float
    decode_s: float
    steps: int
    router_trace: Optional[np.ndarray] = None   # (steps, layers, k)

    @property
    def decode_tokens_per_s(self) -> float:
        b = self.tokens.shape[0]
        return b * self.steps / self.decode_s if self.decode_s else 0.0


def sample(logits: jax.Array, key, temperature: float) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1) \
        .astype(jnp.int32)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig = None,
                 quantized: bool = False, collect_router_trace: bool = False):
        self.cfg = cfg
        self.scfg = scfg or ServeConfig()
        self.params = params
        self.quantized = quantized
        self.collect_router_trace = collect_router_trace
        self._prefill_ctx = make_context(cfg, "prefill", quantized=quantized,
                                         exact_capacity=True)
        self._step_ctx = make_context(cfg, "step", quantized=quantized,
                                      exact_capacity=True)

        @jax.jit
        def prefill(params, caches, tokens):
            out = lm.forward(params, tokens, cfg, self._prefill_ctx,
                             caches=caches)
            return out.logits[:, -1], out.caches

        @jax.jit
        def step(params, caches, tokens):
            out = lm.decode_step(params, tokens, caches, cfg, self._step_ctx)
            return out.logits[:, 0], out.caches

        self._prefill = prefill
        self._step = step

    def generate(self, prompt_tokens: np.ndarray, max_new: int = 32,
                 seed: int = 0) -> GenerationResult:
        cfg, scfg = self.cfg, self.scfg
        b, plen = prompt_tokens.shape
        caches = init_caches(cfg, b, max_len=plen + max_new + 8,
                             dtype=jnp.float32)
        t0 = time.time()
        logits, caches = self._prefill(self.params,
                                       caches, jnp.asarray(prompt_tokens))
        logits.block_until_ready()
        t_prefill = time.time() - t0

        key = jax.random.key(seed)
        outs: List[np.ndarray] = []
        t1 = time.time()
        for i in range(max_new):
            key, k2 = jax.random.split(key)
            nxt = sample(logits, k2, scfg.temperature)
            outs.append(np.asarray(nxt))
            logits, caches = self._step(self.params, caches, nxt[:, None])
        logits.block_until_ready()
        t_decode = time.time() - t1
        return GenerationResult(np.stack(outs, axis=1), None, t_prefill,
                                t_decode, max_new)

    def score(self, tokens: np.ndarray) -> float:
        """Mean next-token NLL (perplexity proxy) under the serving path."""
        ctx = make_context(self.cfg, "train", quantized=self.quantized,
                           exact_capacity=True)
        out = lm.forward(self.params, jnp.asarray(tokens), self.cfg, ctx)
        logits = out.logits[:, :-1].astype(jnp.float32)
        tgt = jnp.asarray(tokens)[:, 1:]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        sel = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        return float(jnp.mean(lse - sel))


def router_trace(cfg: ModelConfig, params, tokens: np.ndarray,
                 quantized: bool = False) -> np.ndarray:
    """Export the per-token routing decisions (tokens, moe_layers, k) for
    the offload simulator — real traces, not synthetic skew."""
    from ..models.transformer import derive_plan, apply_layer
    from ..models.moe import route
    cfg_local = cfg
    ctx = make_context(cfg, "train", quantized=quantized,
                       exact_capacity=True)
    # capture router inputs by re-running the stack and hooking MoE layers
    traces: List[np.ndarray] = []

    import repro.models.moe as moe_mod
    orig = moe_mod.route

    def hooked(x2, w, mcfg):
        info = orig(x2, w, mcfg)
        traces.append(np.asarray(info.topk_idx))
        return info

    moe_mod.route = hooked
    try:
        with jax.disable_jit():   # eager so the hook sees concrete values
            lm.forward(params, jnp.asarray(tokens), cfg, ctx)
    finally:
        moe_mod.route = orig
    # traces: list over layers of (T, k) -> (T, layers, k)
    arr = np.stack(traces, axis=1)
    return arr
