"""Model zoo: composable blocks + LM wrapper for all assigned families."""
from .expert_backend import (DenseBackend, ExpertBackend, PallasQuantBackend,
                             RefQuantBackend, select_backend)
from .model import (LMOutput, abstract_caches, abstract_params, decode_step,
                    forward, input_specs, lm_loss)
from .transformer import (ExecContext, derive_plan, init_caches, init_params)
