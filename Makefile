# Repo verification targets.
#
#   make tier1   fast correctness gate (excludes @pytest.mark.slow)
#   make test    full suite, including slow/benchmarks-adjacent tests
#   make bench-smoke     quick continuous-batching serving sweep
#   make serve-example   live-decode offload report from the serve engine

PY = PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: tier1 test bench-smoke serve-example

tier1:
	$(PY) -m pytest -x -q -m "not slow"

test:
	$(PY) -m pytest -q

bench-smoke:
	$(PY) benchmarks/bench_serving.py --quick

serve-example:
	$(PY) examples/serve_offload.py
