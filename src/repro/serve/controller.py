"""Runtime bandwidth-budget controller: adaptive top-n restoration.

The paper's headline word is *Adaptive*, yet ``QuantConfig.top_n_restore``
is a frozen field — every layer, request, and load level compensates the
same n experts.  This module closes the loop from live offload metering
to per-layer restoration intensity:

    offload/store.py meters wire bytes per scan chunk
        │
        ▼
    BandwidthController.update(bytes, tokens)     (between scan chunks)
        │   integral step on a per-layer intensity ladder
        ▼
    ControllerPlan: per-layer (top_n, rank_cap)
        │
        ├──► traced (L, 2) int32 plan array into the jitted decode scan
        │    (static shape → the compiled loop NEVER recompiles)
        └──► per-layer top_n / rank_cap into the metering replay

Exploits that ``CompressedExpertStack`` factors are rank-padded with true
ranks tracked: capping the rank is a mask over the rank-space activation
(a slice of the padded factors), not a re-SVD.

Determinism: the controller state advances only on metered byte counters
(never wall-clock), so the same routing trace + budget always produces
the same plan sequence — pinned by ``tests/test_controller.py``.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from ..config import ControlConfig


class ControllerPlan(NamedTuple):
    """Per-MoE-layer restoration intensity."""
    top_n: np.ndarray        # (L,) int32: experts compensated per token
    rank_cap: np.ndarray     # (L,) int32: compensator rank ceiling

    def as_array(self) -> np.ndarray:
        """(L, 2) int32 — the static-shape array the decode scan consumes."""
        return np.stack([self.top_n, self.rank_cap], axis=1).astype(np.int32)

    def summary(self) -> dict:
        return {"mean_top_n": float(self.top_n.mean()) if self.top_n.size else 0.0,
                "mean_rank_cap": (float(self.rank_cap.mean())
                                  if self.rank_cap.size else 0.0)}


def static_plan(pad_ranks: Sequence[int], top_n: int) -> ControllerPlan:
    """The frozen pre-controller operating point: ``top_n`` everywhere,
    ranks uncapped (cap = the layer's padded rank)."""
    l = len(pad_ranks)
    return ControllerPlan(np.full((l,), int(top_n), np.int32),
                          np.asarray(pad_ranks, np.int32))


@dataclasses.dataclass
class ControllerRecord:
    """One ``update`` observation (telemetry / convergence reporting)."""
    chunk: int
    tokens: int
    bytes_per_token: float
    level: int


class BandwidthController:
    """Integral controller over a per-layer (top_n, rank_cap) ladder.

    Each layer has the same ladder of intensity *rungs*::

        [(0, 0), (1, c1), ..., (1, R_l), (2, c1), ..., (top_k, R_l)]

    where the rank caps ``c_i`` are ``ControlConfig.rank_fracs`` fractions
    of the layer's padded rank ``R_l``.  The controller state is one
    global *level* in ``[0, L * (rungs - 1)]``: level ``g`` puts every
    layer at rung ``g // L`` and the first ``g % L`` layers one rung
    higher — L micro-steps per rung, so plan granularity is per layer,
    not per model.

    ``update`` moves the level by an integral step proportional to the
    relative budget error (capped at ``gain`` of the whole ladder), with
    a ``deadband`` inside which the plan holds.  With no budget (or
    ``enabled=False``) the plan stays pinned at the static operating
    point and ``update`` only records telemetry.
    """

    def __init__(self, pad_ranks: Sequence[int], top_k: int,
                 ccfg: ControlConfig, static_top_n: int):
        if len(pad_ranks) == 0:
            raise ValueError("controller needs at least one MoE layer")
        self.ccfg = ccfg
        self.top_k = int(top_k)
        self.pad_ranks = tuple(int(r) for r in pad_ranks)
        self.static_top_n = int(static_top_n)
        self.num_layers = len(self.pad_ranks)

        lo = max(0, ccfg.min_top_n)
        hi = self.top_k if ccfg.max_top_n < 0 else min(ccfg.max_top_n,
                                                       self.top_k)
        hi = max(hi, lo)
        # rung schedule shared by all layers: (top_n, rank fraction index);
        # per-layer caps resolve the fraction against that layer's pad rank
        self._rungs: List[Tuple[int, float]] = []
        for n in range(lo, hi + 1):
            if n == 0:
                self._rungs.append((0, 0.0))
            else:
                for f in ccfg.rank_fracs:
                    self._rungs.append((n, float(f)))
        self.max_level = self.num_layers * (len(self._rungs) - 1)
        self._level = self._static_level()
        self._ema: Optional[float] = None   # smoothed bytes/token signal
        self.history: List[ControllerRecord] = []
        self._chunks = 0

    # -- plan mapping ------------------------------------------------------
    def _static_level(self) -> int:
        """Ladder level of the frozen (static_top_n, full-rank) point."""
        n = min(max(self.static_top_n, self._rungs[0][0]),
                self._rungs[-1][0])
        idx = max(i for i, (rn, rf) in enumerate(self._rungs)
                  if rn == n)               # full-rank rung of that top_n
        return idx * self.num_layers

    def _rung_cap(self, rung: int, layer: int) -> int:
        n, frac = self._rungs[rung]
        if n == 0:
            return 0
        return max(1, int(np.ceil(self.pad_ranks[layer] * frac)))

    def plan_at(self, level: int) -> ControllerPlan:
        level = int(np.clip(level, 0, self.max_level))
        base, extra = divmod(level, self.num_layers)
        top_n = np.zeros((self.num_layers,), np.int32)
        cap = np.zeros((self.num_layers,), np.int32)
        for l in range(self.num_layers):
            rung = min(base + (1 if l < extra else 0), len(self._rungs) - 1)
            top_n[l] = self._rungs[rung][0]
            cap[l] = self._rung_cap(rung, l)
        return ControllerPlan(top_n, cap)

    def plan(self) -> ControllerPlan:
        if not self.active:
            return static_plan(self.pad_ranks, self.static_top_n)
        return self.plan_at(self._level)

    @property
    def active(self) -> bool:
        """True when the controller actually moves the plan."""
        return bool(self.ccfg.enabled
                    and self.ccfg.target_bytes_per_token > 0)

    @property
    def level(self) -> int:
        return self._level

    # -- feedback ----------------------------------------------------------
    def update(self, nbytes: int, tokens: int,
               shard_bytes: Optional[Sequence[int]] = None
               ) -> ControllerPlan:
        """Consume one chunk's metered wire bytes; return the next plan.

        The per-chunk bytes/token sample is EMA-smoothed (chunk-scale LRU
        hit/miss dynamics make the raw signal noisy) and the ladder step
        is capped at ``max_step_frac`` of the whole ladder — uncapped
        proportional jumps limit-cycle around the budget instead of
        settling.  Driven purely by byte counters (no wall-clock), so the
        same trace + budget reproduces the same plan sequence exactly.

        ``shard_bytes`` is the chunk's per-shard link traffic under
        expert-parallel serving.  With ``ControlConfig.budget_scope ==
        'per_shard'`` the controlled signal becomes the HOTTEST shard's
        bytes/token (each device has its own host link; the slowest link
        gates decode), so the budget is a per-link ceiling rather than an
        aggregate.  The aggregate scope (default) ignores it — and since
        per-shard totals sum to the aggregate, the plan sequence is then
        independent of the shard count.
        """
        self._chunks += 1
        if (self.ccfg.budget_scope == "per_shard"
                and shard_bytes is not None and len(shard_bytes) > 0):
            nbytes = int(np.max(np.asarray(shard_bytes)))
        measured = nbytes / tokens if tokens > 0 else 0.0
        target = self.ccfg.target_bytes_per_token
        if self.active and tokens > 0:
            a = min(max(self.ccfg.ema, 0.0), 1.0)
            self._ema = (measured if self._ema is None
                         else a * measured + (1.0 - a) * self._ema)
            err = (self._ema - target) / target
            if abs(err) > self.ccfg.deadband:
                cap = max(1, int(round(self.max_level
                                       * self.ccfg.max_step_frac)))
                step = min(cap, max(1, int(round(
                    self.ccfg.gain * min(abs(err), 1.0) * self.max_level))))
                self._level = int(np.clip(
                    self._level - step if err > 0 else self._level + step,
                    0, self.max_level))
        self.history.append(ControllerRecord(
            self._chunks, int(tokens), float(measured), self._level))
        return self.plan()

    # -- construction ------------------------------------------------------
    @classmethod
    def from_stacks(cls, stacks_by_layer: Sequence[dict], top_k: int,
                    ccfg: ControlConfig, static_top_n: int
                    ) -> "BandwidthController":
        """Build from the per-layer ``CompressedExpertStack`` dicts the
        engine's offload metering already holds.

        The rank ladder tops out at each layer's largest TRUE allocated
        rank — not the padded rank.  Under calibrated heterogeneous
        allocation (or an artifact padded for alignment) ``pad_rank``
        can exceed every true rank, and rungs in that gap would be
        identity plans: caps above an expert's true rank neither change
        the math (padding columns are exact zeros) nor the metered
        bytes (``compensator_bytes`` clamps at the true rank).  Topping
        out at the true rank makes every rung a real operating point,
        and the inactive-controller static plan (cap = ladder top) stays
        bit- and byte-identical to the uncontrolled path."""
        tops = []
        for stacks in stacks_by_layer:
            true_top = max(max(s.ranks) for s in stacks.values())
            tops.append(max(true_top, 1))
        return cls(tops, top_k, ccfg, static_top_n)
