"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy (``impl``):
  'auto'              pallas on TPU, ref elsewhere (CPU dry-run lowers real
                      einsum FLOPs rather than interpreter scaffolding)
  'pallas'            compiled Mosaic kernel (TPU)
  'pallas_interpret'  kernel body executed by the Pallas interpreter on CPU
                      (used by tests to validate the kernel against ref)
  'ref'               pure-jnp oracle

Wrappers pad M to the tile size and slice back, fold the compensator factor
scales into the rank-space activation, and expose QuantizedTensor /
CompressedExpertStack-level entry points.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.quantize import QuantizedTensor
from . import ref as ref_ops
from .quant_matmul import (fused_expert_matmul_pallas,
                           lowrank_comp_matmul_pallas, quant_matmul_pallas)

_ENV = "REPRO_KERNEL_IMPL"


def default_impl() -> str:
    env = os.environ.get(_ENV)
    if env and env != "auto":           # 'auto' = platform-based selection
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


IMPLS = ("pallas", "pallas_interpret", "ref")


def resolve_impl(impl: Optional[str] = None) -> str:
    """Resolve an ``impl`` request ('auto'/None, 'pallas', 'pallas_interpret',
    'ref') to the concrete implementation that will run, honouring the
    ``REPRO_KERNEL_IMPL`` env override.  This is the single dispatch policy
    shared by the kernel wrappers below and the model-level ExpertBackend."""
    impl = impl or "auto"
    resolved = default_impl() if impl == "auto" else impl
    if resolved not in IMPLS:
        raise ValueError(
            f"unknown kernel impl {resolved!r} (from "
            f"{'$' + _ENV if impl == 'auto' else 'impl argument'}); "
            f"expected one of {('auto',) + IMPLS}")
    return resolved


_pick = resolve_impl


def _pad_m(x: jax.Array, bm: int):
    """Right-pad the token dim to a multiple of ``bm``.  Callers pair
    this with the small-m tile sizes from ``_tile_sizes`` /
    ``autotune.choose_tiles`` so a single decode token pads to the 8-row
    sublane minimum, not a full 128-row tile per expert."""
    m = x.shape[0]
    pm = (-m) % bm
    if pm:
        x = jnp.pad(x, ((0, pm), (0, 0)))
    return x, m


def _tile_sizes(m: int, k: int, n: int, bm: int, bn: int, bk: int):
    """Clamp tiles to the problem and keep pack/group divisibility.

    ``bm`` clamps to the token count rounded up to the f32 sublane
    minimum (8): decode-sized blocks (m <= 8) run the bm=8 preset
    instead of padding m into a 128-row tile, and ragged m stays
    sublane-aligned so the compiled kernel's tiles are MXU-admissible.
    """
    bm = max(8, min(bm, -(-m // 8) * 8))
    bk = min(bk, k)
    bn = min(bn, n)
    while k % bk:
        bk //= 2
    while n % bn:
        bn //= 2
    return bm, bn, bk


def quant_matmul(x: jax.Array, qt: QuantizedTensor, *,
                 impl: Optional[str] = None, out_dtype=None,
                 bm: int = 128, bn: int = 256, bk: int = 512) -> jax.Array:
    """y = x @ dequant(qt);  x: (M, K) -> (M, N)."""
    out_dtype = out_dtype or x.dtype
    impl = _pick(impl)
    if impl == "ref":
        return ref_ops.quant_matmul_ref(x, qt.planes, qt.scale, qt.zero,
                                        qt.bits, qt.group_size, out_dtype)
    k, n = qt.shape
    bm, bn, bk = _tile_sizes(x.shape[0], k, n, bm, bn, bk)
    xp, m = _pad_m(x, bm)
    y = quant_matmul_pallas(xp, qt.planes, qt.scale, qt.zero,
                            bits=qt.bits, group_size=qt.group_size,
                            bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                            interpret=(impl == "pallas_interpret"))
    return y[:m]


def lowrank_comp_matmul(x: jax.Array, qt: QuantizedTensor,
                        u: jax.Array, v: jax.Array,
                        u_scale: jax.Array, v_scale: jax.Array,
                        mask: Optional[jax.Array] = None, *,
                        impl: Optional[str] = None, out_dtype=None,
                        rank_cap: Optional[jax.Array] = None,
                        bm: int = 128, bn: int = 256, bk: int = 512
                        ) -> jax.Array:
    """Router-guided compensated matmul (paper §3.2).

    y = x @ dequant(qt) + ((x * mask) @ (U u_s)) diag(v_s) @ V_codes

    ``rank_cap`` (traced scalar, None = full padded rank) zeroes rank
    dims >= cap in the rank-space activation — the bandwidth controller's
    runtime rank truncation, a mask rather than a re-SVD, applied before
    the kernel so the fused Pallas path needs no shape change.
    """
    out_dtype = out_dtype or x.dtype
    impl = _pick(impl)
    if impl == "ref":
        return ref_ops.lowrank_comp_matmul_ref(
            x, qt.planes, qt.scale, qt.zero, qt.bits, qt.group_size,
            u, v, u_scale, v_scale, mask, out_dtype, rank_cap=rank_cap)
    # rank-space activation with both factor scales folded in (rank-r cost)
    xf = x.astype(jnp.float32)
    if mask is not None:
        xf = xf * mask[:, None].astype(jnp.float32)
    ud = u.astype(jnp.float32) * u_scale          # (K, R)
    xu = jnp.dot(xf, ud, preferred_element_type=jnp.float32)
    if rank_cap is not None:
        xu = xu * (jnp.arange(u.shape[-1]) < rank_cap).astype(jnp.float32)
    xu = xu * v_scale[None, :, 0]                 # fold (R,1) v_scale
    k, n = qt.shape
    bm, bn, bk = _tile_sizes(x.shape[0], k, n, bm, bn, bk)
    xp, m = _pad_m(x, bm)
    xup, _ = _pad_m(xu, bm)
    y = lowrank_comp_matmul_pallas(
        xp, qt.planes, qt.scale, qt.zero, xup, v,
        bits=qt.bits, group_size=qt.group_size, bm=bm, bn=bn, bk=bk,
        out_dtype=out_dtype, interpret=(impl == "pallas_interpret"))
    return y[:m]


def compensated_matmul_stack(x: jax.Array, stack, mask: jax.Array, *,
                             impl: Optional[str] = None, out_dtype=None,
                             rank_cap: Optional[jax.Array] = None
                             ) -> jax.Array:
    """vmap of lowrank_comp_matmul over an expert stack.

    x: (E, C, K), stack: CompressedExpertStack, mask: (E, C) -> (E, C, N).
    ``rank_cap`` (traced scalar shared by all experts of the layer) caps
    the compensator rank via the padded-factor mask.
    """
    out_dtype = out_dtype or x.dtype

    def one(xe, planes, scale, zero, u, v, us, vs, me):
        qt = QuantizedTensor(planes, scale, zero, stack.bits,
                             stack.group_size, stack.shape[1:])
        return lowrank_comp_matmul(xe, qt, u, v, us, vs, me, impl=impl,
                                   out_dtype=out_dtype, rank_cap=rank_cap)

    return jax.vmap(one)(x, stack.planes, stack.scale, stack.zero,
                         stack.u, stack.v, stack.u_scale, stack.v_scale,
                         mask)


def fused_expert_matmul(xe: jax.Array, stack, me: jax.Array, *,
                        gates: Optional[jax.Array] = None,
                        rank_cap: Optional[jax.Array] = None,
                        impl: Optional[str] = None, out_dtype=None,
                        bm: Optional[int] = None, bn: Optional[int] = None,
                        bk: Optional[int] = None) -> jax.Array:
    """Fused decode-path projection over one expert stack (the tentpole
    kernel entry point; see ``quant_matmul.fused_expert_matmul_pallas``).

    xe: (E, C, K) dispatched tokens, stack: CompressedExpertStack,
    me: (E, C) top-n compensation mask, gates: optional (E, C) router
    gates folded into the output in-kernel (the gate-weighted combine),
    rank_cap: traced per-layer plan scalar (None = full padded rank).

    One ``pallas_call`` covers every expert of the (layer, projection):
    bitplane unpack + HQQ dequant at each expert's TRUE width
    (``stack.expert_bits``), the rank-capped compensator GEMM, and the
    gate weighting — accumulated in f32 VMEM scratch, no HBM
    round-trips.  Block sizes come from ``kernels.autotune`` unless
    pinned by the caller; the traced ``rank_cap``/``gates`` enter as
    data, so controller plan changes never recompile.
    """
    out_dtype = out_dtype or xe.dtype
    impl = _pick(impl)
    if impl == "ref":
        return ref_ops.fused_expert_matmul_ref(
            xe, stack.planes, stack.scale, stack.zero, stack.bits,
            stack.group_size, stack.u, stack.v, stack.u_scale,
            stack.v_scale, me, ge=gates, rank_cap=rank_cap,
            out_dtype=out_dtype)
    e, c, k = xe.shape
    n = stack.scale.shape[-1]
    r = stack.pad_rank
    if bm is None or bn is None or bk is None:
        from .autotune import choose_tiles
        abm, abn, abk = choose_tiles("fused", bits=stack.bits,
                                     group_size=stack.group_size, rank=r,
                                     m=c, k=k, n=n)
        bm, bn, bk = bm or abm, bn or abn, bk or abk
    pc = (-c) % bm
    xep = jnp.pad(xe, ((0, 0), (0, pc), (0, 0))) if pc else xe
    mep = jnp.pad(me, ((0, 0), (0, pc))) if pc else me
    gep = (jnp.pad(gates, ((0, 0), (0, pc)))
           if gates is not None and pc else gates)
    cap = jnp.full((1, 1), r, jnp.int32) if rank_cap is None else \
        jnp.asarray(rank_cap, jnp.int32).reshape(1, 1)
    # TRUE per-expert widths; inside shard_map regions the runtime leaves
    # carry a local expert slice while ``expert_bits`` (static metadata)
    # stays global — fall back to the container width there (bit-exact:
    # sub-width codes leave the upper planes zero)
    ebs = stack.expert_bits
    if ebs is not None and len(ebs) != e:
        ebs = None
    eb = jnp.asarray(ebs if ebs is not None else (stack.bits,) * e,
                     jnp.int32).reshape(e, 1)
    ye = fused_expert_matmul_pallas(
        xep, stack.planes, stack.scale, stack.zero, stack.u, stack.u_scale,
        stack.v, stack.v_scale, mep, gep, cap, eb,
        bits=stack.bits, group_size=stack.group_size, bm=bm, bn=bn, bk=bk,
        out_dtype=out_dtype, interpret=(impl == "pallas_interpret"))
    return ye[:, :c] if pc else ye
