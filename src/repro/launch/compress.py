"""Offline compression CLI: ``python -m repro.launch.compress --arch <id>
--out <dir> [...]`` — the calibrate → allocate → compress → artifact
pipeline (calib/).

1. **calibrate**: run the deterministic synthetic corpus through the
   jitted forward (first-class router trace + MoE-input collection) and
   accumulate per-expert routing frequency, gate mass, and input/hidden
   second moments per MoE layer;
2. **allocate**: water-filling/knapsack assignment of per-expert
   bit-widths and per-(projection, expert) compensator ranks under a
   global wire-byte budget (``--budget-bytes``, or ``--budget-frac`` of
   the uniform reference point), scored by ``--scorer``
   (calibrated | kurtosis | uniform);
3. **compress**: the full pipeline with the allocated plan and
   activation-weighted (moment-whitened) compensator SVDs;
4. **artifact**: serialize plan + packed stacks with a config
   fingerprint, so ``launch/serve.py --artifact <dir>`` boots without
   recompressing.

With no budget flags the tool compresses on the paper's kurtosis-guided
uniform-bit path and still writes an artifact (startup-time win only).
"""
import argparse

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser(
        description="offline calibration + heterogeneous precision "
                    "allocation -> serialized compression artifact")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--out", required=True,
                    help="artifact directory (created if missing)")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--seed", type=int, default=0,
                    help="param-init seed (recorded in the manifest; "
                         "serve --artifact must boot the same params)")
    # -- calibration ------------------------------------------------------
    ap.add_argument("--calib-batches", type=int, default=4,
                    help="calibration corpus size (synthetic batches)")
    ap.add_argument("--calib-batch-size", type=int, default=8)
    ap.add_argument("--calib-seq-len", type=int, default=128)
    # -- allocation -------------------------------------------------------
    ap.add_argument("--budget-bytes", type=float, default=0.0,
                    help="global wire-byte budget for weights + "
                         "compensators (0 = no budgeted allocation: "
                         "uniform-bit kurtosis-guided pipeline)")
    ap.add_argument("--budget-frac", type=float, default=0.0,
                    help="budget as a fraction of the uniform reference "
                         "(every expert at --bits with the configured "
                         "rank budget); overrides --budget-bytes")
    ap.add_argument("--scorer", default="calibrated",
                    choices=("calibrated", "kurtosis", "uniform"),
                    help="importance scorer weighting per-expert errors "
                         "in the allocator objective")
    ap.add_argument("--bits-candidates", default="2,3,4,8",
                    help="comma-separated per-expert width candidates")
    ap.add_argument("--no-whiten", action="store_true",
                    help="plain weight-space compensator SVDs (ablation; "
                         "default whitens by the calibrated moments)")
    args = ap.parse_args()

    from ..calib import (allocate_budget, collect_calibration_stats,
                         moe_weights_by_layer, save_compression_artifact,
                         stacks_wire_bytes, stats_summary, uniform_plan,
                         weighted_restoration_error)
    from ..models import init_params
    from ..models.transformer import compress_moe_params
    from ..registry import get_config

    cfg = get_config(args.arch, reduced=not args.full_config)
    if cfg.moe is None:
        ap.error(f"--arch {args.arch} has no MoE layers to compress")
    params = init_params(jax.random.key(args.seed), cfg, jnp.float32)
    qcfg = cfg.moe.quant
    bits_candidates = tuple(int(b) for b in
                            args.bits_candidates.split(","))

    print(f"[1/4] calibrating {cfg.name}: {args.calib_batches} batches of "
          f"{args.calib_batch_size}x{args.calib_seq_len} synthetic tokens")
    stats = collect_calibration_stats(
        cfg, params, batches=args.calib_batches,
        batch_size=args.calib_batch_size, seq_len=args.calib_seq_len,
        seed=args.seed)
    summ = stats_summary(stats)
    print(f"      {summ['layers']} MoE layers, {summ['tokens']} tokens; "
          f"layer-0 importance {summ['importance'][0]}")

    weights = moe_weights_by_layer(params, cfg)
    plan = None
    if args.budget_frac > 0 or args.budget_bytes > 0:
        ref = uniform_plan(weights, qcfg, bits=qcfg.bits,
                           rank=qcfg.rank_budget)
        budget = (args.budget_frac * ref.spent_bytes
                  if args.budget_frac > 0 else args.budget_bytes)
        print(f"[2/4] allocating under {budget / 2**10:.1f} KiB budget "
              f"(uniform ref {ref.spent_bytes / 2**10:.1f} KiB, scorer "
              f"{args.scorer}, bits {bits_candidates})")
        plan = allocate_budget(weights, qcfg, budget, stats=stats,
                               scorer=args.scorer,
                               bits_candidates=bits_candidates)
        ps = plan.summary()
        print(f"      spent {ps['spent_bytes'] / 2**10:.1f} KiB, mean bits "
              f"{ps['mean_bits']:.2f} (hist {ps['bits_hist']}), mean rank "
              f"{ps['mean_rank']:.1f}, predicted weighted err "
              f"{plan.predicted_err:.4f}")
    else:
        print("[2/4] no budget given: kurtosis-guided uniform-bit "
              "allocation (paper default)")

    print("[3/4] compressing (HQQ + "
          + ("weight-space" if args.no_whiten else "activation-whitened")
          + " residual SVDs)")
    _, _, stacks_by_layer = compress_moe_params(
        params, cfg, plan=plan, stats=None if args.no_whiten else stats)
    imps = [s.importance() for s in stats]
    err = weighted_restoration_error(stacks_by_layer, weights, imps)
    total = stacks_wire_bytes(stacks_by_layer)
    print(f"      artifact wire bytes {total / 2**10:.1f} KiB, "
          f"routing-weighted restoration error {err:.4f}")

    print(f"[4/4] writing artifact -> {args.out}")
    manifest = save_compression_artifact(
        args.out, cfg, stacks_by_layer, plan=plan, seed=args.seed,
        extra={"weighted_restoration_err": err,
               "wire_bytes": total,
               "calib": {"batches": args.calib_batches,
                         "batch_size": args.calib_batch_size,
                         "seq_len": args.calib_seq_len},
               "whitened": not args.no_whiten})
    print(f"      {manifest['n_tensors']} tensors, "
          f"{manifest['bytes'] / 2**20:.2f} MiB on disk, checksum "
          f"{manifest['checksum']}; serve with:\n"
          f"      python -m repro.launch.serve --arch {args.arch} "
          f"--offload --artifact {args.out}")
    return manifest


if __name__ == "__main__":
    main()
