"""Substrate units: data determinism, optimizer, schedules, configs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SHAPES, TrainConfig
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         global_norm, warmup_cosine)
from repro.registry import ASSIGNED, get_config, list_cells


def test_synthetic_data_deterministic_and_restartable():
    cfg = SyntheticLMConfig(seed=7)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    np.testing.assert_array_equal(a.batch(3)["tokens"], b.batch(3)["tokens"])
    # pure function of step: order doesn't matter (elastic resume property)
    x5 = a.batch(5)["tokens"].copy()
    a.batch(0)
    np.testing.assert_array_equal(a.batch(5)["tokens"], x5)


def test_synthetic_data_has_learnable_structure():
    data = SyntheticLM(SyntheticLMConfig(seed=0, markov_states=4))
    toks = np.concatenate([data.batch(i)["tokens"].ravel()
                           for i in range(4)])
    # bigram MI > 0: conditional distribution differs across states
    s0 = toks[:-1] % 4 == 0
    s1 = toks[:-1] % 4 == 1
    m0 = np.bincount(toks[1:][s0], minlength=512).argmax()
    m1 = np.bincount(toks[1:][s1], minlength=512).argmax()
    assert m0 != m1


def test_adamw_descends_quadratic():
    params = {"w": jnp.ones((4,)) * 5.0}
    state = adamw_init(params)
    tcfg = TrainConfig(lr=0.5, warmup_steps=1, total_steps=100,
                       weight_decay=0.0, clip_norm=0.0)
    p = params
    for _ in range(50):
        grads = {"w": state.master["w"]}  # grad of 0.5||w||^2
        p, state, m = adamw_update(grads, state, tcfg, jnp.float32)
    assert float(jnp.abs(p["w"]).max()) < 1.0


def test_warmup_cosine_shape():
    tcfg = TrainConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(warmup_cosine(tcfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(max(0.02, lrs[4]))


def test_clip_by_global_norm():
    g = {"a": jnp.ones((100,)) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(100.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_registry_cells_and_skips():
    cells = list_cells()
    assert len(cells) == 40
    skips = [c for c in cells if c[2] is not None]
    # exactly the pure-full-attention archs skip long_500k
    skip_archs = {c[0] for c in skips}
    assert skip_archs == {"llama3.2-3b", "qwen2-7b", "qwen3-moe-30b-a3b",
                          "llama4-scout-17b-a16e", "qwen2-vl-7b",
                          "whisper-base"}
    assert all(c[1] == "long_500k" for c in skips)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_reduced_configs_instantiable(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.num_layers >= 2
    assert cfg.vocab_size == 512
    full = get_config(arch)
    assert cfg.family == full.family
    assert (cfg.moe is None) == (full.moe is None)
