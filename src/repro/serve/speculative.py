"""Speculative decoding: drafters + rejection-sampling acceptance.

One serve-loop iteration becomes a *round*: a drafter proposes ``k``
continuation tokens per slot, and a single batched step-mode forward
over all ``k+1`` round positions (the sampled token + the k drafts)
verifies them against the target model — the expensive pass runs once
per round instead of once per token, and its router trace is known for
every not-yet-verified position, which is what the
``LookaheadPrefetcher`` (offload/prefetch.py) turns into expert warms.

Acceptance (``accept_drafts``) is standard rejection sampling for
point-mass proposals.  Draft token d_i at verify position i is accepted
with probability p_target(d_i) (greedy: iff d_i == argmax), and
acceptance is cumulative — the first rejection truncates the round, so
per slot the committed tokens are: 1 sampled token + the accepted draft
prefix (accepted length in [1, k+1]).

Distribution preservation for a point-mass proposal q = δ(d): the
residual distribution norm(max(p - q·min(1, p(d)/q(d)), 0)) is exactly
p with d removed and renormalized.  Instead of materializing it, the
rejected token is *banned* from the next round's first sample
(``mask_banned``) — the next round's carry logits are the distribution
at the rejection position, so masking d there IS sampling the residual.
At temperature 0 a rejected draft is by definition not the argmax, so
banning it never changes the argmax and greedy speculative decode stays
token-identical to the autoregressive engine.

KV semantics: the verify pass appends cache entries for all k+1
positions; ``models/transformer.py::cache_rollback`` then invalidates
and zeroes everything past each slot's accepted length, leaving the
cache bit-identical to never having drafted the rejected suffix.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig, SpecConfig, replace as cfg_replace


# ---------------------------------------------------------------------------
# device-side acceptance math (used inside the engine's jitted spec round)
# ---------------------------------------------------------------------------

def mask_banned(logits: jax.Array, banned: jax.Array) -> jax.Array:
    """Mask each row's banned token (-1 = none) to -inf.

    ``banned`` carries the previous round's first-rejected draft token:
    removing it from this round's first sample realizes the residual
    distribution of point-mass rejection sampling (module docstring).
    """
    v = logits.shape[-1]
    oh = jax.nn.one_hot(jnp.maximum(banned, 0), v, dtype=bool)
    oh = oh & (banned >= 0)[:, None]
    return jnp.where(oh, -jnp.inf, logits)


def accept_drafts(logits: jax.Array, draft: jax.Array, key,
                  temperature: float) -> jax.Array:
    """Cumulative acceptance mask (S, k) for point-mass draft proposals.

    ``logits``: (S, k, V) target distributions at the draft positions —
    row i scores draft token i.  temperature <= 0 accepts while the
    draft matches the argmax; otherwise draft i is accepted with
    probability p_target(draft_i).  ``jnp.cumprod`` enforces the
    prefix property: everything after the first rejection is rejected.
    """
    if temperature <= 0.0:
        ok = draft == jnp.argmax(logits, axis=-1).astype(draft.dtype)
    else:
        p = jax.nn.softmax(logits.astype(jnp.float32) / temperature, axis=-1)
        pd = jnp.take_along_axis(p, draft[..., None].astype(jnp.int32),
                                 axis=-1)[..., 0]
        ok = jax.random.uniform(key, draft.shape) < pd
    return jnp.cumprod(ok.astype(jnp.int32), axis=1).astype(bool)


# ---------------------------------------------------------------------------
# drafters (host-side: they only see committed tokens)
# ---------------------------------------------------------------------------

class NGramDrafter:
    """Backoff n-gram proposer over each slot's committed stream.

    Per slot, one table per context length n in [1, order-1] maps the
    last n tokens to the most recently seen continuation; proposals
    back off from the longest matching context to the shortest, falling
    back to repeating the last token when even the unigram context is
    unseen.  A decode stream that settles into a cycle — the common
    case for greedy decoding of small models — is drafted with
    near-perfect acceptance at zero model cost, and the longest-match
    backoff disambiguates repeated tokens inside the cycle that a
    single fixed-order table mispredicts.
    """

    def __init__(self, order: int = 3):
        assert order >= 2, order
        self.order = int(order)
        self._hist: Dict[int, List[int]] = {}
        self._tables: Dict[int, Dict[int, Dict[tuple, int]]] = {}

    def _fresh_tables(self) -> Dict[int, Dict[tuple, int]]:
        return {n: {} for n in range(1, self.order)}

    def reset_slot(self, slot: int, prompt_tokens: np.ndarray):
        """(Re)bind ``slot`` to a fresh request; seed from its prompt."""
        self._hist[slot] = []
        self._tables[slot] = self._fresh_tables()
        self.observe(slot, prompt_tokens)

    def observe(self, slot: int, tokens: np.ndarray):
        """Append committed tokens to the slot's stream."""
        h = self._hist.setdefault(slot, [])
        tabs = self._tables.setdefault(slot, self._fresh_tables())
        for t in np.asarray(tokens).reshape(-1).tolist():
            h.append(int(t))
            for n in range(1, self.order):
                if len(h) > n:
                    tabs[n][tuple(h[-n - 1:-1])] = int(t)

    def propose(self, slot: int, k: int) -> np.ndarray:
        h = self._hist.get(slot)
        if not h:
            return np.zeros((k,), np.int32)
        tabs = self._tables.get(slot) or self._fresh_tables()
        cur = list(h)
        out = []
        for _ in range(k):
            nxt = None
            for n in range(self.order - 1, 0, -1):
                nxt = tabs[n].get(tuple(cur[-n:]))
                if nxt is not None:
                    break
            if nxt is None:
                nxt = cur[-1]
            out.append(nxt)
            cur.append(nxt)
        return np.asarray(out, np.int32)

    def propose_all(self, num_slots: int, k: int) -> np.ndarray:
        """(num_slots, k) proposals; slots never reset draft zeros (their
        rows are dead scheduler slots, masked out downstream)."""
        return np.stack([self.propose(s, k) for s in range(num_slots)])


class DraftModelDrafter:
    """Greedy proposals from a small stand-in draft model.

    The draft model re-reads a fixed ``window`` of each slot's committed
    tail per proposal step (train-mode forward, no draft KV cache: for
    the 1-layer dense configs this targets, re-reading W tokens is
    cheaper than keeping per-slot draft caches in sync with the
    target's commit/rollback) and extends with its argmax ``k`` times
    under one jitted ``lax.scan``.  Proposals are point-mass — the
    verify pass applies the same rejection rule as the n-gram path.
    """

    def __init__(self, cfg: ModelConfig, params, window: int = 32,
                 kernel_impl: Optional[str] = None,
                 quantized: bool = False):
        from ..launch.steps import make_context
        from ..models import model as lm
        assert cfg.encoder is None, "draft model must be decoder-only"
        self.cfg = cfg
        self.params = params
        self.window = w = int(window)
        ctx = make_context(cfg, "train", quantized=quantized,
                           exact_capacity=True, kernel_impl=kernel_impl)

        def propose_fn(params, win, ln, k):
            """win: (S, W) left-aligned tails, ln: (S,) fill counts."""
            def body(carry, _):
                win, ln = carry
                out = lm.forward(params, win, cfg, ctx)
                idx = jnp.maximum(ln - 1, 0)
                lg = jnp.take_along_axis(
                    out.logits, idx[:, None, None], axis=1)[:, 0]
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                full = ln >= w
                shifted = jnp.where(full[:, None],
                                    jnp.roll(win, -1, axis=1), win)
                wi = jnp.minimum(ln, w - 1)
                win2 = shifted.at[jnp.arange(win.shape[0]), wi].set(nxt)
                return (win2, jnp.minimum(ln + 1, w)), nxt

            (_, _), toks = jax.lax.scan(body, (win, ln), xs=None, length=k)
            return toks.T                        # (S, k)

        self._propose = jax.jit(propose_fn, static_argnames=("k",))
        self._hist: Dict[int, List[int]] = {}

    @classmethod
    def from_target(cls, target_cfg: ModelConfig, *, seed: int = 0,
                    window: int = 32, kernel_impl: Optional[str] = None
                    ) -> "DraftModelDrafter":
        """Build a 1-layer dense stand-in sharing the target's vocab and
        width — the 'small-config draft model' counterpart to the
        external distilled drafters real deployments use."""
        from ..models.transformer import init_params
        small = cfg_replace(
            target_cfg, name=target_cfg.name + "-draft", family="dense",
            num_layers=1, moe=None, first_layer_dense=False,
            block_pattern=("global",), encoder=None, tie_embeddings=True,
            quant=dataclasses.replace(target_cfg.quant, enabled=False))
        params = init_params(jax.random.key(seed), small, jnp.float32)
        return cls(small, params, window=window, kernel_impl=kernel_impl)

    @classmethod
    def self_draft(cls, cfg: ModelConfig, params, *, window: int = 64,
                   quantized: bool = False,
                   kernel_impl: Optional[str] = None
                   ) -> "DraftModelDrafter":
        """Draft with the serving model itself (windowed re-read).

        The idealized upper-bound drafter: proposals agree with the
        target wherever the ``window``-token context suffices, so
        acceptance approaches 1 and the measured lookahead-prefetch
        numbers isolate the *prefetcher* from drafter quality — the
        stand-in for the distilled high-acceptance drafters real
        deployments pair with the target.  Pointless as a speedup (the
        draft pass costs a full forward) but exactly what the bandwidth
        benchmarks need.
        """
        return cls(cfg, params, window=window, kernel_impl=kernel_impl,
                   quantized=quantized)

    def reset_slot(self, slot: int, prompt_tokens: np.ndarray):
        self._hist[slot] = np.asarray(prompt_tokens).reshape(-1) \
            .astype(np.int32).tolist()

    def observe(self, slot: int, tokens: np.ndarray):
        self._hist.setdefault(slot, []).extend(
            int(t) for t in np.asarray(tokens).reshape(-1).tolist())

    def propose_all(self, num_slots: int, k: int) -> np.ndarray:
        w = self.window
        win = np.zeros((num_slots, w), np.int32)
        ln = np.zeros((num_slots,), np.int32)
        for s in range(num_slots):
            h = self._hist.get(s, [])
            tail = h[-w:]
            win[s, :len(tail)] = tail
            ln[s] = len(tail)
        return np.asarray(self._propose(self.params, jnp.asarray(win),
                                        jnp.asarray(ln), k))

    def propose(self, slot: int, k: int) -> np.ndarray:
        return self.propose_all(slot + 1, k)[slot]


def make_drafter(spec: SpecConfig, target_cfg: ModelConfig, *,
                 target_params=None, target_quantized: bool = False,
                 kernel_impl: Optional[str] = None):
    """Resolve a SpecConfig drafter name into a drafter instance.

    'ngram' needs nothing beyond the config; 'model' builds the small
    random-init dense stand-in; 'self' re-reads the target itself
    (``DraftModelDrafter.self_draft``) and therefore needs the target's
    params threaded through.
    """
    if spec.drafter == "ngram":
        return NGramDrafter(order=spec.ngram_order)
    if spec.drafter == "self":
        assert target_params is not None, \
            "'self' drafter needs the target model's params"
        return DraftModelDrafter.self_draft(
            target_cfg, target_params, window=spec.draft_window,
            quantized=target_quantized, kernel_impl=kernel_impl)
    return DraftModelDrafter.from_target(target_cfg,
                                         window=spec.draft_window,
                                         kernel_impl=kernel_impl)
