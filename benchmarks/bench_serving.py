"""Continuous-batching serving benchmark: offered-load sweep.

Drives the ``ServeEngine.serve`` scheduler with Poisson request arrivals
at increasing offered loads and reports, per rate:

- decode throughput (accepted tokens/s over the whole run),
- request latency p50 / p95 (wall-clock, arrival -> completion),
- live offload wire bytes/token from the metered per-layer expert stores
  (demand + compensator + prefetch after the ride-the-cache accounting
  fixes), plus the mean per-request attributed bytes/token.

The traffic is genuinely interleaved: ragged prompt lengths, more
requests than slots, slots refilled from the queue between scan chunks —
the expert-cache hit rates reflect multi-request contention, not one
fixed batch.  Self-contained (tiny randomly-initialized MoE, cheap
compression) so ``make bench-smoke`` stays fast.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py --quick
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, MoEConfig, QuantConfig
from repro.core import compress_ffn_weights
from repro.models import init_params
from repro.models.transformer import unstack_params
from repro.serve import ServeEngine, synthetic_workload


def _engine(offload: bool = True) -> ServeEngine:
    cfg = ModelConfig(
        name="serve-bench-moe", family="moe", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0, vocab_size=256,
        block_pattern=("global",), max_position=2048,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=128,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=16,
                                        top_n_restore=1, hqq_iters=2)))
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    if not offload:
        return ServeEngine(cfg, params)
    up = unstack_params(params, cfg)
    segs, stacks_by_layer = [], []
    for seg in up["segments"]:
        p = dict(seg[0])
        mp = dict(p["moe"])
        stacks, _ = compress_ffn_weights(mp["w1"], mp["w2"], mp["w3"],
                                         cfg.moe.quant)
        stacks_by_layer.append(stacks)
        mp["stacks"] = stacks
        for k in ("w1", "w2", "w3"):
            mp.pop(k)
        p["moe"] = mp
        segs.append((p,))
    qparams = dict(up)
    qparams["segments"] = tuple(segs)
    cfg_q = dataclasses.replace(cfg, force_unroll_plan=True)
    eng = ServeEngine(cfg_q, qparams, quantized=True)
    eng.attach_offload(stacks_by_layer, policy="ours", cache_capacity=3)
    return eng


def run(quick: bool = True, rates: Optional[Tuple[float, ...]] = None,
        offload: bool = True) -> List[Dict]:
    n = 8 if quick else 32
    max_new = 12 if quick else 32
    rates = rates if rates is not None else ((0.0, 4.0) if quick
                                             else (0.0, 2.0, 8.0, 32.0))
    eng = _engine(offload=offload)
    slots = 2 if quick else 4
    # warm the compiled prefill/decode loop (same slot count as the sweep)
    # so the sweep measures steady state, not the first-bucket compile
    eng.serve(synthetic_workload(2, eng.cfg.vocab_size, max_new=max_new,
                                 seed=99),
              num_slots=slots, chunk=4)
    rows = []
    for rate in rates:
        stats = eng.serve(
            synthetic_workload(n, eng.cfg.vocab_size, rate=rate,
                               max_new=max_new),
            num_slots=slots, chunk=4)
        lat = stats.latency_percentiles((50.0, 95.0))
        row = {
            "name": f"serving/rate-{rate:g}",
            "offered_rps": rate,
            "tok_s": stats.tokens_per_s,
            "p50_ms": lat[50.0] * 1e3,
            "p95_ms": lat[95.0] * 1e3,
            "requests": float(len(stats.results)),
            "chunks": float(stats.chunks),
        }
        rep = stats.offload_report
        if rep is not None:
            per_req = [r.offload_bytes / max(r.gen_tokens, 1)
                       for r in stats.results]
            row.update({
                "mb_per_tok": rep["bytes_per_token"] / 2 ** 20,
                "hit_rate": rep["hit_rate"],
                "prefetch_acc": rep["prefetch_accuracy"],
                "req_mb_per_tok": float(np.mean(per_req)) / 2 ** 20,
            })
        rows.append(row)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-offload", action="store_true")
    args = ap.parse_args()
    for r in run(quick=args.quick, offload=not args.no_offload):
        extra = ",".join(f"{k}={v:.3f}" if isinstance(v, float) else f"{k}={v}"
                         for k, v in r.items() if k != "name")
        print(f"{r['name']},{extra}", flush=True)


if __name__ == "__main__":
    main()
