"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

mLSTM keeps a matrix memory C (hd x hd per head) with exponential gating;
train/prefill uses the chunkwise-recurrent form (intra-chunk quadratic,
inter-chunk O(1) state carry) in stabilized log space, decode a single
fused update.  sLSTM has true hidden-to-hidden recurrence (block-diagonal
per head) and is evaluated with lax.scan.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel
# ---------------------------------------------------------------------------

def mlstm_chunkwise(q, k, v, log_i, log_f, state=None, chunk: int = 256,
                    unroll: bool = False):
    """q/k/v: (B, S, H, hd); log_i/log_f: (B, S, H).

    Returns h: (B, S, H, hd) and final state {c, n, m}.
    State convention: true_C = c * exp(m) (per batch/head).
    """
    b, s, h, hd = q.shape
    if s % chunk:
        chunk = s  # degenerate single chunk for odd smoke shapes
    nc = s // chunk
    scale = hd ** -0.5

    def rs(x):  # (B, S, ...) -> (nc, B, chunk, ...)
        return jnp.moveaxis(x.reshape(b, nc, chunk, *x.shape[2:]), 1, 0)

    qs, ks, vs = rs(q * scale), rs(k), rs(v)
    lis, lfs = rs(log_i.astype(jnp.float32)), rs(log_f.astype(jnp.float32))

    c0 = jnp.zeros((b, h, hd, hd), jnp.float32) if state is None else state["c"]
    n0 = jnp.zeros((b, h, hd), jnp.float32) if state is None else state["n"]
    m0 = jnp.full((b, h), NEG, jnp.float32) if state is None else state["m"]

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, xs):
        c, n, m = carry
        qc, kc, vc, li, lf = xs          # (B, chunk, H, ...)
        bcum = jnp.cumsum(lf, axis=1)                    # (B, chunk, H)
        # intra-chunk log weights W[t, j] = bcum_t - bcum_j + li_j  (j <= t)
        wij = (bcum[:, :, None] - bcum[:, None, :] + li[:, None, :])
        wij = jnp.where(tri[None, :, :, None], wij, NEG)  # (B, t, j, H)
        a_t = bcum + m[:, None]                           # inter log scale
        m_t = jnp.maximum(a_t, wij.max(axis=2))           # (B, chunk, H)
        inter = jnp.exp(a_t - m_t)                        # (B, chunk, H)
        intra = jnp.exp(wij - m_t[:, :, None])            # (B, t, j, H)
        # numerator / normalizer
        sc = jnp.einsum("bthd,bjhd->btjh", qc.astype(jnp.float32),
                        kc.astype(jnp.float32))
        num = jnp.einsum("btjh,btjh,bjhd->bthd", sc, intra,
                         vc.astype(jnp.float32))
        num += inter[..., None] * jnp.einsum(
            "bthd,bhde->bthe", qc.astype(jnp.float32), c)
        nvec = jnp.einsum("btjh,bjhd->bthd", intra, kc.astype(jnp.float32))
        nvec += inter[..., None] * n[:, None]
        qn = jnp.einsum("bthd,bthd->bth", qc.astype(jnp.float32), nvec)
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
        hout = num / denom[..., None]
        # chunk-end state update
        btot = bcum[:, -1]                                # (B, H)
        wj = btot[:, None] - bcum + li                    # (B, chunk, H)
        m_new = jnp.maximum(btot + m, wj.max(axis=1))
        cd = jnp.exp(btot + m - m_new)
        wj = jnp.exp(wj - m_new[:, None])
        c_new = cd[:, :, None, None] * c + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", wj, kc.astype(jnp.float32),
            vc.astype(jnp.float32))
        n_new = cd[:, :, None] * n + jnp.einsum(
            "bjh,bjhd->bhd", wj, kc.astype(jnp.float32))
        return (c_new, n_new, m_new), hout

    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), (qs, ks, vs, lis, lfs),
                                 unroll=unroll)
    hout = jnp.moveaxis(hs, 0, 1).reshape(b, s, h, hd)
    return hout.astype(q.dtype), {"c": c, "n": n, "m": m}


def mlstm_step(q, k, v, log_i, log_f, state):
    """Single decode step.  q/k/v: (B, 1, H, hd); log gates (B, 1, H)."""
    b, _, h, hd = q.shape
    scale = hd ** -0.5
    q1 = q[:, 0].astype(jnp.float32) * scale
    k1, v1 = k[:, 0].astype(jnp.float32), v[:, 0].astype(jnp.float32)
    li, lf = log_i[:, 0].astype(jnp.float32), log_f[:, 0].astype(jnp.float32)
    m_new = jnp.maximum(lf + state["m"], li)
    cd = jnp.exp(lf + state["m"] - m_new)
    iw = jnp.exp(li - m_new)
    c = cd[..., None, None] * state["c"] + iw[..., None, None] * (
        k1[..., :, None] * v1[..., None, :])
    n = cd[..., None] * state["n"] + iw[..., None] * k1
    num = jnp.einsum("bhd,bhde->bhe", q1, c)
    qn = jnp.einsum("bhd,bhd->bh", q1, n)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    hout = (num / denom[..., None])[:, None]
    return hout.astype(q.dtype), {"c": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM cell — sequential scan with block-diagonal recurrence
# ---------------------------------------------------------------------------

def _slstm_cell(x_zifo, h_prev, c_prev, n_prev, m_prev, rec):
    """One step.  x_zifo: (B, 4, H, hd) pre-activations from the input;
    rec: {rz, ri, rf, ro}: (H, hd, hd) recurrent block-diag weights."""
    def r(name):
        return jnp.einsum("bhd,hde->bhe", h_prev, rec[name])
    z = jnp.tanh(x_zifo[:, 0] + r("rz"))
    li = x_zifo[:, 1] + r("ri")                      # log input gate
    lf = jax.nn.log_sigmoid(x_zifo[:, 2] + r("rf"))  # log forget gate
    o = jax.nn.sigmoid(x_zifo[:, 3] + r("ro"))
    m_new = jnp.maximum(lf + m_prev, li)
    c = jnp.exp(lf + m_prev - m_new) * c_prev + jnp.exp(li - m_new) * z
    n = jnp.exp(lf + m_prev - m_new) * n_prev + jnp.exp(li - m_new)
    h = o * c / jnp.maximum(n, 1e-6)
    return h, c, n, m_new


def slstm_seq(x_zifo: jax.Array, rec: Dict[str, jax.Array],
              state: Optional[Dict[str, jax.Array]] = None
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x_zifo: (B, S, 4, H, hd) -> h: (B, S, H, hd), final state."""
    b, s, _, h, hd = x_zifo.shape
    if state is None:
        zeros = jnp.zeros((b, h, hd), jnp.float32)
        state = {"c": zeros, "n": zeros, "h": zeros,
                 "m": jnp.full((b, h, hd), NEG, jnp.float32)}

    def step(carry, xt):
        hp, cp, np_, mp = carry
        hn, cn, nn, mn = _slstm_cell(xt.astype(jnp.float32), hp, cp, np_,
                                     mp, rec)
        return (hn, cn, nn, mn), hn

    (hf, cf, nf, mf), hs = jax.lax.scan(
        step, (state["h"], state["c"], state["n"], state["m"]),
        jnp.moveaxis(x_zifo, 1, 0))
    return (jnp.moveaxis(hs, 0, 1).astype(x_zifo.dtype),
            {"c": cf, "n": nf, "h": hf, "m": mf})
