# Repo verification targets.
#
#   make tier1   fast correctness gate (excludes @pytest.mark.slow)
#   make test    full suite, including slow/benchmarks-adjacent tests
#   make bench-smoke     quick continuous-batching serving sweep
#   make bench-frontier  bandwidth-budget frontier sweep (controller)
#   make docs-check      every doc cross-reference resolves
#   make serve-example   live-decode offload + controller report

PY = PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: tier1 test bench-smoke bench-frontier docs-check serve-example

tier1:
	$(PY) -m pytest -x -q -m "not slow"

test:
	$(PY) -m pytest -q

bench-smoke:
	$(PY) benchmarks/bench_serving.py --quick

bench-frontier:
	$(PY) benchmarks/bench_serving.py --quick --frontier

docs-check:
	python tools/docs_check.py

serve-example:
	$(PY) examples/serve_offload.py
