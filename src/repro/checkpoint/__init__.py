"""Atomic, keep-k, mesh-agnostic checkpointing + structure-carrying
artifact round-trip for compression-dataclass pytrees."""
from .manager import (CheckpointManager, load_artifact,
                      register_artifact_dataclass, save_artifact)
