import os

# Tests run on the single real CPU device (the 512-device override is
# applied ONLY inside launch/dryrun.py, per the assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("JAX_ENABLE_X64", "0")

import jax  # noqa: E402

jax.config.update("jax_default_prng_impl", "threefry2x32")
