"""Metered-bytes oracle tier for async expert streaming.

The offload byte meter (``ExpertStore``) used to be accounting fiction:
every expert was always device-resident and "bytes moved" was a
counter.  With the transfer engine attached (``attach_streaming``) the
meter DRIVES real copies, which makes it checkable:

    oracle:   per-store metered wire bytes == bytes the transfer engine
              actually put on the link (``observed_copy_bytes``), EXACTLY

checked here for scheduler workloads (ragged prompts through
``generate_many``'s slot scheduler) across expert-parallel store
sharding ``ep in {1, 2, 8}`` and both the ``ref`` and
``pallas_interpret`` kernel impls — together with token identity:
streamed decode must produce exactly the tokens of the all-resident
path (the fixpoint re-run contract), so overlap is never bought with
wrong results.

Also pins simulator-vs-engine agreement: ``offload/simulator.py``
replays a routing trace through the same ``ExpertCache`` + resident-
compensator accounting the live store meters with, so for an identical
trace the simulated bytes/token must equal the metered bytes/token
exactly, and its prefetch issue semantics must be causal (a first-touch
layer has no layer-ahead prediction and falls back to on-demand issue).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (ModelConfig, MoEConfig, QuantConfig, ServeConfig,
                          StreamConfig)
from repro.models import init_params
from repro.models.transformer import compress_moe_params
from repro.offload import GPU_ONLY, LayerSpecSim, simulate_decode
from repro.offload.simulator import make_router_trace
from repro.offload.store import ExpertStore
from repro.serve import ServeEngine

E = 8              # divides every ep in the sweep
MAX_NEW = 6


def moe_cfg():
    return ModelConfig(
        name="stream-oracle", family="moe", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0, vocab_size=128,
        block_pattern=("global",), max_position=512,
        moe=MoEConfig(num_experts=E, top_k=2, d_expert=64,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=16,
                                        top_n_restore=1, hqq_iters=2)))


@pytest.fixture(scope="module")
def base():
    cfg = moe_cfg()
    return cfg, init_params(jax.random.key(0), cfg, jnp.float32)


def prompts():
    rng = np.random.default_rng(3)
    return [rng.integers(1, 128, (int(n),)).astype(np.int32)
            for n in (4, 6, 5)]


def build(cfg, params, impl, stream, ep=1, cache_capacity=E,
          stream_cfg=None):
    # fresh compression per engine: attach_streaming swaps the layer
    # param stacks for its device containers in place
    qp, cq, stacks = compress_moe_params(params, cfg)
    eng = ServeEngine(cq, qp, ServeConfig(temperature=0.0), quantized=True,
                      kernel_impl=impl)
    eng.attach_offload(stacks, policy="ours", cache_capacity=cache_capacity,
                       ep=ep)
    if stream:
        eng.attach_streaming(stream_cfg or StreamConfig(enabled=True))
    return eng


def serve(eng):
    return eng.generate_many(prompts(), max_new=MAX_NEW, num_slots=2,
                             chunk=4)


def assert_oracle(eng, stats):
    for li, s in enumerate(eng._stores):
        assert s.total_bytes == s.observed_copy_bytes, (
            li, s.total_bytes, s.observed_copy_bytes)
        assert s.observed_copies > 0
    rep = stats.offload_report
    assert rep["observed_copy_bytes"] == rep["total_bytes"] > 0
    assert rep["observed_copies"] > 0


_resident = {}


def resident_tokens(cfg, params, impl):
    if impl not in _resident:
        stats = serve(build(cfg, params, impl, stream=False))
        _resident[impl] = [r.tokens.tolist() for r in stats.results]
    return _resident[impl]


@pytest.mark.parametrize("impl", ("ref", "pallas_interpret"))
@pytest.mark.parametrize("ep", (1, 2, 8))
def test_oracle_and_token_identity(base, impl, ep):
    cfg, params = base
    eng = build(cfg, params, impl, stream=True, ep=ep)
    stats = serve(eng)
    toks = [r.tokens.tolist() for r in stats.results]
    assert toks == resident_tokens(cfg, params, impl), (impl, ep)
    assert_oracle(eng, stats)
    sr = stats.stream_report
    assert sr is not None and sr["degraded_tokens"] == 0
    assert sr["issued_copies"] == sum(s.observed_copies
                                      for s in eng._stores)


def test_oracle_holds_under_eviction_pressure(base):
    """cache_capacity < num_experts: the prefetcher re-fetches evicted
    experts through the async ring — the regime where transfer time can
    hide behind compute.  The oracle must stay EXACT (issue-time
    accounting), and tokens must still match the resident path."""
    cfg, params = base
    eng = build(cfg, params, "ref", stream=True, cache_capacity=3)
    stats = serve(eng)
    toks = [r.tokens.tolist() for r in stats.results]
    assert toks == resident_tokens(cfg, params, "ref")
    assert_oracle(eng, stats)
    sr = stats.stream_report
    assert 0.0 <= sr["overlap_efficiency"] <= 1.0
    assert sr["issued_copies"] > 0


def test_warm_second_serve_moves_nothing(base):
    """Streaming blocks only on a TRUE miss: once every routed expert is
    staged (eviction-free regime), a second identical workload must not
    issue a single copy or re-run a single chunk."""
    cfg, params = base
    eng = build(cfg, params, "ref", stream=True)
    serve(eng)
    copies0, reruns0 = eng.stream.issued_copies, eng.stream.reruns
    stats = serve(eng)
    assert [r.tokens.tolist() for r in stats.results] == \
        resident_tokens(cfg, params, "ref")
    assert eng.stream.issued_copies == copies0
    assert eng.stream.reruns == reruns0
    # cumulative per-store oracle still exact; THIS serve's report delta
    # is exactly zero bytes on both sides of it
    for s in eng._stores:
        assert s.total_bytes == s.observed_copy_bytes
    rep = stats.offload_report
    assert rep["observed_copy_bytes"] == rep["total_bytes"] == 0


# ---------------------------------------------------------------------------
# simulator-vs-engine agreement (offload/simulator.py regression)
# ---------------------------------------------------------------------------

def _layer_spec(store, cfg):
    eb = {store.expert_bytes(e, "ours") for e in range(E)}
    assert len(eb) == 1          # uniform-bit stacks -> one demand size
    return LayerSpecSim(
        cfg.d_model, cfg.moe.d_expert, E, cfg.moe.top_k,
        bytes_fp16=store.expert_bytes(0, "fp16"),
        bytes_quant=eb.pop(),
        comp_bytes=[store.compensator_bytes(e) for e in range(E)])


def test_sim_bytes_match_store_meter_exactly(base):
    """The event-driven simulator and the live store meter replay the
    SAME trace to the SAME wire bytes: LRU misses, compensators riding
    the cache, and the rank-delta re-fetch accounting all agree."""
    cfg, params = base
    _, _, stacks = compress_moe_params(params, cfg)
    layers, tokens, cap = 2, 48, 3
    trace = make_router_trace(None, tokens, layers, cfg.moe.top_k,
                              seed=5, num_experts=E)
    stores = [ExpertStore(stacks[0], cache_capacity=cap)
              for _ in range(layers)]
    for t in range(tokens):
        for l in range(layers):
            stores[l].access_token(trace[t, l], top_n=1, policy="ours")
    sim = simulate_decode(trace, _layer_spec(stores[0], cfg), GPU_ONLY,
                          "ours", top_n=1, cache_capacity=cap,
                          num_layers=layers)
    metered = sum(s.total_bytes for s in stores)
    assert int(round(sim.transfer_bytes_per_token * tokens)) == metered


def test_sim_prefetch_moves_same_bytes_no_slower(base):
    """Layer-ahead prefetch changes WHEN fetches issue, never what moves:
    byte totals are identical and the pipeline never gets slower than
    on-demand issue (each fetch issues no later)."""
    cfg, params = base
    _, _, stacks = compress_moe_params(params, cfg)
    store = ExpertStore(stacks[0], cache_capacity=3)
    trace = make_router_trace(None, 32, 4, cfg.moe.top_k, seed=7,
                              num_experts=E)
    spec = _layer_spec(store, cfg)
    od = simulate_decode(trace, spec, GPU_ONLY, "ours", top_n=1,
                         cache_capacity=3, num_layers=4, prefetch=False)
    pf = simulate_decode(trace, spec, GPU_ONLY, "ours", top_n=1,
                         cache_capacity=3, num_layers=4, prefetch=True)
    assert pf.transfer_bytes_per_token == od.transfer_bytes_per_token
    assert pf.tokens_per_s >= od.tokens_per_s * (1 - 1e-9)


def test_sim_prefetch_first_touch_is_causal(base):
    """A first-touch layer has no layer-ahead prediction yet (its router
    has never run), so prefetch MUST fall back to on-demand issue: for a
    single token the two modes are indistinguishable.  Pins the causal
    issue fix — a prediction cannot be acted on before it exists."""
    cfg, params = base
    _, _, stacks = compress_moe_params(params, cfg)
    store = ExpertStore(stacks[0], cache_capacity=2)
    trace = make_router_trace(None, 1, 3, cfg.moe.top_k, seed=11,
                              num_experts=E)
    spec = _layer_spec(store, cfg)
    od = simulate_decode(trace, spec, GPU_ONLY, "ours", top_n=1,
                         cache_capacity=2, num_layers=3, prefetch=False)
    pf = simulate_decode(trace, spec, GPU_ONLY, "ours", top_n=1,
                         cache_capacity=2, num_layers=3, prefetch=True)
    assert pf.tokens_per_s == od.tokens_per_s
    assert pf.transfer_bytes_per_token == od.transfer_bytes_per_token
