"""Fault-tolerant checkpointing: atomic, keep-k, mesh-agnostic resume.

Design for 1000+ nodes (emulated here on one host):
- tensors are saved *unsharded* (gathered per leaf) in an .npz plus a JSON
  manifest, so a restore onto a DIFFERENT mesh/topology re-shards
  transparently (elastic scaling);
- writes go to ``step_XXXX.tmp`` then ``os.replace`` (atomic on POSIX), so
  a crash mid-write can never corrupt the latest checkpoint;
- the manifest carries a content checksum; restore validates it and falls
  back to the previous checkpoint on mismatch (torn-write recovery);
- ``keep`` retention bounds disk; ``latest_step`` scans only committed
  manifests.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple, Type

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _checksum(flat: Dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(flat):
        h.update(k.encode())
        h.update(np.ascontiguousarray(flat[k]).tobytes()[:4096])
        h.update(str(flat[k].shape).encode())
    return h.hexdigest()[:16]


# ---------------------------------------------------------------------------
# structure-carrying artifact round-trip (dataclass pytrees)
# ---------------------------------------------------------------------------
#
# ``CheckpointManager.restore`` rebuilds a tree INTO a caller-provided
# template — fine for resuming training, useless for a compression
# artifact whose whole point is booting WITHOUT recomputing the template
# (per-expert bits/ranks are only known after calibration).  The codec
# below serializes the structure itself: containers recurse, registered
# dataclasses (``QuantizedTensor``/``Compensator``/
# ``CompressedExpertStack`` — registered by ``calib.artifact``) record
# their class name + static meta fields in the JSON spec while their
# array data fields go to the npz.  Restore is exact: same classes, same
# meta (lists back to tuples), bit-identical arrays.

ARTIFACT_TYPES: Dict[str, Type] = {}


def register_artifact_dataclass(cls: Type,
                                meta_fields: Tuple[str, ...]) -> Type:
    """Make ``cls`` (a dataclass) round-trippable by the artifact codec.
    ``meta_fields`` are the static (JSON-encoded) fields; every other
    dataclass field is array data (recursively encoded)."""
    ARTIFACT_TYPES[cls.__name__] = cls
    setattr(cls, "_artifact_meta_fields", tuple(meta_fields))
    return cls


def _npz_safe(arr: np.ndarray):
    """(storable array, dtype name) — np.savez pickles non-native dtypes
    (ml_dtypes bfloat16 factors at ``factor_bits=16``) into object
    entries that np.load then refuses; store them as a same-width uint
    view and record the logical dtype in the leaf spec instead."""
    name = arr.dtype.name
    if arr.dtype.kind in "biufc" and not name.startswith("bfloat"):
        return arr, name
    return arr.view(np.dtype(f"u{arr.dtype.itemsize}")), name


def _npz_restore(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if arr.dtype.name == dtype_name:
        return arr
    import ml_dtypes  # jax dependency; provides bfloat16 et al.
    return arr.view(np.dtype(getattr(ml_dtypes, dtype_name, dtype_name)))


def _full_checksum(arrays: Dict[str, np.ndarray]) -> str:
    """Whole-content hash.  The training-checkpoint ``_checksum`` samples
    a 4 KiB prefix per tensor (cheap torn-write detection at step
    cadence); artifacts claim full integrity — corruption anywhere must
    fail the load — so they hash every byte."""
    h = hashlib.sha256()
    for k in sorted(arrays):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrays[k]).tobytes())
        h.update(str(arrays[k].shape).encode())
    return h.hexdigest()[:16]


def _meta_to_json(v):
    if isinstance(v, tuple):
        return {"__tuple__": [_meta_to_json(x) for x in v]}
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    return v


def _meta_from_json(v):
    if isinstance(v, dict) and "__tuple__" in v:
        return tuple(_meta_from_json(x) for x in v["__tuple__"])
    return v


def _encode_tree(tree, arrays: Dict[str, np.ndarray]) -> Dict:
    """Tree -> JSON-able spec; array leaves appended to ``arrays``."""
    if tree is None:
        return {"kind": "none"}
    if type(tree).__name__ in ARTIFACT_TYPES and dataclasses.is_dataclass(tree):
        meta_names = tree._artifact_meta_fields
        data_names = [f.name for f in dataclasses.fields(tree)
                      if f.name not in meta_names]
        return {
            "kind": "dataclass",
            "cls": type(tree).__name__,
            "meta": {n: _meta_to_json(getattr(tree, n)) for n in meta_names},
            "data": {n: _encode_tree(getattr(tree, n), arrays)
                     for n in data_names},
        }
    if isinstance(tree, dict):
        return {"kind": "dict",
                "items": {k: _encode_tree(v, arrays)
                          for k, v in tree.items()}}
    if isinstance(tree, (tuple, list)):
        return {"kind": "tuple" if isinstance(tree, tuple) else "list",
                "items": [_encode_tree(v, arrays) for v in tree]}
    key = f"a{len(arrays):06d}"
    stored, dtype_name = _npz_safe(np.asarray(tree))
    arrays[key] = stored
    return {"kind": "leaf", "key": key, "dtype": dtype_name}


def _decode_tree(spec: Dict, arrays: Dict[str, np.ndarray]):
    kind = spec["kind"]
    if kind == "none":
        return None
    if kind == "leaf":
        return _npz_restore(arrays[spec["key"]], spec["dtype"])
    if kind == "dict":
        return {k: _decode_tree(v, arrays) for k, v in spec["items"].items()}
    if kind in ("tuple", "list"):
        items = [_decode_tree(v, arrays) for v in spec["items"]]
        return tuple(items) if kind == "tuple" else items
    if kind == "dataclass":
        cls = ARTIFACT_TYPES.get(spec["cls"])
        if cls is None:
            raise KeyError(f"artifact references unregistered dataclass "
                           f"{spec['cls']!r}; register it via "
                           f"register_artifact_dataclass before loading")
        kw = {n: _meta_from_json(v) for n, v in spec["meta"].items()}
        kw.update({n: _decode_tree(v, arrays)
                   for n, v in spec["data"].items()})
        return cls(**kw)
    raise ValueError(f"bad artifact spec kind {kind!r}")


def save_artifact(path, tree: Any, meta: Optional[Dict] = None) -> Dict:
    """Serialize a dataclass pytree + metadata to ``path``
    (``path/artifact.npz`` + ``path/artifact.json``), atomically
    (data first, manifest last = commit point), with a content checksum.
    Returns the manifest."""
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    arrays: Dict[str, np.ndarray] = {}
    spec = _encode_tree(jax.tree.map(np.asarray, tree), arrays)
    tmp_npz = path / "artifact.npz.tmp"
    with open(tmp_npz, "wb") as f:
        np.savez(f, **arrays)
    manifest = {
        "spec": spec,
        "meta": meta or {},
        "time": time.time(),
        "checksum": _full_checksum(arrays),
        "n_tensors": len(arrays),
        "bytes": int(sum(v.nbytes for v in arrays.values())),
    }
    tmp_man = path / "artifact.json.tmp"
    tmp_man.write_text(json.dumps(manifest))
    os.replace(tmp_npz, path / "artifact.npz")
    os.replace(tmp_man, path / "artifact.json")
    return manifest


def load_artifact(path) -> Tuple[Any, Dict]:
    """Inverse of :func:`save_artifact`; validates the content checksum
    (torn/corrupt artifacts fail loudly, never load silently wrong)."""
    path = Path(path)
    manifest = json.loads((path / "artifact.json").read_text())
    with np.load(path / "artifact.npz") as z:
        arrays = {k: z[k] for k in z.files}
    if _full_checksum(arrays) != manifest["checksum"]:
        raise IOError(f"artifact checksum mismatch in {path}")
    return _decode_tree(manifest["spec"], arrays), manifest


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- write --------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> Path:
        flat = _flatten(tree)
        tmp_npz = self.dir / f"step_{step:08d}.npz.tmp"
        final_npz = self.dir / f"step_{step:08d}.npz"
        with open(tmp_npz, "wb") as f:
            np.savez(f, **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "checksum": _checksum(flat),
            "n_tensors": len(flat),
            "bytes": int(sum(v.nbytes for v in flat.values())),
            "extra": extra or {},
        }
        tmp_man = self.dir / f"step_{step:08d}.json.tmp"
        final_man = self.dir / f"step_{step:08d}.json"
        tmp_man.write_text(json.dumps(manifest))
        os.replace(tmp_npz, final_npz)      # atomic commits: data first,
        os.replace(tmp_man, final_man)      # manifest last = commit point
        self._retain()
        return final_npz

    def _retain(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            for suffix in (".npz", ".json"):
                p = self.dir / f"step_{s:08d}{suffix}"
                if p.exists():
                    p.unlink()

    # -- read ---------------------------------------------------------------
    def all_steps(self):
        return sorted(int(p.stem.split("_")[1])
                      for p in self.dir.glob("step_*.json"))

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``template`` (shapes validated).
        ``shardings`` (optional pytree) re-shards onto the current mesh —
        this is what makes restarts elastic across topology changes."""
        steps = self.all_steps()
        if step is None:
            if not steps:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
            candidates = steps[::-1]
        else:
            candidates = [step]
        last_err: Optional[Exception] = None
        for s in candidates:
            try:
                return self._restore_one(template, s, shardings)
            except Exception as e:  # torn write -> try previous
                last_err = e
        raise last_err

    def _restore_one(self, template, step: int, shardings):
        man = json.loads((self.dir / f"step_{step:08d}.json").read_text())
        with np.load(self.dir / f"step_{step:08d}.npz") as z:
            flat = {k: z[k] for k in z.files}
        if _checksum(flat) != man["checksum"]:
            raise IOError(f"checksum mismatch at step {step}")
        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for path, leaf in leaves:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx",
                                                         getattr(p, "name", p))))
                           for p in path)
            arr = flat[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), out)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, man
