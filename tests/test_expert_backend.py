"""ExpertBackend dispatch: policy resolution + numerical equivalence of the
fused Pallas path (interpreter on CPU) against the reference quantized
path, reached *through the model's MoE layer* — the kernels are live code
on the serving path, not benchmark-only."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoEConfig, QuantConfig
from repro.core import compress_ffn_weights
from repro.launch.steps import make_context
from repro.models import forward, init_params
from repro.models.expert_backend import (DenseBackend, PallasQuantBackend,
                                         RefQuantBackend, select_backend)
from repro.models.moe import moe_apply
from repro.models.transformer import unstack_params


def _quant_params(e=4, d=64, fe=128, seed=0, **qkw):
    rng = np.random.default_rng(seed)
    qcfg = QuantConfig(enabled=True, bits=2, rank_budget=8,
                       top_n_restore=1, hqq_iters=2, **qkw)
    mcfg = MoEConfig(num_experts=e, top_k=2, d_expert=fe, quant=qcfg)
    w1 = jnp.asarray(rng.standard_normal((e, d, fe)), jnp.float32) * 0.05
    w3 = jnp.asarray(rng.standard_normal((e, d, fe)), jnp.float32) * 0.05
    w2 = jnp.asarray(rng.standard_normal((e, fe, d)), jnp.float32) * 0.05
    stacks, _ = compress_ffn_weights(w1, w2, w3, qcfg)
    params = {"router": jnp.asarray(rng.standard_normal((d, e)),
                                    jnp.float32),
              "stacks": stacks, "w1": w1, "w3": w3, "w2": w2}
    return params, mcfg


def test_select_backend_policy(monkeypatch):
    params, _ = _quant_params()
    assert isinstance(select_backend(params, quantized=False),
                      DenseBackend)
    dense_only = {k: v for k, v in params.items() if k != "stacks"}
    assert isinstance(select_backend(dense_only, quantized=True),
                      DenseBackend)
    assert isinstance(select_backend(params, True, "ref"), RefQuantBackend)
    be = select_backend(params, True, "pallas_interpret")
    assert isinstance(be, PallasQuantBackend)
    assert be.impl == "pallas_interpret"
    # env override drives the 'auto' resolution (kernels.ops policy)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas_interpret")
    be = select_backend(params, True)          # impl=None -> auto
    assert isinstance(be, PallasQuantBackend)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
    assert isinstance(select_backend(params, True), RefQuantBackend)


def test_moe_apply_pallas_interpret_matches_ref():
    """Quantized moe_apply must reach kernels.ops dispatch: the fused
    Pallas kernel (interpreter) and the reference einsum composition give
    the same compensated output and identical routing."""
    params, mcfg = _quant_params()
    x2 = jnp.asarray(np.random.default_rng(1).standard_normal((24, 64)),
                     jnp.float32)
    y_ref, _, i_ref = moe_apply(x2, params, mcfg, quantized=True,
                                exact_capacity=True, impl="ref")
    y_pl, _, i_pl = moe_apply(x2, params, mcfg, quantized=True,
                              exact_capacity=True, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(i_ref.topk_idx),
                                  np.asarray(i_pl.topk_idx))
    assert float(jnp.max(jnp.abs(y_ref - y_pl))) < 1e-4
    # and the quantized path actually differs from dense (it dispatched
    # through the compressed stacks, not the fp weights)
    y_dense, _, _ = moe_apply(x2, params, mcfg, quantized=False,
                              exact_capacity=True)
    assert float(jnp.max(jnp.abs(y_dense - y_ref))) > 1e-4


@pytest.mark.slow
def test_full_forward_kernel_impl_dispatch():
    """End-to-end: a compressed model's forward under ctx.kernel_impl =
    'pallas_interpret' matches the 'ref' backend logits."""
    cfg = ModelConfig(
        name="tiny-moe", family="moe", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0, vocab_size=128,
        block_pattern=("global",), max_position=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      quant=QuantConfig(enabled=True, bits=2,
                                        rank_budget=16, top_n_restore=1,
                                        hqq_iters=2)))
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    up = unstack_params(params, cfg)
    cfg_q = dataclasses.replace(cfg, force_unroll_plan=True)
    segs = []
    for seg in up["segments"]:
        p = dict(seg[0])
        mp = dict(p["moe"])
        stacks, _ = compress_ffn_weights(mp["w1"], mp["w2"], mp["w3"],
                                         cfg.moe.quant)
        mp["stacks"] = stacks
        for k in ("w1", "w2", "w3"):
            mp.pop(k)
        p["moe"] = mp
        segs.append((p,))
    qparams = dict(up)
    qparams["segments"] = tuple(segs)
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, 128, (2, 8)),
                         jnp.int32)
    outs = {}
    for impl in ("ref", "pallas_interpret"):
        ctx = make_context(cfg_q, "train", quantized=True,
                           exact_capacity=True, kernel_impl=impl)
        outs[impl] = forward(qparams, tokens, cfg_q, ctx).logits
    err = float(jnp.max(jnp.abs(outs["ref"] - outs["pallas_interpret"])))
    assert err < 1e-3, err
