"""Shared test configuration.

Tests run on the single real CPU device by default (the 512-device
override is applied ONLY inside launch/dryrun.py, per the assignment).

Multi-device (expert-parallel / distributed) tests go through the
``dist_run`` fixture instead of skipping when only one device is
visible, so the distributed tier always executes:

- env-guarded in-process mode: when ``REPRO_HOST_DEVICES=N`` is set
  (``make tier1-dist`` / the CI ``tier1-dist`` job), the XLA host-device
  override is applied *before jax import* and the scripts run in this
  process — no subprocess startup or recompilation cost per module;
- subprocess fallback: otherwise each script runs in a fresh
  interpreter with ``--xla_force_host_platform_device_count`` forced,
  keeping the main test process at 1 device.
"""
import json
import os
import pathlib
import subprocess
import sys

_DEVICES_ENV = "REPRO_HOST_DEVICES"
DIST_DEVICES = 8      # device count every distributed test script assumes


def _device_flag(n: int) -> str:
    return f"--xla_force_host_platform_device_count={n}"


if os.environ.get(_DEVICES_ENV):
    flag = _device_flag(int(os.environ[_DEVICES_ENV]))
    xla_flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in xla_flags:
        os.environ["XLA_FLAGS"] = f"{xla_flags} {flag}".strip()

os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("JAX_ENABLE_X64", "0")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_default_prng_impl", "threefry2x32")

_ROOT = pathlib.Path(__file__).parent.parent


@pytest.fixture(scope="session")
def dist_run():
    """Run a multi-device test script and return its ``results`` dict.

    The script must populate a module-level ``results`` dict and finish
    with ``print("RESULTS:" + json.dumps(results))`` (the print feeds the
    subprocess mode; the in-process mode reads ``results`` directly).  It
    must NOT set XLA_FLAGS itself — this fixture owns device topology.
    """
    def run(script: str, devices: int = DIST_DEVICES, timeout: int = 500):
        if jax.device_count() >= devices:
            # tier1-dist mode: the env guard above already gave this
            # process enough host devices — execute inline
            ns: dict = {}
            exec(compile(script, "<dist-script>", "exec"), ns)
            return ns["results"]
        env = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
               "XLA_FLAGS": _device_flag(devices)}
        env.pop(_DEVICES_ENV, None)
        proc = subprocess.run([sys.executable, "-c", script],
                              capture_output=True, text=True, env=env,
                              cwd=_ROOT, timeout=timeout)
        assert proc.returncode == 0, proc.stderr[-3000:]
        lines = [l for l in proc.stdout.splitlines()
                 if l.startswith("RESULTS:")]
        assert lines, f"script printed no RESULTS line:\n{proc.stdout[-2000:]}"
        return json.loads(lines[-1][len("RESULTS:"):])

    return run
