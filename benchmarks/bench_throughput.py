"""Fig 7: end-to-end offloaded decode throughput (event-driven simulator).

Three paper models (Mixtral-8x7B / 8x22B dims, DeepSeek-MoE-16B dims),
two systems (GPU-only PCIe offload, GPU-NDP), policies:
  fp16 (Mixtral-Offloading), quant-int3/int2 (HOBBIT-class uniform),
  ours-int3/int2 (BEAM-LRC), MoNDE-style NDP variants.
Router traces come from the trained bench MoE (real skew) remapped to the
target expert count; spec bytes use the real model dimensions.
"""
from __future__ import annotations

import numpy as np

from repro.core.quantize import packed_nbytes
from repro.offload import (GPU_NDP, GPU_ONLY, LayerSpecSim,
                           make_router_trace, simulate_decode)
from repro.registry import get_config
from repro.serve import ServeEngine

from .common import trained_moe

MODELS = {
    "mixtral-8x7b": dict(layers=32, top_n=1, rank=32),
    "mixtral-8x22b": dict(layers=56, top_n=1, rank=32),
    "deepseek-moe-16b": dict(layers=28, top_n=3, rank=64),
}


def _spec(arch: str, bits: int, rank: int) -> LayerSpecSim:
    cfg = get_config(arch)
    d, fe, e = cfg.d_model, cfg.moe.d_expert, cfg.moe.num_experts
    fp16 = 3 * d * fe * 2
    qb = 3 * (packed_nbytes(bits, d, fe) + (d // 64) * fe * 4)
    comp = [rank * (d + fe) for _ in range(e)]  # int8 factors
    return LayerSpecSim(d, fe, e, cfg.moe.top_k, fp16, qb, comp)


def _trace(arch: str, tokens: int, quick: bool) -> np.ndarray:
    cfg = get_config(arch)
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    layers = MODELS[arch]["layers"]
    # real DECODE-time routing skew from the trained bench model's live
    # CONTINUOUS-BATCHING loop: ragged requests interleaved on 2 slots,
    # per-request traces concatenated, remapped to e experts
    bcfg, params = trained_moe(steps=60 if quick else 200)
    eng = ServeEngine(bcfg, params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, bcfg.vocab_size, (int(l),), dtype=np.int32)
               for l in rng.integers(6, 13, 4)]
    stats = eng.generate_many(prompts, max_new=min(tokens // 2, 32),
                              num_slots=2, chunk=4, seed=0)
    tr = np.concatenate([r.trace for r in stats.results])  # (steps, L, k)
    t, l, kk = tr.shape
    reps_t = -(-tokens // t)
    reps_l = -(-layers // l)
    tr = np.tile(tr, (reps_t, reps_l, 1))[:tokens, :layers, :]
    rng = np.random.default_rng(0)
    # remap 8-expert ids onto e experts per layer (random injections)
    maps = np.stack([rng.permutation(e)[:8] for _ in range(layers)])
    out = maps[np.arange(layers)[None, :, None], tr[..., :kk]]
    if kk < k:  # pad extra slots with random cold experts
        extra = rng.integers(0, e, (tokens, layers, k - kk))
        out = np.concatenate([out, extra], axis=-1)
    return out[..., :k]


def run(quick: bool = True):
    rows = []
    tokens = 32 if quick else 128
    for arch, meta in MODELS.items():
        trace = _trace(arch, tokens, quick)
        nl = meta["layers"]
        for bits in (3, 2):
            spec = _spec(arch, bits, meta["rank"])
            base = simulate_decode(trace, spec, GPU_ONLY, "fp16",
                                   num_layers=nl)
            ours = simulate_decode(trace, spec, GPU_ONLY, "ours",
                                   top_n=meta["top_n"], num_layers=nl)
            ndp_base = simulate_decode(trace, spec, GPU_NDP, "fp16",
                                       num_layers=nl)
            ndp_ours = simulate_decode(trace, spec, GPU_NDP, "ours_ndp",
                                       top_n=meta["top_n"], num_layers=nl)
            rows += [
                {"name": f"fig7/{arch}/gpu/fp16",
                 "tok_s": base.tokens_per_s, "bits": 16,
                 "mb_per_tok": base.transfer_bytes_per_token / 2 ** 20},
                {"name": f"fig7/{arch}/gpu/ours-int{bits}",
                 "tok_s": ours.tokens_per_s, "bits": bits,
                 "mb_per_tok": ours.transfer_bytes_per_token / 2 ** 20,
                 "speedup": ours.tokens_per_s / base.tokens_per_s},
                {"name": f"fig7/{arch}/ndp/fp16",
                 "tok_s": ndp_base.tokens_per_s, "bits": 16,
                 "mb_per_tok": ndp_base.transfer_bytes_per_token / 2 ** 20},
                {"name": f"fig7/{arch}/ndp/ours-int{bits}",
                 "tok_s": ndp_ours.tokens_per_s, "bits": bits,
                 "mb_per_tok": ndp_ours.transfer_bytes_per_token / 2 ** 20,
                 "speedup": ndp_ours.tokens_per_s / ndp_base.tokens_per_s},
            ]
    return rows


if __name__ == "__main__":
    for r in run():
        extra = ",".join(f"{k}={v:.3f}" if isinstance(v, float) else f"{k}={v}"
                         for k, v in r.items() if k != "name")
        print(f"{r['name']},{extra}")
