"""Offload emulation + serving engine + quantized-serving correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoEConfig, QuantConfig
from repro.core import compress_ffn_weights
from repro.models import ExecContext, forward, init_params
from repro.offload import (GPU_NDP, GPU_ONLY, ExpertCache, ExpertStore,
                           LayerSpecSim, LayerAheadPrefetcher,
                           make_router_trace, simulate_decode)
from repro.serve import ServeEngine, router_trace


def moe_cfg():
    return ModelConfig(
        name="tiny-moe", family="moe", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=0, vocab_size=128,
        block_pattern=("global",), max_position=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      quant=QuantConfig(enabled=True, bits=2, rank_budget=16,
                                        top_n_restore=1, hqq_iters=3)))


def test_lru_cache_and_stats():
    c = ExpertCache(capacity=2)
    assert not c.access(0, 100)
    assert not c.access(1, 100)
    assert c.access(0, 100)          # hit
    assert not c.access(2, 100)      # evicts 1
    assert not c.access(1, 100)      # miss again
    assert c.stats.bytes_moved == 400
    assert 0 < c.stats.hit_rate < 1


def test_prefetcher_accuracy_metering():
    pf = LayerAheadPrefetcher(num_layers=2, top_k=2)
    pf.observe(0, np.array([1, 2]))
    pf.observe(0, np.array([1, 3]))   # pred [1,2]: 1 useful 1 wasted
    assert pf.stats.issued == 2
    assert pf.stats.useful == 1
    assert pf.predict(0).tolist() == [1, 3]


def _stacks(seed=0, experts=4):
    rng = np.random.default_rng(seed)
    w = [jnp.asarray(rng.standard_normal((experts, 128, 64)).astype(np.float32)),
         jnp.asarray(rng.standard_normal((experts, 64, 128)).astype(np.float32)),
         jnp.asarray(rng.standard_normal((experts, 128, 64)).astype(np.float32))]
    qcfg = QuantConfig(enabled=True, bits=2, rank_budget=16, hqq_iters=2)
    stacks, _ = compress_ffn_weights(w[0], w[1], w[2], qcfg)
    return stacks


def test_compensator_rides_cache():
    """Compensator factors are fetched once per residency of their expert:
    no re-charge on cache hits, refetch only after eviction."""
    store = ExpertStore(_stacks(), cache_capacity=2)
    c = store.compensator_bytes
    store.access_token(np.array([0, 1]), top_n=1, policy="ours")
    assert store.comp_bytes_moved == c(0)
    # hits: neither weights nor compensators move again
    assert store.access_token(np.array([0, 1]), top_n=1, policy="ours") == 0
    assert store.comp_bytes_moved == c(0)
    # evict 0 and 1 (capacity 2), fetching 2's compensator on the way
    store.access_token(np.array([2, 3]), top_n=1, policy="ours")
    assert store.comp_bytes_moved == c(0) + c(2)
    # 0 was evicted, so its compensator must ride back in with it
    store.access_token(np.array([0, 1]), top_n=1, policy="ours")
    assert store.comp_bytes_moved == 2 * c(0) + c(2)


def test_compensator_promotion_on_topn_boundary():
    """An expert resident WITHOUT compensators (fetched at rank >= top_n)
    pays the compensator bytes when it is later accessed at rank < top_n —
    and only then."""
    store = ExpertStore(_stacks(), cache_capacity=4)
    store.access_token(np.array([0, 1]), top_n=1, policy="ours")
    assert store.comp_bytes_moved == store.compensator_bytes(0)
    # 1 is a cache hit but newly top-ranked: compensator fetched now
    b = store.access_token(np.array([1, 0]), top_n=1, policy="ours")
    assert b == store.compensator_bytes(1)
    assert store.cache.stats.misses == 2              # no new weight fetch


def test_prefetch_bytes_metered_and_wasted_split():
    """Prefetched experts are inserted into the LRU and their traffic is
    metered; bytes for predictions the step never used are additionally
    reported as wasted."""
    from repro.offload import meter_decode_trace
    stacks = _stacks()
    store = ExpertStore(stacks, cache_capacity=2)
    pf = LayerAheadPrefetcher(num_layers=1, top_k=2)
    eb = store.expert_bytes(0, "quant")               # uniform per expert
    # step 0: rows route to all 4 experts -> capacity-2 cache can't hold
    # the prediction set; step 1 narrows to experts {0, 1}
    trace = np.array([
        [[[0, 1], [2, 3]]],
        [[[0, 1], [0, 1]]],
    ])                                                # (2, 1, B=2, k=2)
    rep = meter_decode_trace([store], trace, policy="quant", top_n=0,
                             prefetcher=pf)
    # step 1 prefetches the full predicted set {0,1,2,3} (none resident
    # after {2,3} displaced {0,1}); {2,3} turn out unused -> wasted
    assert rep["prefetch_bytes"] == 4 * eb
    assert rep["wasted_prefetch_bytes"] == 2 * eb
    assert store.prefetch_bytes == 4 * eb
    assert rep["total_bytes"] == rep["demand_bytes"] + rep["prefetch_bytes"]
    assert rep["tokens"] == 4


def test_prefetch_of_resident_experts_is_free():
    """Predictions that are already device-resident must not be re-charged
    (the insert is a no-op), and correct predictions score as useful with
    zero wasted bytes."""
    from repro.offload import meter_decode_trace
    stacks = _stacks()
    # alternating {0,1}/{2,3} on a capacity-2 LRU: the predicted set was
    # accessed last step so it is always resident -> no prefetch traffic,
    # and the always-wrong predictions must not invent hits
    trace = np.array([[[[0, 1]]], [[[2, 3]]],
                      [[[0, 1]]], [[[2, 3]]], [[[0, 1]]]])
    warm = ExpertStore(stacks, cache_capacity=2)
    pf = LayerAheadPrefetcher(num_layers=1, top_k=2)
    rep1 = meter_decode_trace([warm], trace, policy="quant", top_n=0,
                              prefetcher=pf)
    assert rep1["prefetch_bytes"] == 0
    assert rep1["wasted_prefetch_bytes"] == 0
    assert rep1["prefetch_accuracy"] == 0.0
    assert rep1["hit_rate"] == 0.0
    # steady pattern: predictions correct, zero waste, demand hits
    steady = np.array([[[[0, 1]]]] * 4)
    warm2 = ExpertStore(stacks, cache_capacity=2)
    pf2 = LayerAheadPrefetcher(num_layers=1, top_k=2)
    rep2 = meter_decode_trace([warm2], steady, policy="quant", top_n=0,
                              prefetcher=pf2)
    assert rep2["hit_rate"] == 0.75                   # all but the cold step
    assert rep2["prefetch_accuracy"] == 1.0
    assert rep2["wasted_prefetch_bytes"] == 0


def test_prefetcher_keeps_top_k_and_skips_masked():
    pf = LayerAheadPrefetcher(num_layers=1, top_k=1)
    # one stream, top_k=1: prediction capped at the most frequent expert
    pf.observe(0, np.array([[7, 7]]))
    assert pf.predict(0).tolist() == [7]
    # two streams -> cap 2, ranked by frequency (3 twice, then lowest id)
    pf.observe(0, np.array([[7, 3], [3, 2]]))
    assert pf.predict(0).tolist() == [2, 3]
    # masked rows (inactive scheduler slots) are ignored entirely
    pf.observe(0, np.array([[-1, -1], [4, 4]]))
    assert pf.predict(0).tolist() == [4]
    # fully-masked step EXPIRES the pending prediction: nothing consumed
    # it, and a later step would otherwise meter the stale warm as a
    # fresh prefetch for routing that is a full step old
    pf.observe(0, np.array([[-1, -1]]))
    assert pf.predict(0) is None


def test_meter_skips_masked_slots():
    """Rows with expert id -1 (inactive scheduler slots) move no bytes and
    don't count as tokens."""
    from repro.offload import meter_decode_trace
    stacks = _stacks()
    full = np.array([[[[0, 1], [2, 3]]], [[[1, 2], [3, 0]]]])  # (2,1,2,2)
    masked = full.copy()
    masked[:, :, 1, :] = -1
    a = ExpertStore(stacks, cache_capacity=2)
    ra = meter_decode_trace([a], masked, policy="quant", top_n=0)
    b = ExpertStore(stacks, cache_capacity=2)
    rb = meter_decode_trace([b], full[:, :, :1, :], policy="quant", top_n=0)
    assert ra["tokens"] == rb["tokens"] == 2
    assert ra["total_bytes"] == rb["total_bytes"]
    assert ra["hit_rate"] == rb["hit_rate"]


def _sim_spec():
    d, fe, e = 4096, 14336, 8
    fp16 = 3 * d * fe * 2
    q2 = int(3 * d * fe * 0.25) + 3 * (d // 64) * fe * 4
    comp = [32 * (d + fe) for _ in range(e)]
    return LayerSpecSim(d, fe, e, 2, fp16, q2, comp)


def test_simulator_policy_ordering():
    """tokens/s: ours > quant > fp16 on GPU-only; NDP helps further."""
    spec = _sim_spec()
    trace = make_router_trace(None, tokens=48, layers=8, top_k=2,
                              skew=0.8, num_experts=8)
    r_fp16 = simulate_decode(trace, spec, GPU_ONLY, "fp16", num_layers=8)
    r_q = simulate_decode(trace, spec, GPU_ONLY, "quant", num_layers=8)
    r_ours = simulate_decode(trace, spec, GPU_ONLY, "ours", top_n=1,
                             num_layers=8)
    r_ndp = simulate_decode(trace, spec, GPU_NDP, "ours_ndp", top_n=1,
                            num_layers=8)
    assert r_q.tokens_per_s > r_fp16.tokens_per_s * 3
    assert r_ours.tokens_per_s > r_fp16.tokens_per_s * 3
    # compensators cost little vs uniform quant
    assert r_ours.tokens_per_s > 0.7 * r_q.tokens_per_s
    assert r_ndp.tokens_per_s > r_ours.tokens_per_s
    # fp16 offload is transfer-bound (paper Fig 1a)
    assert r_fp16.transfer_time_frac > 0.8


def test_expert_store_metering():
    rng = np.random.default_rng(0)
    w = [jnp.asarray(rng.standard_normal((4, 128, 64)).astype(np.float32)),
         jnp.asarray(rng.standard_normal((4, 64, 128)).astype(np.float32)),
         jnp.asarray(rng.standard_normal((4, 128, 64)).astype(np.float32))]
    qcfg = QuantConfig(enabled=True, bits=2, rank_budget=16, hqq_iters=2)
    stacks, _ = compress_ffn_weights(w[0], w[1], w[2], qcfg)
    store = ExpertStore(stacks, cache_capacity=2)
    b1 = store.access_token(np.array([0, 1]), top_n=1, policy="ours")
    assert b1 > 0
    b2 = store.access_token(np.array([0, 1]), top_n=1, policy="ours")
    # cache hits: only compensator for the top-1 expert moves again
    assert b2 < b1


def test_quantized_serving_close_to_fp(tmp_path):
    """End-to-end: compress a tiny MoE's experts, serve quantized, compare
    logits to full precision — compensated must beat plain quantized."""
    cfg = moe_cfg()
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab_size, (2, 24)).astype(np.int32)

    ref = forward(params, jnp.asarray(tokens), cfg,
                  ExecContext(mode="train", exact_capacity=True))

    # compress every MoE layer (unrolled: per-layer ranks differ)
    def compress(params, n_restore):
        from repro.models.transformer import unstack_params
        qcfg = dataclasses.replace(cfg.moe.quant, top_n_restore=n_restore)
        up = unstack_params(params, cfg)
        new_segs = []
        for seg in up["segments"]:
            pos = []
            for p in seg:
                p = dict(p)
                mp = dict(p["moe"])
                stacks, _ = compress_ffn_weights(
                    mp["w1"], mp["w2"], mp["w3"], qcfg)
                mp["stacks"] = stacks
                for k in ("w1", "w2", "w3"):
                    mp.pop(k)
                p["moe"] = mp
                pos.append(p)
            new_segs.append(tuple(pos))
        out = dict(up)
        out["segments"] = tuple(new_segs)
        return out, dataclasses.replace(
            cfg, force_unroll_plan=True,
            moe=dataclasses.replace(cfg.moe, quant=qcfg))

    qparams, qcfg_model = compress(params, n_restore=1)
    out_comp = forward(qparams, jnp.asarray(tokens), qcfg_model,
                       ExecContext(mode="train", quantized=True,
                                   exact_capacity=True))
    qparams0, qcfg_model0 = compress(params, n_restore=0)
    out_plain = forward(qparams0, jnp.asarray(tokens), qcfg_model0,
                        ExecContext(mode="train", quantized=True,
                                    exact_capacity=True))
    err_comp = float(jnp.mean(jnp.abs(
        out_comp.logits.astype(jnp.float32) - ref.logits.astype(jnp.float32))))
    err_plain = float(jnp.mean(jnp.abs(
        out_plain.logits.astype(jnp.float32) - ref.logits.astype(jnp.float32))))
    assert err_comp < err_plain


def test_serve_engine_generates():
    cfg = moe_cfg()
    params = init_params(jax.random.key(1), cfg, jnp.float32)
    eng = ServeEngine(cfg, params)
    res = eng.generate(np.zeros((2, 4), np.int32), max_new=4)
    assert res.tokens.shape == (2, 4)
    assert res.decode_tokens_per_s > 0


def test_router_trace_export():
    cfg = moe_cfg()
    params = init_params(jax.random.key(2), cfg, jnp.float32)
    tokens = np.zeros((1, 8), np.int32)
    tr = router_trace(cfg, params, tokens)
    assert tr.shape == (8, 2, 2)      # (T, layers, k)
    assert tr.min() >= 0 and tr.max() < 4
