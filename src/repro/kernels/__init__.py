"""Pallas TPU kernels for the paper's compute hot spots.

- quant_matmul:          x @ dequant(bit-plane packed Wq)
- lowrank_comp_matmul:   fused dequant matmul + router-guided rank-r epilogue
- fused_expert_matmul:   whole decode-time expert FFN projection — per-expert
  dequant at true bit width + rank-capped compensation + gate-weighted
  combine — in one pallas_call over the expert stack

Each kernel ships with a pure-jnp oracle in ``ref.py`` and a jit'd dispatch
wrapper in ``ops.py`` (auto-selects pallas on TPU, ref on CPU; tests run
``pallas_interpret``).
"""
from . import autotune, ops, ref
from .ops import (compensated_matmul_stack, default_impl, fused_expert_matmul,
                  lowrank_comp_matmul, quant_matmul)
