"""Fig 1 analogue: offloaded MoE inference time breakdown + operational
intensity (the paper's motivation figure).

(a) fraction of decode wall time spent on host->device expert transfer vs
    compute, per policy (fp16 / int3 / int2) on the GPU-only profile;
(b) operational intensity (FLOPs/byte moved) per policy vs the machine
    balance point — shows quantization moving decode away from the
    memory-bound region exactly as Fig 1(b) draws it.
"""
from __future__ import annotations

import numpy as np

from repro.core.quantize import packed_nbytes
from repro.offload import GPU_ONLY, LayerSpecSim, simulate_decode

from .common import trained_moe
from .bench_throughput import _trace


def run(quick: bool = True):
    rows = []
    d, fe, e, k = 4096, 14336, 8, 2        # Mixtral-8x7B expert dims
    trace = _trace("mixtral-8x7b", 32 if quick else 128, quick)
    flops_per_expert = 2.0 * 3 * d * fe
    for policy, bits in (("fp16", 16), ("quant", 3), ("quant", 2)):
        if bits == 16:
            qb = 3 * d * fe * 2
        else:
            qb = 3 * (packed_nbytes(bits, d, fe) + (d // 64) * fe * 4)
        spec = LayerSpecSim(d, fe, e, k, 3 * d * fe * 2, qb, [0] * e)
        r = simulate_decode(trace, spec, GPU_ONLY, policy, num_layers=32)
        oi = flops_per_expert / qb            # FLOPs per byte moved
        balance = GPU_ONLY.compute_flops / GPU_ONLY.link_bw
        rows.append({
            "name": f"fig1/{policy}-int{bits}",
            "transfer_frac": r.transfer_time_frac,
            "tok_s": r.tokens_per_s,
            "op_intensity": oi,
            "machine_balance": balance,
            "bound": "memory" if oi < balance else "compute",
        })
    return rows


if __name__ == "__main__":
    for r in run():
        extra = ",".join(f"{k}={v:.3f}" if isinstance(v, float) else f"{k}={v}"
                         for k, v in r.items() if k != "name")
        print(f"{r['name']},{extra}")
