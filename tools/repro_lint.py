#!/usr/bin/env python
"""repro-lint: AST lint for jit purity, byte accounting, and tile legality.

Usage:
    python tools/repro_lint.py [paths...]            # default: src tools benchmarks
    python tools/repro_lint.py --list-rules
    python tools/repro_lint.py --update-baseline     # accept current findings

Exit codes: 0 clean, 1 findings, 2 internal error / bad invocation.

Suppress a single finding inline with ``# repro-lint: disable=RL101``
(comma-separate multiple IDs, or ``disable=all``); accept a legacy batch
into ``tools/repro_lint_baseline.json`` with ``--update-baseline``.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis import Baseline, all_rules, lint_paths  # noqa: E402

DEFAULT_PATHS = ("src", "tools", "benchmarks")
DEFAULT_BASELINE = REPO_ROOT / "tools" / "repro_lint_baseline.json"


def parse_args(argv=None):
    ap = argparse.ArgumentParser(prog="repro-lint", description=__doc__)
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src tools benchmarks)")
    ap.add_argument("--root", default=str(REPO_ROOT),
                    help="repo root for relative paths and module names")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="baseline JSON (use 'none' to disable)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write current findings into the baseline and exit 0")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule IDs to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print registered rules and exit")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress the summary line")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)

    if args.list_rules:
        for rid, r in sorted(all_rules().items()):
            print(f"{rid}  {r.description}")
        return 0

    root = Path(args.root)
    paths = [root / p for p in (args.paths or DEFAULT_PATHS)]
    paths = [p for p in paths if p.exists()]
    if not paths:
        print("repro-lint: no lintable paths", file=sys.stderr)
        return 2
    baseline = None if args.baseline.lower() == "none" else Path(args.baseline)
    select = set(args.select.split(",")) if args.select else None

    if args.update_baseline:
        # run without the baseline filter, then accept everything live
        result = lint_paths(paths, root, baseline_path=None, select=select)
        Baseline.dump(result.findings, baseline or DEFAULT_BASELINE)
        print(f"repro-lint: baselined {len(result.findings)} finding(s) "
              f"-> {baseline or DEFAULT_BASELINE}")
        return 0

    result = lint_paths(paths, root, baseline_path=baseline, select=select)
    for f in result.findings:
        print(f.render())
    if not args.quiet:
        print(f"repro-lint: {len(result.findings)} finding(s) over "
              f"{result.files} file(s) "
              f"({result.suppressed} suppressed, "
              f"{result.baselined} baselined)")
    return 1 if result.findings else 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except KeyboardInterrupt:
        sys.exit(2)
