"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the scaffold contract; quality
benchmarks put their headline metric in the `derived` column.

  fig4   kurtosis <-> quant-error correlation; compensator residual gain
  fig6   accuracy ladder (fp32 / rtn / hqq / ours at int2+int3)
  alloc  calibrated vs uniform precision allocation at equal wire bytes
  fig7   offloaded decode throughput (GPU-only + GPU-NDP simulator)
  fig8   ablations: top-n count, rank budget, kurtosis vs uniform
  serving  continuous-batching offered-load sweep (tok/s, p50/p95 latency)
  table2 positional restoration (only-top1 vs only-top2)
  kernel quant/lowrank matmul microbenches + wire-byte accounting
  roofline  dry-run roofline summary (requires dryrun JSONs)
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer training / more tokens")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (fig4,fig6,...)")
    args = ap.parse_args()
    quick = not args.full

    from . import (bench_ablation, bench_accuracy, bench_breakdown,
                   bench_kernels, bench_kurtosis, bench_position,
                   bench_serving, bench_throughput, roofline_table)
    suites = {
        "kernel": bench_kernels.run,
        "fig1": bench_breakdown.run,
        "fig4": bench_kurtosis.run,
        "fig6": bench_accuracy.run,
        "alloc": bench_accuracy.run_alloc,
        "fig8": bench_ablation.run,
        "table2": bench_position.run,
        "fig7": bench_throughput.run,
        "serving": bench_serving.run,
        "roofline": roofline_table.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    failures = []
    print("name,us_per_call,derived")
    for key, fn in suites.items():
        t0 = time.time()
        try:
            rows = fn(quick=quick)
        except Exception as e:
            failures.append((key, repr(e)))
            traceback.print_exc()
            continue
        dt = (time.time() - t0) * 1e6
        for r in rows:
            name = r.pop("name")
            us = r.pop("us_per_call", dt / max(len(rows), 1))
            derived = ";".join(
                f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
                for k, v in r.items())
            print(f"{name},{us:.1f},{derived}", flush=True)
    if failures:
        print("FAILURES:", failures, file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
