"""Core compression pipeline: packing, HQQ, kurtosis allocation, SVD
compensation, restoration math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QuantConfig, RANK_BUCKETS
from repro.core import (allocate_ranks, compress_expert_stack, dequantize,
                        hqq_quantize, kurtosis, pack_bits, quant_error,
                        quantize, topn_mask, uniform_ranks, unpack_bits)


def test_pack_roundtrip_all_widths():
    rng = np.random.default_rng(0)
    for bits in (1, 2, 3, 4, 8):
        q = jnp.asarray(rng.integers(0, 1 << bits, (256, 64)).astype(np.uint8))
        planes = pack_bits(q, bits)
        back = unpack_bits(planes, bits)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(q))
        nbytes = sum(p.size for p in planes)
        assert nbytes * 8 == bits * q.size  # exact sub-byte storage


def test_quant_error_decreases_with_bits():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((512, 256)).astype(np.float32))
    errs = [float(quant_error(w, quantize(w, b, 64))) for b in (2, 3, 4, 8)]
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < 0.01


def test_hqq_beats_rtn_on_heavy_tails():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_t(2.5, (512, 256)).astype(np.float32))
    for bits in (2, 3):
        e_rtn = float(quant_error(w, quantize(w, bits, 64)))
        e_hqq = float(quant_error(w, hqq_quantize(w, bits, 64, iters=20)))
        assert e_hqq < e_rtn


def test_kurtosis_matches_scipy_definition():
    rng = np.random.default_rng(3)
    x = rng.standard_t(4, size=(64, 32)).astype(np.float32)
    k = float(kurtosis(jnp.asarray(x)))
    mu, sd = x.mean(), x.std()
    expect = float(np.mean((x - mu) ** 4) / sd ** 4)
    assert abs(k - expect) / expect < 1e-3


def test_greedy_allocation_respects_budget_and_order():
    kurt = [10.0, 50.0, 5.0, 20.0]
    ranks = allocate_ranks(kurt, rank_budget=32, buckets=RANK_BUCKETS)
    assert ranks.sum() <= 4 * 32
    # highest kurtosis expert gets the largest allocation
    assert ranks[1] == max(ranks)
    assert set(ranks) <= set(RANK_BUCKETS)


def test_uniform_allocation():
    r = uniform_ranks(8, 32)
    assert (r == 32).all()


def test_compensation_reduces_residual():
    rng = np.random.default_rng(4)
    w = jnp.asarray(np.stack([
        rng.standard_t(2.2 + e, (256, 128)).astype(np.float32)
        for e in range(4)]))
    qcfg = QuantConfig(enabled=True, bits=2, rank_budget=32, hqq_iters=5)
    stack, rep = compress_expert_stack(w, qcfg)
    # compensated experts improve strictly; uncompensated unchanged
    comp = rep["ranks"] > 0
    assert comp.any()
    assert (rep["rel_err_comp"][comp] < rep["rel_err_quant"][comp]).all()
    assert np.allclose(rep["rel_err_comp"][~comp],
                       rep["rel_err_quant"][~comp], rtol=1e-5)


def test_kurtosis_error_correlation():
    """Paper Fig 4b: kurtosis positively correlates with quant error."""
    rng = np.random.default_rng(5)
    w = jnp.asarray(np.stack([
        rng.standard_t(df, (256, 128)).astype(np.float32)
        for df in (2.1, 2.5, 3.0, 4.0, 6.0, 10.0, 20.0, 50.0)]))
    qcfg = QuantConfig(enabled=True, bits=2, hqq_iters=3)
    _, rep = compress_expert_stack(w, qcfg)
    corr = np.corrcoef(rep["kurtosis"], rep["rel_err_quant"])[0, 1]
    assert corr > 0.6


def test_topn_mask():
    topk = jnp.asarray([[3, 1, 0], [2, 5, 4]])
    m = topn_mask(topk, n=2, num_experts=6)
    assert m.shape == (2, 6)
    np.testing.assert_array_equal(
        np.asarray(m),
        [[0, 1, 0, 1, 0, 0], [0, 0, 1, 0, 0, 1]])


def test_topn_mask_n_ge_k_clamps():
    topk = jnp.asarray([[3, 1, 0], [2, 5, 4]])
    # n beyond the router width covers exactly the top-k experts
    m = topn_mask(topk, n=7, num_experts=6)
    np.testing.assert_array_equal(np.asarray(m),
                                  np.asarray(topn_mask(topk, 3, 6)))
    assert np.asarray(m).sum(axis=-1).tolist() == [3, 3]


def test_topn_mask_n_zero_is_empty():
    topk = jnp.asarray([[3, 1, 0], [2, 5, 4]])
    m = topn_mask(topk, n=0, num_experts=6)
    assert m.shape == (2, 6)
    assert np.asarray(m).sum() == 0


def test_topn_mask_dense_degenerate_single_expert():
    # E = 1 (dense quantize-then-compensate): every token restores its
    # only expert as soon as n >= 1
    topk = jnp.zeros((4, 1), jnp.int32)
    m = topn_mask(topk, n=1, num_experts=1)
    assert m.shape == (4, 1)
    np.testing.assert_array_equal(np.asarray(m), np.ones((4, 1)))
    assert np.asarray(topn_mask(topk, 0, 1)).sum() == 0


def test_wire_bytes_accounting():
    rng = np.random.default_rng(6)
    w = jnp.asarray(rng.standard_normal((2, 256, 128)).astype(np.float32))
    qcfg = QuantConfig(enabled=True, bits=2, rank_budget=16, hqq_iters=2)
    stack, _ = compress_expert_stack(w, qcfg)
    b_plain = stack.expert_wire_bytes(0, compensated=False)
    b_comp = stack.expert_wire_bytes(0, compensated=True)
    assert b_plain < stack.fp16_wire_bytes / 4     # >4x compression at 2-bit
    r = stack.ranks[0]
    assert b_comp - b_plain == r * (256 + 128) + 4 * r or r == 0
