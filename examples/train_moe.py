"""End-to-end driver: train a ~100M-param MoE LM for a few hundred steps
on the synthetic data pipeline, with checkpointing and restart handling.

This is the assignment's (b) end-to-end training example: a real loop
(AdamW, warmup-cosine, grad clip, router aux losses, z-loss), atomic
checkpoints every 50 steps, straggler monitoring, and a perplexity report
against the stream's entropy floor.

Run:  PYTHONPATH=src python examples/train_moe.py [--steps 300]
"""
import argparse
import math

import numpy as np

from repro.config import ModelConfig, MoEConfig, QuantConfig, TrainConfig
from repro.data import SyntheticLM, SyntheticLMConfig
from repro.train import StragglerMonitor, train


def build_cfg() -> ModelConfig:
    # ~100M params: 4 layers, d=256, 16 experts of d_ff=1024 + GQA attention
    return ModelConfig(
        name="moe-100m", family="moe", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, head_dim=32, d_ff=0, vocab_size=8192,
        block_pattern=("global",), max_position=4096,
        moe=MoEConfig(num_experts=16, top_k=2, d_expert=1024,
                      router_aux_weight=0.02,
                      quant=QuantConfig(enabled=True, bits=2,
                                        rank_budget=32, top_n_restore=1)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="experiments/train_moe_ckpt")
    args = ap.parse_args()

    cfg = build_cfg()
    n_params = cfg.num_params
    print(f"model: {cfg.name}  ~{n_params / 1e6:.0f}M params "
          f"({cfg.moe.num_experts} experts, top-{cfg.moe.top_k})")

    data = SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, batch_size=args.batch, seq_len=args.seq))
    print(f"data entropy floor (unigram): {data.entropy_floor():.3f} nats")

    tcfg = TrainConfig(total_steps=args.steps, lr=1e-3, warmup_steps=30,
                       checkpoint_every=50, keep_checkpoints=3,
                       clip_norm=1.0, loss_chunk=0)
    res = train(cfg, tcfg, data=data, checkpoint_dir=args.ckpt,
                log_every=20, batch_shape=(args.batch, args.seq),
                straggler=StragglerMonitor(threshold=4.0))

    first = np.mean([h["loss"] for h in res.history[:10]])
    last = np.mean([h["loss"] for h in res.history[-10:]])
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"(ppl {math.exp(first):.1f} -> {math.exp(last):.1f})")
    print(f"checkpoints in {args.ckpt}; straggler flags: "
          f"{res.straggler_flags}")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
