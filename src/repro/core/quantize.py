"""Group-wise affine quantization with TPU-friendly bit-plane packing.

The paper offloads INT2/INT3 experts over PCIe; on TPU the analogous win is
streaming packed sub-byte weights HBM->VMEM.  TPU vector units want uniform
shift/mask lanes, so a b-bit tensor is stored as a set of *power-of-two bit
planes* (3 = 2+1): a plane of width ``p`` packs ``c = 8//p`` values per
byte.  Packing is **block-local** along K (block = ``PACK_BLOCK`` rows): the
K axis is cut into blocks, each block into ``c`` contiguous chunks, chunk
``j`` stored at bit offset ``j*p``.  A kernel K-tile that is a multiple of
the block therefore consumes every byte it loads in full — HBM traffic is
exactly ``bits/8`` bytes per weight — and unpacking is a fixed sequence of
uniform shifts + one stack/reshape on the sublane axis (no gathers).

Quantization is asymmetric uint: ``q = clip(round(w/s + z), 0, 2^b-1)`` and
``dequant = (q - z) * s`` with per-group (G along K) scale/zero.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# plane decomposition per bit width: tuple of (plane_width, bit_offset)
PLANES = {
    1: ((1, 0),),
    2: ((2, 0),),
    3: ((2, 0), (1, 2)),
    4: ((4, 0),),
    8: ((8, 0),),
}

PACK_BLOCK = 64  # K rows per packing block; kernel K-tiles must be multiples


def plane_widths(bits: int) -> Tuple[int, ...]:
    return tuple(p for p, _ in PLANES[bits])


def packed_rows(p: int, k: int) -> int:
    """Row count of one width-``p`` bit plane over ``k`` K rows (the
    packed layout stores ``8 // p`` values per byte along K)."""
    return k // (8 // p)


def packed_nbytes(bits: int, k: int, n: int) -> int:
    """Exact packed byte count for a (k, n) matrix at ``bits`` width."""
    return sum(packed_rows(p, k) * n for p, _ in PLANES[bits])


SCALE_WIRE_BYTES = 2  # scale/zero (and factor scales) travel as bf16


def quant_wire_bytes(bits: int, k: int, n: int, group_size: int) -> int:
    """Wire bytes of one (k, n) groupwise-quantized matrix: bit-plane
    packed codes + bf16 scale AND zero per (K-group, column).

    THE single formula for quantized-weight wire accounting — shared by
    ``QuantizedTensor.nbytes_packed``,
    ``CompressedExpertStack.expert_wire_bytes``, and the offload store's
    metering, so packing layout and scale bytes cannot drift between
    compression and metering.
    """
    return (packed_nbytes(bits, k, n)
            + 2 * (k // group_size) * n * SCALE_WIRE_BYTES)


def factor_wire_bytes(rank: int, m: int, n: int, factor_bits: int) -> int:
    """Wire bytes of a rank-``rank`` compensator for an (m, n) matrix:
    sub-byte U/V codes at ``factor_bits`` plus the two bf16 per-rank
    scale vectors.  Shared by ``Compensator.nbytes_wire``,
    ``CompressedExpertStack.expert_wire_bytes``, and
    ``ExpertStore.compensator_bytes`` (the same drift guarantee as
    :func:`quant_wire_bytes`).
    """
    return (int(rank) * (m + n) * factor_bits) // 8 \
        + 2 * SCALE_WIRE_BYTES * int(rank)


# ---------------------------------------------------------------------------
# block-local bit-plane packing
# ---------------------------------------------------------------------------

def pack_plane(vals: jax.Array, p: int, block: int = PACK_BLOCK) -> jax.Array:
    """Pack (K, N) uint8 p-bit values into (K//(8//p), N) bytes, block-local.

    Within each K-block, chunk j (rows [j*block/c, (j+1)*block/c)) goes to
    bit offset j*p of the block's bytes.
    """
    c = 8 // p
    k, n = vals.shape[0], vals.shape[1]
    assert k % block == 0 and block % c == 0, (k, block, c)
    v = vals.reshape(k // block, c, block // c, n).astype(jnp.uint8)
    out = jnp.zeros((k // block, block // c, n), jnp.uint8)
    for j in range(c):
        out = out | (v[:, j] << (j * p))
    return out.reshape(k // c, n)


def unpack_plane(packed: jax.Array, p: int, block: int = PACK_BLOCK) -> jax.Array:
    """Inverse of :func:`pack_plane`: (K//c, N) bytes -> (K, N) uint8."""
    c = 8 // p
    kc, n = packed.shape
    k = kc * c
    mask = jnp.uint8((1 << p) - 1)
    pk = packed.reshape(k // block, block // c, n)
    chunks = [(pk >> (j * p)) & mask for j in range(c)]
    return jnp.stack(chunks, axis=1).reshape(k, n)


def pack_bits(q: jax.Array, bits: int, block: int = PACK_BLOCK
              ) -> Tuple[jax.Array, ...]:
    """Split b-bit codes into power-of-two planes and pack each."""
    planes = []
    for p, off in PLANES[bits]:
        sub = (q >> off) & ((1 << p) - 1)
        planes.append(pack_plane(sub.astype(jnp.uint8), p, block))
    return tuple(planes)


def unpack_bits(planes: Tuple[jax.Array, ...], bits: int,
                block: int = PACK_BLOCK) -> jax.Array:
    """Inverse of :func:`pack_bits` -> uint8 codes (K, N)."""
    out = None
    for (p, off), plane in zip(PLANES[bits], planes):
        sub = unpack_plane(plane, p, block).astype(jnp.uint8) << off
        out = sub if out is None else out | sub
    return out


# ---------------------------------------------------------------------------
# QuantizedTensor container
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=("planes", "scale", "zero"),
         meta_fields=("bits", "group_size", "shape"))
@dataclass
class QuantizedTensor:
    """Packed groupwise-quantized matrix of logical ``shape`` = (K, N).

    ``planes``: tuple of uint8 arrays (one per bit plane).
    ``scale``/``zero``: (K // group_size, N) in f32.
    """
    planes: Tuple[jax.Array, ...]
    scale: jax.Array
    zero: jax.Array
    bits: int
    group_size: int
    shape: Tuple[int, ...]

    @property
    def nbytes_packed(self) -> int:
        k, n = self.shape
        return quant_wire_bytes(self.bits, k, n, self.group_size)

    def astype_codes(self) -> jax.Array:
        return unpack_bits(self.planes, self.bits)


def _group_minmax(w: jax.Array, group_size: int):
    k, n = w.shape
    g = w.reshape(k // group_size, group_size, n)
    return g, g.min(axis=1, keepdims=True), g.max(axis=1, keepdims=True)


def quantize(w: jax.Array, bits: int, group_size: int = 64) -> QuantizedTensor:
    """Plain (round-to-nearest) groupwise asymmetric quantization."""
    k, n = w.shape
    assert k % group_size == 0, (k, group_size)
    w32 = w.astype(jnp.float32)
    g, lo, hi = _group_minmax(w32, group_size)
    qmax = (1 << bits) - 1
    scale = jnp.maximum((hi - lo) / qmax, 1e-8)
    zero = -lo / scale
    q = jnp.clip(jnp.round(g / scale + zero), 0, qmax)
    q = q.reshape(k, n).astype(jnp.uint8)
    return QuantizedTensor(
        planes=pack_bits(q, bits),
        scale=scale.reshape(-1, n),
        zero=zero.reshape(-1, n),
        bits=bits, group_size=group_size, shape=(k, n))


def quantize_codes(w: jax.Array, scale: jax.Array, zero: jax.Array,
                   bits: int, group_size: int) -> jax.Array:
    """Unpacked uint8 codes in [0, 2^bits) for externally-given scale/zero."""
    k, n = w.shape
    qmax = (1 << bits) - 1
    g = w.astype(jnp.float32).reshape(k // group_size, group_size, n)
    q = jnp.clip(jnp.round(g / scale[:, None, :] + zero[:, None, :]), 0, qmax)
    return q.reshape(k, n).astype(jnp.uint8)


def quantize_with_params(w: jax.Array, scale: jax.Array, zero: jax.Array,
                         bits: int, group_size: int,
                         store_bits: Optional[int] = None) -> QuantizedTensor:
    """Quantize with externally-optimized (HQQ) scale/zero.

    ``store_bits`` >= bits packs the codes into a wider bit-plane
    container (heterogeneous per-expert precision shares one stacked
    layout; the true width stays the accounting width — same idiom as
    sub-byte compensator factors in an int8 container).  Dequantization
    is bit-exact either way: codes fit in the container and the math
    only reads scale/zero.
    """
    k, n = w.shape
    q = quantize_codes(w, scale, zero, bits, group_size)
    sb = bits if store_bits is None else store_bits
    assert sb >= bits, (sb, bits)
    return QuantizedTensor(pack_bits(q, sb), scale, zero, sb, group_size,
                           (k, n))


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    k, n = qt.shape
    q = unpack_bits(qt.planes, qt.bits).astype(jnp.float32)
    g = q.reshape(k // qt.group_size, qt.group_size, n)
    w = (g - qt.zero[:, None, :]) * qt.scale[:, None, :]
    return w.reshape(k, n).astype(dtype)


def quant_error(w: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Relative Frobenius residual ||W - Q^-1(Q(W))||_F / ||W||_F."""
    e = w.astype(jnp.float32) - dequantize(qt)
    return jnp.linalg.norm(e) / jnp.maximum(jnp.linalg.norm(w.astype(jnp.float32)), 1e-12)
