"""Continuous-batching request scheduler.

The engine keeps ONE fixed-shape decode state — a slot-indexed KV cache
of ``num_slots`` batch rows and a compiled ``lax.scan`` decode chunk —
and this module owns everything request-shaped around it: the FIFO
admission queue, slot assignment, per-request EOS / max-token
termination, and refilling completed slots from the queue between scan
chunks.  Compiled shapes never change while requests come and go.

Request lifecycle::

    submit() ──► queue ──admit()──► slot (prefill + cache claim by engine)
                                     │  record_chunk() appends tokens,
                                     │  detects EOS / length termination
                                     ▼
                                  finished (RequestResult), slot freed
                                     │
                                     └──► next admit() refills the slot

``record_chunk`` also returns the per-step slot-activity mask so the
engine can mask retired/empty slots out of the router trace (expert id
-1) before offload metering — inactive slots keep decoding garbage to
preserve shapes, but none of it reaches results or the wire-byte meter.

The chunk boundary is also where the engine applies runtime control:
after ``record_chunk`` the masked trace is metered into the expert
stores and the bandwidth controller (``serve/controller.py``) digests
the chunk's wire bytes into the next chunk's per-layer ``(top_n,
rank_cap)`` restoration plan — slots and compiled shapes never change,
only the plan *data* fed to the next scan chunk.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request."""
    uid: int
    tokens: np.ndarray                 # (plen,) int32 prompt ids
    max_new: int = 32
    eos_id: Optional[int] = None       # None = never terminate on a token
    arrival_s: float = 0.0             # offered-load arrival (relative s)

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])


@dataclasses.dataclass
class RequestResult:
    """Completed request: generated stream + per-request telemetry."""
    uid: int
    prompt_len: int
    tokens: np.ndarray                 # (gen,) generated ids (incl. EOS)
    logprobs: np.ndarray               # (gen,)
    trace: Optional[np.ndarray]        # (gen, moe_layers, k) or None
    finish_reason: str                 # 'eos' | 'length'
    arrival_s: float
    admitted_s: float
    first_token_s: float
    finished_s: float
    offload_bytes: int = 0             # demand+compensator bytes attributed

    @property
    def gen_tokens(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def latency_s(self) -> float:
        return self.finished_s - self.arrival_s

    @property
    def ttft_s(self) -> float:
        """Time to first token from arrival; NaN for requests that
        retired before emitting any token (max_new <= 0)."""
        return self.first_token_s - self.arrival_s


@dataclasses.dataclass
class _Active:
    """In-flight request pinned to a slot."""
    req: Request
    slot: int
    admitted_s: float
    tokens: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[float] = dataclasses.field(default_factory=list)
    trace: List[np.ndarray] = dataclasses.field(default_factory=list)
    first_token_s: float = -1.0
    offload_bytes: int = 0


class Scheduler:
    """FIFO admission onto a fixed pool of decode slots."""

    def __init__(self, num_slots: int):
        assert num_slots >= 1
        self.num_slots = num_slots
        self.queue: Deque[Request] = collections.deque()
        self.slots: List[Optional[_Active]] = [None] * num_slots
        self.finished: List[RequestResult] = []
        self._finished_by_uid: Dict[int, RequestResult] = {}

    # -- queue ------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    @property
    def num_active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def has_work(self) -> bool:
        return bool(self.queue) or self.num_active > 0

    def next_arrival(self) -> Optional[float]:
        return self.queue[0].arrival_s if self.queue else None

    # -- admission --------------------------------------------------------
    def admit(self, now: float = float("inf")
              ) -> List[Tuple[int, Request]]:
        """Fill free slots from the queue head (FIFO; only requests whose
        arrival time has passed).  Returns the (slot, request) pairs so
        the engine can prefill and claim the cache rows."""
        out = []
        for i in range(self.num_slots):
            if self.slots[i] is not None:
                continue
            if not self.queue or self.queue[0].arrival_s > now:
                break
            req = self.queue.popleft()
            self.slots[i] = _Active(req, i, admitted_s=now)
            out.append((i, req))
        return out

    def active_mask(self) -> np.ndarray:
        return np.array([s is not None for s in self.slots], bool)

    def uid_by_slot(self) -> Dict[int, int]:
        return {i: s.req.uid for i, s in enumerate(self.slots)
                if s is not None}

    # -- chunk bookkeeping -------------------------------------------------
    def record_chunk(self, tokens: np.ndarray, logprobs: np.ndarray,
                     trace: Optional[np.ndarray], now: float,
                     t_start: Optional[float] = None,
                     valid_len: Optional[np.ndarray] = None) -> np.ndarray:
        """Consume one decode chunk.

        ``tokens``/``logprobs``: (num_slots, chunk); ``trace``:
        (chunk, moe_layers, num_slots, k) or None.  Appends each active
        slot's tokens until its EOS or max-token budget, retires finished
        requests (freeing the slot for the next ``admit``), and returns
        the (chunk, num_slots) bool mask of *accepted* steps — the mask
        the engine applies to the router trace before metering.

        ``t_start``: wall time when the chunk's decode began.  Per-step
        completion times interpolate linearly between ``t_start`` and
        ``now``, so first-token / finish stamps land on their step rather
        than quantizing to the chunk boundary (which inflated reported
        TTFT by up to ``chunk`` steps).  ``t_start=None`` keeps the old
        chunk-end stamping (every step stamps ``now``).

        ``valid_len``: optional (num_slots,) per-slot cap on how many of
        the chunk's steps are consumable — the speculative decoder's
        verify-accepted lengths.  A rejected draft suffix still occupies
        fixed-shape chunk positions but must never reach results; steps
        at c >= valid_len[slot] are skipped exactly like steps past a
        retirement.  ``None`` = every step is consumable (non-speculative
        chunks).
        """
        chunk = tokens.shape[1]

        def step_t(c: int) -> float:
            if t_start is None:
                return now
            return t_start + (c + 1) * (now - t_start) / chunk

        accepted = np.zeros((chunk, self.num_slots), bool)
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            done = None
            done_t = now
            lim = chunk if valid_len is None else int(valid_len[i])
            for c in range(chunk):
                if c >= lim:                  # rejected speculative suffix
                    break
                if len(st.tokens) >= st.req.max_new:   # max_new <= 0 case
                    done = "length"
                    # no step ran for this request; it was done on entry
                    done_t = t_start if t_start is not None else now
                    break
                tok = int(tokens[i, c])
                st.tokens.append(tok)
                st.logprobs.append(float(logprobs[i, c]))
                if trace is not None:
                    st.trace.append(trace[c, :, i, :])
                accepted[c, i] = True
                if st.first_token_s < 0:
                    st.first_token_s = step_t(c)
                if st.req.eos_id is not None and tok == st.req.eos_id:
                    done = "eos"
                elif len(st.tokens) >= st.req.max_new:
                    done = "length"
                if done:
                    done_t = step_t(c)
                    break
            if done:
                self._retire(i, done, done_t)
        return accepted

    def _retire(self, slot: int, reason: str, now: float):
        st = self.slots[slot]
        # a request retired before emitting any token (max_new <= 0) has
        # no first-token time; NaN is the explicit sentinel (the -1.0
        # placeholder used to leak in and skew latency aggregates)
        first = st.first_token_s if st.first_token_s >= 0 else float("nan")
        res = RequestResult(
            uid=st.req.uid, prompt_len=st.req.prompt_len,
            tokens=np.asarray(st.tokens, np.int32),
            logprobs=np.asarray(st.logprobs, np.float32),
            trace=(np.stack(st.trace) if st.trace else None),
            finish_reason=reason, arrival_s=st.req.arrival_s,
            admitted_s=st.admitted_s, first_token_s=first,
            finished_s=now, offload_bytes=st.offload_bytes)
        self.finished.append(res)
        self._finished_by_uid[res.uid] = res
        self.slots[slot] = None

    def add_slot_bytes(self, slot_bytes: np.ndarray,
                       uid_by_slot: Dict[int, int]):
        """Attribute per-slot metered bytes (replay_decode_trace) to the
        requests that occupied those slots during the chunk — they may
        have retired in record_chunk, so match by uid."""
        still_active = {st.req.uid: st for st in self.slots
                        if st is not None}
        for i, uid in uid_by_slot.items():
            nb = int(slot_bytes[i])
            if uid in still_active:
                still_active[uid].offload_bytes += nb
            elif uid in self._finished_by_uid:
                self._finished_by_uid[uid].offload_bytes += nb


def synthetic_workload(n: int, vocab_size: int, *, rate: float = 0.0,
                       max_new: int = 16, min_len: int = 6,
                       max_len: int = 24, seed: int = 0) -> List[Request]:
    """Ragged synthetic requests for serving benchmarks / CLI smoke runs.

    Prompt lengths are uniform in [min_len, max_len]; arrivals are
    Poisson at ``rate`` requests/s (rate <= 0: closed loop, everything
    at t=0), shifted so the first request arrives at t=0.  One generator
    shared by ``launch/serve.py --requests`` and
    ``benchmarks/bench_serving.py`` so the CLI and the benchmark always
    offer the same workload for the same rate."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(min_len, max_len + 1, n)
    if rate > 0:
        gaps = rng.exponential(1.0 / rate, n)
        arrivals = np.cumsum(gaps) - gaps[0]
    else:
        arrivals = np.zeros(n)
    return [Request(uid=i,
                    tokens=rng.integers(0, vocab_size, (int(l),),
                                        dtype=np.int32),
                    max_new=max_new, arrival_s=float(t))
            for i, (l, t) in enumerate(zip(lens, arrivals))]
