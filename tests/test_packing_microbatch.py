"""Sequence packing + gradient accumulation units."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.packing import (pack_documents, packing_efficiency,
                                segment_attention_bias)
from repro.train.microbatch import microbatched_value_and_grad


def test_packing_roundtrip_and_masks():
    docs = [np.arange(1, 6), np.arange(10, 13), np.arange(20, 29)]
    out = pack_documents(docs, seq_len=8)
    assert out["tokens"].shape[1] == 8
    # every document token present exactly once
    got = out["tokens"][out["segment_ids"] > 0]
    assert sorted(got.tolist()) == sorted(
        np.concatenate(docs).tolist())
    assert 0.7 <= packing_efficiency(out) <= 1.0   # 17 tokens, 24 slots
    # loss mask never crosses a segment boundary
    seg, mask = out["segment_ids"], out["mask"]
    idx = np.argwhere(mask > 0)
    for r, c in idx:
        assert seg[r, c] == seg[r, c + 1] > 0


def test_segment_attention_bias_blocks_cross_doc():
    seg = np.array([[1, 1, 2, 2, 0]])
    bias = segment_attention_bias(seg)
    assert bias[0, 0, 1] == 0.0
    assert bias[0, 0, 2] < -1e29       # cross-document blocked
    assert bias[0, 4, 4] < -1e29       # padding blocked


def test_microbatched_grads_match_full_batch():
    w = {"w": jnp.asarray([2.0, -1.0, 0.5])}
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 3)),
                    jnp.float32)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        loss = jnp.mean(pred ** 2)
        return loss, {"loss": loss}

    full = jax.value_and_grad(loss_fn, has_aux=True)(w, {"x": x})
    micro = microbatched_value_and_grad(loss_fn, 4)(w, {"x": x})
    np.testing.assert_allclose(float(micro[0][0]), float(full[0][0]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(micro[1]["w"]),
                               np.asarray(full[1]["w"]), rtol=1e-5)
