"""Fig 4 analogue: (a) low-rank compensators restore quantization residual;
(b) kurtosis predicts per-expert quantization error.

Reported on BOTH the heavy-tailed *init* weights (clean mechanism — the
paper measures on at-scale pretrained weights we cannot load) and the
*trained* toy weights (honest toy-scale finding: brief Adam training
reshapes the grafted tails, and the correlation can invert — see
EXPERIMENTS.md §Claims notes; this motivates the beyond-paper
error-guided allocation in fig8c).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import QuantConfig
from repro.core import compress_expert_stack

from .common import bench_moe_cfg, heavy_tail_expert_init, trained_moe


def _corr_and_gain(params, qcfg):
    kurts, errs, errs_c = [], [], []
    for seg in params["segments"]:
        for p in seg:
            if "moe" not in p:
                continue
            for proj in ("w1", "w2", "w3"):
                w = p["moe"][proj]
                if w.ndim == 4:
                    w = w[0]
                _, rep = compress_expert_stack(jnp.asarray(w), qcfg)
                kurts += list(rep["kurtosis"])
                errs += list(rep["rel_err_quant"])
                errs_c += list(rep["rel_err_comp"])
    corr = float(np.corrcoef(kurts, errs)[0, 1])
    return corr, float(np.mean(errs)), float(np.mean(errs_c))


def _synthetic_sweep():
    """Controlled mechanism demo: t(df)-distributed 256x256 matrices,
    df 2.05…50 — kurtosis spans ~3…10^3 with tight error estimates."""
    from repro.core import hqq_quantize, kurtosis as kurt_fn, quant_error, \
        quantize
    rng = np.random.default_rng(0)
    dfs = np.geomspace(2.05, 50, 10)
    ws = [jnp.asarray(rng.standard_t(df, (256, 256)).astype(np.float32))
          for df in dfs]
    ks = [float(kurt_fn(w)) for w in ws]
    rows = []
    for label, qfn in (("rtn", lambda w: quantize(w, 2, 64)),
                       ("hqq", lambda w: hqq_quantize(w, 2, 64, iters=20))):
        es = [float(quant_error(w, qfn(w))) for w in ws]
        rows.append({"name": f"fig4b/synthetic_{label}",
                     "corr": float(np.corrcoef(ks, es)[0, 1])})
    return rows


def run(quick: bool = True):
    rows = _synthetic_sweep()
    cfg = bench_moe_cfg()
    init_params_ = heavy_tail_expert_init(cfg, 0)(jax.random.key(0))
    # RTN regime: the paper's Fig-4b mechanism (heavy tails hurt naive
    # quantization) reproduces cleanly
    rtn = QuantConfig(enabled=True, bits=2, rank_budget=32, hqq_iters=0)
    c_rtn, _, _ = _corr_and_gain(init_params_, rtn)
    rows.append({"name": "fig4b/kurtosis_error_corr_rtn", "corr": c_rtn})
    # HQQ regime: the half-quadratic l_p prox is built to absorb
    # element-wise tails, so the correlation collapses — on real LLM
    # weights kurtosis is structured (outlier channels) and survives HQQ,
    # which our toy cannot emulate; this motivates the beyond-paper
    # error-guided allocation (fig8c)
    hqq = QuantConfig(enabled=True, bits=2, rank_budget=32, hqq_iters=20)
    c_hqq, e0i, e1i = _corr_and_gain(init_params_, hqq)
    rows.append({"name": "fig4b/kurtosis_error_corr_hqq", "corr": c_hqq})
    _, tparams = trained_moe(steps=60 if quick else 300)
    c_tr, e0, e1 = _corr_and_gain(tparams, hqq)
    rows.append({"name": "fig4b/kurtosis_error_corr_trained", "corr": c_tr})
    rows.append({"name": "fig4a/mean_residual_reduction",
                 "before": e0, "after": e1, "gain": e0 - e1})
    return rows


if __name__ == "__main__":
    for r in run():
        extra = ",".join(f"{k}={v:.4f}" for k, v in r.items() if k != "name")
        print(f"{r['name']},{extra}")
