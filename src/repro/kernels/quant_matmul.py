"""Pallas TPU kernel: matmul against bit-plane-packed quantized weights.

y = x @ dequant(Wq).  The packed planes are streamed HBM->VMEM at their
native sub-byte width (bits/8 bytes per weight), unpacked in VMEM with
uniform shift/mask lanes, dequantized per quantization group, and fed to
the MXU tile-by-tile.  This is the TPU-native analogue of the paper's
"transfer low-bit experts over PCIe": the HBM term of the decode roofline
drops by ~16/bits on every expert matmul.

An optional fused epilogue adds the router-guided low-rank compensation
``+ xu @ V`` (paper §3.2) on the final K step, so the compensated result
never round-trips through HBM.

Grid: (M/bm, N/bn, K/bk) with a VMEM f32 accumulator; K is the innermost
(sequential) dimension.  Constraints: bk % PACK_BLOCK == 0 (block-local
packing), bk % group_size == 0 (whole quant groups per tile).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import PallasCompilerParams

from ..core.quantize import PACK_BLOCK, PLANES


def _unpack_tile(plane_vals, bits: int, bk: int, bn: int) -> jax.Array:
    """Unpack loaded plane tiles -> (bk, bn) uint8 codes (VMEM, vectorized)."""
    out = None
    for (p, off), pk in zip(PLANES[bits], plane_vals):
        c = 8 // p
        mask = jnp.uint8((1 << p) - 1)
        blocks = pk.reshape(bk // PACK_BLOCK, PACK_BLOCK // c, bn)
        chunks = [(blocks >> (j * p)) & mask for j in range(c)]
        sub = jnp.stack(chunks, axis=1).reshape(bk, bn)
        sub = (sub << off).astype(jnp.uint8)
        out = sub if out is None else out | sub
    return out


def _dequant_tile(codes: jax.Array, scale, zero, group_size: int,
                  bk: int, bn: int) -> jax.Array:
    g = codes.astype(jnp.float32).reshape(bk // group_size, group_size, bn)
    w = (g - zero[:, None, :]) * scale[:, None, :]
    return w.reshape(bk, bn)


def _qmm_kernel(bits, group_size, n_k, bk, bn, fuse_lowrank, x_ref, *refs):
    """refs: [planes..., scale, zero, (xu, v)] + [out] + [acc scratch]."""
    n_planes = len(PLANES[bits])
    planes = refs[:n_planes]
    scale_ref, zero_ref = refs[n_planes], refs[n_planes + 1]
    pos = n_planes + 2
    if fuse_lowrank:
        xu_ref, v_ref = refs[pos], refs[pos + 1]
        pos += 2
    out_ref, acc_ref = refs[pos], refs[pos + 1]

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_tile([p[...] for p in planes], bits, bk, bn)
    w = _dequant_tile(codes, scale_ref[...], zero_ref[...], group_size, bk, bn)
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        acc = acc_ref[...]
        if fuse_lowrank:
            # rank-r compensation epilogue: acc += xu @ V (scales pre-folded)
            vd = v_ref[...].astype(jnp.float32)
            acc = acc + jnp.dot(xu_ref[...], vd,
                                preferred_element_type=jnp.float32)
        out_ref[...] = acc.astype(out_ref.dtype)


def _pallas_qmm(x, planes, scale, zero, xu, v, *, bits, group_size,
                bm, bn, bk, out_dtype, interpret):
    m, k = x.shape
    n = scale.shape[-1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bk % PACK_BLOCK == 0 and bk % group_size == 0
    n_k = k // bk
    fuse = xu is not None

    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))]
    in_specs += [pl.BlockSpec((bk // (8 // p), bn), lambda i, j, kk: (kk, j))
                 for p, _ in PLANES[bits]]
    in_specs += [pl.BlockSpec((bk // group_size, bn),
                              lambda i, j, kk: (kk, j))] * 2
    args = [x, *planes, scale, zero]
    if fuse:
        r = xu.shape[-1]
        in_specs += [pl.BlockSpec((bm, r), lambda i, j, kk: (i, 0)),
                     pl.BlockSpec((r, bn), lambda i, j, kk: (0, j))]
        args += [xu, v]

    kernel = functools.partial(_qmm_kernel, bits, group_size, n_k, bk, bn,
                               fuse)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=PallasCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name=f"quant_matmul_b{bits}" + ("_lowrank" if fuse else ""),
    )(*args)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "bm", "bn", "bk", "out_dtype", "interpret"))
def quant_matmul_pallas(x: jax.Array, planes: Tuple[jax.Array, ...],
                        scale: jax.Array, zero: jax.Array, *,
                        bits: int, group_size: int,
                        bm: int = 128, bn: int = 256, bk: int = 512,
                        out_dtype=jnp.float32, interpret: bool = False
                        ) -> jax.Array:
    """x: (M, K) @ packed (K, N) -> (M, N)."""
    return _pallas_qmm(x, planes, scale, zero, None, None, bits=bits,
                       group_size=group_size, bm=bm, bn=bn, bk=bk,
                       out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "bm", "bn", "bk", "out_dtype", "interpret"))
def lowrank_comp_matmul_pallas(x: jax.Array, planes: Tuple[jax.Array, ...],
                               scale: jax.Array, zero: jax.Array,
                               xu: jax.Array, v: jax.Array, *,
                               bits: int, group_size: int,
                               bm: int = 128, bn: int = 256, bk: int = 512,
                               out_dtype=jnp.float32, interpret: bool = False
                               ) -> jax.Array:
    """Fused y = x @ dequant(Wq) + xu @ V.

    ``xu`` is the (M, R) rank-space activation ``(x * mask) @ (U * u_scale)
    * v_scale`` computed by the ops wrapper (rank-r, negligible FLOPs);
    ``v`` is the (R, N) int8 code matrix with its scale pre-folded into xu.
    """
    return _pallas_qmm(x, planes, scale, zero, xu, v, bits=bits,
                       group_size=group_size, bm=bm, bn=bn, bk=bk,
                       out_dtype=out_dtype, interpret=interpret)


# ---------------------------------------------------------------------------
# fused expert-stack decode kernel
# ---------------------------------------------------------------------------

def _fused_kernel(bits, group_size, n_k, bm, bn, bk, pad_rank, has_gates,
                  x_ref, *refs):
    """One grid step of the fused decode kernel (see fused_expert_matmul).

    Grid (e, i, j, kk): expert e, token tile i, output tile j, K step kk
    (innermost, sequential).  refs layout:
      [planes..., scale, zero, u, u_scale, v, v_scale, me, (ge,)
       rank_cap, expert_bits] + [out] + [acc, xu_acc scratch]
    Everything accumulates in f32 VMEM scratch; only the finished
    (bm, bn) gate-weighted tile is ever written back to HBM.
    """
    n_planes = len(PLANES[bits])
    planes = refs[:n_planes]
    pos = n_planes
    scale_ref, zero_ref = refs[pos], refs[pos + 1]
    u_ref, us_ref, v_ref, vs_ref = refs[pos + 2:pos + 6]
    me_ref = refs[pos + 6]
    pos += 7
    if has_gates:
        ge_ref = refs[pos]
        pos += 1
    cap_ref, eb_ref = refs[pos], refs[pos + 1]
    out_ref, acc_ref, xu_ref = refs[pos + 2], refs[pos + 3], refs[pos + 4]

    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xu_ref[...] = jnp.zeros_like(xu_ref)

    # -- dequant at this expert's TRUE width: planes whose bit offset lies
    # at or above expert_bits[e] carry no information (hetero stacks store
    # sub-width codes in a shared container) and are masked out of the
    # unpack, so the true width is first-class in the kernel rather than
    # silently widened to the container.
    eb = eb_ref[0, 0]
    codes = None
    for (p, off), pk in zip(PLANES[bits], [r[...] for r in planes]):
        c = 8 // p
        mask = jnp.uint8((1 << p) - 1)
        blocks = pk.reshape(1, bk // PACK_BLOCK, PACK_BLOCK // c, bn)
        chunks = [(blocks >> (j * p)) & mask for j in range(c)]
        sub = jnp.stack(chunks, axis=2).reshape(bk, bn)
        sub = jnp.where(eb > off, (sub << off).astype(jnp.uint8),
                        jnp.uint8(0))
        codes = sub if codes is None else codes | sub
    w = _dequant_tile(codes, scale_ref[0], zero_ref[0], group_size, bk, bn)

    x = x_ref[0].astype(jnp.float32)                       # (bm, bk)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    # -- rank-space compensator activation: (x * me) @ (U * u_scale),
    # accumulated over K alongside the main matmul (j-invariant; cheap
    # rank-R duplicate work per j tile beats an HBM round-trip for xu)
    xm = x * me_ref[0][:, None].astype(jnp.float32)
    ud = u_ref[0].astype(jnp.float32) * us_ref[0, 0]       # (bk, R)
    xu_ref[...] += jnp.dot(xm, ud, preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _done():
        acc = acc_ref[...]
        # traced rank cap: 0/1 mask over the padded rank dim (a plan-row
        # change is data, never a recompile)
        rmask = (jax.lax.broadcasted_iota(jnp.int32, (1, pad_rank), 1)
                 < cap_ref[0, 0]).astype(jnp.float32)
        xu = xu_ref[...] * rmask * vs_ref[0, :, 0][None, :]
        vd = v_ref[0].astype(jnp.float32)                  # (R, bn)
        acc = acc + jnp.dot(xu, vd, preferred_element_type=jnp.float32)
        if has_gates:
            # top-n combine epilogue: fold the router gate in-kernel so
            # the (E, C, N) buffer leaves as ready-to-scatter partials
            acc = acc * ge_ref[0][:, None].astype(jnp.float32)
        out_ref[0] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "bm", "bn", "bk", "out_dtype", "interpret"))
def fused_expert_matmul_pallas(xe: jax.Array, planes: Tuple[jax.Array, ...],
                               scale: jax.Array, zero: jax.Array,
                               u: jax.Array, u_scale: jax.Array,
                               v: jax.Array, v_scale: jax.Array,
                               me: jax.Array, ge: Optional[jax.Array],
                               rank_cap: jax.Array, expert_bits: jax.Array,
                               *, bits: int, group_size: int,
                               bm: int = 8, bn: int = 256, bk: int = 512,
                               out_dtype=jnp.float32, interpret: bool = False
                               ) -> jax.Array:
    """Fused decode-path expert FFN projection over a routed token block.

    One kernel invocation covers every expert of one (layer, projection):

        ye[e] = (xe[e] @ dequant_e(planes_e)            # true-width HQQ
                 + ((xe[e] * me[e]) @ U_e) @ V_e)       # rank-capped comp
                * ge[e]                                 # gate-weighted

    xe: (E, C, K) dispatched tokens;  planes[i]: (E, K//c_i, N)
    scale/zero: (E, K//G, N);  u: (E, K, R);  v: (E, R, N)
    u_scale: (E, 1, R);  v_scale: (E, R, 1)
    me: (E, C) top-n compensation mask;  ge: (E, C) router gates (None =
    unweighted);  rank_cap: (1, 1) i32 traced plan value;
    expert_bits: (E, 1) i32 TRUE per-expert widths.

    The f32 accumulator and the (bm, R) rank-space activation live in
    VMEM scratch for the whole K walk — no intermediate (dequantized
    weight, compensator product, or pre-gate output) ever round-trips
    to HBM.
    """
    e, m, k = xe.shape
    n = scale.shape[-1]
    r = u.shape[-1]
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (e, m, n, k, bm, bn, bk)
    assert bk % PACK_BLOCK == 0 and bk % group_size == 0
    n_k = k // bk
    has_gates = ge is not None

    in_specs = [pl.BlockSpec((1, bm, bk), lambda e, i, j, kk: (e, i, kk))]
    in_specs += [pl.BlockSpec((1, bk // (8 // p), bn),
                              lambda e, i, j, kk: (e, kk, j))
                 for p, _ in PLANES[bits]]
    in_specs += [pl.BlockSpec((1, bk // group_size, bn),
                              lambda e, i, j, kk: (e, kk, j))] * 2
    in_specs += [pl.BlockSpec((1, bk, r), lambda e, i, j, kk: (e, kk, 0)),
                 pl.BlockSpec((1, 1, r), lambda e, i, j, kk: (e, 0, 0)),
                 pl.BlockSpec((1, r, bn), lambda e, i, j, kk: (e, 0, j)),
                 pl.BlockSpec((1, r, 1), lambda e, i, j, kk: (e, 0, 0)),
                 pl.BlockSpec((1, bm), lambda e, i, j, kk: (e, i))]
    args = [xe, *planes, scale, zero, u, u_scale, v, v_scale, me]
    if has_gates:
        in_specs += [pl.BlockSpec((1, bm), lambda e, i, j, kk: (e, i))]
        args += [ge]
    in_specs += [pl.BlockSpec((1, 1), lambda e, i, j, kk: (0, 0)),
                 pl.BlockSpec((1, 1), lambda e, i, j, kk: (e, 0))]
    args += [rank_cap, expert_bits]

    kernel = functools.partial(_fused_kernel, bits, group_size, n_k,
                               bm, bn, bk, r, has_gates)
    return pl.pallas_call(
        kernel,
        grid=(e, m // bm, n // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, kk: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, r), jnp.float32)],
        compiler_params=PallasCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name=f"fused_expert_b{bits}" + ("_gated" if has_gates else ""),
    )(*args)
