"""Fig 6 analogue: quality under compression on a *trained* MoE.

The paper reports zero-shot accuracy (MMLU etc.); offline we measure
held-out NLL on the synthetic LM.  Because NLL sits just above the data's
irreducible entropy, the headline metric is the paper's actual claim
shape: quantization DEGRADATION (ΔNLL vs fp32) and the fraction of it the
router-guided compensation RECOVERS.

Ladder (mirrors Fig 6's method axis):
  rtn-pc-int2    per-channel round-to-nearest — the GPTQ-int2 collapse
                 regime (paper: 70.03% -> 34.41% on Mixtral-8x7B)
  hqq-int2       group-64 HQQ — survives degraded (paper's base quant)
  ours-int2      HQQ + kurtosis-ranked compensators, router top-1
  ours-pc-int2   compensators on TOP of the per-channel collapse — shows
                 restoration works even at the collapse point
  (ladder repeated at int3)

``run_alloc`` sweeps the *allocation frontier* instead (calib/): at
equal total wire bytes, uniform-bit compression vs the calibrated
heterogeneous allocation (measured routing/gate/moment statistics
driving per-expert bits + ranks and activation-whitened compensators).
Headline metric: routing-weighted restoration error at matched bytes —
the budgeted calibrated allocation must sit strictly below uniform.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.config import QuantConfig

from .common import compress_model, eval_nll, trained_moe

EVAL_BATCHES = 8


def run(quick: bool = True):
    cfg, params = trained_moe(steps=60 if quick else 300)
    rows = []
    ref = eval_nll(cfg, params, quantized=False, batches=EVAL_BATCHES)
    rows.append({"name": "fig6/fp32", "nll": ref, "delta": 0.0})

    def q(name, qcfg, baseline_delta=None):
        cfg2, qp, _ = compress_model(cfg, params, qcfg)
        nll = eval_nll(cfg2, qp, quantized=True, batches=EVAL_BATCHES)
        row = {"name": f"fig6/{name}", "nll": nll, "delta": nll - ref}
        if baseline_delta is not None and baseline_delta > 0:
            row["recovered_pct"] = 100 * (1 - (nll - ref) / baseline_delta)
        rows.append(row)
        return nll - ref

    for bits in (2, 3):
        d_pc = q(f"rtn-pc-int{bits}",
                 QuantConfig(enabled=True, bits=bits, group_size=0,
                             rank_budget=0, top_n_restore=0, hqq_iters=0,
                             kurtosis_guided=False, uniform_rank=0))
        d_hqq = q(f"hqq-int{bits}",
                  QuantConfig(enabled=True, bits=bits, group_size=64,
                              rank_budget=0, top_n_restore=0, hqq_iters=20,
                              kurtosis_guided=False, uniform_rank=0))
        q(f"ours-int{bits}",
          QuantConfig(enabled=True, bits=bits, group_size=64,
                      rank_budget=32, top_n_restore=1, hqq_iters=20),
          baseline_delta=d_hqq)
        q(f"ours-pc-int{bits}",
          QuantConfig(enabled=True, bits=bits, group_size=0,
                      rank_budget=32, top_n_restore=1, hqq_iters=20),
          baseline_delta=d_pc)
    return rows


# ---------------------------------------------------------------------------
# calibrated-vs-uniform allocation frontier (equal wire bytes)
# ---------------------------------------------------------------------------

def allocation_rows(cfg, params, *, bits_points=(2, 3), rank: int = 8,
                    calib_batches: int = 2, nll_batches: int = 0,
                    scorer: str = "calibrated"):
    """Frontier rows for one model: for each uniform operating point
    (every expert at ``bits`` + rank-``rank`` compensators) take its
    total wire bytes as the budget and let the calibrated allocator
    spend the same bytes heterogeneously.  Reports both allocations'
    routing-weighted restoration error (and held-out NLL when
    ``nll_batches`` > 0).  Shared by ``run_alloc`` and the
    tier-1 acceptance test in ``tests/test_calib.py``."""
    from repro.calib import (allocate_budget, collect_calibration_stats,
                             moe_weights_by_layer, stacks_wire_bytes,
                             uniform_plan, weighted_restoration_error)
    from repro.models.transformer import compress_moe_params

    qcfg = cfg.moe.quant
    stats = collect_calibration_stats(cfg, params, batches=calib_batches)
    weights = moe_weights_by_layer(params, cfg)
    imps = [s.importance() for s in stats]
    rows = []
    for bits in bits_points:
        uni = uniform_plan(weights, qcfg, bits=bits, rank=rank)
        budget = uni.spent_bytes
        cal = allocate_budget(weights, qcfg, budget, stats=stats,
                              scorer=scorer)
        _, _, stacks_u = compress_moe_params(params, cfg, plan=uni)
        _, cfg_c, stacks_c = compress_moe_params(params, cfg, plan=cal,
                                                 stats=stats)
        row = {
            "name": f"alloc/int{bits}-r{rank}",
            "budget_kb": budget / 2 ** 10,
            "uniform_kb": stacks_wire_bytes(stacks_u) / 2 ** 10,
            "calib_kb": stacks_wire_bytes(stacks_c) / 2 ** 10,
            "uniform_err": weighted_restoration_error(stacks_u, weights,
                                                      imps),
            "calib_err": weighted_restoration_error(stacks_c, weights,
                                                    imps),
            "calib_mean_bits": cal.summary()["mean_bits"],
            "calib_mean_rank": cal.summary()["mean_rank"],
        }
        row["err_reduction_pct"] = 100 * (1 - row["calib_err"]
                                          / max(row["uniform_err"], 1e-12))
        if nll_batches > 0:
            from repro.models.transformer import apply_compressed_stacks
            qp_u, cfg_u = apply_compressed_stacks(params, cfg, stacks_u)
            qp_c, cfg_cq = apply_compressed_stacks(params, cfg, stacks_c)
            row["uniform_nll"] = eval_nll(cfg_u, qp_u, quantized=True,
                                          batches=nll_batches)
            row["calib_nll"] = eval_nll(cfg_cq, qp_c, quantized=True,
                                        batches=nll_batches)
        rows.append(row)
    return rows


def run_alloc(quick: bool = True):
    """Fig-6 companion: the bandwidth–accuracy frontier of *allocation*
    (uniform vs calibrated) at matched bytes on a trained MoE."""
    cfg, params = trained_moe(steps=60 if quick else 300)
    return allocation_rows(cfg, params, bits_points=(2, 3),
                           rank=8 if quick else 32,
                           calib_batches=2 if quick else 8,
                           nll_batches=2 if quick else EVAL_BATCHES)


if __name__ == "__main__":
    for r in run():
        extra = ",".join(f"{k}={v:+.4f}" if isinstance(v, float) else str(v)
                         for k, v in r.items() if k != "name")
        print(f"{r['name']},{extra}")
