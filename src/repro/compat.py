"""jax version shims, collected in one leaf module (imports jax only).

The repo targets current jax but must run on 0.4.x; every API whose
name/location moved between those lives here so version fixes happen in
exactly one place.
"""
from __future__ import annotations

import inspect

import jax
from jax.experimental.pallas import tpu as _pltpu

try:  # jax >= 0.6 exports shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:  # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

# the replication-check kwarg was renamed check_rep -> check_vma
_SM_CHECK_KW = ("check_vma" if "check_vma"
                in inspect.signature(_shard_map).parameters else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-tolerant ``shard_map`` wrapper (check_vma/check_rep rename)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_SM_CHECK_KW: check_vma})


def axis_size(axis: str) -> int:
    """Static size of a named mesh axis (jax.lax.axis_size is newer jax;
    jax.core.axis_frame returns the int size on 0.4.x)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    fr = jax.core.axis_frame(axis)
    return fr if isinstance(fr, int) else fr.size


# Pallas TPU compiler params were renamed TPUCompilerParams -> CompilerParams
if hasattr(_pltpu, "CompilerParams"):
    PallasCompilerParams = _pltpu.CompilerParams
elif hasattr(_pltpu, "TPUCompilerParams"):
    PallasCompilerParams = _pltpu.TPUCompilerParams
else:  # pragma: no cover - fail eagerly with a clear message
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; unsupported jax version")
