"""recurrentgemma-9b [hybrid]: 38L d=4096 16H (MQA kv=1) ff=12288
vocab=256000.  Griffin 2-recurrent:1-local-attention pattern, window 2048.
[arXiv:2402.19427]"""
from ..config import ModelConfig, QuantConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        head_dim=256, d_ff=12288, vocab_size=256_000,
        block_pattern=("recurrent", "recurrent", "local"),
        window_size=2048, lru_width=4096, conv1d_width=4,
        rope_theta=10_000.0, act="gelu_tanh", tie_embeddings=True,
        scale_embed=True,
        quant=QuantConfig(enabled=True, bits=2, rank_budget=32,
                          top_n_restore=1),
        max_position=1_048_576,
    )
