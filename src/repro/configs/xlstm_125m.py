"""xlstm-125m [ssm]: 12L d=768 4H vocab=50304, alternating mLSTM/sLSTM
blocks (self-contained; d_ff=0).  [arXiv:2405.04517]

Paper technique inapplicable (no MoE / standard FFN experts) — runs
unquantized; see DESIGN.md §5."""
from ..config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
        head_dim=192, d_ff=0, vocab_size=50_304,
        block_pattern=("mlstm", "slstm"),
        rope_kind="none", act="gelu", tie_embeddings=True,
        max_position=1_048_576,
    )
